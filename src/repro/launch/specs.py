"""ShapeDtypeStruct stand-ins for every model input — shardable, weak-type
correct, zero device allocation. Used by the dry-run and the launchers."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig


def input_specs(cfg: ArchConfig, shape: ShapeConfig, *, microbatches: int = 1) -> dict:
    """Batch pytree for one step of the given kind (train/prefill/decode).

    For training with microbatches > 1 the leaves get a leading
    (microbatches, B/microbatches, ...) layout — see train/step.py.
    """
    B, S = shape.global_batch, shape.seq_len
    dt = jnp.dtype(cfg.dtype)
    if shape.kind in ("train", "prefill"):
        def lead(*dims, dtype):
            if microbatches > 1:
                assert B % microbatches == 0, (B, microbatches)
                return jax.ShapeDtypeStruct(
                    (microbatches, B // microbatches, *dims), dtype)
            return jax.ShapeDtypeStruct((B, *dims), dtype)

        if cfg.input_mode == "tokens":
            return {"tokens": lead(S, dtype=jnp.int32)}
        if cfg.input_mode == "embeddings":
            return {
                "embeds": lead(S, cfg.d_model, dtype=dt),
                "labels": lead(S, dtype=jnp.int32),
            }
        if cfg.input_mode == "vlm":
            P = cfg.num_prefix_embeds
            return {
                "tokens": lead(S - P, dtype=jnp.int32),
                "prefix_embeds": lead(P, cfg.d_model, dtype=dt),
            }
        raise ValueError(cfg.input_mode)
    # decode: one new token against a seq_len-deep cache
    if cfg.input_mode == "embeddings":
        return {"embeds": jax.ShapeDtypeStruct((B, 1, cfg.d_model), dt)}
    return {"tokens": jax.ShapeDtypeStruct((B, 1), jnp.int32)}


def params_shape(cfg: ArchConfig):
    from repro.models import transformer as T

    return jax.eval_shape(lambda k: T.init_params(k, cfg), jax.random.PRNGKey(0))


def decode_state_shape(cfg: ArchConfig, batch: int, context_len: int):
    from repro.models import transformer as T

    return jax.eval_shape(
        lambda: T.init_decode_state(cfg, batch, context_len)
    )
