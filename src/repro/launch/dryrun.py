import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST precede any jax import (jax locks the device count on first init).
#   Only the dry-run sees 512 placeholder devices; tests/benches see 1.

"""Multi-pod dry-run: prove every (arch × shape × mesh) lowers and compiles.

For each pair this lowers the right step function (train_step / prefill_step /
serve_step) with production shardings, compiles it AOT, prints
``memory_analysis()`` (proof it fits 16GiB/chip) and ``cost_analysis()``
(FLOPs/bytes for EXPERIMENTS.md §Roofline), and derives the three roofline
terms including collective wire bytes parsed from the optimized HLO.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] --out out.json
"""
import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED_ARCHS, INPUT_SHAPES
from repro.launch import mesh as M
from repro.launch.presets import (
    TRAIN_MICROBATCHES, TRAIN_REMAT_GROUP, config_for,
)
from repro.launch.specs import decode_state_shape, input_specs, params_shape
from repro.models import sharding as SH
from repro.models import transformer as T
from repro.optim.adamw import AdamWState, adamw_init
from repro.roofline.analysis import roofline_terms
from repro.train import make_train_step

from jax.sharding import PartitionSpec as P


def _logits_spec(cfg, mshape, batch, trailing=1):
    db = SH.batch_axes(mshape)
    bax = db if batch % SH._axis_size(mshape, db) == 0 and batch > 1 else None
    vax = "model" if cfg.vocab_size % mshape.get("model", 1) == 0 else None
    mid = [None] * (trailing - 1)
    return P(bax, *mid, vax)


def build_lowered(arch: str, shape_name: str, *, multi_pod: bool = False,
                  strategy=None, microbatches=None, donate: bool = True,
                  flags=None, cfg_overrides=None):
    """Returns (lowered, meta) for one (arch, shape, mesh) combination.

    flags: runtime_flags.FLAGS overrides applied for this lowering (§Perf).
    cfg_overrides: dataclasses.replace overrides on the ArchConfig.
    """
    from repro.models.runtime_flags import FLAGS

    if flags:
        FLAGS.update(flags)
    cfg = config_for(arch, shape_name)
    if cfg_overrides:
        import dataclasses

        cfg = dataclasses.replace(cfg, **cfg_overrides)
    shape = INPUT_SHAPES[shape_name]
    mesh = M.make_production_mesh(multi_pod=multi_pod)
    mshape = M.mesh_shape_dict(mesh)
    pshape = params_shape(cfg)
    pspecs = SH.param_specs(pshape, cfg, mshape, strategy)
    bshape = input_specs(cfg, shape)
    bspecs = SH.batch_specs(bshape, mshape)
    named = lambda s: SH.to_named(s, mesh)

    if shape.kind == "train":
        nmb = microbatches or TRAIN_MICROBATCHES.get(arch, 1)
        # per-microbatch batch must still shard over all data axes
        dsize = 1
        for a in ("pod", "data"):
            dsize *= mshape.get(a, 1)
        while nmb > 1 and (shape.global_batch // nmb) % dsize != 0:
            nmb //= 2
        step = make_train_step(
            cfg, num_microbatches=nmb,
            remat_group=TRAIN_REMAT_GROUP.get(arch, 1))
        bshape = input_specs(cfg, shape, microbatches=nmb)
        bspecs = SH.batch_specs(bshape, mshape, microbatched=nmb > 1)
        oshape = jax.eval_shape(adamw_init, pshape)
        ospecs = AdamWState(step=P(), m=pspecs, v=pspecs)
        jitted = jax.jit(
            step,
            in_shardings=(named(pspecs), named(ospecs), named(bspecs)),
            out_shardings=(named(pspecs), named(ospecs), None),
            donate_argnums=(0, 1) if donate else (),
        )
        with mesh:
            lowered = jitted.lower(pshape, oshape, bshape)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 6.0 * cfg.active_param_count() * tokens

    elif shape.kind == "prefill":
        def prefill_step(params, batch):
            logits, _aux, (cache, _mask) = T.forward(
                params, batch, cfg, collect_cache=True
            )
            return logits[:, -1], cache

        cshape = jax.eval_shape(prefill_step, pshape, bshape)[1]
        cspecs = SH.prefill_cache_specs(cshape, cfg, mshape)
        out_specs = (_logits_spec(cfg, mshape, shape.global_batch), cspecs)
        jitted = jax.jit(
            prefill_step,
            in_shardings=(named(pspecs), named(bspecs)),
            out_shardings=(named(out_specs[0]), named(out_specs[1])),
        )
        with mesh:
            lowered = jitted.lower(pshape, bshape)
        tokens = shape.global_batch * shape.seq_len
        model_flops = 2.0 * cfg.active_param_count() * tokens

    else:  # decode
        sshape = decode_state_shape(cfg, shape.global_batch, shape.seq_len)
        sspecs = SH.decode_state_specs(sshape, cfg, mshape)

        def serve_step(params, state, batch, pos):
            return T.decode_step(params, state, batch, pos, cfg)

        out_specs = (
            _logits_spec(cfg, mshape, shape.global_batch, trailing=2),
            sspecs,
        )
        jitted = jax.jit(
            serve_step,
            in_shardings=(named(pspecs), named(sspecs), named(bspecs), None),
            out_shardings=(named(out_specs[0]), named(out_specs[1])),
            donate_argnums=(1,) if donate else (),
        )
        pos = jax.ShapeDtypeStruct((), jnp.int32)
        with mesh:
            lowered = jitted.lower(pshape, sshape, bshape, pos)
        model_flops = 2.0 * cfg.active_param_count() * shape.global_batch

    meta = dict(
        cfg=cfg, mesh=mesh, mesh_name="2x16x16" if multi_pod else "16x16",
        chips=mesh.devices.size, model_flops=model_flops,
    )
    return lowered, meta


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            verbose: bool = True, strategy=None, microbatches=None,
            flags=None, cfg_overrides=None):
    t0 = time.time()
    lowered, meta = build_lowered(
        arch, shape_name, multi_pod=multi_pod, strategy=strategy,
        microbatches=microbatches, flags=flags, cfg_overrides=cfg_overrides,
    )
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    peak = (mem.argument_size_in_bytes + mem.output_size_in_bytes
            - mem.alias_size_in_bytes + mem.temp_size_in_bytes)
    from repro.roofline.hlo_cost import f32_carry_artifact_bytes

    artifact = f32_carry_artifact_bytes(hlo)
    peak_tpu = peak - artifact
    report = roofline_terms(
        arch=arch, shape=shape_name, mesh_name=meta["mesh_name"],
        chips=meta["chips"], hlo_text=hlo,
        model_flops=meta["model_flops"],
        peak_flops=M.PEAK_FLOPS_BF16, hbm_bw=M.HBM_BW, link_bw=M.ICI_BW,
        peak_memory_bytes=float(peak),
    )
    out = report.to_dict()
    out.update(
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        arg_bytes=mem.argument_size_in_bytes,
        temp_bytes=mem.temp_size_in_bytes,
        out_bytes=mem.output_size_in_bytes,
        alias_bytes=mem.alias_size_in_bytes,
        cpu_f32_artifact_bytes=float(artifact),
        peak_tpu_bytes=float(peak_tpu),
        fits_hbm=bool(peak_tpu <= M.HBM_PER_CHIP),
        fits_hbm_raw_cpu=bool(peak <= M.HBM_PER_CHIP),
    )
    if verbose:
        print(f"== {arch} × {shape_name} × {meta['mesh_name']} "
              f"({meta['chips']} chips) ==")
        print(f"  memory_analysis: {mem}")
        print(f"  peak bytes/device: {peak/2**30:.2f} GiB raw-CPU; "
              f"{peak_tpu/2**30:.2f} GiB TPU-projected "
              f"(f32-carry artifact {artifact/2**30:.2f} GiB) "
              f"({'FITS' if out['fits_hbm'] else 'EXCEEDS'} 16 GiB)")
        print(f"  flops/device={report.flops_per_device:.3e} "
              f"hbm_bytes={report.hbm_bytes_per_device:.3e} "
              f"wire_bytes={report.wire_bytes_per_device:.3e}")
        print(f"  roofline: compute={report.compute_s*1e3:.2f}ms "
              f"memory={report.memory_s*1e3:.2f}ms "
              f"collective={report.collective_s*1e3:.2f}ms "
              f"-> bottleneck={report.bottleneck}")
        print(f"  useful_flops_ratio={report.useful_flops_ratio:.3f} "
              f"lower={t_lower:.1f}s compile={t_compile:.1f}s")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(INPUT_SHAPES) + ["all"], default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    archs = ASSIGNED_ARCHS if args.arch == "all" else [args.arch]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    results, failures = [], []
    for mp in meshes:
        for arch in archs:
            for shape in shapes:
                try:
                    results.append(run_one(arch, shape, multi_pod=mp))
                except Exception as e:  # a failure here is a bug in the system
                    traceback.print_exc()
                    failures.append(dict(
                        arch=arch, shape=shape,
                        mesh="2x16x16" if mp else "16x16", error=str(e)[:500],
                    ))
    if args.out:
        with open(args.out, "w") as f:
            json.dump({"results": results, "failures": failures}, f, indent=1)
    print(f"\n{len(results)} ok, {len(failures)} failed")
    if failures:
        for f_ in failures:
            print("FAIL:", f_["arch"], f_["shape"], f_["mesh"], f_["error"][:200])
        raise SystemExit(1)


if __name__ == "__main__":
    main()
