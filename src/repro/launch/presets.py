"""Per-(arch × shape) execution presets used by the launcher and dry-run.

``TRAIN_MICROBATCHES`` was sized so each arch's train_4k live activations fit
16 GiB/chip on the single-pod mesh (napkin math in EXPERIMENTS.md §Dry-run):
with scan+remat the dominant saved tensor is the per-layer residual stream,
L × (B/data/micro) × S × d × 2 bytes.
"""
from __future__ import annotations

TRAIN_MICROBATCHES = {
    # archs whose head counts don't divide the 16-way model axis (smollm 15H,
    # granite 24H/8KV, musicgen 24H) keep attention replicated over `model`,
    # so their microbatches are sized for per-device B_local=1 at 4k.
    "smollm-360m": 16,
    "granite-moe-3b-a800m": 16,
    "qwen3-moe-30b-a3b": 8,
    "mamba2-2.7b": 8,
    "zamba2-2.7b": 8,
    "musicgen-medium": 16,
    "mistral-nemo-12b": 16,
    "gemma2-27b": 16,
    "internvl2-76b": 32,
    "qwen3-32b": 16,
}

# hierarchical remat: checkpoint groups of N layers (saved residual stack is
# L/N deep; one extra inner forward in backward). Only where activation
# memory is the binding constraint.
TRAIN_REMAT_GROUP = {
    "internvl2-76b": 4,
}

# archs whose long_500k run uses the sliding-window variant (DESIGN.md §4)
NEEDS_SW_FOR_LONG = {
    "smollm-360m",
    "granite-moe-3b-a800m",
    "qwen3-moe-30b-a3b",
    "musicgen-medium",
    "mistral-nemo-12b",
    "internvl2-76b",
    "qwen3-32b",
    # zamba2's shared block attends globally (cache seq-sharded); mamba2 and
    # gemma2 are natively sub-quadratic / windowed.
}


def config_for(arch: str, shape_name: str):
    from repro.configs import get_config

    cfg = get_config(arch)
    if shape_name == "long_500k" and arch in NEEDS_SW_FOR_LONG:
        cfg = cfg.with_sliding_window(4096)
    return cfg
