"""Training launcher.

On real hardware this runs under the production mesh; on this container it
runs any --arch at reduced or full scale on the host mesh. Checkpoints via
repro.checkpoint every --ckpt-every steps.

  PYTHONPATH=src python -m repro.launch.train --arch smollm-360m --reduced \
      --steps 50 --batch 8 --seq 128
"""
from __future__ import annotations

import argparse
import time
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.checkpoint import save_pytree
from repro.configs import ASSIGNED_ARCHS, get_config
from repro.data import SyntheticPipeline
from repro.models import transformer as T
from repro.optim import adamw_init
from repro.train import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS, default="smollm-360m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--d-model", type=int, default=None,
                    help="override reduced d_model (e.g. ~100M scale)")
    ap.add_argument("--layers", type=int, default=None)
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        over = {}
        if args.d_model:
            over.update(d_model=args.d_model,
                        d_ff=args.d_model * 4 if cfg.d_ff else 0,
                        num_heads=max(1, args.d_model // 64) if cfg.num_heads else 0,
                        num_kv_heads=max(1, args.d_model // 128) if cfg.num_kv_heads else 0)
        if args.layers:
            over["num_layers"] = args.layers
        cfg = cfg.reduced(**over)
    print(f"arch={cfg.name} params≈{cfg.param_count()/1e6:.1f}M "
          f"active≈{cfg.active_param_count()/1e6:.1f}M")

    key = jax.random.PRNGKey(args.seed)
    params = T.init_params(key, cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(
        cfg, lr=args.lr, warmup=min(100, args.steps // 10 + 1),
        total_steps=args.steps, num_microbatches=args.microbatches,
        remat=True))
    pipe = SyntheticPipeline(cfg, args.batch, args.seq,
                             microbatches=args.microbatches, seed=args.seed)
    t0 = time.time()
    tokens_per_step = args.batch * args.seq
    for i in range(args.steps):
        params, opt, m = step(params, opt, pipe.batch_at(i))
        if i % max(1, args.steps // 20) == 0 or i == args.steps - 1:
            jax.block_until_ready(m["loss"])
            dt = time.time() - t0
            print(f"step {i:5d} loss {float(m['loss']):.4f} "
                  f"gnorm {float(m['grad_norm']):.3f} "
                  f"tok/s {tokens_per_step*(i+1)/dt:,.0f}")
        if args.ckpt_every and (i + 1) % args.ckpt_every == 0:
            save_pytree(Path(args.ckpt_dir) / f"step_{i+1}", params)
            print(f"  checkpoint -> {args.ckpt_dir}/step_{i+1}")
    print(f"done in {time.time()-t0:.1f}s; final loss {float(m['loss']):.4f}")
    return float(m["loss"])


if __name__ == "__main__":
    main()
