"""Production meshes.

``make_production_mesh`` is a function (never a module-level constant) so that
importing this module never touches jax device state. The dry-run launcher
sets ``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; nothing else in the codebase does.

Target hardware: TPU v5e pods — 16×16 = 256 chips per pod, 2 pods = 512.
Axes: ``data`` (batch / FSDP), ``model`` (tensor parallel), ``pod`` (composed
with ``data`` for batch sharding; crossing DCN/ICI between pods).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(
        shape, axes, axis_types=(AxisType.Auto,) * len(axes)
    )


def make_host_mesh(model: int = 1) -> Mesh:
    """Tiny mesh over however many devices exist — used by smoke tests."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh(
        (data, model), ("data", "model"),
        axis_types=(AxisType.Auto, AxisType.Auto),
    )


def mesh_shape_dict(mesh: Mesh) -> dict:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


# v5e hardware constants used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12       # per chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link (~per chip, 1-link model)
HBM_PER_CHIP = 16 * 1024**3    # 16 GiB
