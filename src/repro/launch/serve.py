"""Serving launcher: batched decode over a reduced model, optionally with a
COLD start through the ColdEngine-style per-layer weight streaming.

  PYTHONPATH=src python -m repro.launch.serve --arch smollm-360m --requests 8
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import transformer as T
from repro.serving import BatchedServer, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ASSIGNED_ARCHS, default="smollm-360m")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--new-tokens", type=int, default=8)
    ap.add_argument("--max-batch", type=int, default=4)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    if cfg.input_mode != "tokens":
        raise SystemExit("serve demo targets token models")
    key = jax.random.PRNGKey(0)
    t0 = time.perf_counter()
    params = T.init_params(key, cfg)
    srv = BatchedServer(params, cfg, max_batch=args.max_batch, max_len=256)
    print(f"server up in {time.perf_counter()-t0:.2f}s "
          f"(arch={cfg.name}, slots={args.max_batch})")
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, args.prompt_len),
                    max_new_tokens=args.new_tokens)
            for i in range(args.requests)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    for r in reqs:
        print(f"req {r.rid}: ttft {r.first_token_s:.3f}s "
              f"done {r.done_s:.3f}s tokens {r.out_tokens[:6]}...")
    return reqs


if __name__ == "__main__":
    main()
