from repro.serving.server import BatchedServer, Request  # noqa: F401
