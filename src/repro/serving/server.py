"""Batched serving loop: continuous batching over prefill + decode steps.

A small but real server: requests enter a queue; the engine admits up to
``max_batch`` concurrent sequences into fixed slots; each scheduler tick
decodes one token for every live slot (one ``decode_step`` for the whole
batch); finished sequences free their slots for queued requests. Prefill of
a new request is a full-sequence ``forward(collect_cache=True)`` whose KV is
packed into the slot.

Combined with the ColdEngine, a cold-started server overlaps model weight
loading with the first prefill (examples/serve_cold.py).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.models import transformer as T


def sample_token(logits: jax.Array, key, *, temperature: float = 0.0,
                 top_k: int = 0, top_p: float = 1.0) -> jax.Array:
    """Sample one token id from (V,) logits. temperature == 0 -> greedy.
    top_k and nucleus (top_p) filters compose."""
    if temperature <= 0.0:
        return jnp.argmax(logits)
    logits = logits.astype(jnp.float32) / temperature
    if top_k and top_k < logits.shape[-1]:
        kth = jnp.sort(logits)[-top_k]
        logits = jnp.where(logits < kth, -jnp.inf, logits)
    if top_p < 1.0:
        sorted_logits = jnp.sort(logits)[::-1]
        probs = jax.nn.softmax(sorted_logits)
        cum = jnp.cumsum(probs)
        # smallest set with cumulative prob >= top_p
        cutoff_idx = jnp.argmax(cum >= top_p)
        cutoff = sorted_logits[cutoff_idx]
        logits = jnp.where(logits < cutoff, -jnp.inf, logits)
    return jax.random.categorical(key, logits)


@dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new_tokens: int = 16
    temperature: float = 0.0     # 0 = greedy
    top_k: int = 0
    top_p: float = 1.0
    out_tokens: List[int] = field(default_factory=list)
    submitted_s: float = 0.0
    first_token_s: Optional[float] = None
    done_s: Optional[float] = None


class BatchedServer:
    def __init__(self, params, cfg: ArchConfig, *, max_batch: int = 4,
                 max_len: int = 512, budget=None):
        """``budget`` (a ``repro.executor.server.MemoryBudget``, duck-typed
        ``reserve``/``release``) charges this server's KV-cache allocation
        to the SAME accounted pool the ColdServer's staged-weight LRU draws
        from: allocating KV for decode may evict another model's resident
        weights instead of silently overcommitting device memory.
        ``close()`` releases the reservation."""
        assert cfg.input_mode == "tokens", "server demo expects token models"
        self.params = params
        self.cfg = cfg
        self.max_batch = max_batch
        self.max_len = max_len
        self.state = T.init_decode_state(cfg, max_batch, max_len)
        self.kv_bytes = sum(int(getattr(x, "nbytes", 0))
                            for x in jax.tree.leaves(self.state))
        self.budget = budget
        self._budget_tag = f"kv:{id(self)}"
        if budget is not None:
            budget.reserve(self._budget_tag, self.kv_bytes)
        self.pos = np.zeros(max_batch, np.int64)        # per-slot position
        self.slot_req: List[Optional[Request]] = [None] * max_batch
        self.queue: List[Request] = []
        # completed since the last drain; run_until_drained hands the list
        # to the caller (a long-running server must not accumulate every
        # request it ever served)
        self.finished: List[Request] = []
        self._decode = jax.jit(
            lambda p, s, b, pos: T.decode_step(p, s, b, pos, cfg))
        self._t0 = time.perf_counter()
        self._key = jax.random.PRNGKey(0)

    def _pick(self, req: Request, logits_row: jax.Array) -> int:
        self._key, sub = jax.random.split(self._key)
        return int(sample_token(
            logits_row, sub, temperature=req.temperature,
            top_k=req.top_k, top_p=req.top_p))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        req.submitted_s = time.perf_counter() - self._t0
        self.queue.append(req)

    def _admit(self):
        for slot in range(self.max_batch):
            if self.slot_req[slot] is None and self.queue:
                req = self.queue.pop(0)
                self._prefill_into(slot, req)

    def _prefill_into(self, slot: int, req: Request):
        """Feed the prompt token-by-token through decode_step for the slot.

        (Slot-granular prefill via the batched decode path: correct if not
        maximal-throughput; a bulk prefill + cache-pack is the optimized
        path exercised by the dry-run's prefill_step.)"""
        self.slot_req[slot] = req
        toks = req.prompt.astype(np.int32)
        for t, tok in enumerate(toks):
            batch_tok = np.zeros((self.max_batch, 1), np.int32)
            batch_tok[slot, 0] = tok
            logits, self.state = self._decode(
                self.params, self.state,
                {"tokens": jnp.asarray(batch_tok)}, jnp.int32(self.pos[slot]))
            self.pos[slot] += 1
        nxt = self._pick(req, logits[slot, 0])
        req.out_tokens.append(nxt)
        req.first_token_s = time.perf_counter() - self._t0

    def step(self) -> int:
        """One decode tick for all live slots. Returns #live slots."""
        self._admit()
        live = [s for s in range(self.max_batch) if self.slot_req[s] is not None]
        if not live:
            return 0
        batch_tok = np.zeros((self.max_batch, 1), np.int32)
        for s in live:
            batch_tok[s, 0] = self.slot_req[s].out_tokens[-1]
        # single shared position per decode_step: use max slot pos (slots
        # prefilled at different times decode with their own mask lengths
        # tracked in the cache ring; demo server keeps slots in lockstep)
        pos = int(max(self.pos[s] for s in live))
        logits, self.state = self._decode(
            self.params, self.state, {"tokens": jnp.asarray(batch_tok)},
            jnp.int32(pos))
        for s in live:
            self.pos[s] = pos + 1
            req = self.slot_req[s]
            req.out_tokens.append(self._pick(req, logits[s, 0]))
            if len(req.out_tokens) >= req.max_new_tokens:
                req.done_s = time.perf_counter() - self._t0
                self.finished.append(req)
                self.slot_req[s] = None
        return len(live)

    def close(self):
        """Release the KV-cache reservation back to the shared budget.
        Idempotent; the server itself remains usable (the accounting is
        advisory — correctness never depends on it)."""
        if self.budget is not None:
            self.budget.release(self._budget_tag)
            self.budget = None

    def run_until_drained(self, max_ticks: int = 10_000) -> List[Request]:
        """Tick until queue and slots are empty; returns every request
        finished since the last drain (in completion order) and clears the
        buffer — ownership passes to the caller."""
        for _ in range(max_ticks):
            if not self.queue and all(r is None for r in self.slot_req):
                break
            self.step()
        out, self.finished = self.finished, []
        return out
