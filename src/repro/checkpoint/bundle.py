"""Packed per-layer weight bundles — the cold path's on-disk format.

MNN-style pre-arranged single-blob layouts: all tensors of one layer live in
ONE file so a cold read is one ``open`` + one (m)mapped scan instead of N
opens + N copies. Layout::

    [0:4)    magic  b"NNVB"
    [4:8)    format version (uint32 LE)
    [8:16)   header length in bytes (uint64 LE)
    [16:16+H) header — UTF-8 JSON:
              {"tensors": [{"name", "dtype", "shape", "offset", "nbytes"}]}
    ...      zero padding to the first 64-byte boundary
    segments tensor payloads, each starting on a 64-byte boundary
             (``offset`` is absolute from the start of the file)

Dtypes are tagged by name ("float32", "bfloat16", "int8", ...); bfloat16 is
stored natively — the payload *is* the bf16 bits, no ``.bf16.npy``
uint16-view hack — and resolved through ``ml_dtypes`` on read.

Reads come in two flavors:

  * ``read_bundle(path)`` — one sequential read, arrays own their memory;
  * ``read_bundle(path, mmap=True)`` — zero-copy: every tensor is a
    read-only view into a single ``np.memmap``. No payload bytes are
    touched until a consumer (transform / device staging) faults them in,
    which is exactly what the pipelined runtime wants: the 'read' op
    becomes metadata-only and the cost surfaces inside transform/stage,
    off the critical exec chain. The views are immutable (writes raise) —
    safe to hand to kernels, which copy on transform anyway.

The 64-byte segment alignment keeps every view aligned for any dtype and
matches cache-line/DMA-friendly boundaries.
"""
from __future__ import annotations

import json
import struct
from pathlib import Path
from typing import Dict, List

import numpy as np

MAGIC = b"NNVB"
VERSION = 1
ALIGN = 64
_HEADER_FMT = "<4sIQ"  # magic, version, header-json length
_HEADER_FIXED = struct.calcsize(_HEADER_FMT)


def _dtype_from_tag(tag: str) -> np.dtype:
    if tag == "bfloat16":
        import ml_dtypes

        return np.dtype(ml_dtypes.bfloat16)
    return np.dtype(tag)


def _dtype_tag(dt: np.dtype) -> str:
    name = dt.name if hasattr(dt, "name") else str(dt)
    if "bfloat16" in str(dt):
        return "bfloat16"
    return name


def _pad_to(n: int, align: int = ALIGN) -> int:
    return (n + align - 1) // align * align


def atomic_write(path: Path, write_fn, *, durable: bool = False) -> None:
    """Publish a file atomically: ``write_fn(f)`` streams into ``<path>.tmp``,
    which is renamed over ``path`` only on success — readers never see a torn
    file, and a failed write never leaves the ``.tmp`` behind. With
    ``durable`` the tmp is fsynced before the rename and the directory
    after it, so the publish also survives power loss (the ordering the
    super-bundle's journaled commits rely on)."""
    from repro.checkpoint.integrity import fsync_dir, fsync_file

    path = Path(path)
    tmp = path.with_suffix(path.suffix + ".tmp")
    try:
        with open(tmp, "wb") as f:
            write_fn(f)
            if durable:
                fsync_file(f)
        tmp.replace(path)
        if durable:
            fsync_dir(path.parent)
    except BaseException:
        tmp.unlink(missing_ok=True)
        raise


def write_bundle(path: Path, weights: Dict[str, np.ndarray]) -> int:
    """Write all tensors of one layer as a single packed bundle file.
    Returns the total file size in bytes."""
    path = Path(path)
    entries: List[dict] = []
    arrs: List[np.ndarray] = []
    # lay out segments first so the header can carry absolute offsets
    for name in sorted(weights):
        a = np.ascontiguousarray(np.asarray(weights[name]))
        entries.append({
            "name": name,
            "dtype": _dtype_tag(a.dtype),
            "shape": list(a.shape),
            "nbytes": int(a.nbytes),
        })
        arrs.append(a)
    header = {"tensors": entries}
    # offsets depend on the header length, which depends on the offsets'
    # digit count — fixed-point iterate (converges in <=3 rounds; offsets
    # only ever grow, so this terminates)
    for _ in range(8):
        hdr_bytes = json.dumps(header, separators=(",", ":")).encode()
        off = _pad_to(_HEADER_FIXED + len(hdr_bytes))
        changed = False
        for e in entries:
            if e.get("offset") != off:
                e["offset"] = off
                changed = True
            off = _pad_to(off + e["nbytes"])
        if not changed:
            break
    else:  # never: guards against writing a header with stale offsets
        raise RuntimeError(f"bundle header layout did not converge: {path}")
    total = off

    def _emit(f):
        f.write(struct.pack(_HEADER_FMT, MAGIC, VERSION, len(hdr_bytes)))
        f.write(hdr_bytes)
        for e, a in zip(entries, arrs):
            f.write(b"\0" * (e["offset"] - f.tell()))
            f.write(a.tobytes())
        f.write(b"\0" * (total - f.tell()))

    atomic_write(path, _emit)
    return total


def read_header(path: Path) -> dict:
    with open(path, "rb") as f:
        magic, version, hlen = struct.unpack(
            _HEADER_FMT, f.read(_HEADER_FIXED))
        if magic != MAGIC:
            raise ValueError(f"{path}: not a bundle (magic={magic!r})")
        if version > VERSION:
            raise ValueError(f"{path}: bundle version {version} > {VERSION}")
        return json.loads(f.read(hlen).decode())


def _parse_header_from(buf) -> dict:
    magic, version, hlen = struct.unpack_from(_HEADER_FMT, buf, 0)
    if magic != MAGIC:
        raise ValueError(f"not a bundle (magic={magic!r})")
    if version > VERSION:
        raise ValueError(f"bundle version {version} > {VERSION}")
    return json.loads(bytes(buf[_HEADER_FIXED:_HEADER_FIXED + hlen]).decode())


def read_bundle(path: Path, *, mmap: bool = False) -> Dict[str, np.ndarray]:
    """ONE open per layer — the header is parsed out of the same buffer the
    payload views come from, no separate metadata read. With ``mmap`` the
    returned arrays are read-only zero-copy views into a shared memory map
    (payload pages fault in lazily); otherwise one ``readinto`` materializes
    everything into a single writable buffer the views share."""
    import mmap as mmap_mod

    path = Path(path)
    with open(path, "rb") as f:
        if mmap:
            # mmap.mmap + frombuffer: ~2x cheaper to construct than
            # np.memmap, and read-only (ACCESS_READ) so views are immutable
            mm = mmap_mod.mmap(f.fileno(), 0, access=mmap_mod.ACCESS_READ)
            buf = np.frombuffer(mm, dtype=np.uint8)
        else:
            size = path.stat().st_size
            buf = np.empty(size, np.uint8)
            f.readinto(memoryview(buf))  # one sequential read for the layer
    out: Dict[str, np.ndarray] = {}
    for e in _parse_header_from(buf)["tensors"]:
        seg = buf[e["offset"]: e["offset"] + e["nbytes"]]
        out[e["name"]] = seg.view(_dtype_from_tag(e["dtype"])).reshape(
            e["shape"])
    return out


def bundle_nbytes(path: Path) -> int:
    """Payload bytes (sum of tensor segments), excluding header/padding —
    the number the storage accounting compares against raw weight sizes."""
    return sum(e["nbytes"] for e in read_header(Path(path))["tensors"])
