"""Checkpoint I/O.

Two facilities:

  * ``LayerStore`` — per-layer weight storage on disk, the cold-inference
    engine's substrate. Raw weights live under ``raw/``; post-transformed
    weights (the paper's §3.1.2 cache) under ``cache/<kernel>/``.

    The default format is the packed single-file *bundle*
    (``checkpoint/bundle.py``): all tensors of a layer in one file with
    64-byte-aligned segments, read back as ONE open + one ``np.memmap``
    (zero-copy, read-only views) instead of N opens + N full copies —
    MNN-style pre-arranged layouts for sequential, cheap cold reads.
    ``fmt="super"`` goes one step further (``checkpoint/superbundle.py``):
    the whole model — raw weights and the per-kernel §3.1.2 cache — lives
    in ONE file (``model.superbundle``) behind one shared mmap; reads are
    zero-copy views into it and ``readahead()`` issues madvise(WILLNEED)
    hints for the layers a plan touches first. Writes are buffered: raw
    installs and first-time cache materializations coalesce into ONE
    atomic container rewrite at the next flush point (raw read /
    accounting / readahead), while replacing a cache entry already in the
    container goes through the super-bundle's in-place/rewrite-on-grow
    path — crash-atomic since format v3 (intent journal + per-extent
    CRC-32C; ``verify=`` picks the checksum-audit mode and ``maintain()``
    compacts dead cache extents — see ``checkpoint/superbundle.py`` and
    ``docs/formats.md``). ``fmt="npy"`` keeps the legacy per-tensor ``.npy`` layout (one
    file per tensor, bf16 stored as uint16 views) for format benchmarks
    and the bundle-vs-legacy equivalence tests.

    ``open_count`` tracks the file opens the read path performs (the
    number the cold-I/O benchmarks compare across formats: N_tensors for
    npy, N_layers for bundle, 1 per model for super).

  * pytree checkpointing (``save_pytree``/``load_pytree``) for the training
    loop — flat .npy files keyed by the pytree path.
"""
from __future__ import annotations

import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, List, Optional, Set, Tuple

import numpy as np

from repro.checkpoint.bundle import (
    _dtype_from_tag, _parse_header_from, read_bundle, write_bundle,
)
from repro.checkpoint.integrity import (  # noqa: F401  (re-exported helpers)
    atomic_write_text, crc32c, fsync_dir, fsync_file,
)
from repro.checkpoint.superbundle import (
    SuperBundle, drop_cache_entry, set_cache_entries, set_cache_entry,  # noqa: F401
    write_superbundle,
)
from repro.faults import classify
from repro import quant


def _safe(name: str) -> str:
    return name.replace("/", "_")


# ---------------------------------------------------------------------------
# async read handles (submit/reap pairs over repro.ioengine)
# ---------------------------------------------------------------------------
class _ImmediateRead:
    """Pending-read interface over bytes already in hand (buffered
    super-bundle writes, npy fallback): wait() returns instantly."""

    def __init__(self, weights: Dict[str, np.ndarray]):
        self._w = weights

    def wait(self, timeout: Optional[float] = None) -> Dict[str, np.ndarray]:
        return self._w

    def nbytes(self) -> int:
        return sum(int(v.nbytes) for v in self._w.values())

    def abort(self) -> None:
        pass

    def release(self) -> None:
        pass


class _PendingBundleRead:
    """Whole-file async read of one per-layer bundle: submit ONE read for
    the blob, parse the header out of the reaped buffer (same trick as
    ``read_bundle``), serve read-only views.  Retry-idempotent like the
    super-bundle's ``PendingLayerRead``: a fault abandons the ticket and
    the next ``wait()`` resubmits."""

    def __init__(self, store: "LayerStore", path: Path, engine, injector,
                 key: str):
        self.store = store
        self.path = path
        self.engine = engine
        self.injector = injector
        self.key = key
        self._fd: Optional[int] = None
        self._ticket = None
        self._size = 0
        self._result: Optional[Dict[str, np.ndarray]] = None

    def submit(self) -> "_PendingBundleRead":
        if self._ticket is None and self._result is None:
            self._fd = os.open(self.path, os.O_RDONLY)
            self.store.open_count += 1
            try:
                self._size = os.fstat(self._fd).st_size
                self._ticket = self.engine.submit(
                    self._fd, 0, self._size, key=self.key,
                    injector=self.injector)
            except BaseException:
                os.close(self._fd)
                self._fd = None
                raise
        return self

    def nbytes(self) -> int:
        return self._size

    def _reset(self) -> None:
        if self._ticket is not None:
            self._ticket.abandon()
            self._ticket = None
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def abort(self) -> None:
        """Flag-only interrupt for a waiter parked in emulated-disk pacing
        (warm-state race loser); never touches the buffer — see
        ``ReadTicket.interrupt``."""
        if self._ticket is not None:
            self._ticket.interrupt()

    def wait(self, timeout: Optional[float] = None) -> Dict[str, np.ndarray]:
        if self._result is not None:
            return self._result
        self.submit()
        try:
            buf = self._ticket.wait(timeout)
            out: Dict[str, np.ndarray] = {}
            for e in _parse_header_from(buf)["tensors"]:
                seg = buf[e["offset"]: e["offset"] + e["nbytes"]]
                out[e["name"]] = seg.view(
                    _dtype_from_tag(e["dtype"])).reshape(e["shape"])
        except Exception:
            self._reset()  # transient: the retry's next wait() resubmits
            raise
        os.close(self._fd)  # payload fully reaped; only the buffer lives on
        self._fd = None
        self._result = out
        return out

    def release(self) -> None:
        if self._ticket is not None:
            self._ticket.abandon()
            self._ticket = None
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None


# ---------------------------------------------------------------------------
# legacy per-tensor .npy layout (fmt="npy")
# ---------------------------------------------------------------------------
def _save_arr(path_base: Path, v: np.ndarray):
    """np.save with bf16 support (stored as uint16 + .bf16.npy suffix —
    numpy cannot round-trip ml_dtypes through .npy)."""
    import ml_dtypes

    v = np.asarray(v)
    if v.dtype == ml_dtypes.bfloat16:
        np.save(path_base.with_suffix(".bf16.npy"), v.view(np.uint16),
                allow_pickle=False)
    else:
        np.save(path_base.with_suffix(".npy"), v, allow_pickle=False)


def _load_dir(d: Path) -> Dict[str, np.ndarray]:
    import ml_dtypes

    out: Dict[str, np.ndarray] = {}
    for p in sorted(d.glob("*.npy")):
        if p.name.endswith(".bf16.npy"):
            out[p.name[: -len(".bf16.npy")]] = np.load(
                p, allow_pickle=False).view(ml_dtypes.bfloat16)
        else:
            out[p.stem] = np.load(p, allow_pickle=False)
    return out


class LayerStore:
    """Per-layer weight store. ``fmt="bundle"`` (default) packs each layer
    into one aligned blob; ``fmt="super"`` packs the whole model into one;
    reads default to zero-copy mmap views (``mmap=False`` forces a
    materializing read that pays the byte movement up front)."""

    def __init__(self, root: Path, *, fmt: str = "bundle", mmap: bool = True,
                 verify: str = "lazy"):
        assert fmt in ("bundle", "npy", "super"), fmt
        assert verify in ("never", "lazy", "eager"), verify
        self.root = Path(root)
        self.fmt = fmt
        self.mmap = mmap
        self.verify = verify  # super-bundle checksum audit mode
        self.open_count = 0  # file opens performed by reads
        self.cache_write_count = 0  # write_cached calls (cache materializations)
        # chaos hook: a repro.faults.FaultInjector with "store.read_raw" /
        # "store.read_cached" sites armed (None = no injection)
        self.fault_injector = None
        # cache entries dropped by journal recovery / checksum verification
        # ({"layer", "kernel", "reason"}; fmt="super" only)
        self.dropped_entries: List[dict] = []
        # coverage of the last readahead() call (satellite of the async
        # engine work: a silent madvise no-op is now visible downstream)
        self.readahead_stats: Optional[Dict[str, Any]] = None
        (self.root / "raw").mkdir(parents=True, exist_ok=True)
        (self.root / "cache").mkdir(parents=True, exist_ok=True)
        if fmt == "super":
            self._super_path = self.root / "model.superbundle"
            self._pending_raw: Dict[str, Dict[str, np.ndarray]] = {}
            self._pending_cache: Dict[Tuple[str, str], Dict[str, np.ndarray]] = {}
            self._pending_drop: Set[Tuple[str, str]] = set()
            self._order: List[str] = []  # write order == graph order
            self._reader: Optional[SuperBundle] = None
            self._reader_seen = 0  # reader.dropped entries already harvested
            # container bytes served by readers already closed; live reader
            # bytes are added on top by bytes_served()
            self._bytes_served_base = 0
            self._maintain_thread = None
            self._maintain_result = None

    # -- super-bundle plumbing ----------------------------------------------
    def _super_dirty(self) -> bool:
        return bool(self._pending_raw or self._pending_cache
                    or self._pending_drop)

    def _invalidate_reader(self):
        if self._reader is not None:
            # harvest entries the reader dropped AFTER open (lazy checksum
            # audits on materializing reads) so dropped_entries stays the
            # complete report
            self.dropped_entries += self._reader.dropped[self._reader_seen:]
            self._bytes_served_base += self._reader.bytes_served
            self._reader.close()
            self._reader = None

    def bytes_served(self) -> int:
        """Container extent bytes served through reads (mmap views + async
        waits) across all reader generations — the measured cold-bytes
        counter the quantized-cache benchmarks snapshot around a run.
        0 for non-super formats (no shared counter to aggregate)."""
        if self.fmt != "super":
            return 0
        live = self._reader.bytes_served if self._reader is not None else 0
        return self._bytes_served_base + live

    def close(self):
        """Release the shared super-bundle mmap (the next read reopens it) —
        lets benchmarks measure truly cold opens. No-op for other fmts."""
        if self.fmt == "super":
            self._invalidate_reader()

    def _quiesce_maintenance(self):
        """Join a live background compaction before mutating the container —
        two concurrent rewrites would interleave into the same tmp file. A
        failed compaction surfaces here (or at ``maintain_wait()``)."""
        t = getattr(self, "_maintain_thread", None)
        if t is not None:
            self.maintain_wait()

    def _super_flush(self):
        """Merge all buffered writes/drops into the container in ONE atomic
        rewrite (write_raw during model install is buffered so an N-layer
        install costs one rewrite, not N). When the only pending work is
        cache-entry writes against an existing container — the decide()
        refresh pattern — they commit as ONE batched intent-journal
        transaction instead (one fsync pair however many entries)."""
        if not self._super_dirty():
            return
        self._quiesce_maintenance()
        if (not self._pending_raw and not self._pending_drop
                and self._super_path.exists()):
            self._invalidate_reader()
            res = set_cache_entries(self._super_path,
                                    dict(self._pending_cache),
                                    verify=self.verify)
            self.dropped_entries += res["dropped"]
            self._pending_cache.clear()
            return
        raw: Dict[str, Dict[str, np.ndarray]] = {}
        cache: Dict[str, Dict[str, Dict[str, np.ndarray]]] = {}
        order: List[str] = []
        generation = 0
        sb = (SuperBundle(self._super_path, verify=self.verify)
              if self._super_path.exists() else None)
        try:
            if sb is not None:
                from repro.checkpoint.superbundle import _load_all

                generation = sb.generation + 1
                order = list(sb.order)
                # _load_all audits every extent it copies forward (unless
                # verify="never") — the rewrite restamps fresh checksums,
                # so unverified bytes would launder bit-rot into the new
                # container; corrupt cache entries drop, corrupt raw raises
                raw, cache = _load_all(sb)
                self.dropped_entries += sb.dropped
            for l, w in self._pending_raw.items():
                raw[l] = w
            for l in self._order:
                if l not in order:
                    order.append(l)
            for (l, k) in self._pending_drop:
                cache.get(l, {}).pop(k, None)
            for (l, k), w in self._pending_cache.items():
                cache.setdefault(l, {})[k] = w
                raw.setdefault(l, {})
                if l not in order:
                    order.append(l)
            write_superbundle(self._super_path, raw, cache, order=order,
                              generation=generation)
        finally:
            if sb is not None:
                sb.close()
        self._pending_raw.clear()
        self._pending_cache.clear()
        self._pending_drop.clear()
        self._invalidate_reader()

    def _super(self, *, flush_all: bool = False) -> Optional[SuperBundle]:
        """The shared reader. Pending RAW writes force a flush (raw reads
        must see them in the file); pending cache writes/drops do NOT —
        cache queries are served from the buffers until something needs the
        file complete (``flush_all``), which keeps an N-layer cache
        materialization at one container rewrite instead of N."""
        if flush_all or self._pending_raw:
            self._super_flush()
        if self._reader is None and self._super_path.exists():
            self._reader = SuperBundle(self._super_path, verify=self.verify)
            self.open_count += 1
            if self._reader.dropped:
                self.dropped_entries += self._reader.dropped
            self._reader_seen = len(self._reader.dropped)
        return self._reader

    def readahead(self, layers) -> int:
        """madvise(WILLNEED)-style hints for the layers a plan touches
        first. Effective for ``fmt="super"``; 0 otherwise.  Coverage of
        the last call lands in ``readahead_stats`` (hinted layer/byte
        counts + whether madvise exists at all) so runs where the hint
        silently no-ops are distinguishable downstream."""
        layers = list(layers)
        if self.fmt != "super":
            self.readahead_stats = {
                "layers_requested": len(layers), "layers_hinted": 0,
                "bytes_hinted": 0, "madvise_available": False}
            return 0
        sb = self._super(flush_all=True)
        if sb is None:
            self.readahead_stats = {
                "layers_requested": len(layers), "layers_hinted": 0,
                "bytes_hinted": 0, "madvise_available": False}
            return 0
        hinted = sb.advise_willneed(layers)
        self.readahead_stats = dict(sb.last_readahead or {})
        return hinted

    def maintain(self, *, min_reclaim_bytes: int = 1,
                 background: bool = False) -> Dict[str, Any]:
        """Storage maintenance hook (the engine calls it after ``decide()``):
        flush buffered writes, then compact the super-bundle if dropped/
        superseded cache extents left at least ``min_reclaim_bytes`` dead on
        disk. ``background=True`` runs the compaction in a daemon thread
        (call ``maintain_wait()`` before mutating the store again). No-op
        for non-super formats."""
        out: Dict[str, Any] = {"compacted": False, "reclaimed_bytes": 0,
                               "dropped": []}
        if self.fmt != "super":
            return out
        self._quiesce_maintenance()  # never two compactions in flight
        sb = self._super(flush_all=True)
        if sb is None:
            return out
        reclaim = sb.reclaimable_bytes()
        if reclaim < max(min_reclaim_bytes, 1):
            return out
        self._invalidate_reader()

        def _run():
            from repro.checkpoint.superbundle import compact

            return compact(self._super_path)

        if background:
            import threading

            self._maintain_result = None  # (stats, exception)

            def _bg():
                try:
                    self._maintain_result = (_run(), None)
                except BaseException as exc:  # surfaced by maintain_wait()
                    self._maintain_result = (None, exc)

            t = threading.Thread(target=_bg, name="superbundle-compact",
                                 daemon=True)
            t.start()
            self._maintain_thread = t
            # reclaimed_bytes here is the pre-compaction estimate; call
            # maintain_wait() for the real stats (or the failure)
            out.update(compacted=True, background=True,
                       reclaimed_bytes=reclaim)
            return out
        stats = _run()
        self.dropped_entries += stats["dropped"]
        out.update(compacted=True, reclaimed_bytes=stats["reclaimed_bytes"],
                   dropped=stats["dropped"])
        return out

    def warm_verify(self, layers) -> int:
        """Materialize the given layers' raw entries now so their one-off
        lazy CRC audit lands here instead of inside a caller's timed read
        region. No-op (returns 0) unless ``fmt="super"`` with
        ``verify="lazy"`` — the only configuration that audits reads."""
        if self.fmt != "super" or self.verify != "lazy":
            return 0
        n = 0
        for name in layers:
            self.read_raw(name, mmap=False)
            n += 1
        return n

    def maintain_wait(self) -> Optional[dict]:
        """Join a background compaction started by ``maintain()``: returns
        its real stats, re-raises its failure, or returns None if no
        background compaction is pending."""
        t = getattr(self, "_maintain_thread", None)
        if t is None:
            return None
        t.join()
        self._maintain_thread = None
        stats, exc = self._maintain_result
        self._maintain_result = None
        if exc is not None:
            raise exc
        self.dropped_entries += stats["dropped"]
        return stats

    # -- layout -------------------------------------------------------------
    def _raw_path(self, layer: str) -> Path:
        base = self.root / "raw" / _safe(layer)
        # NOT with_suffix: dotted layer names ("block.0") must not collide
        return base.parent / (base.name + ".bundle") if self.fmt == "bundle" else base

    def _cache_path(self, layer: str, kernel: str) -> Path:
        base = self.root / "cache" / kernel / _safe(layer)
        return base.parent / (base.name + ".bundle") if self.fmt == "bundle" else base

    def _write(self, path: Path, weights: Dict[str, np.ndarray]):
        if self.fmt == "bundle":
            path.parent.mkdir(parents=True, exist_ok=True)
            write_bundle(path, weights)
        else:
            path.mkdir(parents=True, exist_ok=True)
            for k, v in weights.items():
                _save_arr(path / k, v)

    def _read(self, path: Path, mmap: Optional[bool]) -> Dict[str, np.ndarray]:
        if not path.exists():
            return {}  # weightless (stateless) layers have no file on disk
        if self.fmt == "bundle":
            use = self.mmap if mmap is None else mmap
            self.open_count += 1
            return read_bundle(path, mmap=use)
        self.open_count += sum(1 for _ in path.glob("*.npy"))
        return _load_dir(path)

    # -- raw weights --------------------------------------------------------
    def write_raw(self, layer: str, weights: Dict[str, np.ndarray]):
        if self.fmt == "super":
            self._pending_raw[layer] = {
                k: np.asarray(v) for k, v in weights.items()}
            if layer not in self._order:
                self._order.append(layer)
            return
        self._write(self._raw_path(layer), weights)

    def read_raw(self, layer: str, *, mmap: Optional[bool] = None) -> Dict[str, np.ndarray]:
        if self.fault_injector is not None:
            self.fault_injector.maybe_fault("store.read_raw", layer)
        try:
            if self.fmt == "super":
                sb = self._super()
                if sb is None:
                    return {}
                use = self.mmap if mmap is None else mmap
                return sb.read_raw(layer, materialize=not use)
            return self._read(self._raw_path(layer), mmap)
        except OSError as e:
            # transient-errno I/O errors become typed retryable ReadFaults;
            # real conditions (ENOENT, EACCES, ...) pass through unchanged
            f = classify(e, site="store.read_raw", layer=layer)
            if f is e:
                raise
            raise f from e

    def raw_bytes(self, layer: str) -> int:
        if self.fmt == "super":
            sb = self._super()
            return sb.raw_nbytes(layer) if sb is not None else 0
        p = self._raw_path(layer)
        if self.fmt == "bundle":
            return p.stat().st_size if p.exists() else 0
        return sum(q.stat().st_size for q in p.glob("*.npy"))

    def cached_bytes(self, layer: str, kernel: str) -> int:
        """Extent bytes a cold read of one cache entry costs. For
        ``fmt="super"`` this is the FOLDED payload size — a quantized
        entry's int8/int4 bytes, not its dequantized footprint — i.e. the
        read-cost side of the scheduler's smaller-read/dequant trade."""
        if self.fmt == "super":
            pend = self._pending_cache.get((layer, kernel))
            if pend is not None:
                groups, rest = quant.split_groups(pend)
                return (sum(int(np.asarray(v).nbytes) for v in rest.values())
                        + sum(int(np.asarray(g["data"]).nbytes)
                              for g in groups.values()))
            sb = self._super()
            if sb is None or not sb.has_cached(layer, kernel):
                return 0
            return sum(e["nbytes"]
                       for e in sb._layers[layer]["cache"][kernel])
        p = self._cache_path(layer, kernel)
        if self.fmt == "bundle":
            return p.stat().st_size if p.exists() else 0
        return sum(q.stat().st_size for q in p.glob("*.npy"))

    # -- post-transformed cache (§3.1.2) ------------------------------------
    def write_cached(self, layer: str, kernel: str, weights: Dict[str, np.ndarray]):
        self.cache_write_count += 1
        if self.fmt == "super":
            self._quiesce_maintenance()
            self._pending_drop.discard((layer, kernel))
            # buffer first materializations AND replacements alike: at the
            # next flush point, N replacements commit as ONE batched
            # journal transaction (one fsync pair) and N first-time
            # entries land in ONE rewrite — never N commits
            self._pending_cache[(layer, kernel)] = {
                k: np.asarray(v) for k, v in weights.items()}
            if layer not in self._order:
                self._order.append(layer)
            return
        self._write(self._cache_path(layer, kernel), weights)

    def read_cached(self, layer: str, kernel: str, *,
                    mmap: Optional[bool] = None) -> Dict[str, np.ndarray]:
        if self.fault_injector is not None:
            self.fault_injector.maybe_fault("store.read_cached", layer)
        try:
            if self.fmt == "super":
                if (layer, kernel) in self._pending_drop:
                    return {}
                use = self.mmap if mmap is None else mmap
                pend = self._pending_cache.get((layer, kernel))
                if pend is not None:
                    # serve the buffered entry without forcing a flush (copies
                    # under mmap=False so callers may mutate freely)
                    return ({k: np.array(v) for k, v in pend.items()}
                            if not use else dict(pend))
                sb = self._super()
                if sb is None:
                    return {}
                return sb.read_cached(layer, kernel, materialize=not use)
            return self._read(self._cache_path(layer, kernel), mmap)
        except OSError as e:
            f = classify(e, site="store.read_cached", layer=layer)
            if f is e:
                raise
            raise f from e

    # -- async submit/reap reads (repro.ioengine) ---------------------------
    @property
    def supports_async(self) -> bool:
        """True when reads can go through the async I/O engine (the npy
        legacy layout stays sync — its N-tiny-files shape is the thing
        the benchmarks keep it around to demonstrate)."""
        return self.fmt in ("super", "bundle")

    def submit_read_raw(self, engine, layer: str):
        """Submit ``layer``'s raw extents to the async engine; returns a
        pending-read handle (``wait()``/``nbytes()``/``release()``).  The
        same fault-injection site as ``read_raw`` is armed at submit, and
        the engine arms ``ioengine.submit``/``ioengine.reap``, so chaos
        runs cover the async path without new wiring."""
        if self.fault_injector is not None:
            self.fault_injector.maybe_fault("store.read_raw", layer)
        try:
            if self.fmt == "super":
                sb = self._super()
                pend = (sb.submit_read(engine, layer,
                                       injector=self.fault_injector)
                        if sb is not None else None)
                return pend if pend is not None else _ImmediateRead({})
            if self.fmt == "bundle":
                p = self._raw_path(layer)
                if not p.exists():
                    return _ImmediateRead({})
                return _PendingBundleRead(self, p, engine,
                                          self.fault_injector,
                                          key=layer).submit()
            return _ImmediateRead(self._read(self._raw_path(layer), False))
        except OSError as e:
            f = classify(e, site="store.read_raw", layer=layer)
            if f is e:
                raise
            raise f from e

    def submit_read_cached(self, engine, layer: str, kernel: str):
        """Async counterpart of ``read_cached``; buffered (not-yet-flushed)
        entries are served immediately, a dropped-pending entry reads as
        absent, and a reaped extent failing its CRC audit drops exactly
        like the sync path (``wait()`` returns ``{}``)."""
        if self.fault_injector is not None:
            self.fault_injector.maybe_fault("store.read_cached", layer)
        try:
            if self.fmt == "super":
                if (layer, kernel) in self._pending_drop:
                    return _ImmediateRead({})
                pend_w = self._pending_cache.get((layer, kernel))
                if pend_w is not None:
                    return _ImmediateRead(
                        {k: np.array(v) for k, v in pend_w.items()})
                sb = self._super()
                pend = (sb.submit_read(engine, layer, kernel=kernel,
                                       injector=self.fault_injector)
                        if sb is not None else None)
                if pend is None:
                    return _ImmediateRead({})
                pend.on_drop = self._harvest_drops
                return pend
            if self.fmt == "bundle":
                p = self._cache_path(layer, kernel)
                if not p.exists():
                    return _ImmediateRead({})
                return _PendingBundleRead(self, p, engine,
                                          self.fault_injector,
                                          key=f"{layer}@{kernel}").submit()
            return _ImmediateRead(
                self._read(self._cache_path(layer, kernel), False))
        except OSError as e:
            f = classify(e, site="store.read_cached", layer=layer)
            if f is e:
                raise
            raise f from e

    def audit_cached(self, layer: str, kernel: str) -> bool:
        """Run the lazy CRC audit on a cache entry NOW, covering the
        zero-copy mmap path (which normally serves views unverified). The
        runtime's degradation ladder calls this before trusting a cached
        entry mid-run: a failing extent is dropped from the header
        (reported via ``dropped_entries``) and the caller transparently
        recomputes the transform from raw. Returns False exactly when the
        entry just failed its audit; True when it verifies, is still
        buffered, is absent (``read_cached`` returns ``{}`` anyway), or
        auditing is off (non-super format / ``verify="never"``)."""
        if self.fmt != "super" or self.verify == "never":
            return True
        if (layer, kernel) in self._pending_cache:
            return True
        if (layer, kernel) in self._pending_drop:
            return False
        sb = self._super()
        if sb is None or not sb.has_cached(layer, kernel):
            return True
        ok = sb._verify_cached(layer, kernel)
        if not ok:
            self._harvest_drops()
        return ok

    def _harvest_drops(self) -> None:
        """Sync the reader's drop reports into ``dropped_entries`` NOW, so
        a repair event can cite the reason without waiting for the reader
        to reopen (audit failures and async CRC drops both land here)."""
        sb = self._reader
        if sb is None:
            return
        self.dropped_entries += sb.dropped[self._reader_seen:]
        self._reader_seen = len(sb.dropped)

    def has_cached(self, layer: str, kernel: str) -> bool:
        if self.fmt == "super":
            if (layer, kernel) in self._pending_cache:
                return True
            if (layer, kernel) in self._pending_drop:
                return False
            if not self._super_path.exists():
                return False
            sb = self._super()
            return sb is not None and sb.has_cached(layer, kernel)
        return self._cache_path(layer, kernel).exists()

    def drop_cached(self, layer: str, kernel: str):
        if self.fmt == "super":
            self._quiesce_maintenance()
            self._pending_cache.pop((layer, kernel), None)
            if self._super_dirty():
                self._pending_drop.add((layer, kernel))
            elif self._super_path.exists():
                self._invalidate_reader()
                drop_cache_entry(self._super_path, layer, kernel)
            return
        p = self._cache_path(layer, kernel)
        if p.is_dir():
            shutil.rmtree(p)
        elif p.exists():
            p.unlink()

    # -- storage accounting (real on-disk footprint) ------------------------
    def cache_bytes(self) -> int:
        if self.fmt == "super":
            sb = self._super(flush_all=True)
            return sb.cache_disk_bytes() if sb is not None else 0
        return sum(p.stat().st_size
                   for p in (self.root / "cache").rglob("*") if p.is_file())

    def model_bytes(self) -> int:
        # for super, model + cache sums to the container's real file size
        # (header/slack/padding are attributed to the model side)
        if self.fmt == "super":
            sb = self._super(flush_all=True)
            if sb is None:
                return 0
            return sb.file_size() - sb.cache_disk_bytes()
        return sum(p.stat().st_size
                   for p in (self.root / "raw").rglob("*") if p.is_file())


# ---------------------------------------------------------------------------
# training-checkpoint pytrees
# ---------------------------------------------------------------------------
def save_pytree(root: Path, tree: Any):
    import jax
    import ml_dtypes

    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    index = []
    for i, (path, leaf) in enumerate(flat):
        key = jax.tree_util.keystr(path)
        fname = f"leaf_{i:05d}.npy"
        arr = np.asarray(leaf)
        dtype_str = str(arr.dtype)
        if arr.dtype == ml_dtypes.bfloat16:
            # numpy can't round-trip bf16 via .npy: store widened to f32,
            # the recorded dtype restores it on load
            import jax.numpy as jnp

            arr = np.asarray(jnp.asarray(leaf, jnp.float32))
            dtype_str = "bfloat16"
        elif arr.dtype.kind == "V":
            # any other void-kind dtype (structured, or a non-bf16
            # ml_dtypes extension) would be silently widened/mislabeled
            raise TypeError(
                f"save_pytree: unsupported dtype {arr.dtype} at {key!r} — "
                "only numpy-native dtypes and bfloat16 round-trip")
        np.save(root / fname, arr, allow_pickle=False)
        index.append({"key": key, "file": fname, "dtype": dtype_str})
    (root / "index.json").write_text(json.dumps(
        {"leaves": index, "treedef": str(treedef)}, indent=1))


def load_pytree(root: Path, like: Any) -> Any:
    import jax

    root = Path(root)
    flat, treedef = jax.tree_util.tree_flatten(like)
    idx = json.loads((root / "index.json").read_text())["leaves"]
    assert len(idx) == len(flat), (len(idx), len(flat))
    leaves = [np.load(root / e["file"], allow_pickle=False) for e in idx]
    import jax.numpy as jnp

    leaves = [jnp.asarray(l, dtype=f.dtype) for l, f in zip(leaves, flat)]
    return jax.tree_util.tree_unflatten(treedef, leaves)
