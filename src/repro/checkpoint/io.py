"""Checkpoint I/O.

Two facilities:

  * ``LayerStore`` — per-layer weight files on disk, the cold-inference
    engine's substrate. Raw weights live under ``raw/``; post-transformed
    weights (the paper's §3.1.2 cache) under ``cache/<kernel>/``. Reads are
    real ``np.load`` disk I/O — these are the 'weights reading' operations
    the scheduler pipelines.

  * pytree checkpointing (``save_pytree``/``load_pytree``) for the training
    loop — flat .npy files keyed by the pytree path.
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np


def _safe(name: str) -> str:
    return name.replace("/", "_")


def _save_arr(path_base: Path, v: np.ndarray):
    """np.save with bf16 support (stored as uint16 + .bf16.npy suffix —
    numpy cannot round-trip ml_dtypes through .npy)."""
    import ml_dtypes

    v = np.asarray(v)
    if v.dtype == ml_dtypes.bfloat16:
        np.save(path_base.with_suffix(".bf16.npy"), v.view(np.uint16),
                allow_pickle=False)
    else:
        np.save(path_base.with_suffix(".npy"), v, allow_pickle=False)


def _load_dir(d: Path) -> Dict[str, np.ndarray]:
    import ml_dtypes

    out: Dict[str, np.ndarray] = {}
    for p in sorted(d.glob("*.npy")):
        if p.name.endswith(".bf16.npy"):
            out[p.name[: -len(".bf16.npy")]] = np.load(
                p, allow_pickle=False).view(ml_dtypes.bfloat16)
        else:
            out[p.stem] = np.load(p, allow_pickle=False)
    return out


class LayerStore:
    def __init__(self, root: Path):
        self.root = Path(root)
        (self.root / "raw").mkdir(parents=True, exist_ok=True)
        (self.root / "cache").mkdir(parents=True, exist_ok=True)

    # -- raw weights --------------------------------------------------------
    def write_raw(self, layer: str, weights: Dict[str, np.ndarray]):
        d = self.root / "raw" / _safe(layer)
        d.mkdir(parents=True, exist_ok=True)
        for k, v in weights.items():
            _save_arr(d / k, v)

    def read_raw(self, layer: str) -> Dict[str, np.ndarray]:
        return _load_dir(self.root / "raw" / _safe(layer))

    def raw_bytes(self, layer: str) -> int:
        d = self.root / "raw" / _safe(layer)
        return sum(p.stat().st_size for p in d.glob("*.npy"))

    # -- post-transformed cache (§3.1.2) ------------------------------------
    def _cache_dir(self, layer: str, kernel: str) -> Path:
        return self.root / "cache" / kernel / _safe(layer)

    def write_cached(self, layer: str, kernel: str, weights: Dict[str, np.ndarray]):
        d = self._cache_dir(layer, kernel)
        d.mkdir(parents=True, exist_ok=True)
        for k, v in weights.items():
            _save_arr(d / k, v)

    def read_cached(self, layer: str, kernel: str) -> Dict[str, np.ndarray]:
        return _load_dir(self._cache_dir(layer, kernel))

    def has_cached(self, layer: str, kernel: str) -> bool:
        return self._cache_dir(layer, kernel).exists()

    def drop_cached(self, layer: str, kernel: str):
        d = self._cache_dir(layer, kernel)
        if d.exists():
            shutil.rmtree(d)

    def cache_bytes(self) -> int:
        return sum(p.stat().st_size for p in (self.root / "cache").rglob("*.npy"))

    def model_bytes(self) -> int:
        return sum(p.stat().st_size for p in (self.root / "raw").rglob("*.npy"))


# ---------------------------------------------------------------------------
# training-checkpoint pytrees
# ---------------------------------------------------------------------------
def save_pytree(root: Path, tree: Any):
    import jax

    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    index = []
    for i, (path, leaf) in enumerate(flat):
        key = jax.tree_util.keystr(path)
        fname = f"leaf_{i:05d}.npy"
        arr = np.asarray(leaf)
        dtype_str = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in dtype_str:
            # numpy can't round-trip bf16 via .npy: store widened to f32,
            # the recorded dtype restores it on load
            import jax.numpy as jnp

            arr = np.asarray(jnp.asarray(leaf, jnp.float32))
            dtype_str = "bfloat16"
        np.save(root / fname, arr, allow_pickle=False)
        index.append({"key": key, "file": fname, "dtype": dtype_str})
    (root / "index.json").write_text(json.dumps(
        {"leaves": index, "treedef": str(treedef)}, indent=1))


def load_pytree(root: Path, like: Any) -> Any:
    import jax

    root = Path(root)
    flat, treedef = jax.tree_util.tree_flatten(like)
    idx = json.loads((root / "index.json").read_text())["leaves"]
    assert len(idx) == len(flat), (len(idx), len(flat))
    leaves = [np.load(root / e["file"], allow_pickle=False) for e in idx]
    import jax.numpy as jnp

    leaves = [jnp.asarray(l, dtype=f.dtype) for l, f in zip(leaves, flat)]
    return jax.tree_util.tree_unflatten(treedef, leaves)
