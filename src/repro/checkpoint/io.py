"""Checkpoint I/O.

Two facilities:

  * ``LayerStore`` — per-layer weight storage on disk, the cold-inference
    engine's substrate. Raw weights live under ``raw/``; post-transformed
    weights (the paper's §3.1.2 cache) under ``cache/<kernel>/``.

    The default format is the packed single-file *bundle*
    (``checkpoint/bundle.py``): all tensors of a layer in one file with
    64-byte-aligned segments, read back as ONE open + one ``np.memmap``
    (zero-copy, read-only views) instead of N opens + N full copies —
    MNN-style pre-arranged layouts for sequential, cheap cold reads.
    ``fmt="npy"`` keeps the legacy per-tensor ``.npy`` layout (one file
    per tensor, bf16 stored as uint16 views) for format benchmarks and
    the bundle-vs-legacy equivalence tests.

  * pytree checkpointing (``save_pytree``/``load_pytree``) for the training
    loop — flat .npy files keyed by the pytree path.
"""
from __future__ import annotations

import json
import shutil
from pathlib import Path
from typing import Any, Dict, Optional

import numpy as np

from repro.checkpoint.bundle import read_bundle, write_bundle


def _safe(name: str) -> str:
    return name.replace("/", "_")


# ---------------------------------------------------------------------------
# legacy per-tensor .npy layout (fmt="npy")
# ---------------------------------------------------------------------------
def _save_arr(path_base: Path, v: np.ndarray):
    """np.save with bf16 support (stored as uint16 + .bf16.npy suffix —
    numpy cannot round-trip ml_dtypes through .npy)."""
    import ml_dtypes

    v = np.asarray(v)
    if v.dtype == ml_dtypes.bfloat16:
        np.save(path_base.with_suffix(".bf16.npy"), v.view(np.uint16),
                allow_pickle=False)
    else:
        np.save(path_base.with_suffix(".npy"), v, allow_pickle=False)


def _load_dir(d: Path) -> Dict[str, np.ndarray]:
    import ml_dtypes

    out: Dict[str, np.ndarray] = {}
    for p in sorted(d.glob("*.npy")):
        if p.name.endswith(".bf16.npy"):
            out[p.name[: -len(".bf16.npy")]] = np.load(
                p, allow_pickle=False).view(ml_dtypes.bfloat16)
        else:
            out[p.stem] = np.load(p, allow_pickle=False)
    return out


class LayerStore:
    """Per-layer weight store. ``fmt="bundle"`` (default) packs each layer
    into one aligned blob; reads default to zero-copy mmap views
    (``mmap=False`` forces one materializing sequential read)."""

    def __init__(self, root: Path, *, fmt: str = "bundle", mmap: bool = True):
        assert fmt in ("bundle", "npy"), fmt
        self.root = Path(root)
        self.fmt = fmt
        self.mmap = mmap
        (self.root / "raw").mkdir(parents=True, exist_ok=True)
        (self.root / "cache").mkdir(parents=True, exist_ok=True)

    # -- layout -------------------------------------------------------------
    def _raw_path(self, layer: str) -> Path:
        base = self.root / "raw" / _safe(layer)
        # NOT with_suffix: dotted layer names ("block.0") must not collide
        return base.parent / (base.name + ".bundle") if self.fmt == "bundle" else base

    def _cache_path(self, layer: str, kernel: str) -> Path:
        base = self.root / "cache" / kernel / _safe(layer)
        return base.parent / (base.name + ".bundle") if self.fmt == "bundle" else base

    def _write(self, path: Path, weights: Dict[str, np.ndarray]):
        if self.fmt == "bundle":
            path.parent.mkdir(parents=True, exist_ok=True)
            write_bundle(path, weights)
        else:
            path.mkdir(parents=True, exist_ok=True)
            for k, v in weights.items():
                _save_arr(path / k, v)

    def _read(self, path: Path, mmap: Optional[bool]) -> Dict[str, np.ndarray]:
        if not path.exists():
            return {}  # weightless (stateless) layers have no file on disk
        if self.fmt == "bundle":
            use = self.mmap if mmap is None else mmap
            return read_bundle(path, mmap=use)
        return _load_dir(path)

    # -- raw weights --------------------------------------------------------
    def write_raw(self, layer: str, weights: Dict[str, np.ndarray]):
        self._write(self._raw_path(layer), weights)

    def read_raw(self, layer: str, *, mmap: Optional[bool] = None) -> Dict[str, np.ndarray]:
        return self._read(self._raw_path(layer), mmap)

    def raw_bytes(self, layer: str) -> int:
        p = self._raw_path(layer)
        if self.fmt == "bundle":
            return p.stat().st_size if p.exists() else 0
        return sum(q.stat().st_size for q in p.glob("*.npy"))

    # -- post-transformed cache (§3.1.2) ------------------------------------
    def write_cached(self, layer: str, kernel: str, weights: Dict[str, np.ndarray]):
        self._write(self._cache_path(layer, kernel), weights)

    def read_cached(self, layer: str, kernel: str, *,
                    mmap: Optional[bool] = None) -> Dict[str, np.ndarray]:
        return self._read(self._cache_path(layer, kernel), mmap)

    def has_cached(self, layer: str, kernel: str) -> bool:
        return self._cache_path(layer, kernel).exists()

    def drop_cached(self, layer: str, kernel: str):
        p = self._cache_path(layer, kernel)
        if p.is_dir():
            shutil.rmtree(p)
        elif p.exists():
            p.unlink()

    # -- storage accounting (real on-disk footprint) ------------------------
    def cache_bytes(self) -> int:
        return sum(p.stat().st_size
                   for p in (self.root / "cache").rglob("*") if p.is_file())

    def model_bytes(self) -> int:
        return sum(p.stat().st_size
                   for p in (self.root / "raw").rglob("*") if p.is_file())


# ---------------------------------------------------------------------------
# training-checkpoint pytrees
# ---------------------------------------------------------------------------
def save_pytree(root: Path, tree: Any):
    import jax

    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    index = []
    for i, (path, leaf) in enumerate(flat):
        key = jax.tree_util.keystr(path)
        fname = f"leaf_{i:05d}.npy"
        arr = np.asarray(leaf)
        dtype_str = str(arr.dtype)
        if arr.dtype.kind == "V" or "bfloat16" in dtype_str:
            # numpy can't round-trip bf16 via .npy: store widened to f32,
            # the recorded dtype restores it on load
            import jax.numpy as jnp

            arr = np.asarray(jnp.asarray(leaf, jnp.float32))
            dtype_str = "bfloat16"
        np.save(root / fname, arr, allow_pickle=False)
        index.append({"key": key, "file": fname, "dtype": dtype_str})
    (root / "index.json").write_text(json.dumps(
        {"leaves": index, "treedef": str(treedef)}, indent=1))


def load_pytree(root: Path, like: Any) -> Any:
    import jax

    root = Path(root)
    flat, treedef = jax.tree_util.tree_flatten(like)
    idx = json.loads((root / "index.json").read_text())["leaves"]
    assert len(idx) == len(flat), (len(idx), len(flat))
    leaves = [np.load(root / e["file"], allow_pickle=False) for e in idx]
    import jax.numpy as jnp

    leaves = [jnp.asarray(l, dtype=f.dtype) for l, f in zip(leaves, flat)]
    return jax.tree_util.tree_unflatten(treedef, leaves)
