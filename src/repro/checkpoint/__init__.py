from repro.checkpoint.io import LayerStore, save_pytree, load_pytree  # noqa: F401
from repro.checkpoint.bundle import (  # noqa: F401
    atomic_write, bundle_nbytes, read_bundle, read_header, write_bundle,
)
from repro.checkpoint.superbundle import (  # noqa: F401
    SuperBundle, drop_cache_entry, migrate, read_super_header,
    set_cache_entry, write_superbundle,
)
