from repro.checkpoint.io import LayerStore, save_pytree, load_pytree  # noqa: F401
from repro.checkpoint.bundle import (  # noqa: F401
    atomic_write, bundle_nbytes, read_bundle, read_header, write_bundle,
)
from repro.checkpoint.integrity import (  # noqa: F401
    atomic_write_text, crc32c, fsync_dir, fsync_file,
)
from repro.checkpoint.superbundle import (  # noqa: F401
    IntegrityError, SuperBundle, compact, drop_cache_entry, journal_path,
    migrate, read_super_header, recover_journal, set_cache_entry,
    write_superbundle,
)
