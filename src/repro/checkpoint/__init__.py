from repro.checkpoint.io import LayerStore, save_pytree, load_pytree  # noqa: F401
from repro.checkpoint.bundle import (  # noqa: F401
    bundle_nbytes, read_bundle, read_header, write_bundle,
)
