from repro.checkpoint.io import LayerStore, save_pytree, load_pytree  # noqa: F401
