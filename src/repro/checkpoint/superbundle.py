"""Model-level super-bundles — the cold path's v2 on-disk container.

PR 1's per-layer bundles turned N-tensor layer loads into one open *per
layer*; the super-bundle turns a whole model into ONE open + ONE shared
mmap: every layer's tensors — raw weights AND the §3.1.2 post-transformed
per-kernel cache — live in a single file, laid out in plan/graph order so
the exec chain's cold sweep reads the file front to back.

Layout (format version 2)::

    [0:4)     magic  b"NNVS"
    [4:8)     format version (uint32 LE, = 2)
    [8:16)    header length in bytes (uint64 LE)
    [16:16+H) header — UTF-8 JSON:
              {"order":  [layer, ...],          # plan/graph order
               "layers": {layer: {
                   "raw":   [{"name","dtype","shape","offset","nbytes"}],
                   "cache": {kernel: [{same-entry-shape}, ...]}}}}
    ...       zero padding to the first 64-byte boundary; the header
              region carries HEADER_SLACK spare bytes so small metadata
              updates can be committed in place
    segments  tensor payloads, each starting on a 64-byte boundary,
              grouped layer-after-layer in ``order`` (a layer's raw
              tensors and its cache entries are adjacent)

Offsets are absolute from the start of the file. Dtypes are tagged by
name; bfloat16 is stored natively and resolved through ``ml_dtypes`` on
read, exactly as in v1 per-layer bundles.

Reading: ``SuperBundle`` holds the single read-only mmap; ``read_raw`` /
``read_cached`` return zero-copy views into it (``materialize=True``
copies the segment out, paying the page-in cost up front — what a
sequential baseline's "read" op must do). ``advise_willneed`` issues
``madvise(MADV_WILLNEED)`` on the extents of the layers a plan will touch
first, so the kernel readahead runs ahead of the prep pipeline.

Mutation: ``set_cache_entry`` replaces a layer's post-transformed cache
IN PLACE when the new payload fits the existing segment slots and the
updated header fits the header region; otherwise it falls back to
rewrite-on-grow — the whole container is regenerated through the same
``atomic_write`` tmp+rename publish as v1 bundles, so readers never see a
torn file. The in-place fast path is NOT crash-atomic (payload bytes are
written first, header metadata last): a crash mid-write can tear the
entry. It is only ever taken for the §3.1.2 cache — derived data the
engine's decide() re-materializes from raw weights — and raw sections are
only ever published through the atomic rewrite path; a journaled/
checksummed in-place commit is a ROADMAP follow-up. ``drop_cache_entry``
always rewrites, which also compacts the dead segments out. Replacing an
entry in place invalidates views of that entry handed out earlier (they
alias the same pages).

``migrate`` converts a per-layer bundle ``LayerStore`` tree (``raw/
*.bundle`` + ``cache/<kernel>/*.bundle``) into one super-bundle.
"""
from __future__ import annotations

import json
import mmap as mmap_mod
import struct
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint.bundle import (
    ALIGN, _HEADER_FIXED, _HEADER_FMT, _dtype_from_tag, _dtype_tag, _pad_to,
    atomic_write, read_bundle,
)

MAGIC = b"NNVS"
VERSION = 2
# spare header bytes so in-place cache replacement survives small metadata
# growth (shape/nbytes digit changes) without forcing a rewrite
HEADER_SLACK = 256

LayerWeights = Dict[str, np.ndarray]


def _payload(weights: LayerWeights) -> Tuple[List[dict], List[np.ndarray]]:
    """Name-sorted (header entries, contiguous arrays) for one section."""
    entries: List[dict] = []
    arrs: List[np.ndarray] = []
    for name in sorted(weights):
        a = np.ascontiguousarray(np.asarray(weights[name]))
        entries.append({"name": name, "dtype": _dtype_tag(a.dtype),
                        "shape": list(a.shape), "nbytes": int(a.nbytes)})
        arrs.append(a)
    return entries, arrs


def write_superbundle(
    path: Path,
    raw: Dict[str, LayerWeights],
    cache: Optional[Dict[str, Dict[str, LayerWeights]]] = None,
    order: Optional[Sequence[str]] = None,
) -> int:
    """Write the whole model as one super-bundle (atomic tmp+rename).
    ``order`` fixes the on-disk layer layout (plan/graph order); layers
    not listed are appended. Returns the total file size in bytes."""
    path = Path(path)
    cache = cache or {}
    order = list(order) if order is not None else list(raw)
    order += [l for l in raw if l not in order]
    order += sorted(set(cache) - set(order))

    layers_hdr: Dict[str, dict] = {}
    flat: List[Tuple[dict, np.ndarray]] = []
    for layer in order:
        ent_raw, arrs = _payload(raw.get(layer, {}))
        sect = {"raw": ent_raw, "cache": {}}
        flat += list(zip(ent_raw, arrs))
        for kern in sorted(cache.get(layer, {})):
            ent_c, arrs_c = _payload(cache[layer][kern])
            sect["cache"][kern] = ent_c
            flat += list(zip(ent_c, arrs_c))
        layers_hdr[layer] = sect
    header = {"order": order, "layers": layers_hdr}

    # offsets depend on the header length which depends on the offsets'
    # digit count — fixed-point iterate, as in the v1 bundle writer
    for _ in range(8):
        hdr_bytes = json.dumps(header, separators=(",", ":")).encode()
        off = _pad_to(_HEADER_FIXED + len(hdr_bytes) + HEADER_SLACK)
        changed = False
        for e, _a in flat:
            if e.get("offset") != off:
                e["offset"] = off
                changed = True
            off = _pad_to(off + e["nbytes"])
        if not changed:
            break
    else:
        raise RuntimeError(
            f"super-bundle header layout did not converge: {path}")
    total = off

    def _emit(f):
        f.write(struct.pack(_HEADER_FMT, MAGIC, VERSION, len(hdr_bytes)))
        f.write(hdr_bytes)
        for e, a in flat:
            f.write(b"\0" * (e["offset"] - f.tell()))
            f.write(a.tobytes())
        f.write(b"\0" * (total - f.tell()))

    atomic_write(path, _emit)
    return total


def _parse_super_header(buf) -> dict:
    magic, version, hlen = struct.unpack_from(_HEADER_FMT, buf, 0)
    if magic != MAGIC:
        raise ValueError(f"not a super-bundle (magic={magic!r})")
    if version > VERSION:
        raise ValueError(f"super-bundle version {version} > {VERSION}")
    return json.loads(bytes(buf[_HEADER_FIXED:_HEADER_FIXED + hlen]).decode())


def read_super_header(path: Path) -> dict:
    with open(path, "rb") as f:
        magic, version, hlen = struct.unpack(
            _HEADER_FMT, f.read(_HEADER_FIXED))
        if magic != MAGIC:
            raise ValueError(f"{path}: not a super-bundle (magic={magic!r})")
        if version > VERSION:
            raise ValueError(
                f"{path}: super-bundle version {version} > {VERSION}")
        return json.loads(f.read(hlen).decode())


class SuperBundle:
    """ONE open + ONE shared read-only mmap for a whole model; every
    ``read_raw``/``read_cached`` is a dict of zero-copy views into it."""

    def __init__(self, path: Path):
        self.path = Path(path)
        with open(self.path, "rb") as f:
            self._mm = mmap_mod.mmap(f.fileno(), 0,
                                     access=mmap_mod.ACCESS_READ)
        self._buf = np.frombuffer(self._mm, dtype=np.uint8)
        self.header = _parse_super_header(self._buf)
        self.order: List[str] = list(self.header["order"])
        self._layers: Dict[str, dict] = self.header["layers"]

    # -- lifecycle ----------------------------------------------------------
    def close(self):
        self._buf = None
        try:
            self._mm.close()
        except BufferError:
            pass  # live views pin the map; the GC reclaims it with them

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- introspection ------------------------------------------------------
    def has_raw(self, layer: str) -> bool:
        return layer in self._layers

    def has_cached(self, layer: str, kernel: str) -> bool:
        return kernel in self._layers.get(layer, {}).get("cache", {})

    def kernels_cached(self, layer: str) -> List[str]:
        return list(self._layers.get(layer, {}).get("cache", {}))

    def _all_entries(self, layer: str) -> List[dict]:
        sect = self._layers.get(layer)
        if sect is None:
            return []
        out = list(sect["raw"])
        for ents in sect.get("cache", {}).values():
            out += ents
        return out

    def extent(self, layer: str) -> Optional[Tuple[int, int]]:
        """Byte range covering all of a layer's segments (raw + cache)."""
        ents = self._all_entries(layer)
        if not ents:
            return None
        return (min(e["offset"] for e in ents),
                max(e["offset"] + e["nbytes"] for e in ents))

    # -- reads --------------------------------------------------------------
    def _views(self, entries: List[dict], materialize: bool) -> LayerWeights:
        out: LayerWeights = {}
        for e in entries:
            seg = self._buf[e["offset"]: e["offset"] + e["nbytes"]]
            v = seg.view(_dtype_from_tag(e["dtype"])).reshape(e["shape"])
            out[e["name"]] = np.array(v) if materialize else v
        return out

    def read_raw(self, layer: str, *, materialize: bool = False) -> LayerWeights:
        sect = self._layers.get(layer)
        return self._views(sect["raw"], materialize) if sect else {}

    def read_cached(self, layer: str, kernel: str, *,
                    materialize: bool = False) -> LayerWeights:
        ents = self._layers.get(layer, {}).get("cache", {}).get(kernel)
        return self._views(ents, materialize) if ents is not None else {}

    # -- readahead ----------------------------------------------------------
    def advise_willneed(self, layers: Optional[Sequence[str]] = None) -> int:
        """``madvise(MADV_WILLNEED)`` the extents of the given layers (the
        first-k of the plan) so the kernel prefetches ahead of the prep
        pipeline. Returns the number of layers hinted (0 where madvise is
        unavailable)."""
        if not hasattr(self._mm, "madvise"):
            return 0
        page = mmap_mod.PAGESIZE
        hinted = 0
        for layer in (self.order if layers is None else layers):
            ext = self.extent(layer)
            if ext is None:
                continue
            lo = ext[0] // page * page
            try:
                self._mm.madvise(mmap_mod.MADV_WILLNEED, lo, ext[1] - lo)
                hinted += 1
            except (ValueError, OSError):
                pass
        return hinted

    # -- payload accounting --------------------------------------------------
    def raw_nbytes(self, layer: Optional[str] = None) -> int:
        layers = [layer] if layer is not None else self.order
        return sum(e["nbytes"] for l in layers
                   for e in self._layers.get(l, {"raw": []})["raw"])

    def cache_nbytes(self) -> int:
        return sum(e["nbytes"] for l in self.order
                   for ents in self._layers[l].get("cache", {}).values()
                   for e in ents)

    # -- on-disk accounting ---------------------------------------------------
    def file_size(self) -> int:
        return len(self._buf)

    def cache_disk_bytes(self) -> int:
        """Disk bytes the cache sections occupy (padded 64-byte slots), so
        ``model + cache`` accounting sums to the real file size."""
        return sum(_pad_to(e["nbytes"]) for l in self.order
                   for ents in self._layers[l].get("cache", {}).values()
                   for e in ents)


# ---------------------------------------------------------------------------
# mutation: in-place cache replace / rewrite-on-grow / drop
# ---------------------------------------------------------------------------
def _load_all(sb: SuperBundle):
    raw = {l: sb.read_raw(l) for l in sb.order}
    cache = {l: {k: sb.read_cached(l, k) for k in sb.kernels_cached(l)}
             for l in sb.order}
    return raw, cache


def _slot_sizes(sb: SuperBundle) -> Dict[int, int]:
    """id(entry) -> writable slot size (distance to the next segment or to
    EOF) — how far an in-place replacement may grow without moving data."""
    all_e = sorted((e for l in sb.order for e in sb._all_entries(l)),
                   key=lambda e: e["offset"])
    size = len(sb._buf)
    slots: Dict[int, int] = {}
    for e, nxt in zip(all_e, all_e[1:] + [None]):
        end = nxt["offset"] if nxt is not None else size
        slots[id(e)] = end - e["offset"]
    return slots


def _try_inplace(path: Path, sb: SuperBundle, layer: str, kernel: str,
                 entries_new: List[dict], arrs: List[np.ndarray]) -> bool:
    old = sb._layers[layer]["cache"][kernel]
    if [e["name"] for e in old] != [e["name"] for e in entries_new]:
        return False
    slots = _slot_sizes(sb)
    if any(en["nbytes"] > slots[id(eo)] for eo, en in zip(old, entries_new)):
        return False
    # candidate header on a deep copy — sb.header must stay untouched unless
    # the in-place path actually commits
    hdr = json.loads(json.dumps(sb.header))
    for eo, en in zip(hdr["layers"][layer]["cache"][kernel], entries_new):
        eo.update(dtype=en["dtype"], shape=en["shape"], nbytes=en["nbytes"])
    hdr_bytes = json.dumps(hdr, separators=(",", ":")).encode()
    first_off = min(e["offset"] for l in sb.order for e in sb._all_entries(l))
    if _HEADER_FIXED + len(hdr_bytes) > first_off:
        return False
    offsets = [e["offset"] for e in old]
    with open(path, "r+b") as f:
        for off, a in zip(offsets, arrs):
            f.seek(off)
            f.write(a.tobytes())
        f.seek(0)
        f.write(struct.pack(_HEADER_FMT, MAGIC, VERSION, len(hdr_bytes)))
        f.write(hdr_bytes)
        f.write(b"\0" * (first_off - _HEADER_FIXED - len(hdr_bytes)))
    return True


def set_cache_entry(path: Path, layer: str, kernel: str,
                    weights: LayerWeights) -> str:
    """Append/replace one layer's post-transformed cache entry. In-place
    when the payload fits the existing slots and the header region; else
    rewrite-on-grow (atomic tmp+rename). Returns ``"inplace"`` or
    ``"rewrite"``."""
    path = Path(path)
    entries_new, arrs = _payload(weights)
    with SuperBundle(path) as sb:
        if (sb.has_cached(layer, kernel)
                and _try_inplace(path, sb, layer, kernel, entries_new, arrs)):
            return "inplace"
        raw, cache = _load_all(sb)
        order = list(sb.order)
        if layer not in order:
            order.append(layer)
            raw.setdefault(layer, {})
        cache.setdefault(layer, {})[kernel] = dict(
            zip([e["name"] for e in entries_new], arrs))
        write_superbundle(path, raw, cache, order=order)
    return "rewrite"


def drop_cache_entry(path: Path, layer: str, kernel: str) -> bool:
    """Remove a cache entry; rewrites (and thereby compacts) the file.
    Returns whether the entry existed."""
    path = Path(path)
    with SuperBundle(path) as sb:
        if not sb.has_cached(layer, kernel):
            return False
        raw, cache = _load_all(sb)
        del cache[layer][kernel]
        write_superbundle(path, raw, cache, order=sb.order)
    return True


# ---------------------------------------------------------------------------
# migration: per-layer bundle LayerStore tree -> one super-bundle
# ---------------------------------------------------------------------------
def migrate(src_root: Path, dest: Path,
            order: Optional[Sequence[str]] = None) -> Path:
    """Convert a per-layer bundle store (``raw/*.bundle`` +
    ``cache/<kernel>/*.bundle``) into one super-bundle at ``dest`` (a file
    path, or a directory that receives ``model.superbundle``). Layer names
    are recovered from bundle file stems — names whose ``/`` was flattened
    to ``_`` on write stay flattened."""
    src = Path(src_root)
    dest = Path(dest)
    if dest.is_dir():
        dest = dest / "model.superbundle"
    raw: Dict[str, LayerWeights] = {}
    for p in sorted((src / "raw").glob("*.bundle")):
        raw[p.name[: -len(".bundle")]] = read_bundle(p, mmap=True)
    cache: Dict[str, Dict[str, LayerWeights]] = {}
    cdir = src / "cache"
    if cdir.exists():
        for kdir in sorted(d for d in cdir.iterdir() if d.is_dir()):
            for p in sorted(kdir.glob("*.bundle")):
                layer = p.name[: -len(".bundle")]
                cache.setdefault(layer, {})[kdir.name] = read_bundle(
                    p, mmap=True)
    write_superbundle(dest, raw, cache, order=order)
    return dest
