"""Model-level super-bundles — the cold path's on-disk container (format v4).

PR 1's per-layer bundles turned N-tensor layer loads into one open *per
layer*; the super-bundle turns a whole model into ONE open + ONE shared
mmap: every layer's tensors — raw weights AND the §3.1.2 post-transformed
per-kernel cache — live in a single file, laid out in plan/graph order so
the exec chain's cold sweep reads the file front to back.

Layout (format version 4; the full byte-level specification of v1–v4
lives in ``docs/formats.md``)::

    [0:4)     magic  b"NNVS"
    [4:8)     format version (uint32 LE, = 4)
    [8:16)    header length in bytes (uint64 LE)
    [16:20)   CRC-32C of the header JSON (uint32 LE)   [v3+]
    [20:20+H) header — UTF-8 JSON:
              {"generation": n,                 # bumped by every rewrite
               "order":  [layer, ...],          # plan/graph order
               "layers": {layer: {
                   "raw":   [{"name","dtype","shape","offset","nbytes",
                              "crc32c", "quant"?}],
                   "cache": {kernel: [{same-entry-shape}, ...]}}}}
    ...       zero padding to the first 64-byte boundary; the header
              region carries HEADER_SLACK spare bytes so metadata
              updates can be committed in place
    segments  tensor payloads, each starting on a 64-byte boundary,
              grouped layer-after-layer in ``order`` (a layer's raw
              tensors and its cache entries are adjacent)

Offsets are absolute from the start of the file. Dtypes are tagged by
name; bfloat16 is stored natively and resolved through ``ml_dtypes`` on
read. Version-2 files (no checksums, no generation, header JSON at byte
16) and v3 files (no quantized extents) still open read-only; any rewrite
or in-place commit upgrades them to v4.

Quantized cache extents (format v4): a weight dict written under the
``repro.quant`` companion-key convention (``w:q8``/``w:q4`` +
``w:qscale`` [+ ``w:qzero``]) FOLDS into ONE extent per tensor — entry
``name`` is the base tensor name, ``dtype`` is the scheme tag (``int8``
or ``int4``), the payload is exactly the quantized bytes (CRC-32C over
them), and the entry's ``"quant"`` metadata carries the per-channel
scales/zero-points inline in the header. Reads EXPAND the extent back to
the identical companion dict, so fold → write → read → refold is
bit-exact through rewrites and journal replay, and every durability path
(intent journal, torn-slot resolution, lazy/eager verification, async
``submit_read`` audits) treats quantized extents as ordinary
checksum-protected slots. ``int4`` payloads are nibble-packed uint8 of
shape ``((K+1)//2, N)``; consumers recover the logical K from the layer
spec.

Reading: ``SuperBundle`` holds the single read-only mmap; ``read_raw`` /
``read_cached`` return zero-copy views into it (``materialize=True``
copies the segment out, paying the page-in cost up front — what a
sequential baseline's "read" op must do). ``advise_willneed`` issues
``madvise(MADV_WILLNEED)`` on the extents of the layers a plan will touch
first, so the kernel readahead runs ahead of the prep pipeline.

Durability: in-place cache commits are CRASH-ATOMIC. Every in-place
mutation is preceded by an append-only intent journal record
(``<model>.sbj``, fsynced ahead of any container write) that carries the
slot offsets/lengths/CRC-32Cs of the new payload plus the full new header
bytes. Opening a ``SuperBundle`` replays the journal first
(``recover_journal``): a fully-applied-but-uncommitted transaction is
rolled forward, an untouched one rolls back to the intact old entry, and
a genuinely torn entry is detected by checksum, dropped from the header
(never served — the engine re-materializes it from raw weights), and
reported in ``SuperBundle.dropped``. Raw sections are only ever published
through the atomic tmp+rename rewrite, so raw weights always survive.

Verification: the ``verify`` knob ("never" | "lazy" | "eager") controls
checksum auditing beyond journal recovery. "lazy" (default) verifies an
entry the first time its bytes are *materialized* — zero-copy mmap views
are served unverified, since faulting every page in to checksum it is
exactly the work the mmap path exists to avoid, and crash tears are
already impossible after recovery. "eager" checksums every extent at
open (corrupt cache entries are dropped, corrupt raw raises
``IntegrityError``) — the fsck mode for detecting latent bit-rot.

Space: ``drop_cache_entry`` now just unlinks the entry from the header
(an in-place journaled commit), leaving a dead extent; ``compact``
rewrites the live contents into a fresh container via the same atomic
tmp+rename, reclaiming every dead extent (``reclaimable_bytes`` says how
many bytes that would recover). The engine runs it as the
``LayerStore.maintain()`` hook after ``decide()``.

``migrate`` converts a per-layer bundle ``LayerStore`` tree (``raw/
*.bundle`` + ``cache/<kernel>/*.bundle``) into one super-bundle.
"""
from __future__ import annotations

import base64
import json
import mmap as mmap_mod
import os
import struct
from pathlib import Path
from typing import Callable, Dict, List, Optional, Sequence, Set, Tuple

import numpy as np

from repro.checkpoint.bundle import (
    ALIGN, _HEADER_FIXED, _HEADER_FMT, _dtype_from_tag, _dtype_tag, _pad_to,
    atomic_write, read_bundle,
)
from repro.checkpoint.integrity import crc32c, fsync_file
from repro.faults import IntegrityFault
from repro import quant

MAGIC = b"NNVS"
# v4 adds quantized cache extents (folded int8/int4 payloads + header
# "quant" metadata); the fixed prefix is identical to v3, so v3 readers of
# this module's lineage reject v4 by version, not by parse failure
VERSION = 4
# v3+ fixed prefix: magic, version, header length, header CRC-32C
_V3_FIXED_FMT = "<4sIQI"
_V3_FIXED = struct.calcsize(_V3_FIXED_FMT)
# spare header bytes so in-place cache replacement survives small metadata
# growth (shape/nbytes/crc digit changes) without forcing a rewrite
HEADER_SLACK = 256

JOURNAL_SUFFIX = ".sbj"
_JOURNAL_MAGIC = b"SBJ1"
# journal layout per record: magic(4) type(1) payload_len(u32) payload crc(u32)
_JOURNAL_PREFIX = len(_JOURNAL_MAGIC) + 1 + 4
# a clean journal above this size is truncated after the next commit
_JOURNAL_RESET_BYTES = 256 * 1024

LayerWeights = Dict[str, np.ndarray]

# test hook: called at commit phases with context kwargs; a hook that raises
# InjectedCrash simulates power loss mid-commit (nothing in this module
# catches it, exactly like a real crash)
_crash_hook: Optional[Callable[..., None]] = None


class InjectedCrash(BaseException):
    """Raised by crash-injection hooks; derives from BaseException so no
    in-process cleanup path swallows it."""


class IntegrityError(IntegrityFault, ValueError):
    """A checksum-protected region failed verification. Part of the typed
    fault taxonomy (a PermanentFault — retrying re-reads the same bad
    bytes); still a ValueError for pre-taxonomy callers."""


def _hook(phase: str, **ctx):
    if _crash_hook is not None:
        _crash_hook(phase, **ctx)


def _payload(weights: LayerWeights) -> Tuple[List[dict], List[np.ndarray]]:
    """Name-sorted (header entries, contiguous arrays) for one section.

    Format v4 fold point: a quantized companion group (``w:q8``/``w:q4`` +
    ``w:qscale`` [+ ``w:qzero``]) becomes ONE extent named after the base
    tensor — the payload is exactly the quantized bytes (CRC over them),
    the dtype tag is the scheme (``int8``/``int4``), ``shape`` is the
    STORED payload shape (packed, for int4), and the scales/zero-points
    ride in the entry's ``"quant"`` metadata."""
    groups, rest = quant.split_groups(weights)
    entries: List[dict] = []
    arrs: List[np.ndarray] = []
    for name in sorted(set(rest) | set(groups)):
        if name in groups:
            g = groups[name]
            a = np.ascontiguousarray(np.asarray(g["data"]))
            entries.append({"name": name, "dtype": g["scheme"],
                            "shape": list(a.shape), "nbytes": int(a.nbytes),
                            "crc32c": crc32c(a),
                            "quant": quant.quant_meta(g)})
        else:
            a = np.ascontiguousarray(np.asarray(rest[name]))
            entries.append({"name": name, "dtype": _dtype_tag(a.dtype),
                            "shape": list(a.shape), "nbytes": int(a.nbytes),
                            "crc32c": crc32c(a)})
        arrs.append(a)
    return entries, arrs


def journal_path(path: Path) -> Path:
    """The container's intent journal (``model.superbundle`` → ``model.sbj``)."""
    path = Path(path)
    return path.with_suffix(JOURNAL_SUFFIX)


def _next_generation(path: Path) -> int:
    """Generation for a rewrite of ``path``: strictly past the existing
    container's AND past every journal record's, so no stale journal record
    can ever be replayed against the new file — even when the old header is
    torn and unreadable."""
    path = Path(path)
    gen = 0
    try:
        gen = int(read_super_header(path).get("generation", 0)) + 1
    except FileNotFoundError:
        return 0
    except (ValueError, OSError):
        pass  # torn/unreadable old header: fall back to the journal scan
    return max(gen, 1 + max((p.get("gen", 0) for _t, p in
                             _journal_records(journal_path(path))),
                            default=-1))


def write_superbundle(
    path: Path,
    raw: Dict[str, LayerWeights],
    cache: Optional[Dict[str, Dict[str, LayerWeights]]] = None,
    order: Optional[Sequence[str]] = None,
    generation: Optional[int] = None,
) -> int:
    """Write the whole model as one super-bundle (atomic tmp+rename, fsynced).
    ``order`` fixes the on-disk layer layout (plan/graph order); layers
    not listed are appended. ``generation`` stamps the container identity;
    the default derives one strictly past the file being replaced (and its
    journal), so stale journal records can never be replayed against the
    new file. Returns the total file size."""
    path = Path(path)
    if generation is None:
        generation = _next_generation(path)
    cache = cache or {}
    order = list(order) if order is not None else list(raw)
    order += [l for l in raw if l not in order]
    order += sorted(set(cache) - set(order))

    layers_hdr: Dict[str, dict] = {}
    flat: List[Tuple[dict, np.ndarray]] = []
    for layer in order:
        ent_raw, arrs = _payload(raw.get(layer, {}))
        sect = {"raw": ent_raw, "cache": {}}
        flat += list(zip(ent_raw, arrs))
        for kern in sorted(cache.get(layer, {})):
            ent_c, arrs_c = _payload(cache[layer][kern])
            sect["cache"][kern] = ent_c
            flat += list(zip(ent_c, arrs_c))
        layers_hdr[layer] = sect
    header = {"generation": int(generation), "order": order,
              "layers": layers_hdr}

    # offsets depend on the header length which depends on the offsets'
    # digit count — fixed-point iterate, as in the v1 bundle writer
    for _ in range(8):
        hdr_bytes = json.dumps(header, separators=(",", ":")).encode()
        off = _pad_to(_V3_FIXED + len(hdr_bytes) + HEADER_SLACK)
        changed = False
        for e, _a in flat:
            if e.get("offset") != off:
                e["offset"] = off
                changed = True
            off = _pad_to(off + e["nbytes"])
        if not changed:
            break
    else:
        raise RuntimeError(
            f"super-bundle header layout did not converge: {path}")
    total = off

    def _emit(f):
        f.write(struct.pack(_V3_FIXED_FMT, MAGIC, VERSION, len(hdr_bytes),
                            crc32c(hdr_bytes)))
        f.write(hdr_bytes)
        for e, a in flat:
            f.write(b"\0" * (e["offset"] - f.tell()))
            f.write(a.tobytes())
        f.write(b"\0" * (total - f.tell()))

    atomic_write(path, _emit, durable=True)
    # the rewrite published a complete container under a new generation:
    # journal records targeting the old file must never be replayed
    _journal_reset(journal_path(path))
    return total


# ---------------------------------------------------------------------------
# header parsing — ONE validation helper shared by every entry point
# ---------------------------------------------------------------------------
def _check_magic_version(magic: bytes, version: int, src) -> None:
    if magic != MAGIC:
        raise ValueError(f"{src}: not a super-bundle (magic={magic!r})")
    if version > VERSION:
        raise ValueError(
            f"{src}: super-bundle format version {version} is newer than "
            f"the supported version {VERSION}")


def _parse_super_header(buf, src="<buffer>") -> Tuple[dict, int, int]:
    """Validate + parse a super-bundle header out of a bytes-like buffer.
    Returns ``(header, version, header_json_len)``; v3 headers are checksum
    verified (a torn in-place header write raises ``IntegrityError``)."""
    view = memoryview(buf)
    if len(view) < _HEADER_FIXED:
        raise ValueError(f"{src}: truncated super-bundle header")
    magic, version, hlen = struct.unpack_from(_HEADER_FMT, view, 0)
    _check_magic_version(magic, version, src)
    start = _V3_FIXED if version >= 3 else _HEADER_FIXED
    if start + hlen > len(view):
        raise ValueError(f"{src}: truncated super-bundle header")
    raw = bytes(view[start:start + hlen])
    if version >= 3:
        (hcrc,) = struct.unpack_from("<I", view, _HEADER_FIXED)
        if crc32c(raw) != hcrc:
            raise IntegrityError(
                f"{src}: super-bundle header checksum mismatch")
    return json.loads(raw.decode()), version, hlen


def _header_from_file(f, src) -> Tuple[dict, int, bytes]:
    """Read + parse the header from an open file via the shared validator.
    Returns ``(header, version, raw_header_json_bytes)``."""
    f.seek(0, os.SEEK_END)
    size = f.tell()
    f.seek(0)
    pre = f.read(_V3_FIXED)
    if len(pre) < _HEADER_FIXED:
        raise ValueError(f"{src}: truncated super-bundle header")
    magic, version, hlen = struct.unpack_from(_HEADER_FMT, pre, 0)
    _check_magic_version(magic, version, src)
    start = _V3_FIXED if version >= 3 else _HEADER_FIXED
    if start + hlen > size:  # also guards garbage hlen in a torn v3 header
        raise ValueError(f"{src}: truncated super-bundle header")
    buf = pre + f.read(start + hlen - len(pre))
    hdr, ver, _hlen = _parse_super_header(buf, src)
    return hdr, ver, buf[start:start + hlen]


def read_super_header(path: Path) -> dict:
    """Parse a container's header (pure read: no journal recovery)."""
    path = Path(path)
    with open(path, "rb") as f:
        hdr, _version, _raw = _header_from_file(f, path)
    return hdr


def _write_header_inplace(f, hdr_bytes: bytes) -> None:
    """Overwrite the header region (fixed prefix + JSON) and fsync. Only
    called with headers known to fit ahead of the first data segment."""
    f.seek(0)
    f.write(struct.pack(_V3_FIXED_FMT, MAGIC, VERSION, len(hdr_bytes),
                        crc32c(hdr_bytes)))
    f.write(hdr_bytes)
    fsync_file(f)


# ---------------------------------------------------------------------------
# intent journal — append-only, fsync-ordered ahead of in-place writes
# ---------------------------------------------------------------------------
def _journal_records(jp: Path) -> List[Tuple[bytes, dict]]:
    """All valid ``(type, payload)`` records; scanning stops at the first
    torn/garbled record (a crash mid-append only ever tears the tail)."""
    try:
        data = jp.read_bytes()
    except FileNotFoundError:
        return []
    recs: List[Tuple[bytes, dict]] = []
    off = 0
    while off + _JOURNAL_PREFIX + 4 <= len(data):
        if data[off:off + 4] != _JOURNAL_MAGIC:
            break
        rtype = data[off + 4:off + 5]
        (plen,) = struct.unpack_from("<I", data, off + 5)
        end = off + _JOURNAL_PREFIX + plen + 4
        if rtype not in (b"B", b"C") or end > len(data):
            break
        (crc,) = struct.unpack_from("<I", data, off + _JOURNAL_PREFIX + plen)
        body = data[off:off + _JOURNAL_PREFIX + plen]
        if crc32c(body) != crc:
            break
        try:
            payload = json.loads(
                body[_JOURNAL_PREFIX:].decode())
        except ValueError:
            break
        recs.append((rtype, payload))
        off = end
    return recs


def _journal_append(jp: Path, rtype: bytes, payload: dict, *,
                    sync: bool) -> None:
    body = json.dumps(payload, separators=(",", ":")).encode()
    rec = _JOURNAL_MAGIC + rtype + struct.pack("<I", len(body)) + body
    rec += struct.pack("<I", crc32c(rec))
    with open(jp, "ab") as f:
        f.write(rec)
        if sync:
            fsync_file(f)


def _journal_reset(jp: Path) -> None:
    if jp.exists():
        with open(jp, "r+b") as f:
            f.truncate(0)
            fsync_file(f)


def _next_txn(jp: Path) -> int:
    return 1 + max((p.get("txn", 0) for _t, p in _journal_records(jp)),
                   default=0)


def _extent_ok(f, e: dict) -> bool:
    f.seek(e["offset"])
    return crc32c(f.read(e["nbytes"])) == e["crc32c"]


def _record_entries(rec: dict) -> List[dict]:
    """Normalize a BEGIN record to its per-entry view. Batched records carry
    ``entries=[{"layer","kernel","slots"}, ...]``; legacy single-entry
    records carry top-level ``layer``/``kernel``/``slots``."""
    ents = rec.get("entries")
    if ents:
        return ents
    return [{"layer": rec["layer"], "kernel": rec["kernel"],
             "slots": rec.get("slots", [])}]


def _resolve_txn(path: Path, rec: dict) -> List[dict]:
    """Resolve one un-committed BEGIN record against the container: roll
    forward if the new data fully landed, keep old entries where nothing was
    overwritten, otherwise drop exactly the torn entries from the header.
    A record may cover several cache entries (one batched transaction);
    resolution is per-entry. Returns reports of dropped entries."""
    hdr_new = base64.b64decode(rec["header"]["b64"])
    entries = _record_entries(rec)
    all_slots = [s for ent in entries for s in ent["slots"]]
    dropped: List[dict] = []
    with open(path, "r+b") as f:
        cur_hdr: Optional[dict] = None
        cur_raw: Optional[bytes] = None
        try:
            cur_hdr, _ver, cur_raw = _header_from_file(f, path)
        except ValueError:  # torn header (IntegrityError included)
            pass
        if (cur_hdr is not None
                and int(cur_hdr.get("generation", 0)) != rec.get("gen")):
            return []  # stale record from a superseded container: ignore
        if all(_extent_ok(f, s) for s in all_slots):
            # data fully applied — roll forward (restore the new header if
            # the crash tore it or hit before it was written)
            if cur_raw != hdr_new:
                _write_header_inplace(f, hdr_new)
            return []
        if cur_raw is not None and cur_raw != hdr_new:
            # old header still current — every entry whose old bytes verify
            # was not overwritten and survives under the old header; entries
            # whose old extents fail were partially clobbered and are torn
            base = cur_hdr
            torn = []
            for ent in entries:
                old = (cur_hdr["layers"].get(ent["layer"], {})
                       .get("cache", {}).get(ent["kernel"]))
                if old is not None and all(
                        "crc32c" in e and _extent_ok(f, e) for e in old):
                    continue
                torn.append(ent)
            if not torn:
                return []  # pure rollback, all old entries intact
        else:
            # header already (or restored to) the new one: keep entries
            # whose NEW slots fully landed; the rest are torn
            base = json.loads(hdr_new.decode())
            torn = [ent for ent in entries
                    if not all(_extent_ok(f, s) for s in ent["slots"])]
        for ent in torn:
            base["layers"].get(ent["layer"], {}).get("cache", {}).pop(
                ent["kernel"], None)
            dropped.append({"layer": ent["layer"], "kernel": ent["kernel"],
                            "reason": "torn in-place commit rolled back"})
        _write_header_inplace(
            f, json.dumps(base, separators=(",", ":")).encode())
    return dropped


def recover_journal(path: Path) -> List[dict]:
    """Replay/roll back the container's intent journal. Runs automatically
    when a ``SuperBundle`` opens; idempotent; truncates the journal once the
    container is consistent. Returns reports of entries that had to be
    dropped (``[{"layer", "kernel", "reason"}, ...]``)."""
    path = Path(path)
    jp = journal_path(path)
    try:
        if jp.stat().st_size == 0:
            return []
    except FileNotFoundError:
        return []
    recs = _journal_records(jp)
    committed = {p.get("txn") for t, p in recs if t == b"C"}
    dropped: List[dict] = []
    if path.exists():
        for rtype, payload in recs:
            if rtype == b"B" and payload.get("txn") not in committed:
                dropped += _resolve_txn(path, payload)
    _journal_reset(jp)
    return dropped


class SuperBundle:
    """ONE open + ONE shared read-only mmap for a whole model; every
    ``read_raw``/``read_cached`` is a dict of zero-copy views into it.

    Opening replays the intent journal (crash recovery) unless
    ``recover=False``; ``verify`` selects the checksum-audit mode (see the
    module docstring). Entries dropped by recovery or verification are
    reported in ``self.dropped``."""

    def __init__(self, path: Path, *, verify: str = "lazy",
                 recover: bool = True):
        if verify not in ("never", "lazy", "eager"):
            raise ValueError(f"verify must be never|lazy|eager, got {verify}")
        self.path = Path(path)
        self.verify = verify
        self.dropped: List[dict] = []
        # extent bytes served through _views / async waits since open — the
        # measured-cold-bytes counter the benchmarks snapshot around a run
        self.bytes_served = 0
        if recover:
            self.dropped += recover_journal(self.path)
        with open(self.path, "rb") as f:
            self._mm = mmap_mod.mmap(f.fileno(), 0,
                                     access=mmap_mod.ACCESS_READ)
        self._buf = np.frombuffer(self._mm, dtype=np.uint8)
        # separate fd for the async engine's extent preads: the shared
        # mmap stays the sequential-baseline/profiler path, the engine
        # reads the same extents at queue depth through this descriptor
        self._fd: Optional[int] = os.open(self.path, os.O_RDONLY)
        self.last_readahead: Optional[dict] = None
        self.header, self.version, self._hlen = _parse_super_header(
            self._buf, src=self.path)
        self.generation = int(self.header.get("generation", 0))
        self.order: List[str] = list(self.header["order"])
        self._layers: Dict[str, dict] = self.header["layers"]
        self._verified: Set[int] = set()  # id(entry) of checksum-ok entries
        if verify == "eager":
            try:
                self._verify_all()
            except BaseException:
                self.close()
                raise

    # -- lifecycle ----------------------------------------------------------
    def close(self):
        self._buf = None
        try:
            self._mm.close()
        except BufferError:
            pass  # live views pin the map; the GC reclaims it with them
        if self._fd is not None:
            os.close(self._fd)
            self._fd = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # -- introspection ------------------------------------------------------
    def has_raw(self, layer: str) -> bool:
        return layer in self._layers

    def has_cached(self, layer: str, kernel: str) -> bool:
        return kernel in self._layers.get(layer, {}).get("cache", {})

    def kernels_cached(self, layer: str) -> List[str]:
        return list(self._layers.get(layer, {}).get("cache", {}))

    def _all_entries(self, layer: str) -> List[dict]:
        sect = self._layers.get(layer)
        if sect is None:
            return []
        out = list(sect["raw"])
        for ents in sect.get("cache", {}).values():
            out += ents
        return out

    def extent(self, layer: str) -> Optional[Tuple[int, int]]:
        """Byte range covering all of a layer's segments (raw + cache)."""
        ents = self._all_entries(layer)
        if not ents:
            return None
        return (min(e["offset"] for e in ents),
                max(e["offset"] + e["nbytes"] for e in ents))

    # -- verification -------------------------------------------------------
    def _entry_ok(self, e: dict) -> bool:
        if "crc32c" not in e:
            return True  # v2 entry: nothing recorded to verify against
        seg = self._buf[e["offset"]: e["offset"] + e["nbytes"]]
        return crc32c(seg) == e["crc32c"]

    def _verify_raw(self, layer: str, entries: List[dict]) -> None:
        for e in entries:
            if id(e) in self._verified:
                continue
            if not self._entry_ok(e):
                raise IntegrityError(
                    f"{self.path}: raw tensor {layer}/{e['name']} failed "
                    "checksum verification")
            self._verified.add(id(e))

    def _verify_cached(self, layer: str, kernel: str) -> bool:
        """True if the entry's checksums hold; a failing entry is dropped
        from the in-memory header (persisted at the next compaction) and
        reported in ``self.dropped``."""
        ents = self._layers[layer]["cache"][kernel]
        for e in ents:
            if id(e) in self._verified:
                continue
            if not self._entry_ok(e):
                del self._layers[layer]["cache"][kernel]
                self.dropped.append({
                    "layer": layer, "kernel": kernel,
                    "reason": f"checksum mismatch in {e['name']}"})
                return False
            self._verified.add(id(e))
        return True

    def _verify_all(self) -> None:
        for layer in self.order:
            sect = self._layers.get(layer)
            if sect is None:
                continue
            self._verify_raw(layer, sect["raw"])
            for kern in list(sect.get("cache", {})):
                self._verify_cached(layer, kern)

    # -- reads --------------------------------------------------------------
    def _views(self, entries: List[dict], materialize: bool) -> LayerWeights:
        out: LayerWeights = {}
        for e in entries:
            seg = self._buf[e["offset"]: e["offset"] + e["nbytes"]]
            self.bytes_served += e["nbytes"]
            if "quant" in e:
                # v4 expand point: the payload view under the scheme dtype,
                # scales/zero-points decoded from the header metadata
                pv = seg.view(quant.payload_dtype(e["dtype"])).reshape(
                    e["shape"])
                out.update(quant.expand_entry(e["name"], e["quant"], pv,
                                              materialize=materialize))
                continue
            v = seg.view(_dtype_from_tag(e["dtype"])).reshape(e["shape"])
            out[e["name"]] = np.array(v) if materialize else v
        return out

    def read_raw(self, layer: str, *, materialize: bool = False) -> LayerWeights:
        sect = self._layers.get(layer)
        if not sect:
            return {}
        if materialize and self.verify == "lazy":
            self._verify_raw(layer, sect["raw"])
        return self._views(sect["raw"], materialize)

    def read_cached(self, layer: str, kernel: str, *,
                    materialize: bool = False) -> LayerWeights:
        ents = self._layers.get(layer, {}).get("cache", {}).get(kernel)
        if ents is None:
            return {}
        if (materialize and self.verify == "lazy"
                and not self._verify_cached(layer, kernel)):
            return {}  # torn/corrupt entry: never served; caller falls
            #            back to raw + transform
        return self._views(ents, materialize)

    # -- async extent reads --------------------------------------------------
    def submit_read(self, engine, layer: str, *, kernel: Optional[str] = None,
                    injector=None) -> Optional["PendingLayerRead"]:
        """Submit every extent of ``layer`` (raw, or one kernel's cache
        when ``kernel`` is given) to the async I/O engine and return a
        :class:`PendingLayerRead`; ``None`` when the section is absent
        (mirrors ``read_raw``/``read_cached`` returning ``{}``).

        The reaped bytes go through the SAME verification ladder as the
        mmap path — lazily-verified cache mismatches drop the entry and
        surface in ``self.dropped``, raw mismatches raise
        ``IntegrityError`` — except checksums audit the engine-read bytes
        themselves, so the audit covers the path actually served."""
        if self._fd is None:
            raise RuntimeError(f"{self.path}: submit_read on closed bundle")
        sect = self._layers.get(layer)
        if not sect:
            return None
        if kernel is None:
            entries = sect["raw"]
        else:
            entries = sect.get("cache", {}).get(kernel)
            if entries is None:
                return None
        return PendingLayerRead(self, layer, kernel, entries, engine,
                                injector).submit()

    # -- readahead ----------------------------------------------------------
    def advise_willneed(self, layers: Optional[Sequence[str]] = None) -> int:
        """``madvise(MADV_WILLNEED)`` the extents of the given layers (the
        first-k of the plan) so the kernel prefetches ahead of the prep
        pipeline. Returns the number of layers hinted (0 where madvise is
        unavailable) and records coverage in ``self.last_readahead`` so
        callers can tell a hinted run from a silently-unhinted one."""
        wanted = list(self.order if layers is None else layers)
        stats = {"layers_requested": len(wanted), "layers_hinted": 0,
                 "bytes_hinted": 0,
                 "madvise_available": hasattr(self._mm, "madvise")}
        self.last_readahead = stats
        if not stats["madvise_available"]:
            return 0
        page = mmap_mod.PAGESIZE
        for layer in wanted:
            ext = self.extent(layer)
            if ext is None:
                continue
            lo = ext[0] // page * page
            try:
                self._mm.madvise(mmap_mod.MADV_WILLNEED, lo, ext[1] - lo)
                stats["layers_hinted"] += 1
                stats["bytes_hinted"] += ext[1] - lo
            except (ValueError, OSError):
                pass
        return stats["layers_hinted"]

    # -- payload accounting --------------------------------------------------
    def raw_nbytes(self, layer: Optional[str] = None) -> int:
        layers = [layer] if layer is not None else self.order
        return sum(e["nbytes"] for l in layers
                   for e in self._layers.get(l, {"raw": []})["raw"])

    def cache_nbytes(self) -> int:
        return sum(e["nbytes"] for l in self.order
                   for ents in self._layers[l].get("cache", {}).values()
                   for e in ents)

    # -- on-disk accounting ---------------------------------------------------
    def file_size(self) -> int:
        return len(self._buf)

    def cache_disk_bytes(self) -> int:
        """Disk bytes the live cache sections occupy (padded 64-byte slots),
        so ``model + cache`` accounting sums to the real file size."""
        return sum(_pad_to(e["nbytes"]) for l in self.order
                   for ents in self._layers[l].get("cache", {}).values()
                   for e in ents)

    def header_region_bytes(self) -> int:
        """Bytes before the first possible data segment (fixed prefix +
        header JSON + slack, padded)."""
        fixed = _V3_FIXED if self.version >= 3 else _HEADER_FIXED
        return _pad_to(fixed + self._hlen + HEADER_SLACK)

    def live_disk_bytes(self) -> int:
        """Padded slot bytes of every live extent (raw + cache)."""
        return sum(_pad_to(e["nbytes"]) for l in self.order
                   for e in self._all_entries(l))

    def reclaimable_bytes(self) -> int:
        """Dead bytes ``compact`` would reclaim: extents orphaned by
        dropped/superseded cache entries (0 for a freshly-written file)."""
        return max(0, self.file_size() - self.header_region_bytes()
                   - self.live_disk_bytes())


class PendingLayerRead:
    """In-flight async reads for one layer section (raw, or one kernel's
    cache entries).

    ``wait()`` reaps every extent, runs the verification ladder on the
    reaped bytes, and returns ``{name: array}`` of **read-only** typed
    views into engine pool buffers (a corrupt lazily-verified cache
    section returns ``{}`` after dropping the entry, exactly like the
    mmap path).  The views stay valid until ``release()`` recycles the
    buffers — the executor calls that per job, after staging has copied
    everything device-side.

    ``wait()`` is retry-idempotent: a transient fault (injected or real)
    abandons the in-flight tickets — buffers recycle only once the
    backend is done with them — and resets the pending read, so the
    executor's next bounded-retry attempt resubmits cleanly.
    """

    def __init__(self, sb: SuperBundle, layer: str, kernel: Optional[str],
                 entries: List[dict], engine, injector):
        self.sb = sb
        self.layer = layer
        self.kernel = kernel
        self.engine = engine
        self.injector = injector
        self._entries = entries
        self._tickets: Optional[List[tuple]] = None
        self._result: Optional[LayerWeights] = None
        # set by the owning LayerStore: called right after a corrupt cache
        # entry is dropped, so store-level drop reporting sees it without
        # waiting for the reader to reopen
        self.on_drop: Optional[Callable[[], None]] = None

    def submit(self) -> "PendingLayerRead":
        if self._tickets is None and self._result is None:
            tickets = []
            try:
                for e in self._entries:
                    tickets.append((e, self.engine.submit(
                        self.sb._fd, e["offset"], e["nbytes"],
                        key=f"{self.layer}/{e['name']}",
                        injector=self.injector)))
            except BaseException:
                for _, t in tickets:
                    t.abandon()
                raise
            self._tickets = tickets
        return self

    def nbytes(self) -> int:
        return sum(e["nbytes"] for e in self._entries)

    def _reset(self) -> None:
        if self._tickets is not None:
            for _, t in self._tickets:
                t.abandon()
            self._tickets = None

    def wait(self, timeout: Optional[float] = None) -> LayerWeights:
        if self._result is not None:
            return self._result
        self.submit()
        out: LayerWeights = {}
        try:
            for e, t in self._tickets:
                view = t.wait(timeout)
                if (self.sb.verify != "never"
                        and id(e) not in self.sb._verified
                        and "crc32c" in e
                        and crc32c(view) != e["crc32c"]):
                    if self.kernel is None:
                        raise IntegrityError(
                            f"{self.sb.path}: raw tensor "
                            f"{self.layer}/{e['name']} failed checksum "
                            "verification")
                    # cache tear: drop the entry like _verify_cached and
                    # let the caller fall back to raw + transform
                    self.sb._layers[self.layer]["cache"].pop(self.kernel,
                                                             None)
                    self.sb.dropped.append({
                        "layer": self.layer, "kernel": self.kernel,
                        "reason": f"checksum mismatch in {e['name']}"})
                    self._reset()
                    self._result = {}
                    if self.on_drop is not None:
                        self.on_drop()
                    return self._result
                self.sb._verified.add(id(e))
                self.sb.bytes_served += e["nbytes"]
                if "quant" in e:
                    pv = view.view(quant.payload_dtype(
                        e["dtype"])).reshape(e["shape"])
                    out.update(quant.expand_entry(e["name"], e["quant"], pv))
                else:
                    out[e["name"]] = view.view(
                        _dtype_from_tag(e["dtype"])).reshape(e["shape"])
        except IntegrityError:
            self._reset()
            raise
        except Exception:
            self._reset()  # transient: next retry attempt resubmits
            raise
        self._result = out
        return out

    def abort(self) -> None:
        """Interrupt a waiter parked in the engine's emulated-disk pacing
        (warm-state race loser): flags only — buffers are untouched, so a
        waiter already past pacing (verifying/parsing views) completes
        normally. ``release()`` still recycles everything at job end."""
        if self._tickets is not None:
            for _, t in self._tickets:
                t.interrupt()

    def release(self) -> None:
        if self._tickets is not None:
            for _, t in self._tickets:
                t.abandon()


# ---------------------------------------------------------------------------
# mutation: journaled in-place commit / rewrite-on-grow / drop / compact
# ---------------------------------------------------------------------------
def _load_all(sb: SuperBundle):
    """Live contents as zero-copy views, for a rewrite. Unless the reader
    was opened with ``verify="never"``, every extent is audited on the way
    through: a rewrite restamps fresh checksums, so copying unverified
    bytes forward would launder latent bit-rot into "verified" data.
    Corrupt cache entries are dropped (reported in ``sb.dropped``);
    corrupt raw raises ``IntegrityError``."""
    audit = sb.verify != "never"
    raw: Dict[str, LayerWeights] = {}
    cache: Dict[str, Dict[str, LayerWeights]] = {}
    for l in sb.order:
        sect = sb._layers.get(l)
        if audit and sect:
            sb._verify_raw(l, sect["raw"])
        raw[l] = sb.read_raw(l)
        ks: Dict[str, LayerWeights] = {}
        for k in list(sb.kernels_cached(l)):
            if audit and not sb._verify_cached(l, k):
                continue  # dropped + reported via sb.dropped
            ks[k] = sb.read_cached(l, k)
        cache[l] = ks
    return raw, cache


def _slot_sizes(sb: SuperBundle) -> Dict[int, int]:
    """id(entry) -> writable slot size (distance to the next live segment or
    to EOF) — how far an in-place replacement may grow without moving data.
    Dead extents left by dropped entries merge into the preceding slot."""
    all_e = sorted((e for l in sb.order for e in sb._all_entries(l)),
                   key=lambda e: e["offset"])
    size = len(sb._buf)
    slots: Dict[int, int] = {}
    for e, nxt in zip(all_e, all_e[1:] + [None]):
        end = nxt["offset"] if nxt is not None else size
        slots[id(e)] = end - e["offset"]
    return slots


def _first_data_offset(sb: SuperBundle) -> int:
    offs = [e["offset"] for l in sb.order for e in sb._all_entries(l)]
    return min(offs) if offs else sb.file_size()


def _commit_inplace(path: Path, sb: SuperBundle, entries: List[dict],
                    hdr_bytes: bytes,
                    slots: List[Tuple[int, bytes]]) -> None:
    """The crash-atomic in-place commit: journal the intent (slot checksums
    + full new header), fsync it AHEAD of any container write, then write
    payload slots and the new header, fsync, and mark the transaction
    committed — ONE fsync pair however many cache entries the transaction
    covers. ``entries`` is ``[{"layer","kernel","slots":[meta]}, ...]``;
    any tear in between is resolved per-entry by ``recover_journal`` at the
    next open."""
    jp = journal_path(path)
    begin = {
        "txn": _next_txn(jp), "gen": sb.generation,
        "entries": entries,
        "slots": [s for ent in entries for s in ent["slots"]],
        "header": {"len": len(hdr_bytes), "crc32c": crc32c(hdr_bytes),
                   "b64": base64.b64encode(hdr_bytes).decode()},
    }
    if len(entries) == 1:  # legacy single-entry shape, kept for introspection
        begin["layer"] = entries[0]["layer"]
        begin["kernel"] = entries[0]["kernel"]
    _hook("journal", record=begin, journal=jp)
    _journal_append(jp, b"B", begin, sync=True)
    _hook("journal-synced", record=begin, journal=jp)
    with open(path, "r+b") as f:
        for off, payload in slots:
            _hook("slot", file=f, offset=off, payload=payload)
            f.seek(off)
            f.write(payload)
        _hook("slots-written", file=f)
        _hook("header", file=f, header=hdr_bytes)
        _write_header_inplace(f, hdr_bytes)  # fsyncs slots + header together
        _hook("header-written", file=f)
    _journal_append(jp, b"C", {"txn": begin["txn"]}, sync=False)
    if jp.stat().st_size > _JOURNAL_RESET_BYTES:
        _journal_reset(jp)


def _try_inplace_many(
        path: Path, sb: SuperBundle,
        payloads: Dict[Tuple[str, str],
                       Tuple[List[dict], List[np.ndarray]]]) -> bool:
    """Attempt ONE journaled in-place transaction replacing every entry in
    ``payloads``. All-or-nothing: if any entry's tensors changed names, grew
    past its slot, or the combined header outgrows the header region, no
    bytes are touched and the caller falls back to a rewrite."""
    if sb.version < 3:
        return False  # pre-checksum container: upgrade via full rewrite
    slots = _slot_sizes(sb)
    # candidate header on a deep copy — sb.header must stay untouched unless
    # the in-place path actually commits
    hdr = json.loads(json.dumps(sb.header))
    rec_entries: List[dict] = []
    flat: List[Tuple[int, bytes]] = []
    for (layer, kernel), (entries_new, arrs) in payloads.items():
        old = sb._layers[layer]["cache"][kernel]
        if [e["name"] for e in old] != [e["name"] for e in entries_new]:
            return False
        if any(en["nbytes"] > slots[id(eo)]
               for eo, en in zip(old, entries_new)):
            return False
        for eo, en in zip(hdr["layers"][layer]["cache"][kernel], entries_new):
            eo.update(dtype=en["dtype"], shape=en["shape"],
                      nbytes=en["nbytes"], crc32c=en["crc32c"])
            # carry (or clear) the v4 quantization metadata with the entry
            if "quant" in en:
                eo["quant"] = en["quant"]
            else:
                eo.pop("quant", None)
        metas = []
        for eo, a in zip(old, arrs):
            b = a.tobytes()
            flat.append((eo["offset"], b))
            metas.append({"offset": eo["offset"], "nbytes": len(b),
                          "crc32c": crc32c(b)})
        rec_entries.append({"layer": layer, "kernel": kernel, "slots": metas})
    hdr_bytes = json.dumps(hdr, separators=(",", ":")).encode()
    if _V3_FIXED + len(hdr_bytes) > _first_data_offset(sb):
        return False
    _commit_inplace(path, sb, rec_entries, hdr_bytes, flat)
    return True


def set_cache_entries(
        path: Path,
        updates: Dict[Tuple[str, str], LayerWeights], *,
        verify: str = "lazy") -> dict:
    """Commit several cache-entry writes as ONE transaction. When every
    entry already exists and fits its slot (the decide() refresh pattern),
    this is a single journaled in-place commit — one journal fsync + one
    container fsync, instead of a pair per entry. Anything that grows or is
    new falls back to one atomic rewrite covering all updates. Returns
    ``{"mode": "inplace"|"rewrite", "dropped": [...]}`` (recovery/audit
    drop reports from opening the container)."""
    path = Path(path)
    payloads = {(l, k): _payload(w) for (l, k), w in updates.items()}
    with SuperBundle(path, verify=verify) as sb:
        dropped = list(sb.dropped)
        if (payloads
                and all(sb.has_cached(l, k) for l, k in payloads)
                and _try_inplace_many(path, sb, payloads)):
            return {"mode": "inplace", "dropped": dropped}
        raw, cache = _load_all(sb)
        dropped = list(sb.dropped)  # _load_all may audit-drop more
        order = list(sb.order)
        for (layer, kernel), weights in updates.items():
            if layer not in order:
                order.append(layer)
                raw.setdefault(layer, {})
            # keep the ORIGINAL weight dict (companion keys included) so the
            # rewrite's _payload refolds quantized groups instead of writing
            # a folded payload as a plain tensor with its metadata lost
            cache.setdefault(layer, {})[kernel] = dict(weights)
        write_superbundle(path, raw, cache, order=order,
                          generation=sb.generation + 1)
    return {"mode": "rewrite", "dropped": dropped}


def set_cache_entry(path: Path, layer: str, kernel: str,
                    weights: LayerWeights) -> str:
    """Append/replace one layer's post-transformed cache entry. In-place
    (crash-atomic, journaled) when the payload fits the existing slots and
    the header region; else rewrite-on-grow (atomic tmp+rename). Returns
    ``"inplace"`` or ``"rewrite"``."""
    return set_cache_entries(path, {(layer, kernel): weights})["mode"]


def drop_cache_entry(path: Path, layer: str, kernel: str) -> bool:
    """Remove a cache entry. On a v3 container this is a journaled in-place
    header commit that leaves the extent dead on disk — O(header), not
    O(file) — to be reclaimed by the next ``compact``. Older containers
    fall back to the compacting rewrite. Returns whether the entry existed."""
    path = Path(path)
    with SuperBundle(path) as sb:
        if not sb.has_cached(layer, kernel):
            return False
        if sb.version >= 3:
            hdr = json.loads(json.dumps(sb.header))
            hdr["layers"][layer]["cache"].pop(kernel)
            hdr_bytes = json.dumps(hdr, separators=(",", ":")).encode()
            if _V3_FIXED + len(hdr_bytes) <= _first_data_offset(sb):
                _commit_inplace(
                    path, sb,
                    [{"layer": layer, "kernel": kernel, "slots": []}],
                    hdr_bytes, [])
                return True
        raw, cache = _load_all(sb)
        del cache[layer][kernel]
        write_superbundle(path, raw, cache, order=sb.order,
                          generation=sb.generation + 1)
    return True


def compact(path: Path, *, order: Optional[Sequence[str]] = None) -> dict:
    """Reclaim dead extents (dropped/superseded cache entries) by rewriting
    the live contents into a fresh container via the atomic tmp+rename
    publish. Every extent is checksum-verified on the way through (a
    corrupt cache entry is dropped, not copied forward; corrupt raw
    raises); the generation is bumped and the journal reset. Returns
    ``{"file_size", "reclaimed_bytes", "dropped"}``."""
    path = Path(path)
    with SuperBundle(path, verify="lazy") as sb:
        before = sb.file_size()
        raw, cache = _load_all(sb)
        dropped = list(sb.dropped)
        keep_order = list(order) if order is not None else list(sb.order)
        size = write_superbundle(path, raw, cache, order=keep_order,
                                 generation=sb.generation + 1)
    return {"file_size": size, "reclaimed_bytes": before - size,
            "dropped": dropped}


# ---------------------------------------------------------------------------
# migration: per-layer bundle LayerStore tree -> one super-bundle
# ---------------------------------------------------------------------------
def migrate(src_root: Path, dest: Path,
            order: Optional[Sequence[str]] = None) -> Path:
    """Convert a per-layer bundle store (``raw/*.bundle`` +
    ``cache/<kernel>/*.bundle``) into one super-bundle at ``dest`` (a file
    path, or a directory that receives ``model.superbundle``). Layer names
    are recovered from bundle file stems — names whose ``/`` was flattened
    to ``_`` on write stay flattened."""
    src = Path(src_root)
    dest = Path(dest)
    if dest.is_dir():
        dest = dest / "model.superbundle"
    raw: Dict[str, LayerWeights] = {}
    for p in sorted((src / "raw").glob("*.bundle")):
        raw[p.name[: -len(".bundle")]] = read_bundle(p, mmap=True)
    cache: Dict[str, Dict[str, LayerWeights]] = {}
    cdir = src / "cache"
    if cdir.exists():
        for kdir in sorted(d for d in cdir.iterdir() if d.is_dir()):
            for p in sorted(kdir.glob("*.bundle")):
                layer = p.name[: -len(".bundle")]
                cache.setdefault(layer, {})[kdir.name] = read_bundle(
                    p, mmap=True)
    write_superbundle(dest, raw, cache, order=order)
    return dest
