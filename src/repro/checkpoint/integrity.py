"""Integrity primitives shared by the on-disk containers.

Two facilities, both dependency-free (stdlib + numpy):

  * ``crc32c`` — the Castagnoli CRC (poly 0x1EDC6F41, reflected), the
    checksum the super-bundle v3 format stores per extent entry and per
    journal record. When a C-backed implementation is importable
    (``google_crc32c``, which uses SSE4.2/ARMv8 CRC instructions where
    available, or the ``crc32c`` package) it is used for the payload work
    — the ~100 MB/s software path makes an eager fsck of a GB-scale model
    noticeably slow. NOTE: stdlib ``zlib.crc32`` is the *wrong
    polynomial* (CRC-32/ISO-HDLC, 0x04C11DB7) and can never back this
    function. The numpy software implementation remains the always-
    available fallback (and the cross-check oracle for the fast paths):
    pure Python CRC loops run at ~2 MB/s, far too slow to checksum weight
    payloads, so it exploits the GF(2) linearity of CRCs: the
    contribution of byte ``b`` at distance ``d`` from the end of a block
    is a pure table lookup ``PT[d][b]``, which lets whole blocks be
    reduced with one vectorized numpy gather + XOR instead of a byte
    loop. Blocks are then folded left-to-right with a precomputed
    advance-by-block-of-zeros operator. The one-time table build (~1 MB)
    is lazy. ``REPRO_CRC32C=software`` forces the fallback.

  * fsync-ordered durable writes — ``fsync_file``/``fsync_dir`` plus
    ``atomic_write_text``, the commit primitive for small JSON sidecars
    (plan, fingerprints, profile DB): write to ``.tmp``, fsync, rename,
    fsync the directory, so a crash never leaves a torn sidecar and a
    published one survives power loss. The container writers use the same
    helpers through ``bundle.atomic_write(durable=True)``.
"""
from __future__ import annotations

import os
from pathlib import Path
from typing import List, Optional

import numpy as np

_POLY = 0x82F63B78  # CRC-32C (Castagnoli), reflected
_CHUNK = 1024       # block size of the vectorized reduction
_MASK = 0xFFFFFFFF

_TABLE: Optional[np.ndarray] = None      # (256,) uint32 byte table
_TABLE_LIST: Optional[List[int]] = None  # same, as a Python list (tail loop)
_PT: Optional[np.ndarray] = None         # (CHUNK, 256): PT[d] = advance^d(table)
_PT_REV: Optional[np.ndarray] = None     # PT[::-1], gather layout
_ADV: Optional[List[List[int]]] = None   # advance state by CHUNK zero bytes


def _build_tables():
    global _TABLE, _TABLE_LIST, _PT, _PT_REV, _ADV
    x = np.arange(256, dtype=np.uint32)
    for _ in range(8):
        x = np.where(x & 1, (x >> 1) ^ np.uint32(_POLY), x >> 1)
    _TABLE = x
    _TABLE_LIST = x.tolist()
    # PT[d][v] = state contribution of table[v] advanced by d zero bytes
    pt = np.empty((_CHUNK, 256), np.uint32)
    pt[0] = x
    cur = x
    for d in range(1, _CHUNK):
        cur = (cur >> 8) ^ x[cur & 0xFF]
        pt[d] = cur
    _PT = pt
    _PT_REV = np.ascontiguousarray(pt[::-1])
    # advancing a 32-bit state across one CHUNK of zeros decomposes by
    # state byte k into PT[CHUNK-1-k] (byte k needs k plain shifts to reach
    # the low byte, then CHUNK-1-k table-fed steps)
    _ADV = [pt[_CHUNK - 1 - k].tolist() for k in range(4)]


def _as_u8(data) -> np.ndarray:
    if isinstance(data, np.ndarray):
        a = np.ascontiguousarray(data)
        if a.nbytes == 0:
            return np.empty(0, np.uint8)
        return a.reshape(-1).view(np.uint8)
    return np.frombuffer(memoryview(data), dtype=np.uint8)


# -- C-backed fast paths ----------------------------------------------------
_FAST = None            # (backend_name, fn(bytes_like, value) -> int)
_FAST_PROBED = False
_CHECK_VECTOR = (b"123456789", 0xE3069283)  # canonical CRC-32C test vector


def _probe_fast():
    """Resolve an accelerated CRC-32C backend once, self-checked against
    the canonical test vector so a mis-behaving import can never corrupt
    container checksums."""
    global _FAST, _FAST_PROBED
    _FAST_PROBED = True
    if os.environ.get("REPRO_CRC32C", "").lower() == "software":
        return
    candidates = []
    try:
        import google_crc32c

        candidates.append(("google-crc32c",
                           lambda b, v: google_crc32c.extend(v, b)))
    except ImportError:
        pass
    try:
        import crc32c as _crc32c_mod

        candidates.append(("crc32c",
                           lambda b, v: _crc32c_mod.crc32c(b, v)))
    except ImportError:
        pass
    vec, want = _CHECK_VECTOR
    for name, fn in candidates:
        # zero-copy first (numpy views hand over memoryviews); fall back to
        # a copying wrapper if the backend only takes bytes
        for wrap in (fn, lambda b, v, fn=fn: fn(bytes(b), v)):
            try:
                mv = memoryview(vec)
                if wrap(mv, 0) == want and \
                        wrap(mv[4:], wrap(mv[:4], 0)) == want:
                    _FAST = (name, wrap)
                    return
            except Exception:
                continue


def crc32c_backend() -> str:
    """Name of the active CRC-32C implementation."""
    if not _FAST_PROBED:
        _probe_fast()
    return _FAST[0] if _FAST is not None else "numpy-software"


def crc32c(data, value: int = 0) -> int:
    """CRC-32C of ``data`` (bytes-like or ndarray); pass a previous return
    as ``value`` to checksum a concatenation incrementally. Routed through
    a C-backed implementation when one is importable (see module
    docstring); the numpy software path is the fallback."""
    if not _FAST_PROBED:
        _probe_fast()
    if _FAST is not None:
        buf = _as_u8(data)
        return int(_FAST[1](buf.data if buf.size else b"", value & _MASK))
    return _crc32c_software(data, value)


def _crc32c_software(data, value: int = 0) -> int:
    """The numpy-vectorized software CRC-32C — always available, and the
    oracle the fast-path cross-check tests compare against."""
    if _TABLE is None:
        _build_tables()
    buf = _as_u8(data)
    crc = (value & _MASK) ^ _MASK
    n = buf.size
    head = n % _CHUNK
    if head:
        tab = _TABLE_LIST
        for b in buf[:head].tolist():
            crc = (crc >> 8) ^ tab[(crc ^ b) & 0xFF]
    if n > head:
        a0, a1, a2, a3 = _ADV
        rest = buf[head:]
        # bound temporaries: reduce at most 16 MB of input per slab
        slab = 16384 * _CHUNK
        for s0 in range(0, rest.size, slab):
            chunks = rest[s0:s0 + slab].reshape(-1, _CHUNK)
            if chunks.shape[0] < 64:
                # few blocks: one fancy-indexed gather beats paying numpy
                # per-call overhead _CHUNK times in the position loop
                acc = np.bitwise_xor.reduce(
                    _PT_REV[np.arange(_CHUNK), chunks], axis=1)
            else:
                # many blocks: walk positions — each step gathers from one
                # cache-resident 1 KB table row across every block at once
                acc = np.zeros(chunks.shape[0], np.uint32)
                for j in range(_CHUNK):
                    np.bitwise_xor(acc, _PT_REV[j][chunks[:, j]], out=acc)
            for c in acc.tolist():
                crc = (a0[crc & 0xFF] ^ a1[(crc >> 8) & 0xFF]
                       ^ a2[(crc >> 16) & 0xFF] ^ a3[crc >> 24] ^ c)
    return crc ^ _MASK


# ---------------------------------------------------------------------------
# fsync-ordered commits
# ---------------------------------------------------------------------------
def fsync_file(f) -> None:
    """Flush + fsync an open file object (data reaches the medium before
    any later write is allowed to — the ordering journaled commits need)."""
    f.flush()
    os.fsync(f.fileno())


def fsync_dir(path: Path) -> None:
    """fsync a directory so a rename published into it survives power loss.
    Best-effort on filesystems that refuse directory fds."""
    try:
        fd = os.open(Path(path), os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def atomic_write_text(path: Path, text: str, *, durable: bool = False) -> None:
    """Publish a small text file atomically (tmp + rename); with ``durable``
    the tmp is fsynced before the rename and the directory after it."""
    path = Path(path)
    tmp = path.with_suffix(path.suffix + ".tmp")
    try:
        with open(tmp, "w") as f:
            f.write(text)
            if durable:
                fsync_file(f)
        tmp.replace(path)
        if durable:
            fsync_dir(path.parent)
    except BaseException:
        tmp.unlink(missing_ok=True)
        raise
