"""Per-channel weight quantization — the §3.1.2 transform taken one step
further: a cache entry that stores FEWER BYTES than the deployed precision.

Cold inference is I/O-bound, so the biggest lever on cold latency is bytes
read from disk. This module provides the numpy substrate for int8 / packed
int4 post-transform cache entries:

  * symmetric (and optionally asymmetric, int8 only) per-channel absmax
    quantization with a hard elementwise error bound of half a quantization
    step (``|w - dq(q(w))| <= scale/2`` per channel);
  * int4 nibble packing along axis 0 (rows 2i/2i+1 -> low/high nibble of one
    byte; odd row counts pad the final high nibble with the encoding of 0);
  * the *companion-key convention* quantized weight dicts use everywhere
    (kernels, the LayerStore, the super-bundle reader):

        {base}:q8      int8 data, the logical (K, N) shape
        {base}:q4      packed uint8 data, ((K+1)//2, N)
        {base}:qscale  float32 per-channel scales, keepdims shape (1, N)
        {base}:qzero   int32 per-channel zero points (asymmetric int8 only)

    Kernels emit and consume PLAIN numpy arrays under these names, so the
    profiler's scratch bundles, ``avatars_of``, the ProfileDB's JSON
    serialization and ``jax.ShapeDtypeStruct`` compile avatars all work
    unchanged — quantization never introduces a new array type;
  * fold/expand helpers for the super-bundle's format v4: on write, one
    companion group folds into ONE container extent (payload = the
    quantized bytes, CRC over exactly those bytes) whose header entry
    carries the scales/zero-points as metadata; on read, the extent
    expands back to the identical companion dict. ``docs/formats.md``
    has the byte-level spec.

The jnp/Pallas consumers (dequant-on-the-fly and fused dequant-matmul)
live in ``repro.kernels.quant``; this module stays numpy-only so the
checkpoint layer can import it without pulling in jax.
"""
from __future__ import annotations

import base64
from typing import Dict, List, Optional, Tuple

import numpy as np

Q8_SUFFIX = ":q8"
Q4_SUFFIX = ":q4"
SCALE_SUFFIX = ":qscale"
ZERO_SUFFIX = ":qzero"

# scheme tag (the folded extent's dtype tag) -> data-companion suffix
SCHEME_SUFFIX = {"int8": Q8_SUFFIX, "int4": Q4_SUFFIX}
_SUFFIX_SCHEME = {v: k for k, v in SCHEME_SUFFIX.items()}

# symmetric ranges: +/-127 and +/-7 (never -128/-8) keep |w - dq(q(w))|
# <= scale/2 without an asymmetric clipping tail
_QMAX = {"int8": 127, "int4": 7}


def payload_dtype(scheme: str) -> np.dtype:
    """Storage dtype of a folded extent's payload: int8 data is stored as
    int8; int4 data is nibble-packed into uint8 bytes."""
    if scheme == "int8":
        return np.dtype(np.int8)
    if scheme == "int4":
        return np.dtype(np.uint8)
    raise ValueError(f"unknown quantization scheme {scheme!r}")


def error_bound(scale: np.ndarray) -> np.ndarray:
    """Hard elementwise reconstruction bound: half a quantization step."""
    return 0.5 * np.abs(np.asarray(scale, np.float32))


# ---------------------------------------------------------------------------
# quantize / dequantize (numpy)
# ---------------------------------------------------------------------------
def _channel_scale(a: np.ndarray, axis: int, qmax: int) -> np.ndarray:
    absmax = np.max(np.abs(a), axis=axis, keepdims=True)
    s = absmax / float(qmax)
    # all-zero channels quantize to 0 exactly under any nonzero scale; 1.0
    # keeps dequantization well-defined without special-casing readers
    return np.where(s > 0, s, 1.0).astype(np.float32)


def quantize_int8(a: np.ndarray, *, axis: int = 0,
                  symmetric: bool = True
                  ) -> Tuple[np.ndarray, np.ndarray, Optional[np.ndarray]]:
    """Per-channel int8 quantization of ``a`` along ``axis``. Returns
    ``(q, scale, zero)``; ``zero`` is None for symmetric. Guarantees
    ``|a - dequant| <= scale/2`` elementwise."""
    a = np.asarray(a, np.float32)
    if symmetric:
        s = _channel_scale(a, axis, _QMAX["int8"])
        q = np.clip(np.rint(a / s), -127, 127).astype(np.int8)
        return q, s, None
    lo = np.min(a, axis=axis, keepdims=True)
    hi = np.max(a, axis=axis, keepdims=True)
    s = ((hi - lo) / 254.0).astype(np.float32)
    s = np.where(s > 0, s, 1.0).astype(np.float32)
    # zero point placed so lo -> -127 and hi -> +127; the zero point enters
    # the arithmetic as an exact integer, so dq = (q - z) * s = rint(a/s)*s
    z = (-127 - np.rint(lo / s)).astype(np.int32)
    q = np.clip(np.rint(a / s) + z, -127, 127).astype(np.int8)
    return q, s, z


def quantize_int4(a: np.ndarray, *, axis: int = 0
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-channel symmetric int4 quantization of a 2-D array; returns
    ``(packed, scale)`` with ``packed`` uint8 of shape ``((K+1)//2, N)``.
    Values land in [-7, 7]; ``|a - dequant| <= scale/2`` elementwise."""
    a = np.asarray(a, np.float32)
    if a.ndim != 2:
        raise ValueError(f"int4 packing needs a 2-D array, got {a.shape}")
    s = _channel_scale(a, axis, _QMAX["int4"])
    q = np.clip(np.rint(a / s), -7, 7).astype(np.int8)
    return pack_int4(q), s


def pack_int4(q: np.ndarray) -> np.ndarray:
    """Pack int8 values in [-8, 7] two-per-byte along axis 0: row ``2i``
    into the low nibble, row ``2i+1`` into the high nibble. An odd row
    count pads the final high nibble with 0 (the encoding of 0)."""
    q = np.asarray(q, np.int8)
    K = q.shape[0]
    if K % 2:
        q = np.concatenate([q, np.zeros((1,) + q.shape[1:], np.int8)])
    lo = q[0::2].astype(np.uint8) & 0x0F
    hi = q[1::2].astype(np.uint8) & 0x0F
    return (lo | (hi << 4)).astype(np.uint8)


def unpack_int4(packed: np.ndarray, k: int) -> np.ndarray:
    """Inverse of :func:`pack_int4`: ``((K+1)//2, ...)`` uint8 bytes back to
    ``(k, ...)`` int8 values (sign-extended nibbles)."""
    packed = np.asarray(packed, np.uint8)
    lo = (packed & 0x0F).astype(np.int8)
    hi = (packed >> 4).astype(np.int8)
    lo = np.where(lo >= 8, lo - 16, lo).astype(np.int8)
    hi = np.where(hi >= 8, hi - 16, hi).astype(np.int8)
    out = np.empty((2 * packed.shape[0],) + packed.shape[1:], np.int8)
    out[0::2] = lo
    out[1::2] = hi
    return out[:k]


def quantize_weight(name: str, a: np.ndarray, *, bits: int = 8,
                    axis: int = 0, symmetric: bool = True
                    ) -> Dict[str, np.ndarray]:
    """One tensor -> its companion dict under the module's key convention."""
    if bits == 8:
        q, s, z = quantize_int8(a, axis=axis, symmetric=symmetric)
        out = {name + Q8_SUFFIX: q, name + SCALE_SUFFIX: s}
        if z is not None:
            out[name + ZERO_SUFFIX] = z
        return out
    if bits == 4:
        packed, s = quantize_int4(a, axis=axis)
        return {name + Q4_SUFFIX: packed, name + SCALE_SUFFIX: s}
    raise ValueError(f"bits must be 8 or 4, got {bits}")


def dequantize_weight(companions: Dict[str, np.ndarray], base: str,
                      logical_shape: Optional[Tuple[int, ...]] = None
                      ) -> np.ndarray:
    """Reconstruct ``base`` (float32) from its companions. ``logical_shape``
    is required for int4 (the packed payload cannot recover an odd K)."""
    s = np.asarray(companions[base + SCALE_SUFFIX], np.float32)
    if base + Q8_SUFFIX in companions:
        q = np.asarray(companions[base + Q8_SUFFIX], np.float32)
        z = companions.get(base + ZERO_SUFFIX)
        if z is not None:
            q = q - np.asarray(z, np.float32)  # dq = (q - z) * s
        return q * s
    packed = companions[base + Q4_SUFFIX]
    if logical_shape is None:
        raise ValueError(f"{base}: int4 dequantization needs logical_shape")
    q = unpack_int4(packed, logical_shape[0]).astype(np.float32)
    return q * s


def quantize_weights(raw: Dict[str, np.ndarray], *, bits: int = 8,
                     axis: int = 0, min_size: int = 16
                     ) -> Dict[str, np.ndarray]:
    """Kernel-transform helper: quantize every 2-D float tensor of a raw
    weight dict (the matmul operands), pass everything else — biases,
    norms, already-integer tensors — through unchanged."""
    out: Dict[str, np.ndarray] = {}
    for name, v in raw.items():
        a = np.asarray(v)
        floaty = a.dtype.kind == "f" or "bfloat16" in str(a.dtype)
        if a.ndim == 2 and a.size >= min_size and floaty:
            out.update(quantize_weight(name, np.asarray(a, np.float32),
                                       bits=bits, axis=axis))
        else:
            out[name] = a
    return out


# ---------------------------------------------------------------------------
# companion-group detection + fold/expand (the super-bundle v4 hooks)
# ---------------------------------------------------------------------------
def split_groups(weights: Dict[str, np.ndarray]
                 ) -> Tuple[Dict[str, dict], Dict[str, np.ndarray]]:
    """Partition a weight dict into quantized companion groups and plain
    tensors. Returns ``(groups, rest)``: ``groups[base]`` is
    ``{"scheme", "data", "scale", "zero"(opt)}``. A ``:q8``/``:q4`` key
    without its ``:qscale`` companion stays a plain tensor."""
    groups: Dict[str, dict] = {}
    consumed: set = set()
    for name in weights:
        for suf, scheme in _SUFFIX_SCHEME.items():
            if not name.endswith(suf):
                continue
            base = name[: -len(suf)]
            if base + SCALE_SUFFIX not in weights:
                continue
            g = {"scheme": scheme, "data": np.asarray(weights[name]),
                 "scale": np.asarray(weights[base + SCALE_SUFFIX])}
            consumed.update((name, base + SCALE_SUFFIX))
            if base + ZERO_SUFFIX in weights:
                g["zero"] = np.asarray(weights[base + ZERO_SUFFIX])
                consumed.add(base + ZERO_SUFFIX)
            groups[base] = g
    rest = {n: v for n, v in weights.items() if n not in consumed}
    return groups, rest


def _arr_to_json(a: np.ndarray) -> dict:
    a = np.ascontiguousarray(a)
    return {"dtype": str(a.dtype), "shape": list(a.shape),
            "b64": base64.b64encode(a.tobytes()).decode()}


def _arr_from_json(d: dict) -> np.ndarray:
    a = np.frombuffer(base64.b64decode(d["b64"]),
                      dtype=np.dtype(d["dtype"])).reshape(d["shape"])
    a.flags.writeable = False
    return a


def quant_meta(group: dict) -> dict:
    """Header-JSON quantization metadata for one folded extent: the scheme
    plus the (small) per-channel scale/zero-point arrays inline — the
    payload carries ONLY the quantized bytes, so its CRC covers exactly
    them."""
    meta = {"scheme": group["scheme"], "scale": _arr_to_json(group["scale"])}
    if group.get("zero") is not None:
        meta["zero"] = _arr_to_json(group["zero"])
    return meta


def expand_entry(name: str, meta: dict, payload: np.ndarray,
                 *, materialize: bool = False) -> Dict[str, np.ndarray]:
    """A folded extent back to its companion dict: the payload view under
    the data key, scales (and zero points) decoded from the header
    metadata. Exact inverse of ``split_groups`` + ``quant_meta`` — a
    fold/expand round-trip is bit-identical."""
    suf = SCHEME_SUFFIX[meta["scheme"]]
    out = {name + suf: np.array(payload) if materialize else payload,
           name + SCALE_SUFFIX: _arr_from_json(meta["scale"])}
    if "zero" in meta:
        out[name + ZERO_SUFFIX] = _arr_from_json(meta["zero"])
    return out


def is_quantized(weights: Dict[str, np.ndarray]) -> bool:
    groups, _rest = split_groups(weights)
    return bool(groups)


def logical_nbytes(weights: Dict[str, np.ndarray]) -> int:
    """float32 bytes of the dequantized view of a (possibly quantized)
    weight dict — the synthetic profiler's dequant-cost denominator."""
    groups, rest = split_groups(weights)
    n = sum(int(np.asarray(v).nbytes) for v in rest.values())
    for g in groups.values():
        elems = int(np.asarray(g["data"]).size)
        if g["scheme"] == "int4":
            elems *= 2
        n += 4 * elems
    return n
