"""Config registry: ``get_config(arch_id)`` for every assigned architecture."""
from __future__ import annotations

import importlib

from repro.configs.base import ArchConfig, ShapeConfig, INPUT_SHAPES  # noqa: F401

# arch-id (dashed, as used on CLI) -> module name
_ARCH_MODULES = {
    "zamba2-2.7b": "zamba2_2_7b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "smollm-360m": "smollm_360m",
    "mamba2-2.7b": "mamba2_2_7b",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "musicgen-medium": "musicgen_medium",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "gemma2-27b": "gemma2_27b",
    "internvl2-76b": "internvl2_76b",
    "qwen3-32b": "qwen3_32b",
}

# the paper's own evaluation models (CNN chains for the cold engine) are in
# repro.configs.cnn_zoo / repro.models.cnn — built via build_cnn(name), not
# ArchConfig (they are host-scale engine graphs, not distributed decoders)
PAPER_CNNS = ["resnet18", "resnet50", "mobilenet", "squeezenet", "alexnet"]

ASSIGNED_ARCHS = [
    "zamba2-2.7b",
    "granite-moe-3b-a800m",
    "smollm-360m",
    "mamba2-2.7b",
    "qwen3-moe-30b-a3b",
    "musicgen-medium",
    "mistral-nemo-12b",
    "gemma2-27b",
    "internvl2-76b",
    "qwen3-32b",
]


def get_config(arch: str) -> ArchConfig:
    if arch.endswith("-reduced"):
        return get_config(arch[: -len("-reduced")]).reduced()
    try:
        mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    except KeyError:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    return mod.CONFIG


def list_archs() -> list[str]:
    return list(ASSIGNED_ARCHS)
