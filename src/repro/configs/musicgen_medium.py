"""MusicGen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284]. The EnCodec conv codec frontend is STUBBED per the
assignment: ``input_specs`` feeds precomputed frame embeddings of shape
(batch, seq, d_model); the decoder and its token head are fully implemented.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    input_mode="embeddings",
    tie_embeddings=False,
    source="arXiv:2306.05284",
)
