"""Mistral-Nemo-12B — dense, 128k context, head_dim 128.

[hf:mistralai/Mistral-Nemo-Base-2407]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mistral-nemo-12b",
    family="dense",
    num_layers=40,
    d_model=5120,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14_336,
    vocab_size=131_072,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="hf:mistralai/Mistral-Nemo-Base-2407",
)
