"""Architecture config system.

Every assigned architecture is expressed as an ``ArchConfig``. The decoder in
``repro.models.transformer`` is driven entirely by this config; no
architecture has bespoke model code outside the layer library.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    d_ff: int
    vocab_size: int
    num_heads: int = 0
    num_kv_heads: int = 0
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention features
    qk_norm: bool = False
    attn_softcap: Optional[float] = None    # gemma2: 50.0 on attention logits
    final_softcap: Optional[float] = None   # gemma2: 30.0 on lm logits
    sliding_window: Optional[int] = None    # window for 'local' layers
    local_global_pattern: bool = False      # gemma2: alternate local/global
    rope_theta: float = 10_000.0

    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_capacity_factor: float = 2.0

    # SSM (Mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2-style): shared attention block every N ssm layers
    shared_attn_every: int = 0

    # input modality
    input_mode: str = "tokens"        # tokens | embeddings | vlm
    num_prefix_embeds: int = 0        # vlm: number of vision patch embeddings

    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    dtype: str = "bfloat16"

    # citation for the config numbers
    source: str = ""

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def attn_free(self) -> bool:
        return self.family == "ssm"

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    def layer_kinds(self) -> Tuple[str, ...]:
        """Per-layer mixer kind: 'attn' | 'local' | 'mamba'."""
        if self.family in ("ssm", "hybrid"):
            return ("mamba",) * self.num_layers
        if self.local_global_pattern:
            # gemma2: even layers local (sliding window), odd layers global
            return tuple(
                "local" if i % 2 == 0 else "attn" for i in range(self.num_layers)
            )
        return ("attn",) * self.num_layers

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        n = V * d  # embedding
        if not self.tie_embeddings:
            n += V * d
        per_attn = 0
        if self.num_heads:
            qdim = self.num_heads * self.head_dim
            kvdim = self.num_kv_heads * self.head_dim
            per_attn = d * qdim + 2 * d * kvdim + qdim * d
            if self.qk_norm:
                per_attn += 2 * self.head_dim
        per_mlp = 3 * d * ff if ff else 0
        if self.is_moe:
            per_mlp = self.num_experts * 3 * d * ff + d * self.num_experts
        per_mamba = 0
        if self.family in ("ssm", "hybrid"):
            di, G, N, H = self.ssm_inner, 1, self.ssm_state, self.ssm_heads
            per_mamba = (
                d * (2 * di + 2 * G * N + H)  # in_proj (x,z,B,C,dt)
                + self.ssm_conv_width * (di + 2 * G * N)
                + 3 * H  # A_log, D, dt_bias
                + di     # gated norm
                + di * d  # out_proj
            )
        kinds = self.layer_kinds()
        for k in kinds:
            n += 2 * d  # block norms
            if k == "mamba":
                n += per_mamba
            else:
                n += per_attn + per_mlp
        if self.family == "hybrid":
            n += per_mlp  # ssm layers have no mlp; hybrid shared block has one
        if self.family in ("dense", "moe", "vlm", "audio") or self.local_global_pattern:
            pass
        if self.shared_attn_every:
            # one shared attention+mlp block (zamba2)
            qdim = self.num_heads * self.head_dim
            kvdim = self.num_kv_heads * self.head_dim
            n += d * qdim + 2 * d * kvdim + qdim * d + 3 * d * self.d_ff + 2 * d
        n += d  # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        inactive = self.num_layers * (self.num_experts - self.top_k) * 3 * d * ff
        return self.param_count() - inactive

    def reduced(self, **overrides) -> "ArchConfig":
        """Tiny same-family variant for CPU smoke tests."""
        small = dict(
            name=self.name + "-reduced",
            num_layers=2,
            d_model=min(self.d_model, 256),
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            head_dim=64 if self.num_heads else 0,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            top_k=min(self.top_k, 2) if self.top_k else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_chunk=32,
            sliding_window=min(self.sliding_window, 32) if self.sliding_window else None,
            shared_attn_every=2 if self.shared_attn_every else 0,
            num_prefix_embeds=min(self.num_prefix_embeds, 8),
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)

    def with_sliding_window(self, window: int = 4096) -> "ArchConfig":
        """Sub-quadratic variant for long_500k on otherwise-full-attention archs."""
        if self.family in ("ssm",):
            return self
        return dataclasses.replace(
            self,
            sliding_window=window if self.sliding_window is None else self.sliding_window,
            local_global_pattern=self.local_global_pattern,
            name=self.name if self.sliding_window or self.local_global_pattern
            else self.name + "-sw",
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
