"""Gemma2-27B — dense, alternating local(4k)/global attention, logit softcaps.

[arXiv:2408.00118]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36_864,
    vocab_size=256_000,
    attn_softcap=50.0,
    final_softcap=30.0,
    sliding_window=4096,
    local_global_pattern=True,
    tie_embeddings=True,
    source="arXiv:2408.00118",
)
