"""Zamba2-2.7B — Mamba2 backbone + shared attention block.

[arXiv:2411.15242]; shared transformer block applied every 6 mamba layers
(weights shared across applications; the published model adds per-invocation
LoRA deltas, which we omit — noted in DESIGN.md).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10_240,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_attn_every=6,
    tie_embeddings=True,
    source="arXiv:2411.15242",
)
