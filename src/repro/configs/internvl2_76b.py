"""InternVL2-76B — InternViT + InternLM2(llama3-70b-class) decoder.

[arXiv:2404.16821]. The InternViT vision tower + MLP projector are STUBBED
per the assignment: ``input_specs`` feeds 256 precomputed patch embeddings
per image, prepended to the text token embeddings. The 80-layer language
decoder is fully implemented.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-76b",
    family="vlm",
    num_layers=80,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28_672,
    vocab_size=128_256,
    input_mode="vlm",
    num_prefix_embeds=256,
    rope_theta=500_000.0,
    tie_embeddings=False,
    source="arXiv:2404.16821",
)
