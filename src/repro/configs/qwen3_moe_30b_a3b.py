"""Qwen3-MoE 30B (3B active) — 128 experts, top-8, qk_norm.

[hf:Qwen/Qwen3-30B-A3B]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=768,           # per-expert ffn width
    vocab_size=151_936,
    num_experts=128,
    top_k=8,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="hf:Qwen/Qwen3-30B-A3B",
)
