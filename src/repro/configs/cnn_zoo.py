"""The paper's own evaluation models (Table 4) as engine-buildable configs.

These are CNN chains for the cold-inference engine (host-scale), not
ArchConfigs for the distributed decoder — kept separate deliberately. Sizes
are scaled for this container; ``width``/``image`` control cost.
[ResNet: He'16; MobileNet: Howard'17; SqueezeNet: Iandola'16; AlexNet:
Krizhevsky'12]
"""
from repro.models.cnn import build_cnn, CNN_NAMES  # noqa: F401

CONFIGS = {name: name for name in CNN_NAMES}
