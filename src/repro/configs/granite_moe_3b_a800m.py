"""Granite-MoE 3B (800M active) — 40 experts, top-8.

[hf:ibm-granite/granite-3.0-1b-a400m-base family, scaled per assignment]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,           # per-expert ffn width
    vocab_size=49_155,
    num_experts=40,
    top_k=8,
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
