"""Qwen3-32B — dense, qk_norm, GQA.

[hf:Qwen/Qwen3-8B family card, scaled per assignment]
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-32b",
    family="dense",
    num_layers=64,
    d_model=5120,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=25_600,
    vocab_size=151_936,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=False,
    source="hf:Qwen/Qwen3-8B",
)
