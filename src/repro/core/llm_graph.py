"""Cold-start LLM serving: express a transformer as a ColdEngine layer graph.

Each decoder block is one schedulable unit ('tblock') whose weights stream
from disk, so the paper's three knobs apply to LLM serving directly:
  K — kernel selection: `f32_direct` (read f32 master weights, cast at
      execute) vs `bf16_cast` (weights transformed to bf16 — when cached,
      HALF the disk bytes per cold read; numerically identical to the bf16
      model definition, so zero accuracy loss w.r.t. the deployed model);
  C — cache the post-transformed (bf16) weights on disk;
  P — pipeline block weight reads with execution: the first blocks compute
      while later blocks are still loading — cold first-token latency
      approaches warm prefill latency.

The graph is embed -> L× tblock -> final_norm+lm_head, all chain-shaped (the
engine's dependency model); residual adds live inside each block unit.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig
from repro.core.engine import LayerDef
from repro.core.registry import (
    Kernel, KERNEL_REGISTRY, LOSSY_KERNELS, LayerSpec,
)
from repro.models import layers as L


def _block_forward(w: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ArchConfig,
                   dtype) -> jnp.ndarray:
    B, S, _ = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    wd = {k: v.astype(dtype) for k, v in w.items()}
    p = {"wq": wd["wq"], "wk": wd["wk"], "wv": wd["wv"], "wo": wd["wo"]}
    if cfg.qk_norm:
        p["q_norm"], p["k_norm"] = wd["q_norm"], wd["k_norm"]
    h = L.rms_norm(x, wd["ln1"], cfg.norm_eps)
    attn, _ = L.attn_apply_seq(p, h, cfg, positions,
                               window=cfg.sliding_window)
    x = x + attn
    h = L.rms_norm(x, wd["ln2"], cfg.norm_eps)
    mlp = L.mlp_apply(
        {"w_gate": wd["w_gate"], "w_up": wd["w_up"], "w_down": wd["w_down"]}, h)
    return x + mlp


class TBlockF32Direct(Kernel):
    """Read f32 master weights, cast to bf16 at execute — zero transform."""
    name = "f32_direct"
    op_type = "tblock"

    def execute(self, w, x, spec):
        return _block_forward(w, x, spec.config["cfg"], jnp.bfloat16)


class TBlockBf16(Kernel):
    """Transform = cast the block to bf16 (the deployed precision): cached
    post-transform weights are HALF the raw bytes -> ~2x faster cold reads.
    Bit-identical to f32_direct's execution (both run the block in bf16)."""
    name = "bf16_cast"
    op_type = "tblock"

    def transform(self, raw, spec):
        return {k: np.asarray(jnp.asarray(v, jnp.bfloat16))
                for k, v in raw.items()}

    def execute(self, w, x, spec):
        return _block_forward(w, x, spec.config["cfg"], jnp.bfloat16)


class EmbedDirect(Kernel):
    name = "direct"
    op_type = "embed"

    def execute(self, w, x, spec):
        return w["embed"].astype(jnp.bfloat16)[x]


class EmbedBf16(Kernel):
    name = "bf16_cast"
    op_type = "embed"

    def transform(self, raw, spec):
        return {"embed": np.asarray(jnp.asarray(raw["embed"], jnp.bfloat16))}

    def execute(self, w, x, spec):
        return w["embed"][x]


class HeadDirect(Kernel):
    name = "direct"
    op_type = "lmhead"

    def execute(self, w, x, spec):
        cfg = spec.config["cfg"]
        h = L.rms_norm(x, w["final_norm"].astype(jnp.bfloat16), cfg.norm_eps)
        return (h @ w["w"].astype(jnp.bfloat16)).astype(jnp.float32)


class HeadBf16(Kernel):
    name = "bf16_cast"
    op_type = "lmhead"

    def transform(self, raw, spec):
        return {k: np.asarray(jnp.asarray(v, jnp.bfloat16))
                for k, v in raw.items()}

    def execute(self, w, x, spec):
        cfg = spec.config["cfg"]
        h = L.rms_norm(x, w["final_norm"], cfg.norm_eps)
        return (h @ w["w"]).astype(jnp.float32)


def _dequant(w: Dict[str, jnp.ndarray], spec: LayerSpec
             ) -> Dict[str, jnp.ndarray]:
    """Expand a companion-key weight dict (``repro.quant`` convention) to a
    plain dict: int8/int4 tensors dequantized to f32 in-graph, everything
    else passed through. Logical K of a packed int4 tensor comes from the
    layer spec (static under jit)."""
    out: Dict[str, jnp.ndarray] = {}
    for k, v in w.items():
        if k.endswith(":qscale") or k.endswith(":qzero"):
            continue
        if k.endswith(":q8"):
            base = k[: -len(":q8")]
            out[base] = v.astype(jnp.float32) * w[base + ":qscale"]
        elif k.endswith(":q4"):
            base = k[: -len(":q4")]
            K = spec.weight_shapes[base][0]
            p = v.astype(jnp.int32)
            lo = p & 0x0F
            hi = (p >> 4) & 0x0F
            lo = jnp.where(lo >= 8, lo - 16, lo)
            hi = jnp.where(hi >= 8, hi - 16, hi)
            q = jnp.stack([lo, hi], axis=1).reshape(
                2 * p.shape[0], p.shape[1])[:K]
            out[base] = q.astype(jnp.float32) * w[base + ":qscale"]
        else:
            out[k] = v
    return out


class TBlockInt8(Kernel):
    """Quantized transform cache for a decoder block: every 2-D matmul
    operand stored as per-channel int8 (+f32 scales in the extent header),
    1-D norm gains as bf16 — ~4x fewer cold cache bytes than f32, ~2x
    fewer than bf16_cast. Execution dequantizes in-graph and runs the same
    bf16 block forward. Lossy (bounded per-weight error), so gated behind
    the engine's ``allow_lossy``."""
    name = "int8"
    op_type = "tblock"
    bits = 8

    def transform(self, raw, spec):
        from repro import quant

        out = quant.quantize_weights(raw, bits=self.bits)
        return {k: (np.asarray(jnp.asarray(v, jnp.bfloat16))
                    if getattr(v, "ndim", 0) == 1 else v)
                for k, v in out.items()}

    def execute(self, w, x, spec):
        return _block_forward(_dequant(w, spec), x, spec.config["cfg"],
                              jnp.bfloat16)


class TBlockInt4(TBlockInt8):
    """Nibble-packed int4 block cache: ~8x fewer cold cache bytes than f32
    — the last rung of the read-bytes ladder; coarser than int8."""
    name = "int4"
    bits = 4


class HeadInt8(Kernel):
    """lm_head with the vocab-projection matrix as per-channel int8."""
    name = "int8"
    op_type = "lmhead"
    bits = 8

    def transform(self, raw, spec):
        from repro import quant

        out = quant.quantize_weights(raw, bits=self.bits)
        return {k: (np.asarray(jnp.asarray(v, jnp.bfloat16))
                    if getattr(v, "ndim", 0) == 1 else v)
                for k, v in out.items()}

    def execute(self, w, x, spec):
        cfg = spec.config["cfg"]
        wd = _dequant(w, spec)
        h = L.rms_norm(x, wd["final_norm"].astype(jnp.bfloat16), cfg.norm_eps)
        return (h @ wd["w"].astype(jnp.bfloat16)).astype(jnp.float32)


class HeadInt4(HeadInt8):
    name = "int4"
    bits = 4


KERNEL_REGISTRY.setdefault("tblock", [TBlockF32Direct(), TBlockBf16()])
KERNEL_REGISTRY.setdefault("embed", [EmbedDirect(), EmbedBf16()])
KERNEL_REGISTRY.setdefault("lmhead", [HeadDirect(), HeadBf16()])
# quantized variants are lossy: eligible only under the engine's allow_lossy
# (embed stays unquantized — it's a gather, not a matmul, and its rows feed
# the residual stream directly)
LOSSY_KERNELS.setdefault("tblock", [TBlockInt8(), TBlockInt4()])
LOSSY_KERNELS.setdefault("lmhead", [HeadInt8(), HeadInt4()])


def build_llm_graph(cfg: ArchConfig, params) -> Tuple[List[LayerDef], np.ndarray]:
    """Convert dense-family transformer params (from T.init_params) into an
    engine graph + an example token batch. Raw storage is f32 (the master
    checkpoint); execution is bf16 (the deployed precision)."""
    assert cfg.family in ("dense",), "cold-LLM graph demo targets dense archs"
    defs: List[LayerDef] = []

    def f32(a):
        return np.asarray(jnp.asarray(a, jnp.float32))

    defs.append(LayerDef(
        spec=LayerSpec("embed", "embed", {"cfg": cfg},
                       {"embed": tuple(params["embed"].shape)}),
        weights={"embed": f32(params["embed"])},
    ))
    blocks = params["blocks"]
    for i in range(cfg.num_layers):
        bw = {
            "ln1": f32(blocks["ln1"][i]), "ln2": f32(blocks["ln2"][i]),
            "wq": f32(blocks["attn"]["wq"][i]),
            "wk": f32(blocks["attn"]["wk"][i]),
            "wv": f32(blocks["attn"]["wv"][i]),
            "wo": f32(blocks["attn"]["wo"][i]),
            "w_gate": f32(blocks["mlp"]["w_gate"][i]),
            "w_up": f32(blocks["mlp"]["w_up"][i]),
            "w_down": f32(blocks["mlp"]["w_down"][i]),
        }
        if cfg.qk_norm:
            bw["q_norm"] = f32(blocks["attn"]["q_norm"][i])
            bw["k_norm"] = f32(blocks["attn"]["k_norm"][i])
        defs.append(LayerDef(
            spec=LayerSpec(f"block{i:03d}", "tblock", {"cfg": cfg},
                           {k: tuple(v.shape) for k, v in bw.items()}),
            weights=bw,
        ))
    head_w = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    defs.append(LayerDef(
        spec=LayerSpec("lm_head", "lmhead", {"cfg": cfg},
                       {"w": tuple(head_w.shape),
                        "final_norm": tuple(params["final_norm"].shape)}),
        weights={"w": f32(head_w), "final_norm": f32(params["final_norm"])},
    ))
    rng = np.random.default_rng(0)
    x = rng.integers(0, cfg.vocab_size, size=(1, 64)).astype(np.int32)
    return defs, x


def tiny_llm_graph(num_layers: int = 8, *, seed: int = 0
                   ) -> Tuple[List[LayerDef], np.ndarray]:
    """A small dense graph with ``num_layers`` byte-identical decoder blocks
    — the canonical shape-class workload for tests and the
    ``plan_generation`` benchmark: all tblocks fall into ONE shape class, so
    ``decide()`` should profile/compile each kernel once, not L times."""
    from repro.configs import get_config
    from repro.models import transformer as T

    cfg = get_config("smollm-360m").reduced(
        num_layers=num_layers, d_model=128, d_ff=256, num_heads=2,
        num_kv_heads=1, head_dim=64, vocab_size=512)
    params = T.init_params(jax.random.PRNGKey(seed), cfg)
    return build_llm_graph(cfg, params)
