"""Executable ("shader") cache — §3.4 adapted to XLA.

On GPU the paper caches compiled SPIR-V shaders to skip shader compilation in
cold inference. The XLA analogue is jit compilation: each (kernel, shape)
pair costs a lower+compile on first use. We cache serialized compiled
executables on disk via ``jax.experimental.serialize_executable`` and restore
them on cold start, turning the compile stage into a (much cheaper) disk
read — exactly the shader-cache trade.

Keys are (kernel, *shape-class*, example shapes, jax/jaxlib version):

  * shape-class instead of layer name — the L byte-identical decoder blocks
    of an LLM graph share ONE compiled executable instead of compiling L
    times (``registry.shape_class_key``);
  * the jax/jaxlib version folded into the key makes entries from another
    runtime miss cleanly instead of relying on a deserialize exception;
  * examples may be real arrays or ``jax.ShapeDtypeStruct`` avatars — the
    cache only lowers, so no weight bytes are needed to compile.
"""
from __future__ import annotations

import functools
import hashlib
import pickle
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax


@functools.lru_cache(maxsize=1)
def _version_tag() -> str:
    """jax/jaxlib versions — constant per process, probed once. Also feeds
    ``profiler.host_fingerprint``."""
    try:
        import jaxlib

        jl = getattr(jaxlib, "__version__", "?")
    except Exception:  # pragma: no cover - jaxlib always ships with jax
        jl = "?"
    return f"{jax.__version__}/{jl}"


def _key(kernel_name: str, ident: str, shapes: Tuple, version: str) -> str:
    h = hashlib.sha1(repr((kernel_name, ident, shapes, version)).encode())
    return h.hexdigest()[:24]


class CompileCache:
    def __init__(self, root: Optional[Path] = None):
        self.root = Path(root) if root else None
        if self.root:
            self.root.mkdir(parents=True, exist_ok=True)
        self.mem: Dict[str, Callable] = {}
        self.stats = {"hits": 0, "misses": 0, "disk_hits": 0,
                      "compile_s": 0.0, "deserialize_s": 0.0}

    def get(self, kernel_name: str, spec, fn: Callable, w_example, x_example,
            *, shape_class: Optional[str] = None):
        """Returns a compiled callable for fn(w, x). ``shape_class`` is the
        sharing identity — all layers of one class get the same executable;
        without it the cache degrades to per-spec keying."""
        shapes = (
            tuple(sorted((k, tuple(v.shape), str(v.dtype))
                         for k, v in w_example.items())),
            (tuple(x_example.shape), str(x_example.dtype)),
        )
        ident = shape_class if shape_class is not None else spec.name
        key = _key(kernel_name, ident, shapes, _version_tag())
        if key in self.mem:
            self.stats["hits"] += 1
            return self.mem[key]
        path = self.root / f"{key}.xla" if self.root else None
        if path and path.exists():
            try:
                from jax.experimental import serialize_executable as se

                t0 = time.perf_counter()
                with open(path, "rb") as f:
                    payload = pickle.load(f)
                compiled = se.deserialize_and_load(*payload)
                self.stats["deserialize_s"] += time.perf_counter() - t0
                self.stats["disk_hits"] += 1
                self.mem[key] = compiled
                return compiled
            except Exception:
                pass  # stale/incompatible cache entry: recompile below
        # jax.jit is only built on a genuine miss — on hits it was dead work
        t0 = time.perf_counter()
        lowered = jax.jit(fn).lower(w_example, x_example)
        compiled = lowered.compile()
        self.stats["compile_s"] += time.perf_counter() - t0
        self.stats["misses"] += 1
        if path:
            try:
                from jax.experimental import serialize_executable as se

                payload = se.serialize(compiled)
                with open(path, "wb") as f:
                    pickle.dump(payload, f)
            except Exception:
                pass
        self.mem[key] = compiled
        return compiled
