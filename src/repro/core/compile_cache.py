"""Executable ("shader") cache — §3.4 adapted to XLA.

On GPU the paper caches compiled SPIR-V shaders to skip shader compilation in
cold inference. The XLA analogue is jit compilation: each (kernel, shape)
pair costs a lower+compile on first use. We cache serialized compiled
executables on disk via ``jax.experimental.serialize_executable`` and restore
them on cold start, turning the compile stage into a (much cheaper) disk
read — exactly the shader-cache trade.
"""
from __future__ import annotations

import hashlib
import pickle
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax


def _key(kernel_name: str, spec_name: str, shapes: Tuple) -> str:
    h = hashlib.sha1(repr((kernel_name, spec_name, shapes)).encode()).hexdigest()
    return h[:24]


class CompileCache:
    def __init__(self, root: Optional[Path] = None):
        self.root = Path(root) if root else None
        if self.root:
            self.root.mkdir(parents=True, exist_ok=True)
        self.mem: Dict[str, Callable] = {}
        self.stats = {"hits": 0, "misses": 0, "disk_hits": 0,
                      "compile_s": 0.0, "deserialize_s": 0.0}

    def get(self, kernel_name: str, spec, fn: Callable, w_example, x_example):
        """Returns a compiled callable for fn(w, x)."""
        shapes = (
            tuple(sorted((k, v.shape, str(v.dtype)) for k, v in w_example.items())),
            (x_example.shape, str(x_example.dtype)),
        )
        key = _key(kernel_name, spec.name, shapes)
        if key in self.mem:
            self.stats["hits"] += 1
            return self.mem[key]
        jitted = jax.jit(fn)
        path = self.root / f"{key}.xla" if self.root else None
        if path and path.exists():
            try:
                from jax.experimental import serialize_executable as se

                t0 = time.perf_counter()
                with open(path, "rb") as f:
                    payload = pickle.load(f)
                compiled = se.deserialize_and_load(*payload)
                self.stats["deserialize_s"] += time.perf_counter() - t0
                self.stats["disk_hits"] += 1
                self.mem[key] = compiled
                return compiled
            except Exception:
                pass  # stale/incompatible cache entry: recompile below
        t0 = time.perf_counter()
        lowered = jitted.lower(w_example, x_example)
        compiled = lowered.compile()
        self.stats["compile_s"] += time.perf_counter() - t0
        self.stats["misses"] += 1
        if path:
            try:
                from jax.experimental import serialize_executable as se

                payload = se.serialize(compiled)
                with open(path, "wb") as f:
                    pickle.dump(payload, f)
            except Exception:
                pass
        self.mem[key] = compiled
        return compiled
