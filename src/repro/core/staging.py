"""Host→device weight staging — the pipeline's 'stage' op.

One subtlety makes this more than a loop of ``jax.device_put``: the CPU
backend zero-copy *aliases* suitably aligned host buffers instead of
copying them. A read-only mmap view from a weight bundle (64-byte-aligned
by construction) staged that way would keep pointing at file-backed pages,
leaving its disk I/O to fault in lazily inside the execute op — exactly
the host-side work staging exists to move off the critical exec chain.

``stage_weights`` therefore materializes read-only (file-backed) views
into anonymous memory first: the stage op pays the page-in and transfer
cost, and execute runs against device-resident buffers that can never
touch the disk. Heap arrays produced by kernel transforms pass straight
through. The profiler uses the same helper, so measured ``stage_s`` is
the cost the runtime actually pays.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import numpy as np


def stage_weights(w: Dict[str, Any]) -> Dict[str, Any]:
    staged = {}
    for k, v in w.items():
        if isinstance(v, np.ndarray) and not v.flags.writeable:
            v = np.array(v)  # fault file-backed pages into anonymous memory
        staged[k] = jax.device_put(v)
    if staged:
        jax.block_until_ready(staged)
    return staged
