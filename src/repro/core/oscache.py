"""OS page-cache control: the paper clears the system file cache before each
cold inference ('To eliminate the impacts of file cache, we clear the system
cache before each cold inference'). Works when running privileged; no-op
otherwise (reported so benchmarks can label their numbers)."""
from __future__ import annotations

import os


def drop_page_cache() -> bool:
    try:
        os.sync()
        with open("/proc/sys/vm/drop_caches", "w") as f:
            f.write("3\n")
        return True
    except (PermissionError, FileNotFoundError, OSError):
        return False


CAN_DROP = drop_page_cache()
