"""Kernel switching for continuous inference — §3.5.

K_cold (the scheduler's choices) can be slower at steady state than K_warm
(fastest-execution kernels). In continuous mode the engine:
  1. runs the cold inference with K_cold as usual;
  2. on idle little-core threads, prepares the kernels in K_warm − K_cold
     (read raw + transform into the warm format, and compile);
  3. switches layer-by-layer: the 2nd inference uses the warm kernel for
     every layer whose preparation finished, pipelining the rest exactly
     like a cold inference (paper: 2nd inference ≈ 8% slower, 3rd equal).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.engine import ColdEngine
from repro.core.pipeline import RunResult, OpTrace
from repro.core.staging import stage_weights
from repro.executor.graph import TaskGraph
from repro.executor.pool import Job


@dataclass
class ContinuousSession:
    engine: ColdEngine
    n_little: int = 3
    warm_weights: Dict[str, Any] = field(default_factory=dict)
    _lock: threading.Lock = field(default_factory=threading.Lock)
    _bg: Optional[Job] = None

    cold_weights: Dict[str, Any] = field(default_factory=dict)

    def cold_infer(self, x) -> RunResult:
        """First inference: K_cold plan + background warm-kernel prep."""
        res = self.engine.run_cold(x, n_little=self.n_little)
        self.cold_weights = {
            k: {k2: jnp.asarray(v2) for k2, v2 in w.items()}
            for k, w in (res.weights or {}).items()
        }
        self._start_background_prep()
        return res

    def _start_background_prep(self):
        """Queue K_warm − K_cold preps as one 'any'-affinity job on the
        persistent pool: idle little workers pick them up between cold
        runs, with no per-call thread creation."""
        eng = self.engine
        warm = eng.warm_best_choices()
        todo = [
            (l, wc) for l, wc, cc in
            zip(eng.layers, warm, eng.plan.choices)
            if wc.kernel != cc.kernel and l.spec.weight_shapes
        ]
        if not todo:
            self._bg = None
            return

        def prep(l, wc):
            def fn():
                kern = eng._kernel_by_name(l.spec, wc.kernel)
                raw = eng.store.read_raw(l.spec.name)
                w = kern.transform(raw, l.spec)
                with self._lock:
                    # stage_weights (not bare jnp.asarray): identity
                    # transforms hand back read-only mmap views, which CPU
                    # XLA would alias — leaving their disk I/O to fault in
                    # during execute
                    self.warm_weights[l.spec.name] = (
                        wc.kernel, stage_weights(w))
            return fn

        g = TaskGraph()
        for l, wc in todo:
            g.add(l.spec.name, "warm_prep", affinity="any", fn=prep(l, wc))
        rt = eng._runtime(n_little=self.n_little, work_stealing=True)
        self._bg = rt._get_pool().submit(g, name="warm-switch")

    def warm_infer(self, x, wait: bool = False) -> RunResult:
        """Subsequent inference: use warm kernels where prepared."""
        eng = self.engine
        if wait and self._bg is not None:
            self._bg.wait()
        t0 = time.perf_counter()
        traces = []
        # weights for layers not yet switched: use the cold plan's kernels
        rt = eng.make_runtime(n_little=self.n_little)
        y = jnp.asarray(x)
        warm = {c.kernel: c for c in eng.warm_best_choices()}
        jitted_warm = eng._jitted_map(eng.warm_best_choices(), eng._input_example)
        jitted_cold = rt.jitted
        for l, cold_choice in zip(eng.layers, eng.plan.choices):
            name = l.spec.name
            with self._lock:
                ready = self.warm_weights.get(name)
            ts = time.perf_counter()
            if ready is not None:
                _, w = ready
                y = jitted_warm[name](w, y)
            elif name in self.cold_weights:
                # unswitched layer: resident K_cold weights from the 1st run
                y = jitted_cold[name](self.cold_weights[name], y)
            else:
                kern = eng._kernel_by_name(l.spec, cold_choice.kernel)
                if cold_choice.use_cache:
                    w = eng.store.read_cached(name, kern.name)
                    if not w and l.spec.weight_shapes:
                        # dropped/torn cache entry: re-derive from raw
                        w = kern.transform(eng.store.read_raw(name), l.spec)
                else:
                    w = kern.transform(eng.store.read_raw(name), l.spec) \
                        if l.spec.weight_shapes else {}
                y = jitted_cold[name](stage_weights(w), y)
            jax.block_until_ready(y)
            traces.append(OpTrace(name, "execute", "big",
                                  ts - t0, time.perf_counter() - t0))
        return RunResult(output=y, total_s=time.perf_counter() - t0,
                         traces=traces)
