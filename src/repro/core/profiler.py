"""Per-operation profiling — the measurement substrate of the decision stage.

The paper profiles read / transform / execute per (layer, kernel) on the real
device; we additionally split out *stage* — the host→device transfer of the
transformed weights (``jax.device_put``) that the pipeline runs as the tail
of each preparation op. With mmap-backed bundles the read op is metadata-
cheap and staging carries the byte movement, so the scheduler needs both
numbers separately. This container has one CPU core, so:

  * `wall` numbers are real measured seconds on this host (real disk reads,
    real transforms, real jitted execution);
  * the big.LITTLE asymmetry is applied through a calibratable ``CoreModel``
    whose default factors follow the paper's Fig. 6 (big core ≈ 6× faster at
    execution, 2× at reads, 3.8× at transforms than a little core) — used by
    the deterministic scheduler simulation (sim mode).

Profiles are cached to JSON next to the model store, and — keyed by shape
class rather than layer name — in a persistent ``ProfileDB`` so a second
``decide()`` (or a sibling model sharing the DB file) skips profiling
entirely.
"""
from __future__ import annotations

import hashlib
import json
import os
import platform
import shutil
import tempfile
import time
from dataclasses import dataclass, field, asdict, replace
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.registry import Kernel, LayerSpec, OpKind
from repro.core.staging import stage_weights


@dataclass(frozen=True)
class CoreModel:
    """Relative op-time multipliers for a little core vs a big core (Fig. 6)."""
    little_exec: float = 6.0
    little_read: float = 2.0
    little_transform: float = 3.8
    # host->device staging is DMA-bound, not core-bound: a little core
    # initiating the transfer is barely slower than a big one
    little_stage: float = 1.2
    n_big: int = 4
    n_little: int = 4
    # multithread scaling on big cores for execution (near-linear, Fig. 6)
    exec_parallel_eff: float = 0.85

    def little_factor(self, kind: OpKind) -> float:
        return {
            OpKind.READ: self.little_read,
            OpKind.TRANSFORM: self.little_transform,
            OpKind.EXECUTE: self.little_exec,
            OpKind.COMPILE: self.little_transform,
            OpKind.STAGE: self.little_stage,
        }[kind]


@dataclass
class OpProfile:
    layer: str
    kernel: str
    read_raw_s: float
    transform_s: float
    read_cached_s: float
    exec_s: float
    compile_s: float
    raw_bytes: int
    transformed_bytes: int
    # host->device transfer of the transformed weights (the pipeline's new
    # 'stage' op). Defaults to 0 so pre-split profile JSONs still load.
    stage_s: float = 0.0
    # shapes/dtypes of the TRANSFORMED weights: {name: [shape, dtype_str]}.
    # Lets the engine build jax.ShapeDtypeStruct avatars for compilation
    # without re-reading + re-transforming real weights per layer.
    transformed_avatars: Optional[Dict[str, Any]] = None

    def prep_s(self, use_cache: bool, *, include_stage: bool = True) -> float:
        """Full preparation time on a BIG core: read (+transform) + device
        staging. ``include_stage=False`` gives the legacy read/transform-only
        number for read-vs-stage breakdowns."""
        io = self.read_cached_s if use_cache else self.read_raw_s + self.transform_s
        return io + (self.stage_s if include_stage else 0.0)

    def to_dict(self):
        return asdict(self)


def avatars_of(weights: Dict[str, Any]) -> Dict[str, Any]:
    """JSON-able {name: [shape, dtype_str]} description of a weight dict —
    the transformed-weight avatars ``OpProfile`` carries and the engine
    rehydrates into ``jax.ShapeDtypeStruct`` examples for compilation."""
    return {k: [list(np.asarray(v).shape), str(np.asarray(v).dtype)]
            for k, v in weights.items()}


def _time(fn, *args, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


class Profiler:
    """Measures one (layer, kernel) pair. Candidate transformed weights are
    written to a private *scratch* directory for cached-read timing — never
    to the model store: only ``decide()`` materializes the chosen entries
    (with ``fmt="super"`` a store write is a container rewrite, so a
    profiling pass that wrote every candidate would rewrite the whole model
    file once per candidate)."""

    def __init__(self, store, repeats: int = 3, cold_reads: bool = True):
        self.store = store  # checkpoint.LayerStore
        self.repeats = repeats
        self.cold_reads = cold_reads
        self._scratch: Optional[Path] = None
        self.calls = 0

    @property
    def scratch(self) -> Path:
        if self._scratch is None:
            self._scratch = Path(tempfile.mkdtemp(prefix="nnv12_prof_"))
        return self._scratch

    def close(self):
        if self._scratch is not None:
            shutil.rmtree(self._scratch, ignore_errors=True)
            self._scratch = None

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    def _time_read(self, fn) -> float:
        """Disk-read timing. With cold_reads (and privilege) the OS page
        cache is dropped first, like the paper's methodology; otherwise the
        warm-cache read time is reported."""
        from repro.core.oscache import CAN_DROP, drop_page_cache

        if self.cold_reads and CAN_DROP:
            drop_page_cache()
            t0 = time.perf_counter()
            fn()
            return time.perf_counter() - t0
        return _time(fn, repeats=self.repeats)

    def profile(
        self, spec: LayerSpec, kernel: Kernel, x: np.ndarray,
    ) -> OpProfile:
        import jax.numpy as jnp

        # Reads are profiled MATERIALIZING (mmap=False) so the read term
        # keeps meaning "move the layer's bytes off the disk" — measurable
        # cold and scalable by the co-read interference factor. The runtime's
        # mmap read is lazier (its payload I/O surfaces inside transform/
        # stage on first touch), but read+transform+stage is scheduled as
        # ONE prep op, so only the total matters — and the total matches.
        def _read_raw():
            return self.store.read_raw(spec.name, mmap=False)

        self.calls += 1
        # pin the store's one-off lazy CRC audit outside the timed region —
        # it must not inflate the profiled read cost
        warm = getattr(self.store, "warm_verify", None)
        if warm is not None:
            warm([spec.name])
        raw = self.store.read_raw(spec.name)
        t_read = self._time_read(_read_raw)
        if spec.weight_shapes:
            from repro.checkpoint.bundle import read_bundle, write_bundle

            t_transform = _time(lambda: kernel.transform(raw, spec), repeats=self.repeats)
            transformed = kernel.transform(raw, spec)
            # cached-read timing goes through a scratch bundle, NOT the
            # model store — decide() drops the losers, and a super-bundle
            # store would pay one container rewrite per candidate
            scratch = self.scratch / f"{spec.name.replace('/', '_')}.{kernel.name}.bundle"
            write_bundle(scratch, transformed)
            try:
                t_read_cached = self._time_read(
                    lambda: read_bundle(scratch, mmap=False))
            finally:
                scratch.unlink(missing_ok=True)
            tbytes = sum(v.nbytes for v in transformed.values())
            rbytes = sum(v.nbytes for v in raw.values())
        else:
            t_transform, t_read_cached, tbytes, rbytes = 0.0, 0.0, 0, 0
            transformed = raw
        # stage: host->device transfer of the transformed weights — the
        # pipeline runs this as part of prep, so the scheduler must see it
        # split out from the (now metadata-cheap, mmap-backed) read
        if transformed:
            t_stage = _time(lambda: stage_weights(transformed),
                            repeats=self.repeats)
        else:
            t_stage = 0.0
        wj = {k: jnp.asarray(v) for k, v in transformed.items()}
        xj = jnp.asarray(x)
        fn = jax.jit(lambda w, x: kernel.execute(w, x, spec))
        t0 = time.perf_counter()
        y = fn(wj, xj)
        jax.block_until_ready(y)
        t_compile_and_first = time.perf_counter() - t0
        t_exec = _time(lambda: jax.block_until_ready(fn(wj, xj)), repeats=self.repeats)
        return OpProfile(
            layer=spec.name, kernel=kernel.name,
            read_raw_s=t_read, transform_s=t_transform,
            read_cached_s=t_read_cached, exec_s=t_exec,
            compile_s=max(t_compile_and_first - t_exec, 0.0),
            raw_bytes=rbytes, transformed_bytes=tbytes,
            stage_s=t_stage,
            transformed_avatars=avatars_of(transformed),
        )


def measure_read_interference(store, layer_names, n_threads: int = 3) -> float:
    """§3.2: co-running read operations interfere through shared disk
    bandwidth. Measures the real slowdown factor on this host: wall time of
    n_threads concurrent cold reads of different layers vs the same reads
    serial. Returns per-op slowdown ≥ 1 (1.0 = no interference)."""
    import threading

    from repro.core.oscache import CAN_DROP, drop_page_cache

    names = [n for n in layer_names if store.raw_bytes(n) > 0][: n_threads * 2]
    if len(names) < 2:
        return 1.0
    names = names[:n_threads]

    # force materializing reads: with mmap-backed bundles the default read is
    # metadata-only and would measure nothing about disk bandwidth
    def _read(n):
        try:
            store.read_raw(n, mmap=False)
        except TypeError:  # stores without an mmap switch
            store.read_raw(n)

    # land the store's one-off lazy CRC audit now so neither timed pass
    # pays it
    warm = getattr(store, "warm_verify", None)
    if warm is not None:
        warm(names)

    if CAN_DROP:
        drop_page_cache()
    t0 = time.perf_counter()
    for n in names:
        _read(n)
    serial = time.perf_counter() - t0

    if CAN_DROP:
        drop_page_cache()
    threads = [threading.Thread(target=_read, args=(n,))
               for n in names]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    concurrent = time.perf_counter() - t0
    # perfect overlap -> concurrent == serial/n; full serialization ->
    # concurrent == serial. slowdown per op = concurrent * n / serial.
    return max(1.0, concurrent * len(names) / max(serial, 1e-9))


def save_profiles(path: Path, profiles: Dict[str, List[OpProfile]]):
    from repro.checkpoint import atomic_write_text

    out = {k: [p.to_dict() for p in v] for k, v in profiles.items()}
    atomic_write_text(Path(path), json.dumps(out, indent=1))


def load_profiles(path: Path) -> Optional[Dict[str, List[OpProfile]]]:
    if not path.exists():
        return None
    raw = json.loads(path.read_text())
    return {k: [OpProfile(**d) for d in v] for k, v in raw.items()}


class SyntheticProfiler(Profiler):
    """Deterministic profiles derived from shapes alone — no disk reads, no
    jit, no clocks. Costs are a pure function of (shape class, kernel), so
    byte-identical layers get bit-identical numbers: the substrate for the
    shared-vs-per-layer plan-equivalence gates in tests and
    ``benchmarks/plan_generation.py``."""

    GB_S = 1.0e9       # synthetic disk bandwidth
    # compute is much faster than disk on the modeled edge device (cold
    # inference is I/O-bound — §2): exec/dequant run at this bandwidth, so
    # Algorithm 1's read-vs-exec trade deterministically favors entries
    # that shrink the cold read unless their exec surcharge is outsized
    EXEC_GB_S = 24.0e9

    def profile(self, spec: LayerSpec, kernel: Kernel, x: np.ndarray) -> OpProfile:
        self.calls += 1
        raw = {k: np.zeros(s, np.float32)
               for k, s in spec.weight_shapes.items()}
        transformed = kernel.transform(raw, spec) if spec.weight_shapes else {}
        rbytes = sum(v.nbytes for v in raw.values())
        tbytes = sum(np.asarray(v).nbytes for v in transformed.values())
        # per-kernel multipliers from a stable hash — kernels trade off
        # transform vs execute like real ones, deterministically
        h = int(hashlib.sha1(kernel.name.encode()).hexdigest()[:8], 16)
        t_mult = 0.5 + (h % 997) / 997.0
        e_mult = 0.5 + ((h >> 8) % 997) / 997.0
        xbytes = int(np.asarray(x).nbytes)
        # exec cost is based on LOGICAL bytes (a FLOP proxy): a compressed
        # cache entry (bf16, int8, int4) shrinks the read, not the matmul.
        # Quantized transforms additionally pay a dequant surcharge — smaller
        # reads buy nonzero extra execute time, which is exactly the trade
        # Algorithm 1 must see deterministically
        from repro import quant

        ebytes = max(tbytes, rbytes)
        dequant_s = 0.0
        if transformed and quant.is_quantized(transformed):
            ebytes = max(quant.logical_nbytes(transformed), rbytes)
            # one extra compute-bandwidth pass over the quantized payload:
            # the fused kernels unpack/scale in VMEM with the per-channel
            # scale factored out of the K loop (repro.kernels.quant)
            dequant_s = tbytes / self.EXEC_GB_S
        return OpProfile(
            layer=spec.name, kernel=kernel.name,
            read_raw_s=rbytes / self.GB_S + 1e-5,
            transform_s=t_mult * tbytes / self.GB_S,
            read_cached_s=tbytes / self.GB_S + 1e-5,
            exec_s=e_mult * (ebytes + xbytes) / self.EXEC_GB_S
                   + dequant_s + 1e-6,
            compile_s=1e-3,
            raw_bytes=rbytes, transformed_bytes=tbytes,
            stage_s=tbytes / (4 * self.GB_S),
            transformed_avatars=avatars_of(transformed),
        )


# ---------------------------------------------------------------------------
# persistent profile DB — shape-class keyed, host-scoped
# ---------------------------------------------------------------------------
def host_fingerprint() -> str:
    """Identity of the measuring host: profiles are wall-clock measurements,
    so entries from a different machine/CPU count/jax build must miss."""
    from repro.core.compile_cache import _version_tag

    parts = [platform.system(), platform.machine(),
             str(os.cpu_count()), _version_tag()]
    return hashlib.sha1("|".join(parts).encode()).hexdigest()[:16]


class ProfileDB:
    """Persistent (shape-class × kernel) -> OpProfile store.

    Lives as one JSON file (by default next to the model store), keyed by
    the canonical shape-class hash (``registry.shape_class_key``) + kernel
    name, scoped by ``host_fingerprint()``. A second ``decide()`` on the
    same model — or a first ``decide()`` on a sibling model whose layers
    fall into already-measured shape classes — performs zero
    ``Profiler.profile`` calls. ``force_reprofile`` bypasses reads and
    overwrites on save."""

    VERSION = 2

    def __init__(self, path: Path):
        self.path = Path(path)
        self.host = host_fingerprint()
        # all hosts' entries are kept side by side: a shared DB file (two
        # machines, or two jax builds on one machine) must not clobber the
        # other host's profiles on save
        self._hosts: Dict[str, Dict[str, Dict[str, dict]]] = {}
        self.entries: Dict[str, Dict[str, dict]] = {}
        # sibling index (batch-agnostic fan-out): sibling_key -> list of
        # exact shape classes profiled under it, per host. Approximate
        # lookups resolve through it AFTER the exact key misses.
        self._host_siblings: Dict[str, Dict[str, List[str]]] = {}
        self.siblings: Dict[str, List[str]] = {}
        # host-fingerprint drift: when this host has NO entries but another
        # fingerprint in the same file does (same machine after a jax
        # upgrade / CPU-count change), that host's entries are kept as
        # STALE fallbacks — ``get`` serves them (so the cold path never
        # pays in-line re-profiling for a fingerprint bump) and records the
        # key in ``self.stale`` so background re-profiling (the server's
        # idle tick → ``ColdEngine.reprofile_stale``) can refresh them off
        # the request path. ``put`` un-stales a key.
        self._stale_entries: Dict[str, Dict[str, dict]] = {}
        self.stale: set = set()          # (shape_class, kernel) served stale
        self.drifted_from: Optional[str] = None
        self.stats = {"hits": 0, "misses": 0, "approx_hits": 0,
                      "stale_hits": 0}
        self._dirty = False
        self._load()

    def _load(self):
        if not self.path.exists():
            return
        try:
            raw = json.loads(self.path.read_text())
        except Exception:
            return  # torn/corrupt DB: reprofile
        if raw.get("version") != self.VERSION:
            return  # different schema: everything misses cleanly
        self._hosts = raw.get("hosts", {})
        self.entries = self._hosts.get(self.host, {})
        # optional key: DB files from before the sibling index load fine
        self._host_siblings = raw.get("siblings", {})
        self.siblings = self._host_siblings.get(self.host, {})
        if not self.entries:
            # fingerprint drift: adopt the richest other host's entries as
            # stale estimates (measurements of the right shapes on almost
            # this machine beat re-profiling on the cold path)
            donors = [h for h in self._hosts if h != self.host
                      and self._hosts[h]]
            if donors:
                self.drifted_from = max(
                    donors, key=lambda h: sum(len(v) for v
                                              in self._hosts[h].values()))
                self._stale_entries = self._hosts[self.drifted_from]

    def get(self, shape_class: str, kernel: str, *,
            sibling_key: Optional[str] = None,
            approx: bool = False) -> Optional[OpProfile]:
        """Exact (shape-class, kernel) lookup; with ``approx=True`` and a
        ``sibling_key``, a miss falls through to any already-profiled class
        that differs only in the batch dim (``shape_class_sibling_key``).
        Exact entries always win — the approximate rung only spares a
        profiling call when nothing exact exists, and its per-op costs are
        estimates for candidate ranking, never correctness inputs."""
        d = self.entries.get(shape_class, {}).get(kernel)
        if d is not None:
            self.stats["hits"] += 1
            return OpProfile(**d)
        # stale (drifted-host) exact entry: same shapes, almost this host —
        # served so decide() stays off the profiler, marked for background
        # refresh. Checked before the approx rung: an exact-shape stale
        # measurement beats a fresh sibling estimate.
        d = self._stale_entries.get(shape_class, {}).get(kernel)
        if d is not None:
            self.stats["stale_hits"] += 1
            self.stale.add((shape_class, kernel))
            return OpProfile(**d)
        if approx and sibling_key is not None:
            for sc in self.siblings.get(sibling_key, ()):
                if sc == shape_class:
                    continue
                d = self.entries.get(sc, {}).get(kernel)
                if d is not None:
                    self.stats["approx_hits"] += 1
                    return OpProfile(**d)
        self.stats["misses"] += 1
        return None

    def put(self, shape_class: str, kernel: str, profile: OpProfile, *,
            sibling_key: Optional[str] = None):
        self.entries.setdefault(shape_class, {})[kernel] = asdict(profile)
        # a fresh measurement supersedes the drifted-host fallback
        self.stale.discard((shape_class, kernel))
        if sibling_key is not None:
            sibs = self.siblings.setdefault(sibling_key, [])
            if shape_class not in sibs:
                sibs.append(shape_class)
        self._dirty = True

    def stale_pending(self) -> List[tuple]:
        """(shape_class, kernel) keys served stale and not yet re-measured —
        the background re-profiling work list."""
        return sorted(self.stale)

    def save(self):
        from repro.checkpoint import atomic_write_text

        if not self._dirty:
            return
        self._hosts[self.host] = self.entries
        if self.siblings:
            self._host_siblings[self.host] = self.siblings
        self.path.parent.mkdir(parents=True, exist_ok=True)
        # durable commit: the DB is the cross-decide()/cross-model profile
        # substrate — a torn file would silently force a full reprofile
        atomic_write_text(self.path, json.dumps({
            "version": self.VERSION, "hosts": self._hosts,
            "siblings": self._host_siblings}, indent=1),
            durable=True)
        self._dirty = False
