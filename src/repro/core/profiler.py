"""Per-operation profiling — the measurement substrate of the decision stage.

The paper profiles read / transform / execute per (layer, kernel) on the real
device. This container has one CPU core, so:

  * `wall` numbers are real measured seconds on this host (real disk reads,
    real transforms, real jitted execution);
  * the big.LITTLE asymmetry is applied through a calibratable ``CoreModel``
    whose default factors follow the paper's Fig. 6 (big core ≈ 6× faster at
    execution, 2× at reads, 3.8× at transforms than a little core) — used by
    the deterministic scheduler simulation (sim mode).

Profiles are cached to JSON next to the model store.
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field, asdict
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core.registry import Kernel, LayerSpec, OpKind


@dataclass(frozen=True)
class CoreModel:
    """Relative op-time multipliers for a little core vs a big core (Fig. 6)."""
    little_exec: float = 6.0
    little_read: float = 2.0
    little_transform: float = 3.8
    n_big: int = 4
    n_little: int = 4
    # multithread scaling on big cores for execution (near-linear, Fig. 6)
    exec_parallel_eff: float = 0.85

    def little_factor(self, kind: OpKind) -> float:
        return {
            OpKind.READ: self.little_read,
            OpKind.TRANSFORM: self.little_transform,
            OpKind.EXECUTE: self.little_exec,
            OpKind.COMPILE: self.little_transform,
        }[kind]


@dataclass
class OpProfile:
    layer: str
    kernel: str
    read_raw_s: float
    transform_s: float
    read_cached_s: float
    exec_s: float
    compile_s: float
    raw_bytes: int
    transformed_bytes: int

    def prep_s(self, use_cache: bool) -> float:
        """read(+transform) time on a BIG core."""
        return self.read_cached_s if use_cache else self.read_raw_s + self.transform_s

    def to_dict(self):
        return asdict(self)


def _time(fn, *args, repeats: int = 3) -> float:
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
    return best


class Profiler:
    def __init__(self, store, repeats: int = 3, cold_reads: bool = True):
        self.store = store  # checkpoint.LayerStore
        self.repeats = repeats
        self.cold_reads = cold_reads

    def _time_read(self, fn) -> float:
        """Disk-read timing. With cold_reads (and privilege) the OS page
        cache is dropped first, like the paper's methodology; otherwise the
        warm-cache read time is reported."""
        from repro.core.oscache import CAN_DROP, drop_page_cache

        if self.cold_reads and CAN_DROP:
            drop_page_cache()
            t0 = time.perf_counter()
            fn()
            return time.perf_counter() - t0
        return _time(fn, repeats=self.repeats)

    def profile(
        self, spec: LayerSpec, kernel: Kernel, x: np.ndarray,
    ) -> OpProfile:
        import jax.numpy as jnp

        raw = self.store.read_raw(spec.name)
        t_read = self._time_read(lambda: self.store.read_raw(spec.name))
        if spec.weight_shapes:
            t_transform = _time(lambda: kernel.transform(raw, spec), repeats=self.repeats)
            transformed = kernel.transform(raw, spec)
            self.store.write_cached(spec.name, kernel.name, transformed)
            t_read_cached = self._time_read(
                lambda: self.store.read_cached(spec.name, kernel.name),
            )
            tbytes = sum(v.nbytes for v in transformed.values())
            rbytes = sum(v.nbytes for v in raw.values())
        else:
            t_transform, t_read_cached, tbytes, rbytes = 0.0, 0.0, 0, 0
            transformed = raw
        wj = {k: jnp.asarray(v) for k, v in transformed.items()}
        xj = jnp.asarray(x)
        fn = jax.jit(lambda w, x: kernel.execute(w, x, spec))
        t0 = time.perf_counter()
        y = fn(wj, xj)
        jax.block_until_ready(y)
        t_compile_and_first = time.perf_counter() - t0
        t_exec = _time(lambda: jax.block_until_ready(fn(wj, xj)), repeats=self.repeats)
        return OpProfile(
            layer=spec.name, kernel=kernel.name,
            read_raw_s=t_read, transform_s=t_transform,
            read_cached_s=t_read_cached, exec_s=t_exec,
            compile_s=max(t_compile_and_first - t_exec, 0.0),
            raw_bytes=rbytes, transformed_bytes=tbytes,
        )


def measure_read_interference(store, layer_names, n_threads: int = 3) -> float:
    """§3.2: co-running read operations interfere through shared disk
    bandwidth. Measures the real slowdown factor on this host: wall time of
    n_threads concurrent cold reads of different layers vs the same reads
    serial. Returns per-op slowdown ≥ 1 (1.0 = no interference)."""
    import threading

    from repro.core.oscache import CAN_DROP, drop_page_cache

    names = [n for n in layer_names if store.raw_bytes(n) > 0][: n_threads * 2]
    if len(names) < 2:
        return 1.0
    names = names[:n_threads]

    if CAN_DROP:
        drop_page_cache()
    t0 = time.perf_counter()
    for n in names:
        store.read_raw(n)
    serial = time.perf_counter() - t0

    if CAN_DROP:
        drop_page_cache()
    threads = [threading.Thread(target=store.read_raw, args=(n,))
               for n in names]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    concurrent = time.perf_counter() - t0
    # perfect overlap -> concurrent == serial/n; full serialization ->
    # concurrent == serial. slowdown per op = concurrent * n / serial.
    return max(1.0, concurrent * len(names) / max(serial, 1e-9))


def save_profiles(path: Path, profiles: Dict[str, List[OpProfile]]):
    out = {k: [p.to_dict() for p in v] for k, v in profiles.items()}
    path.write_text(json.dumps(out, indent=1))


def load_profiles(path: Path) -> Optional[Dict[str, List[OpProfile]]]:
    if not path.exists():
        return None
    raw = json.loads(path.read_text())
    return {k: [OpProfile(**d) for d in v] for k, v in raw.items()}
