"""ColdEngine — the NNV12 workflow (Fig. 4): offline decision generation +
online cold-inference runtime.

Offline ``decide()`` (runs once when a model lands on the device):
  1. partition layers into *shape classes* (``registry.shape_class_key``) and
     profile ONE representative per (shape-class × kernel) — consulting the
     persistent shape-class ``ProfileDB`` first, so a second ``decide()`` or
     a sibling model with equivalent layers skips profiling entirely;
  2. fan the profiles out to every equivalent layer, build per-layer
     candidate lists (kernel × {raw, cached}) and Pareto-filter them once
     per shape class (Algorithm 1 line 1);
  3. run the kernel scheduler (Algorithm 1, memoized/incremental) to get
     the plan;
  4. materialize the post-transformed weight cache for chosen cached layers
     (and drop unused cache entries — storage accounting);
  5. optionally pre-serialize compiled executables (the shader cache),
     shared per (kernel × shape-class): L identical decoder blocks cost one
     lower+compile, with examples built from ``jax.ShapeDtypeStruct``
     avatars instead of reading + transforming real weights per layer.

Online ``run_cold()`` executes the plan with the pipelined runtime;
``run_warm()`` is the steady-state path (everything resident + compiled).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field, replace
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import LayerStore, atomic_write_text
from repro.core.compile_cache import CompileCache
from repro.core.pipeline import PipelineJob, PipelineRuntime, RunResult
from repro.executor.pool import CorePool
from repro.core.profiler import CoreModel, OpProfile, ProfileDB, Profiler
from repro.core.registry import (
    Kernel, LayerSpec, StatelessKernel, registry_for, shape_class_key,
    shape_class_sibling_key,
)
from repro.core.scheduler import (
    Choice, LayerCandidates, Plan, pareto_filter, plan_read_depth, schedule,
)
from repro.core.staging import stage_weights
from repro.faults import (
    CircuitBreaker, Fault, KernelFault, PlanFault, RepairLog,
)


@dataclass
class LayerDef:
    """One unit of the model graph: spec + (for stateless units) a fn."""
    spec: LayerSpec
    weights: Dict[str, np.ndarray] = field(default_factory=dict)
    fn: Optional[Callable] = None  # stateless units


class ColdEngine:
    def __init__(
        self,
        layers: List[LayerDef],
        store_dir: Path,
        *,
        core_model: CoreModel = CoreModel(),
        allow_lossy: bool = False,
        kernel_allowlist: Optional[Sequence[str]] = None,
        shader_cache: bool = True,
        store_fmt: str = "bundle",
        store_verify: str = "lazy",
        share_shape_classes: bool = True,
        profile_db: Union[str, Path, ProfileDB, None] = "auto",
        profile_db_approx: bool = False,
        pool: Optional[CorePool] = None,
        io_engine: Any = "auto",
        stage_engine: Any = "auto",
    ):
        self.layers = layers
        self.specs = [l.spec for l in layers]
        self.store = LayerStore(Path(store_dir), fmt=store_fmt,
                                verify=store_verify)
        self.core_model = core_model
        self.allow_lossy = allow_lossy
        # restrict Algorithm-1's kernel candidates by name (benchmark arms:
        # a bf16-only vs int8-only engine differ ONLY in eligible kernels).
        # The first supported registry kernel always stays eligible — it is
        # the raw-weights default used by shape tracing and fault fallback.
        self.kernel_allowlist = (set(kernel_allowlist)
                                 if kernel_allowlist is not None else None)
        self.compile_cache = CompileCache(
            Path(store_dir) / "xla_cache" if shader_cache else None)
        # shape-class sharing: profile/compile one representative per class
        # and fan out. False = the legacy per-layer path (every layer keyed
        # uniquely) — kept for baselines and equivalence tests.
        self.share_shape_classes = share_shape_classes
        if profile_db == "auto":
            self.profile_db: Optional[ProfileDB] = ProfileDB(
                Path(store_dir) / "profile_db.json")
        elif profile_db is None or isinstance(profile_db, ProfileDB):
            self.profile_db = profile_db
        else:
            self.profile_db = ProfileDB(Path(profile_db))
        self.profiler_factory: Callable[..., Profiler] = Profiler
        # approximate shape-class matching: a profile DB miss may fall back
        # to a sibling class identical up to the batch dim (exact first)
        self.profile_db_approx = profile_db_approx
        self.pool = pool                  # shared persistent CorePool
        # async prep I/O: "auto" resolves to the process-wide IOEngine when
        # the store format supports extent submission; False/None forces
        # the sync reference path; an IOEngine instance is used as-is
        self._io_engine_opt = io_engine
        self._stage_engine_opt = stage_engine
        # -- fault domain (docs/robustness.md) --------------------------
        self.fault_injector = None            # chaos: threaded into runtimes
        self.retry_policy = None              # per-task retry (None=default)
        self.task_deadline_s: Optional[float] = None  # pool watchdog
        self.repairs = RepairLog(self.store.root / "repairs.jsonl")
        self.breaker = CircuitBreaker(self.store.root / "breakers.json")
        self._fallback_jitted: Dict[Tuple[str, str], Tuple[Callable, Dict]] = {}
        self._runtimes: Dict[tuple, PipelineRuntime] = {}
        self.plan: Optional[Plan] = None
        self.profiles: Dict[str, List[OpProfile]] = {}
        self._input_example: Optional[np.ndarray] = None
        self._layer_inputs: Optional[List[np.ndarray]] = None
        self._jitted_cache: Dict[tuple, Dict[str, Callable]] = {}
        self._sc_by_layer: Dict[str, str] = {}
        self._sib_by_sc: Dict[str, Optional[str]] = {}
        # shape classes whose decide() profiles came from a drifted-host
        # ProfileDB entry: sc -> representative layer index, consumed by
        # background re-profiling (reprofile_stale, the server idle tick)
        self._stale_reps: Dict[str, int] = {}
        self._transform_avatars: Dict[Tuple[str, str], Dict[str, Any]] = {}
        # persist raw weights (the on-device model files)
        for l in layers:
            if l.weights:
                self.store.write_raw(l.spec.name, l.weights)

    # ------------------------------------------------------------------
    def _kernels_for(self, spec: LayerSpec) -> List[Kernel]:
        if spec.op_type == "stateless":
            layer = next(l for l in self.layers if l.spec.name == spec.name)
            return [StatelessKernel(layer.fn, name="fn")]
        ks = [k for k in registry_for(spec.op_type, allow_lossy=self.allow_lossy)
              if k.supports(spec)]
        if not ks:
            raise ValueError(f"no kernel for {spec}")
        if self.kernel_allowlist is not None:
            ks = [k for i, k in enumerate(ks)
                  if i == 0 or k.name in self.kernel_allowlist]
        return ks

    def _trace_shapes(self, x: np.ndarray) -> List[np.ndarray]:
        """Propagate an example input through default kernels to get each
        layer's input example (needed to profile per-layer execution)."""
        xs = []
        y = jnp.asarray(x)
        for l in self.layers:
            xs.append(np.asarray(y))
            kern = self._kernels_for(l.spec)[0]
            w = {k: jnp.asarray(v) for k, v in l.weights.items()}
            y = kern.execute(w, y, l.spec)
        self._output_example = np.asarray(y)
        return xs

    # ------------------------------------------------------------------
    def _shape_class_for(self, l: LayerDef, xin: np.ndarray) -> str:
        """Profile/compile-sharing identity of a layer. With sharing off the
        layer name is folded in, making every class a singleton (the legacy
        per-layer path)."""
        xin = np.asarray(xin)
        kw = dict(
            input_shape=tuple(xin.shape), input_dtype=str(xin.dtype),
            weight_dtypes={k: str(np.asarray(v).dtype)
                           for k, v in l.weights.items()} or None,
        )
        key = shape_class_key(l.spec, **kw)
        if not self.share_shape_classes:
            key = f"{key}:{l.spec.name}"
        else:
            # batch-agnostic sibling identity for approximate ProfileDB
            # fan-out (legacy per-layer classes never share, so no sibling)
            self._sib_by_sc[key] = shape_class_sibling_key(l.spec, **kw)
        return key

    def _options_from_profiles(
        self, plist: List[OpProfile], spec: LayerSpec,
    ) -> List[Tuple[Choice, float, float, float]]:
        """Candidate (choice, prep_little, prep_big, exec) tuples from one
        shape class's profiles, Pareto-filtered once and shared by every
        member layer."""
        cm = self.core_model
        options = []
        for p in plist:
            for use_cache in ((False, True) if spec.weight_shapes else (False,)):
                # big-core prep = read(+transform)+stage; reads are
                # metadata-cheap with mmap bundles, staging carries the
                # actual byte movement — the split the scheduler needs
                prep_big = p.prep_s(use_cache)
                # little-core factors per op kind (Fig. 6 affinity),
                # reads scaled by the measured co-read interference
                rd = cm.little_read * self.io_interference
                stage = p.stage_s * cm.little_stage
                if use_cache:
                    prep_little = p.read_cached_s * rd + stage
                else:
                    prep_little = (p.read_raw_s * rd
                                   + p.transform_s * cm.little_transform
                                   + stage)
                options.append(
                    (Choice(p.kernel, use_cache), prep_little, prep_big,
                     p.exec_s))
        filtered = pareto_filter([(c, pl, ex) for c, pl, pb, ex in options])
        keep_keys = {id(c[0]) for c in filtered}
        return [o for o in options if id(o[0]) in keep_keys]

    # -- degradation ladder: the plan itself --------------------------------
    def fallback_plan(self, n_little: int = 3) -> Plan:
        """Default heuristic plan — the ladder's last rung when no decision
        exists and none can be recovered. Reference kernel (registry head)
        per layer, no weight cache, first weighted layer prepped on the big
        cores, the rest round-robin across the little lanes. Correct by
        construction; only the latency is degraded."""
        choices = [Choice(self._kernels_for(l.spec)[0].name, False)
                   for l in self.layers]
        weighted = [i for i, l in enumerate(self.layers)
                    if l.spec.weight_shapes]
        if n_little <= 0:
            return Plan(choices, weighted, [], 0.0)
        rest = weighted[1:]
        return Plan(choices, weighted[:1],
                    [rest[j::n_little] for j in range(n_little)], 0.0)

    def ensure_plan(self, x_example: np.ndarray, *,
                    n_little: int = 3) -> Plan:
        """A usable plan, never an exception: in-memory plan → ``plan.json``
        reload (validated) → :meth:`fallback_plan`. A cold request on a
        fresh process must not fail because the offline decision is missing
        or corrupt — it proceeds degraded and journals the repair."""
        if self._input_example is None:
            self._input_example = x_example
        if self.plan is not None:
            return self.plan
        plan_path = self.store.root / "plan.json"
        try:
            d = json.loads(plan_path.read_text())["plan"]
            plan = Plan.from_dict(d)
            if len(plan.choices) != len(self.layers):
                raise PlanFault(
                    f"plan.json has {len(plan.choices)} choices for "
                    f"{len(self.layers)} layers")
            for l, c in zip(self.layers, plan.choices):
                if all(k.name != c.kernel
                       for k in self._kernels_for(l.spec)):
                    raise PlanFault(
                        f"plan.json picks unknown kernel {c.kernel!r} "
                        f"for layer {l.spec.name!r}", layer=l.spec.name,
                        kernel=c.kernel)
            self.plan = plan
            return plan
        except FileNotFoundError:
            pass
        except Exception as e:
            self.repairs.record("plan_fallback",
                                reason=f"plan.json unusable: {e}")
        self.plan = self.fallback_plan(n_little)
        return self.plan

    def decide(
        self, x_example: np.ndarray, *, n_little: int = 3,
        force_reprofile: bool = False, calibrate_interference: bool = True,
    ) -> Dict[str, Any]:
        """Offline decision stage. Returns stats incl. generation time.

        Degradation ladder: a typed ``Fault`` raised while profiling or
        scheduling (sick store, poisoned ProfileDB, ...) demotes the
        decision to :meth:`fallback_plan` instead of failing — the stats
        carry ``degraded=True`` and the repair is journaled."""
        if force_reprofile:
            # operator lever: a forced re-decide also gives kernels demoted
            # by the runtime circuit breakers another chance
            self.breaker.reset()
            self.breaker.save()
        t0 = time.perf_counter()
        try:
            return self._decide_core(
                x_example, n_little=n_little,
                force_reprofile=force_reprofile,
                calibrate_interference=calibrate_interference, t0=t0)
        except Fault as e:
            self.repairs.record("decide_degraded", reason=repr(e))
            self.plan = self.fallback_plan(n_little)
            self._runtimes.clear()
            stats = {"degraded": True, "error": str(e) or repr(e),
                     "plan_generation_s": time.perf_counter() - t0,
                     "est_makespan_s": 0.0}
            try:
                atomic_write_text(
                    self.store.root / "plan.json", json.dumps(
                        {"plan": self.plan.to_dict(), "stats": stats},
                        indent=1))
            except OSError:
                pass
            return stats

    def _decide_core(
        self, x_example: np.ndarray, *, n_little: int,
        force_reprofile: bool, calibrate_interference: bool, t0: float,
    ) -> Dict[str, Any]:
        self._input_example = x_example
        layer_inputs = self._layer_inputs = self._trace_shapes(x_example)
        # §3.2: co-running preps share disk bandwidth — measure the real
        # per-op slowdown with n_little concurrent readers and fold it into
        # the little-core prep costs the scheduler optimizes against.
        self.io_interference = 1.0
        if calibrate_interference and n_little > 1:
            from repro.core.profiler import measure_read_interference

            self.io_interference = measure_read_interference(
                self.store, [l.spec.name for l in self.layers], n_little)

        # partition into shape classes; profile one representative per
        # (class × kernel), consulting the persistent profile DB first
        self._sc_by_layer = {}
        groups: Dict[str, List[int]] = {}
        for i, (l, xin) in enumerate(zip(self.layers, layer_inputs)):
            sc = self._shape_class_for(l, xin)
            self._sc_by_layer[l.spec.name] = sc
            groups.setdefault(sc, []).append(i)

        db = self.profile_db
        db_hits = 0
        prof = self.profiler_factory(self.store)
        sc_profiles: Dict[str, List[OpProfile]] = {}
        try:
            for sc, idxs in groups.items():
                rep, xin = self.layers[idxs[0]], layer_inputs[idxs[0]]
                plist: List[OpProfile] = []
                sib = self._sib_by_sc.get(sc)
                for kern in self._kernels_for(rep.spec):
                    p = None
                    if db is not None and not force_reprofile:
                        p = db.get(sc, kern.name, sibling_key=sib,
                                   approx=self.profile_db_approx)
                        if p is not None:
                            db_hits += 1
                    if p is None:
                        p = prof.profile(rep.spec, kern, xin)
                        if db is not None:
                            db.put(sc, kern.name, p, sibling_key=sib)
                    plist.append(p)
                    if p.transformed_avatars is not None:
                        self._transform_avatars[(sc, kern.name)] = \
                            p.transformed_avatars
                sc_profiles[sc] = plist
        finally:
            prof.close()
        if db is not None:
            db.save()
        profile_calls = prof.calls
        # host-fingerprint drift: classes resolved from a stale (drifted)
        # DB entry keep serving — record their representatives so the idle
        # tick can re-measure off the cold path (reprofile_stale)
        if db is not None and db.stale:
            for sc, idxs in groups.items():
                if any((sc, k) in db.stale for k in
                       (kern.name for kern in
                        self._kernels_for(self.layers[idxs[0]].spec))):
                    self._stale_reps[sc] = idxs[0]

        # fan profiles out to every member layer; candidate sweeps (incl.
        # the Pareto filter) collapse to one per shape class
        self.profiles = {}
        cands: List[Optional[LayerCandidates]] = [None] * len(self.layers)
        open_keys = set(self.breaker.open_keys())
        for sc, idxs in groups.items():
            plist = sc_profiles[sc]
            spec0 = self.layers[idxs[0]].spec
            options = self._options_from_profiles(plist, spec0)
            for i in idxs:
                name = self.layers[i].spec.name
                self.profiles[name] = [replace(p, layer=name) for p in plist]
                opts = options
                if open_keys:
                    # kernels demoted at runtime (open circuit breaker for
                    # this shape class or layer) are excluded from re-decide
                    # until force_reprofile resets them; the registry-head
                    # reference kernel is always kept as a floor
                    healthy = [
                        p.kernel for p in plist
                        if CircuitBreaker.key(p.kernel, sc) not in open_keys
                        and CircuitBreaker.key(p.kernel, name) not in open_keys
                    ] or [plist[0].kernel]
                    opts = [o for o in options if o[0].kernel in healthy]
                    if not opts:  # every healthy kernel was Pareto-dominated
                        opts = self._options_from_profiles(
                            [p for p in plist if p.kernel in healthy], spec0)
                cands[i] = LayerCandidates(layer=name, options=opts)

        self.plan = schedule(cands, n_little)
        # I/O queue depth for the async engine: planned from the same
        # profiled costs the kernel scheduler just optimized — enough
        # parallel reads to hide the read column behind transform+stage,
        # clamped so a lane never floods the disk past the measured
        # interference regime. Persisted in plan.json with the rest of the
        # decision (graph.compile_plan stamps it on every read task).
        cm = self.core_model
        read_costs, other_costs = [], []
        for l, c in zip(self.layers, self.plan.choices):
            p = next((pp for pp in sc_profiles[self._sc_by_layer[l.spec.name]]
                      if pp.kernel == c.kernel), None)
            if p is None:
                continue
            rd = p.read_cached_s if c.use_cache else p.read_raw_s
            read_costs.append(rd * cm.little_read)
            xf = 0.0 if c.use_cache else p.transform_s * cm.little_transform
            other_costs.append(xf + p.stage_s * cm.little_stage)
        self.plan.read_depth = plan_read_depth(
            read_costs, other_costs, io_interference=self.io_interference)
        self._runtimes.clear()     # cached runtimes are plan-bound
        # materialize/drop the weight cache per the plan; entries already
        # materialized by a previous decide() from the SAME raw weights
        # (fingerprint sidecar) are kept as-is, so a warm-DB decide performs
        # zero transforms — but an updated checkpoint invalidates them
        fp_path = self.store.root / "cache_fingerprints.json"
        try:
            fps: Dict[str, Dict[str, str]] = json.loads(fp_path.read_text())
        except (FileNotFoundError, json.JSONDecodeError):
            fps = {}
        for l, choice in zip(self.layers, self.plan.choices):
            if not l.spec.weight_shapes:
                continue
            kern = self._kernel_by_name(l.spec, choice.kernel)
            for k2 in self._kernels_for(l.spec):
                if k2.name != kern.name or not choice.use_cache:
                    self.store.drop_cached(l.spec.name, k2.name)
            if not choice.use_cache:
                fps.pop(l.spec.name, None)
                continue
            fp = self._raw_fingerprint(l)
            fresh = (not force_reprofile and fp != ""
                     and self.store.has_cached(l.spec.name, kern.name)
                     and fps.get(l.spec.name, {}).get(kern.name) == fp)
            if not fresh:
                raw = self.store.read_raw(l.spec.name)
                self.store.write_cached(l.spec.name, kern.name,
                                        kern.transform(raw, l.spec))
            fps[l.spec.name] = {kern.name: fp}
        # durable sidecar commit: a crash mid-write must not leave a torn
        # fingerprint file silently validating stale cache entries
        atomic_write_text(fp_path, json.dumps(fps, indent=1), durable=True)
        # post-materialization maintenance: dropped/superseded cache entries
        # leave dead extents in a super-bundle container; compact them out
        maintenance = self.store.maintain()
        # a fresh decision answers any pending re-decide requests left by
        # runtime kernel demotions (_fallback_execute)
        rp = self.store.root / "replan_pending.json"
        replan_cleared: List[str] = []
        try:
            replan_cleared = sorted(json.loads(rp.read_text()))
            rp.unlink()
        except (FileNotFoundError, json.JSONDecodeError, OSError, ValueError):
            pass
        gen_s = time.perf_counter() - t0
        # read-vs-stage split of the chosen plan's big-core prep costs
        split = {"read_s": 0.0, "transform_s": 0.0, "stage_s": 0.0}
        for l, c in zip(self.layers, self.plan.choices):
            p = next(pp for pp in self.profiles[l.spec.name]
                     if pp.kernel == c.kernel)
            if c.use_cache:
                split["read_s"] += p.read_cached_s
            else:
                split["read_s"] += p.read_raw_s
                split["transform_s"] += p.transform_s
            split["stage_s"] += p.stage_s
        # planned cold-read bytes of the chosen plan: the FOLDED extent
        # bytes each choice will pull off disk (quantized entries count
        # their int8/int4 payload, not the dequantized footprint)
        cold = {"raw_bytes": 0, "cached_bytes": 0,
                "by_kernel": {}}  # type: Dict[str, Any]
        for l, c in zip(self.layers, self.plan.choices):
            if not l.spec.weight_shapes:
                continue
            if c.use_cache:
                nb = self.store.cached_bytes(l.spec.name, c.kernel)
                cold["cached_bytes"] += nb
            else:
                nb = self.store.raw_bytes(l.spec.name)
                cold["raw_bytes"] += nb
            cold["by_kernel"][c.kernel] = cold["by_kernel"].get(c.kernel,
                                                                0) + nb
        stats = {
            "plan_generation_s": gen_s,
            "est_makespan_s": self.plan.est_makespan,
            "planned_cold_read_bytes": cold,
            "io_interference": self.io_interference,
            "read_depth": self.plan.read_depth,
            "cache_bytes": self.store.cache_bytes(),
            "model_bytes": self.store.model_bytes(),
            "prep_split": split,
            "shape_classes": len(groups),
            "profile_calls": profile_calls,
            "profile_db_hits": db_hits,
            "profile_db_approx_hits": (
                db.stats["approx_hits"] if db is not None else 0),
            "profile_db_stale_hits": (
                db.stats.get("stale_hits", 0) if db is not None else 0),
            "store_maintenance": maintenance,
            "replan_cleared": replan_cleared,
            "choices": {l.spec.name: (c.kernel, c.use_cache)
                        for l, c in zip(self.layers, self.plan.choices)},
        }
        atomic_write_text(self.store.root / "plan.json", json.dumps(
            {"plan": self.plan.to_dict(), "stats": stats}, indent=1))
        return stats

    def _kernel_by_name(self, spec: LayerSpec, name: str) -> Kernel:
        return next(k for k in self._kernels_for(spec) if k.name == name)

    # -- background re-profiling on host-fingerprint drift -------------------
    def reprofile_stale(self, max_classes: Optional[int] = None) -> int:
        """Re-measure shape classes whose last ``decide()`` was served by a
        drifted-host ProfileDB entry. Runs on the server's IDLE tick — never
        on the cold path: the stale estimates keep serving until the fresh
        measurements land in the DB (picked up by the next ``decide()``).
        Returns the number of classes refreshed."""
        db = self.profile_db
        if db is None or not self._stale_reps or self._layer_inputs is None:
            return 0
        done = 0
        prof = self.profiler_factory(self.store)
        try:
            for sc, rep_idx in list(self._stale_reps.items()):
                if max_classes is not None and done >= max_classes:
                    break
                rep = self.layers[rep_idx]
                xin = self._layer_inputs[rep_idx]
                sib = self._sib_by_sc.get(sc)
                for kern in self._kernels_for(rep.spec):
                    if (sc, kern.name) not in db.stale:
                        continue
                    p = prof.profile(rep.spec, kern, xin)
                    db.put(sc, kern.name, p, sibling_key=sib)
                del self._stale_reps[sc]
                done += 1
                self.repairs.record(
                    "reprofile_drift", layer=rep.spec.name,
                    shape_class=sc[:40],
                    drifted_from=getattr(db, "drifted_from", None))
        finally:
            prof.close()
        if done:
            db.save()
        return done

    def _raw_fingerprint(self, l: LayerDef) -> str:
        """Content hash of a layer's raw weights — guards cached transformed
        entries against checkpoint updates (a stale entry would silently
        change outputs)."""
        import hashlib

        if not l.weights:
            return ""  # content unknown: never matches -> always rewrite
        h = hashlib.sha1()
        for k in sorted(l.weights):
            h.update(k.encode())
            h.update(np.ascontiguousarray(l.weights[k]).tobytes())
        return h.hexdigest()[:20]

    # -- degradation ladder: the execute rung -------------------------------
    def _mark_replan(self, layer: str) -> None:
        """Persist a re-decide request: the next ``decide()`` on this store
        sees and clears it (``stats["replan_cleared"]``)."""
        rp = self.store.root / "replan_pending.json"
        try:
            pending = set(json.loads(rp.read_text()))
        except (FileNotFoundError, json.JSONDecodeError, ValueError):
            pending = set()
        pending.add(layer)
        try:
            atomic_write_text(rp, json.dumps(sorted(pending)))
        except OSError:
            pass  # advisory marker; losing it only delays the re-decide

    def _fallback_execute(self, layer: str, x, exc,
                          chosen: Optional[str] = None):
        """A layer's chosen kernel faulted at execute (or its circuit
        breaker is already open, ``exc is None``): demote the
        (kernel, shape-class) pair, journal the repair, mark the plan for
        re-decide, and finish the request on the reference kernel. The
        request degrades in latency, never in correctness — the reference
        kernel is the zero-transform registry head the oracles pin down."""
        l = next(ld for ld in self.layers if ld.spec.name == layer)
        if chosen is None and self.plan is not None:
            idx = next(i for i, ld in enumerate(self.layers)
                       if ld.spec.name == layer)
            chosen = self.plan.choices[idx].kernel
        sc = self._sc_by_layer.get(layer) or layer
        if exc is not None and chosen is not None:
            key = CircuitBreaker.key(chosen, sc)
            self.breaker.record_failure(key, reason=repr(exc))
            self.breaker.save()
            self.repairs.record("kernel_demoted", layer=layer, kernel=chosen,
                                shape_class=sc, reason=repr(exc))
            self._mark_replan(layer)
        ref = next(
            (k for k in self._kernels_for(l.spec)
             if k.name != chosen
             and self.breaker.allow(CircuitBreaker.key(k.name, sc))),
            None)
        if ref is None:
            raise KernelFault(
                f"no healthy fallback kernel for layer {layer!r}",
                layer=layer, kernel=chosen) from exc
        ent = self._fallback_jitted.get((layer, ref.name))
        if ent is None:
            w = {}
            if l.spec.weight_shapes:
                w = stage_weights(
                    ref.transform(self.store.read_raw(layer), l.spec))
            fn = jax.jit(
                (lambda kern, spec: lambda w_, x_:
                 kern.execute(w_, x_, spec))(ref, l.spec))
            ent = self._fallback_jitted[(layer, ref.name)] = (fn, w)
        fn, w = ent
        y = fn(w, jnp.asarray(x))
        jax.block_until_ready(y)
        return y

    # ------------------------------------------------------------------
    def _avatar_dtype(self, name: str):
        try:
            return np.dtype(name)
        except TypeError:
            import ml_dtypes

            return np.dtype(getattr(ml_dtypes, name))

    def _jitted_map(self, choices: List[Choice], x_example) -> Dict[str, Callable]:
        """Compiled executables per layer (through the shader cache, keyed
        by shape class — equivalent layers share one executable); memoized
        per kernel-choice tuple. Compile examples are ``ShapeDtypeStruct``
        avatars: no layer's real weights are read or transformed here. The
        transformed shapes come from profiling (or the profile DB); a layer
        whose profiles never ran falls back to one real transform per
        (shape-class, kernel)."""
        key = tuple(c.kernel for c in choices)
        if key in self._jitted_cache:
            return self._jitted_cache[key]
        jitted = {}
        if self._layer_inputs is None:
            self._layer_inputs = self._trace_shapes(x_example)
        layer_inputs = self._layer_inputs
        for l, ch, xin in zip(self.layers, choices, layer_inputs):
            kern = self._kernel_by_name(l.spec, ch.kernel)
            sc = self._sc_by_layer.get(l.spec.name)
            if sc is None:
                sc = self._sc_by_layer[l.spec.name] = \
                    self._shape_class_for(l, xin)
            if l.spec.weight_shapes:
                avatars = self._transform_avatars.get((sc, kern.name))
                if avatars is None:
                    from repro.core.profiler import avatars_of

                    raw = self.store.read_raw(l.spec.name)
                    avatars = avatars_of(kern.transform(raw, l.spec))
                    self._transform_avatars[(sc, kern.name)] = avatars
                w_ex = {k2: jax.ShapeDtypeStruct(
                            tuple(shape), self._avatar_dtype(dt))
                        for k2, (shape, dt) in avatars.items()}
            else:
                w_ex = {}
            xin = np.asarray(xin)
            x_ex = jax.ShapeDtypeStruct(tuple(xin.shape), xin.dtype)
            fn = (lambda kern, spec: lambda w, x: kern.execute(w, x, spec))(kern, l.spec)
            compiled = self.compile_cache.get(kern.name, l.spec, fn, w_ex,
                                              x_ex, shape_class=sc)
            jitted[l.spec.name] = compiled
        self._jitted_cache[key] = jitted
        return jitted

    def _resolve_io_engines(self) -> Tuple[Optional[Any], Optional[Any]]:
        """Resolve the ``io_engine``/``stage_engine`` knobs to instances.

        ``"auto"`` binds the process-wide engines lazily — only when a
        runtime is actually built, and only when the store format supports
        extent submission (legacy npy stays on the sync reference path).
        ``False``/``None`` disables; instances pass through."""
        io_eng = self._io_engine_opt
        if io_eng == "auto":
            io_eng = None
            if getattr(self.store, "supports_async", False):
                from repro.ioengine import get_io_engine

                io_eng = get_io_engine()
        elif not io_eng:
            io_eng = None
        st_eng = self._stage_engine_opt
        if st_eng == "auto":
            st_eng = None
            if io_eng is not None:
                from repro.ioengine import get_stage_engine

                st_eng = get_stage_engine()
        elif not st_eng:
            st_eng = None
        return io_eng, st_eng

    def make_runtime(self, *, n_little: int = 3, plan: Optional[Plan] = None,
                     work_stealing: bool = True) -> PipelineRuntime:
        plan = plan or self.plan
        assert plan is not None, "call decide() first"
        kernels = {l.spec.name: self._kernel_by_name(l.spec, c.kernel)
                   for l, c in zip(self.layers, plan.choices)}
        use_cache = {l.spec.name: c.use_cache
                     for l, c in zip(self.layers, plan.choices)}
        jitted = self._jitted_map(plan.choices, self._input_example)
        # profiled per-layer LITTLE-core prep costs (same factors the
        # simulator uses) let the runtime's work stealer pick the donor by
        # remaining prep time, matching the plan's makespan model
        cm = self.core_model
        interference = getattr(self, "io_interference", 1.0)
        prep_costs = {}
        for l, c in zip(self.layers, plan.choices):
            p = next((pp for pp in self.profiles.get(l.spec.name, [])
                      if pp.kernel == c.kernel), None)
            if p is not None:
                rd = cm.little_read * interference
                stage = p.stage_s * cm.little_stage
                if c.use_cache:
                    prep_costs[l.spec.name] = p.read_cached_s * rd + stage
                else:
                    prep_costs[l.spec.name] = (
                        p.read_raw_s * rd
                        + p.transform_s * cm.little_transform + stage)
        # fault-domain plumbing: the runtime's execute tasks consult the
        # circuit breakers and demote to _fallback_execute; repairs and
        # injected chaos flow through the engine-owned log/injector
        choice_by_layer = {l.spec.name: c
                           for l, c in zip(self.layers, plan.choices)}

        def exec_allowed(name: str) -> bool:
            sc = self._sc_by_layer.get(name) or name
            return self.breaker.allow(
                CircuitBreaker.key(choice_by_layer[name].kernel, sc))

        def fallback_exec(name: str, x, exc):
            return self._fallback_execute(
                name, x, exc, chosen=choice_by_layer[name].kernel)

        io_eng, st_eng = self._resolve_io_engines()
        return PipelineRuntime(
            self.specs, kernels, use_cache, self.store, jitted,
            n_little=n_little, work_stealing=work_stealing,
            prep_costs=prep_costs or None, pool=self.pool,
            retry=self.retry_policy, deadline_s=self.task_deadline_s,
            fault_injector=self.fault_injector, repair_log=self.repairs,
            fallback_exec=fallback_exec, exec_allowed=exec_allowed,
            io_engine=io_eng, stage_engine=st_eng,
        )

    def _runtime(self, *, n_little: int, work_stealing: bool) -> PipelineRuntime:
        """The steady-path runtime: built once per (plan, n_little,
        stealing) and reused — no per-run construction, and the underlying
        persistent CorePool means no per-run threads either."""
        key = (n_little, work_stealing)
        rt = self._runtimes.get(key)
        if rt is None:
            rt = self._runtimes[key] = self.make_runtime(
                n_little=n_little, work_stealing=work_stealing)
        return rt

    def submit_cold(self, x, *, n_little: int = 3, work_stealing: bool = True,
                    graph_hook=None, deadline_s: Optional[float] = None,
                    peer_fetch=None) -> PipelineJob:
        """Non-blocking cold run: compile the plan's task graph and enqueue
        it on the shared pool (the ColdServer's admission path).
        ``deadline_s`` bounds the whole run end-to-end (typed
        ``DeadlineExceeded`` from the pool watchdog once blown).
        ``peer_fetch`` (a ``warmstate.PeerFetcher``) arms the peer
        warm-state race — see ``PipelineRuntime.submit``."""
        rt = self._runtime(n_little=n_little, work_stealing=work_stealing)
        return rt.submit(jnp.asarray(x), self.plan, graph_hook=graph_hook,
                         job_deadline_s=deadline_s, peer_fetch=peer_fetch)

    def run_cold(self, x, *, n_little: int = 3, mode: str = "nnv12") -> RunResult:
        """mode: nnv12 (full) | sequential (ncnn-like baseline) |
        nnv12_nosteal"""
        if mode == "sequential":
            rt = self.make_runtime(n_little=n_little)
            # baseline: warm-best kernels, no cache, fully sequential
            warm_best = self.warm_best_choices()
            # the ncnn-like baseline models an engine WITHOUT a checksum
            # layer: land the store's one-off lazy CRC audit here, not
            # inside the baseline's timed traces
            self.store.warm_verify(
                l.spec.name for l in self.layers if l.spec.weight_shapes)
            kernels = {l.spec.name: self._kernel_by_name(l.spec, c.kernel)
                       for l, c in zip(self.layers, warm_best)}
            rt2 = PipelineRuntime(
                self.specs, kernels, {n: False for n in rt.use_cache},
                self.store, self._jitted_map(warm_best, self._input_example),
                n_little=0)
            return rt2.run_sequential(jnp.asarray(x))
        return self.submit_cold(
            x, n_little=n_little,
            work_stealing=(mode != "nnv12_nosteal")).result()

    def run_warm(self, x, repeats: int = 3) -> float:
        """Steady-state latency with warm-best kernels, weights resident."""
        choices = self.warm_best_choices()
        jitted = self._jitted_map(choices, self._input_example)
        weights = {}
        for l, ch in zip(self.layers, choices):
            kern = self._kernel_by_name(l.spec, ch.kernel)
            raw = self.store.read_raw(l.spec.name) if l.spec.weight_shapes else {}
            w = kern.transform(raw, l.spec) if l.spec.weight_shapes else {}
            # stage_weights, not jnp.asarray: identity transforms hand back
            # mmap views whose aliasing would leave disk I/O in execute
            weights[l.spec.name] = stage_weights(w)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            y = jnp.asarray(x)
            for l in self.layers:
                y = jitted[l.spec.name](weights[l.spec.name], y)
            jax.block_until_ready(y)
            best = min(best, time.perf_counter() - t0)
        return best

    def warm_best_choices(self) -> List[Choice]:
        """Per-layer kernel with the fastest *execution* (ncnn's policy)."""
        out = []
        for l in self.layers:
            ps = self.profiles.get(l.spec.name)
            assert ps, "decide() must run first"
            best = min(ps, key=lambda p: p.exec_s)
            out.append(Choice(best.kernel, False))
        return out
