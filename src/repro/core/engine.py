"""ColdEngine — the NNV12 workflow (Fig. 4): offline decision generation +
online cold-inference runtime.

Offline ``decide()`` (runs once when a model lands on the device):
  1. profile every (layer × kernel) read/transform/execute (+compile);
  2. build per-layer candidate lists (kernel × {raw, cached}) and
     Pareto-filter them (Algorithm 1 line 1);
  3. run the kernel scheduler (Algorithm 1) to get the plan;
  4. materialize the post-transformed weight cache for chosen cached layers
     (and drop unused cache entries — storage accounting);
  5. optionally pre-serialize compiled executables (the shader cache).

Online ``run_cold()`` executes the plan with the pipelined runtime;
``run_warm()`` is the steady-state path (everything resident + compiled).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import LayerStore
from repro.core.compile_cache import CompileCache
from repro.core.pipeline import PipelineRuntime, RunResult
from repro.core.profiler import CoreModel, OpProfile, Profiler
from repro.core.registry import (
    Kernel, LayerSpec, StatelessKernel, registry_for,
)
from repro.core.scheduler import (
    Choice, LayerCandidates, Plan, pareto_filter, schedule,
)
from repro.core.staging import stage_weights


@dataclass
class LayerDef:
    """One unit of the model graph: spec + (for stateless units) a fn."""
    spec: LayerSpec
    weights: Dict[str, np.ndarray] = field(default_factory=dict)
    fn: Optional[Callable] = None  # stateless units


class ColdEngine:
    def __init__(
        self,
        layers: List[LayerDef],
        store_dir: Path,
        *,
        core_model: CoreModel = CoreModel(),
        allow_lossy: bool = False,
        shader_cache: bool = True,
        store_fmt: str = "bundle",
    ):
        self.layers = layers
        self.specs = [l.spec for l in layers]
        self.store = LayerStore(Path(store_dir), fmt=store_fmt)
        self.core_model = core_model
        self.allow_lossy = allow_lossy
        self.compile_cache = CompileCache(
            Path(store_dir) / "xla_cache" if shader_cache else None)
        self.plan: Optional[Plan] = None
        self.profiles: Dict[str, List[OpProfile]] = {}
        self._input_example: Optional[np.ndarray] = None
        self._layer_inputs: Optional[List[np.ndarray]] = None
        self._jitted_cache: Dict[tuple, Dict[str, Callable]] = {}
        # persist raw weights (the on-device model files)
        for l in layers:
            if l.weights:
                self.store.write_raw(l.spec.name, l.weights)

    # ------------------------------------------------------------------
    def _kernels_for(self, spec: LayerSpec) -> List[Kernel]:
        if spec.op_type == "stateless":
            layer = next(l for l in self.layers if l.spec.name == spec.name)
            return [StatelessKernel(layer.fn, name="fn")]
        ks = [k for k in registry_for(spec.op_type, allow_lossy=self.allow_lossy)
              if k.supports(spec)]
        if not ks:
            raise ValueError(f"no kernel for {spec}")
        return ks

    def _trace_shapes(self, x: np.ndarray) -> List[np.ndarray]:
        """Propagate an example input through default kernels to get each
        layer's input example (needed to profile per-layer execution)."""
        xs = []
        y = jnp.asarray(x)
        for l in self.layers:
            xs.append(np.asarray(y))
            kern = self._kernels_for(l.spec)[0]
            w = {k: jnp.asarray(v) for k, v in l.weights.items()}
            y = kern.execute(w, y, l.spec)
        self._output_example = np.asarray(y)
        return xs

    # ------------------------------------------------------------------
    def decide(
        self, x_example: np.ndarray, *, n_little: int = 3,
        force_reprofile: bool = False, calibrate_interference: bool = True,
    ) -> Dict[str, Any]:
        """Offline decision stage. Returns stats incl. generation time."""
        t0 = time.perf_counter()
        self._input_example = x_example
        layer_inputs = self._layer_inputs = self._trace_shapes(x_example)
        prof = Profiler(self.store)
        cands: List[LayerCandidates] = []
        cm = self.core_model
        # §3.2: co-running preps share disk bandwidth — measure the real
        # per-op slowdown with n_little concurrent readers and fold it into
        # the little-core prep costs the scheduler optimizes against.
        self.io_interference = 1.0
        if calibrate_interference and n_little > 1:
            from repro.core.profiler import measure_read_interference

            self.io_interference = measure_read_interference(
                self.store, [l.spec.name for l in self.layers], n_little)
        for l, xin in zip(self.layers, layer_inputs):
            plist: List[OpProfile] = []
            options = []
            for kern in self._kernels_for(l.spec):
                p = prof.profile(l.spec, kern, xin)
                plist.append(p)
                for use_cache in ((False, True) if l.spec.weight_shapes else (False,)):
                    # big-core prep = read(+transform)+stage; reads are
                    # metadata-cheap with mmap bundles, staging carries the
                    # actual byte movement — the split the scheduler needs
                    prep_big = p.prep_s(use_cache)
                    # little-core factors per op kind (Fig. 6 affinity),
                    # reads scaled by the measured co-read interference
                    rd = cm.little_read * self.io_interference
                    stage = p.stage_s * cm.little_stage
                    if use_cache:
                        prep_little = p.read_cached_s * rd + stage
                    else:
                        prep_little = (p.read_raw_s * rd
                                       + p.transform_s * cm.little_transform
                                       + stage)
                    options.append(
                        (Choice(kern.name, use_cache), prep_little, prep_big,
                         p.exec_s))
            self.profiles[l.spec.name] = plist
            filtered = pareto_filter([(c, pl, ex) for c, pl, pb, ex in options])
            keep_keys = {id(c[0]) for c in filtered}
            options = [o for o in options if id(o[0]) in keep_keys]
            cands.append(LayerCandidates(layer=l.spec.name, options=options))

        self.plan = schedule(cands, n_little)
        # materialize/drop the weight cache per the plan
        for l, choice in zip(self.layers, self.plan.choices):
            if not l.spec.weight_shapes:
                continue
            kern = self._kernel_by_name(l.spec, choice.kernel)
            for k2 in self._kernels_for(l.spec):
                if k2.name != kern.name or not choice.use_cache:
                    self.store.drop_cached(l.spec.name, k2.name)
            if choice.use_cache:
                raw = self.store.read_raw(l.spec.name)
                self.store.write_cached(l.spec.name, kern.name,
                                        kern.transform(raw, l.spec))
        gen_s = time.perf_counter() - t0
        # read-vs-stage split of the chosen plan's big-core prep costs
        split = {"read_s": 0.0, "transform_s": 0.0, "stage_s": 0.0}
        for l, c in zip(self.layers, self.plan.choices):
            p = next(pp for pp in self.profiles[l.spec.name]
                     if pp.kernel == c.kernel)
            if c.use_cache:
                split["read_s"] += p.read_cached_s
            else:
                split["read_s"] += p.read_raw_s
                split["transform_s"] += p.transform_s
            split["stage_s"] += p.stage_s
        stats = {
            "plan_generation_s": gen_s,
            "est_makespan_s": self.plan.est_makespan,
            "io_interference": self.io_interference,
            "cache_bytes": self.store.cache_bytes(),
            "model_bytes": self.store.model_bytes(),
            "prep_split": split,
            "choices": {l.spec.name: (c.kernel, c.use_cache)
                        for l, c in zip(self.layers, self.plan.choices)},
        }
        (self.store.root / "plan.json").write_text(json.dumps(
            {"plan": self.plan.to_dict(), "stats": stats}, indent=1))
        return stats

    def _kernel_by_name(self, spec: LayerSpec, name: str) -> Kernel:
        return next(k for k in self._kernels_for(spec) if k.name == name)

    # ------------------------------------------------------------------
    def _jitted_map(self, choices: List[Choice], x_example) -> Dict[str, Callable]:
        """Compiled executables per layer (through the shader cache);
        memoized per kernel-choice tuple."""
        key = tuple(c.kernel for c in choices)
        if key in self._jitted_cache:
            return self._jitted_cache[key]
        jitted = {}
        if self._layer_inputs is None:
            self._layer_inputs = self._trace_shapes(x_example)
        layer_inputs = self._layer_inputs
        for l, ch, xin in zip(self.layers, choices, layer_inputs):
            kern = self._kernel_by_name(l.spec, ch.kernel)
            if l.spec.weight_shapes:
                raw = self.store.read_raw(l.spec.name)
                w_ex = {k: jnp.asarray(v)
                        for k, v in kern.transform(raw, l.spec).items()}
            else:
                w_ex = {}
            fn = (lambda kern, spec: lambda w, x: kern.execute(w, x, spec))(kern, l.spec)
            compiled = self.compile_cache.get(kern.name, l.spec, fn, w_ex,
                                              jnp.asarray(xin))
            jitted[l.spec.name] = compiled
        self._jitted_cache[key] = jitted
        return jitted

    def make_runtime(self, *, n_little: int = 3, plan: Optional[Plan] = None,
                     work_stealing: bool = True) -> PipelineRuntime:
        plan = plan or self.plan
        assert plan is not None, "call decide() first"
        kernels = {l.spec.name: self._kernel_by_name(l.spec, c.kernel)
                   for l, c in zip(self.layers, plan.choices)}
        use_cache = {l.spec.name: c.use_cache
                     for l, c in zip(self.layers, plan.choices)}
        jitted = self._jitted_map(plan.choices, self._input_example)
        # profiled per-layer LITTLE-core prep costs (same factors the
        # simulator uses) let the runtime's work stealer pick the donor by
        # remaining prep time, matching the plan's makespan model
        cm = self.core_model
        interference = getattr(self, "io_interference", 1.0)
        prep_costs = {}
        for l, c in zip(self.layers, plan.choices):
            p = next((pp for pp in self.profiles.get(l.spec.name, [])
                      if pp.kernel == c.kernel), None)
            if p is not None:
                rd = cm.little_read * interference
                stage = p.stage_s * cm.little_stage
                if c.use_cache:
                    prep_costs[l.spec.name] = p.read_cached_s * rd + stage
                else:
                    prep_costs[l.spec.name] = (
                        p.read_raw_s * rd
                        + p.transform_s * cm.little_transform + stage)
        return PipelineRuntime(
            self.specs, kernels, use_cache, self.store, jitted,
            n_little=n_little, work_stealing=work_stealing,
            prep_costs=prep_costs or None,
        )

    def run_cold(self, x, *, n_little: int = 3, mode: str = "nnv12") -> RunResult:
        """mode: nnv12 (full) | sequential (ncnn-like baseline) |
        nnv12_nosteal"""
        rt = self.make_runtime(n_little=n_little,
                               work_stealing=(mode != "nnv12_nosteal"))
        if mode == "sequential":
            # baseline: warm-best kernels, no cache, fully sequential
            warm_best = self.warm_best_choices()
            kernels = {l.spec.name: self._kernel_by_name(l.spec, c.kernel)
                       for l, c in zip(self.layers, warm_best)}
            rt2 = PipelineRuntime(
                self.specs, kernels, {n: False for n in rt.use_cache},
                self.store, self._jitted_map(warm_best, self._input_example),
                n_little=0)
            return rt2.run_sequential(jnp.asarray(x))
        return rt.run(jnp.asarray(x), self.plan)

    def run_warm(self, x, repeats: int = 3) -> float:
        """Steady-state latency with warm-best kernels, weights resident."""
        choices = self.warm_best_choices()
        jitted = self._jitted_map(choices, self._input_example)
        weights = {}
        for l, ch in zip(self.layers, choices):
            kern = self._kernel_by_name(l.spec, ch.kernel)
            raw = self.store.read_raw(l.spec.name) if l.spec.weight_shapes else {}
            w = kern.transform(raw, l.spec) if l.spec.weight_shapes else {}
            # stage_weights, not jnp.asarray: identity transforms hand back
            # mmap views whose aliasing would leave disk I/O in execute
            weights[l.spec.name] = stage_weights(w)
        best = float("inf")
        for _ in range(repeats):
            t0 = time.perf_counter()
            y = jnp.asarray(x)
            for l in self.layers:
                y = jitted[l.spec.name](weights[l.spec.name], y)
            jax.block_until_ready(y)
            best = min(best, time.perf_counter() - t0)
        return best

    def warm_best_choices(self) -> List[Choice]:
        """Per-layer kernel with the fastest *execution* (ncnn's policy)."""
        out = []
        for l in self.layers:
            ps = self.profiles.get(l.spec.name)
            assert ps, "decide() must run first"
            best = min(ps, key=lambda p: p.exec_s)
            out.append(Choice(best.kernel, False))
        return out
