"""Online pipelined runtime — §3.1.3 / §3.3 at execution time.

Since PR 5 this module is a thin façade over the ``repro.executor``
subsystem: ``run`` compiles the scheduling ``Plan`` into a typed task graph
(``read → transform → stage → execute`` with per-layer deps and core
affinities — ``executor.graph.compile_plan``) and submits it to the
process-wide persistent ``CorePool``. The pool's big/little workers are
created once and reused across runs *and models*: the steady path performs
no thread creation, and an idle worker steals the tail of the prep queue
with the most remaining preparation time (§3.3, the same
``pick_steal_donor`` rule the scheduler's simulator models).

Preparation still ends with an explicit *stage* op (``jax.device_put``):
weights arrive on device as part of prep, off the critical exec chain. With
``stage_in_prep=False`` staging is deferred to ``any``-affinity tasks —
whoever idles first stages layer i+1 while layer i executes (the old
dedicated "stager" threads are gone); ``prefetch=False`` pins deferred
staging to the big cores, strictly inline before each execute.

Every op's (start, end) is recorded per job for the benchmark breakdowns;
trace kinds are ``read`` / ``transform`` / ``stage`` / ``execute``.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.registry import Kernel, LayerSpec
from repro.core.scheduler import Plan
from repro.core.staging import stage_weights
from repro.executor.graph import OpTrace, compile_plan
from repro.executor.pool import CorePool, Job, get_core_pool
from repro.faults import TransientFault
from repro.ioengine import ReadAbandoned

__all__ = ["OpTrace", "PipelineJob", "PipelineRuntime", "RunResult"]


@dataclass
class RunResult:
    output: Any
    total_s: float
    traces: List[OpTrace] = field(default_factory=list)
    weights: Optional[Dict[str, Any]] = None  # resident post-run weights
    # readahead coverage of this run ({"mode", "layers_requested",
    # "layers_hinted", "bytes_hinted", ...}); None when the runtime issued
    # no hint at all — benchmark breakdowns use this to tell hinted runs
    # from ones where the hint silently no-oped (e.g. no madvise)
    readahead: Optional[Dict[str, Any]] = None

    def stage_seconds(self) -> Dict[str, float]:
        agg: Dict[str, float] = {}
        for t in self.traces:
            agg[t.kind] = agg.get(t.kind, 0.0) + (t.end - t.start)
        return agg


class PipelineJob:
    """Handle for one in-flight cold run submitted to the pool."""

    def __init__(self, job: Job, state: Dict[str, Any],
                 weights: Dict[str, Any],
                 readahead: Optional[Dict[str, Any]] = None):
        self.job = job
        self._state = state
        self._weights = weights
        self._readahead = readahead

    @property
    def t0(self) -> float:
        return self.job.t0

    @property
    def traces(self) -> List[OpTrace]:
        return self.job.traces

    def done(self) -> bool:
        return self.job.done.is_set()

    def result(self, timeout: Optional[float] = None) -> RunResult:
        self.job.wait(timeout)
        return RunResult(output=self._state["y"], total_s=self.job.total_s,
                         traces=self.job.traces, weights=self._weights,
                         readahead=self._readahead)


class _AsyncReads:
    """Per-job submit/reap ledger over the async I/O engine.

    Submissions are keyed by layer and idempotent, so the depth-prefetch
    a read task issues for its lane successors composes with work
    stealing (whoever ends up running a stolen layer's read reaps the
    same pending handle).  Pending handles self-reset on transient
    faults, so the pool's bounded retries resubmit cleanly; pool buffers
    recycle at job end via ``close()`` (a ``Job.add_done_callback``),
    the first moment no retry or zombie attempt can still need the
    views."""

    def __init__(self, runtime: "PipelineRuntime", engine):
        self.rt = runtime
        self.engine = engine
        self.lock = threading.Lock()
        self.pending: Dict[str, Any] = {}
        self.closed = False
        self.prefetched = 0
        self.prefetch_bytes = 0

    def _submit_locked(self, layer: str):
        if layer in self.pending or self.closed:
            return self.pending.get(layer)
        rt = self.rt
        if not rt.specs[layer].weight_shapes:
            return None
        if rt.use_cache.get(layer, False):
            h = rt.store.submit_read_cached(self.engine, layer,
                                            rt.kernels[layer].name)
        else:
            h = rt.store.submit_read_raw(self.engine, layer)
        self.pending[layer] = h
        return h

    def prefetch(self, layers) -> None:
        """Best-effort submissions for upcoming layers (depth readahead).
        Failures are swallowed: the layer's own read task resubmits with
        the pool's retry budget when its turn comes."""
        for name in layers:
            try:
                with self.lock:
                    before = name in self.pending
                    h = self._submit_locked(name)
                if h is not None and not before:
                    self.prefetched += 1
                    self.prefetch_bytes += h.nbytes()
            except Exception:
                continue

    def wait(self, layer: str):
        with self.lock:
            h = self._submit_locked(layer)
        if h is None:
            return {}
        return h.wait()

    def abort(self, layer: str) -> None:
        """Race-loser interrupt: flag the layer's in-flight read abandoned
        so a waiter parked in the engine's emulated-disk pacing raises
        ``ReadAbandoned`` and frees its pool slot now. Flag-only — buffer
        recycling still happens at job-end ``close()``."""
        with self.lock:
            h = self.pending.get(layer)
        ab = getattr(h, "abort", None)
        if ab is not None:
            ab()

    def close(self) -> None:
        with self.lock:
            self.closed = True
            handles = list(self.pending.values())
            self.pending.clear()
        for h in handles:
            h.release()


class PipelineRuntime:
    def __init__(
        self,
        specs: List[LayerSpec],
        kernels: Dict[str, Kernel],       # layer name -> chosen kernel
        use_cache: Dict[str, bool],
        store,
        jitted: Dict[str, Callable],      # layer name -> jitted exec fn
        n_little: int,
        work_stealing: bool = True,
        stage_in_prep: bool = True,
        prefetch: bool = True,
        prep_costs: Optional[Dict[str, float]] = None,
        pool: Optional[CorePool] = None,
        retry=None,                       # faults.RetryPolicy for the job
        deadline_s: Optional[float] = None,  # per-task watchdog deadline
        fault_injector=None,              # faults.FaultInjector (chaos)
        repair_log=None,                  # faults.RepairLog (ladder events)
        fallback_exec: Optional[Callable] = None,  # (layer, x, exc) -> y
        exec_allowed: Optional[Callable[[str], bool]] = None,  # breaker
        io_engine=None,                   # repro.ioengine.IOEngine (async
                                          # submit/reap reads; None = sync)
        stage_engine=None,                # repro.ioengine.StageEngine
    ):
        self.specs = {s.name: s for s in specs}
        self.order = [s.name for s in specs]
        self.kernels = kernels
        self.use_cache = use_cache
        self.store = store
        self.jitted = jitted
        self.n_little = n_little
        self.work_stealing = work_stealing
        self.stage_in_prep = stage_in_prep
        self.prefetch = prefetch
        self.pool = pool
        self.retry = retry
        self.deadline_s = deadline_s
        self.fault_injector = fault_injector
        self.repair_log = repair_log
        self.fallback_exec = fallback_exec
        self.exec_allowed = exec_allowed
        # async reads go through the engine only when the store's format
        # supports extent submission (npy legacy stays sync by design)
        self.io_engine = (io_engine if io_engine is not None
                          and getattr(store, "supports_async", False)
                          else None)
        self.stage_engine = stage_engine
        # per-layer prep-cost estimates drive donor selection when stealing;
        # weight bytes are the fallback proxy when no profile is plumbed in
        self.prep_costs = prep_costs or {
            s.name: float(s.weight_bytes) for s in specs}

    # -- device staging (the prep tail) -------------------------------------
    _device_put = staticmethod(stage_weights)

    def _get_pool(self) -> CorePool:
        if self.pool is None:
            self.pool = get_core_pool(n_little=self.n_little)
        return self.pool

    def _hint_readahead(self, layers: List[str]):
        """Super-bundle stores can madvise(WILLNEED) the extents the plan
        touches first, so kernel readahead runs ahead of the prep threads."""
        ra = getattr(self.store, "readahead", None)
        if ra is None:
            return
        seen, first = set(), []
        for n in layers:
            if n not in seen:
                seen.add(n)
                first.append(n)
        ra(first)

    # -- one preparation op (read [+ transform] + stage) --------------------
    # kept whole for callers that prep a single layer synchronously (tests,
    # fallback paths); the task graph uses the finer-grained ops below
    def _prepare(self, layer: str, weights_out: Dict[str, Any],
                 traces: List[OpTrace], core: str, t0: float, lock,
                 staged: Optional[Dict[str, threading.Event]] = None):
        spec = self.specs[layer]
        if not spec.weight_shapes:
            with lock:
                weights_out[layer] = {}
            if staged is not None:
                staged[layer].set()
            return
        if self.use_cache.get(layer, False):
            ts = time.perf_counter()
            w = self._read_op(layer)
            te = time.perf_counter()
            traces.append(OpTrace(layer, "read", core, ts - t0, te - t0))
        else:
            ts = time.perf_counter()
            raw = self.store.read_raw(layer)
            tm = time.perf_counter()
            w = self.kernels[layer].transform(raw, spec)
            te = time.perf_counter()
            traces.append(OpTrace(layer, "read", core, ts - t0, tm - t0))
            traces.append(OpTrace(layer, "transform", core, tm - t0, te - t0))
        if self.stage_in_prep and staged is not None:
            ts = time.perf_counter()
            w = self._device_put(w)
            traces.append(OpTrace(layer, "stage", core, ts - t0,
                                  time.perf_counter() - t0))
            with lock:
                weights_out[layer] = w
            staged[layer].set()
        else:
            with lock:
                weights_out[layer] = w

    def _read_op(self, layer: str):
        """The 'read' task body: cached entry (§3.1.2) or raw weights.

        Degradation ladder, first rung: the cache entry is CRC-audited
        before it is trusted (``LayerStore.audit_cached`` covers the
        zero-copy mmap path that lazy verification normally skips). A
        failing or missing entry is transparently recomputed from raw and
        the repair is journaled — the request never fails over bit-rot."""
        spec = self.specs[layer]
        kern = self.kernels[layer]
        if self.use_cache.get(layer, False):
            audit = getattr(self.store, "audit_cached", None)
            ok = audit(layer, kern.name) if audit is not None else True
            w = self.store.read_cached(layer, kern.name) if ok else {}
            if not w:
                # dropped under the plan's feet (journal recovery, checksum
                # audit, bit-rot): recompute rather than execute weightless
                w = kern.transform(self.store.read_raw(layer), spec)
                if self.repair_log is not None:
                    self.repair_log.record(
                        "cache_recompute", layer=layer, kernel=kern.name,
                        reason=("failed CRC audit" if not ok
                                else "entry missing/dropped"))
            return w
        return self.store.read_raw(layer)

    def _read_op_async(self, reads: _AsyncReads, layer: str):
        """Async 'read' task body: reap the layer's pending submission.

        Same degradation ladder as ``_read_op`` — the CRC audit runs on
        the reaped bytes inside the pending read (covering exactly the
        bytes served), and a dropped/missing cache entry recomputes from
        raw with the repair journaled."""
        w = reads.wait(layer)
        if self.use_cache.get(layer, False) and not w:
            spec = self.specs[layer]
            kern = self.kernels[layer]
            if spec.weight_shapes:
                w = kern.transform(self.store.read_raw(layer), spec)
                if self.repair_log is not None:
                    self.repair_log.record(
                        "cache_recompute", layer=layer, kernel=kern.name,
                        reason="entry missing/dropped (async read)")
        return w

    # -- graph compilation + submission -------------------------------------
    def submit(self, x, plan: Plan, *, graph_hook=None,
               job_deadline_s: Optional[float] = None,
               peer_fetch=None) -> PipelineJob:
        """Compile the plan into a task graph and hand it to the persistent
        pool; returns immediately with a :class:`PipelineJob`.

        ``graph_hook(graph, weights, lock)`` may append extra tasks (e.g.
        the LLM bridge's decode-path packing) before submission.
        ``job_deadline_s`` is the run's END-TO-END budget: the pool
        watchdog fails the job with a typed ``DeadlineExceeded`` once it is
        blown (the front door's deadline propagation lands here).

        ``peer_fetch`` (a ``warmstate.PeerFetcher``) arms the warm-state
        race: the peer's post-transform staged weights stream in on the
        fetcher's own background thread (started at submit, so the wire
        races the disk from t=0), each layer racing its local
        ``read → transform → stage`` chain.  First finisher wins — the
        winner cancels the loser via ``CorePool.cancel_tasks`` (preps-done
        still fires exactly once); every weighted layer also gets a
        dep-free ``fetch_remote`` marker task so the race is visible and
        cancellable in the DAG.  Any ``TransientFault`` on the wire falls
        back to the local chains without failing the job.  Every outcome
        lands in the job's ``fault_events`` journal."""
        t0 = time.perf_counter()
        weights: Dict[str, Any] = {
            n: {} for n in self.order if not self.specs[n].weight_shapes}
        pending: Dict[str, Any] = {}     # intra-chain intermediates
        lock = threading.Lock()
        state: Dict[str, Any] = {"y": jnp.asarray(x)}

        queues = [[self.order[i] for i in q] for q in plan.little_queues]
        hint_layers = (
            [q[0] for q in queues if q]
            + [self.order[i] for i in plan.big_prep]
            + self.order[: 2 * (len(queues) + 1)])

        reads = (_AsyncReads(self, self.io_engine)
                 if self.io_engine is not None else None)
        ra_stats: Optional[Dict[str, Any]] = None
        if reads is not None:
            # readahead hints route through the engine: the plan's first
            # layers are submitted NOW, so their bytes are moving before
            # any worker picks up a read task (the async analogue of the
            # madvise hint, and counted the same way)
            seen: set = set()
            first = [n for n in hint_layers
                     if not (n in seen or seen.add(n))]
            reads.prefetch(first)
            ra_stats = {"mode": "engine", "layers_requested": len(first),
                        "layers_hinted": reads.prefetched,
                        "bytes_hinted": reads.prefetch_bytes,
                        "madvise_available": False}
        else:
            self._hint_readahead(hint_layers)
            st = getattr(self.store, "readahead_stats", None)
            if st is not None:
                ra_stats = {"mode": "madvise", **st}

        fetch_layers = None
        if peer_fetch is not None:
            fetch_layers = [n for n in self.order
                            if self.specs[n].weight_shapes]
        graph = compile_plan(
            self.order, plan,
            weighted={n: bool(self.specs[n].weight_shapes)
                      for n in self.order},
            use_cache=self.use_cache,
            prep_costs=self.prep_costs,
            stage_in_prep=self.stage_in_prep,
            deferred_stage_affinity="any" if self.prefetch else "big",
            fetch_layers=fetch_layers,
        )
        # race bookkeeping: the winner cancels the loser by tid.  jobref is
        # a late-bound cell — task fns can start before ``pool.submit``
        # returns the Job; a miss in that window just means both sides run
        # to completion and write bit-identical weights (value-idempotent).
        jobref: List[Optional[Job]] = [None]
        chain_tids: Dict[str, List[int]] = {
            n: [t.tid for t in ts] for n, ts in graph.prep_chains().items()}
        fetch_tids: Dict[str, int] = {
            t.layer: t.tid for t in graph.tasks if t.kind == "fetch_remote"}
        # lane successors for depth prefetch: a read task submits its own
        # layer plus the next (depth-1) layers of its lane, so a little
        # core keeps Plan.read_depth reads in flight instead of one
        succ: Dict[str, List[str]] = {}
        if reads is not None:
            seqs = list(graph.lane_queues().values())
            seqs.append(graph.big_prep_layers())
            for seq in seqs:
                for i, n in enumerate(seq):
                    succ[n] = seq[i + 1:]

        # task fns are VALUE-IDEMPOTENT: every stage writes its own
        # (name, kind) key instead of mutating/popping a shared one, so a
        # retried attempt — or a watchdog-zombie that finishes late —
        # recomputes the identical value into the same slot and cannot
        # corrupt the chain. (Intermediates are held until the job ends;
        # the pool-retry safety is worth the transient footprint.)
        def read_fn(name, depth=1):
            if reads is None:
                def fn():
                    pending[(name, "read")] = self._read_op(name)
                return fn

            ahead = succ.get(name, [])[:max(0, depth - 1)]

            def fn():
                reads.prefetch(ahead)   # keep the lane at planned depth
                try:
                    pending[(name, "read")] = self._read_op_async(reads,
                                                                  name)
                except ReadAbandoned:
                    # warm-state fetch won this layer mid-read: the chain's
                    # later tasks are already cancelled — bail, freeing the
                    # slot instead of sleeping out the emulated disk
                    return
            return fn

        def transform_fn(name):
            def fn():
                pending[(name, "xf")] = self.kernels[name].transform(
                    pending[(name, "read")], self.specs[name])
            return fn

        def stage_fn(name):
            def fn():
                src = pending.get((name, "xf"), None)
                if src is None:
                    src = pending[(name, "read")]
                if self.stage_engine is not None:
                    w = self.stage_engine.stage(src)
                else:
                    w = self._device_put(src)
                with lock:
                    won = name not in weights
                    weights[name] = w
                # local chain finished first: retire the pending fetch task
                # (a RUNNING fetch is left alone — it re-checks ``weights``
                # before writing, and both values are bit-identical anyway)
                ftid = fetch_tids.get(name)
                if won and ftid is not None:
                    job = jobref[0]
                    if job is not None:
                        self._get_pool().cancel_tasks(
                            job, [ftid], reason="race_local_won")
            return fn

        # The peer stream drains on the PeerFetcher's OWN thread (started
        # eagerly below, like the read prefetch — bytes are moving before
        # any worker picks up a task) and delivers layers through these
        # callbacks; the graph's ``fetch_remote`` tasks are the race's
        # instant, cancellable markers (running one backstop-starts the
        # stream; a local win retires its layer's pending marker).  The
        # stream NEVER fails the job: any TransientFault (refusal,
        # disconnect, CRC mismatch, injected chaos at the warmstate.*
        # sites) journals a fallback and leaves the local chains — always
        # racing — authoritative.
        def fetch_landed(name, w):
            with lock:
                lost = name in weights           # local chain already won
            if not lost:
                if self.stage_engine is not None:
                    staged = self.stage_engine.stage(w)
                else:
                    staged = self._device_put(w)
                with lock:
                    lost = name in weights       # ...or won while we staged
                    if not lost:
                        weights[name] = staged
            job = jobref[0]
            if lost:
                if job is not None:
                    job.fault_events.append(
                        {"action": "fetch_lost", "layer": name})
                return
            # fetch won: retire the local read→transform→stage chain;
            # cancellation fires preps-done through the pool's
            # exactly-once accounting. A read task already RUNNING can't
            # be cancelled — interrupt its (emulated-disk) wait instead
            if job is not None:
                self._get_pool().cancel_tasks(
                    job, chain_tids.get(name, ()), reason="race_fetch_won")
            if reads is not None:
                reads.abort(name)

        def fetch_failed(e):
            job = jobref[0]
            if job is not None:
                job.fault_events.append({
                    "action": "fetch_fallback",
                    "error": type(e).__name__, "detail": str(e)})
            if self.repair_log is not None:
                self.repair_log.record(
                    "fetch_fallback", error=type(e).__name__)

        def race_decided():
            with lock:
                return all(n in weights for n in (fetch_layers or ()))

        def fetch_fn(name):
            def fn():
                peer_fetch.start_stream(fetch_landed, on_error=fetch_failed,
                                        should_stop=race_decided)
            return fn

        def execute_fn(name):
            def fn():
                with lock:
                    w = weights.get(name, {})
                x_in = state["y"]
                if (self.fallback_exec is not None
                        and self.exec_allowed is not None
                        and not self.exec_allowed(name)):
                    # circuit breaker already open for this layer's kernel:
                    # demote straight to the reference path
                    y = self.fallback_exec(name, x_in, None)
                else:
                    try:
                        inj = self.fault_injector
                        if inj is not None:
                            inj.maybe_fault("kernel.execute", name)
                        y = self.jitted[name](w, x_in)
                        jax.block_until_ready(y)
                    except TransientFault:
                        raise  # pool-level bounded retry (state["y"] is
                        #        untouched, so the retry reads the same x)
                    except Exception as e:
                        if self.fallback_exec is None:
                            raise
                        # degradation ladder: a faulting kernel demotes to
                        # the reference kernel instead of failing the run
                        y = self.fallback_exec(name, x_in, e)
                state["y"] = y
            return fn

        binders = {"read": read_fn, "transform": transform_fn,
                   "stage": stage_fn, "execute": execute_fn,
                   "fetch_remote": fetch_fn}
        for task in graph.tasks:
            if task.kind == "read":
                task.fn = read_fn(task.layer, task.depth)
            else:
                task.fn = binders[task.kind](task.layer)
        if graph_hook is not None:
            graph_hook(graph, weights, lock)

        if peer_fetch is not None and fetch_layers:
            # arm the race NOW — the peer stream races the disk from t=0,
            # not from whenever a pool worker first idles
            peer_fetch.start_stream(fetch_landed, on_error=fetch_failed,
                                    should_stop=race_decided)

        job = self._get_pool().submit(
            graph, name=f"cold:{self.order[0]}..{self.order[-1]}",
            allow_steal=self.work_stealing, t0=t0,
            retry=self.retry, deadline_s=self.deadline_s,
            job_deadline_s=job_deadline_s)
        jobref[0] = job
        if reads is not None:
            # engine buffers recycle only once no retry/zombie can still
            # reap them — i.e. when the job is finished for good
            job.add_done_callback(lambda _j: reads.close())
        if peer_fetch is not None:
            def _end_race(j):
                peer_fetch.close()
                # journal the race's closing line next to the per-layer
                # win/loss/fallback events
                j.fault_events.append({
                    "action": "fetch_race_end",
                    **{k: peer_fetch.stats[k]
                       for k in ("layers_fetched", "bytes_fetched",
                                 "crc_failures", "refused")}})
            job.add_done_callback(_end_race)
        return PipelineJob(job, state, weights, readahead=ra_stats)

    def run(self, x, plan: Plan) -> RunResult:
        return self.submit(x, plan).result()

    # -- baseline: fully sequential cold inference (ncnn-like) --------------
    def run_sequential(self, x, kernels: Optional[Dict[str, Kernel]] = None) -> RunResult:
        kernels = kernels or self.kernels
        t0 = time.perf_counter()
        traces: List[OpTrace] = []
        weights: Dict[str, Any] = {}
        self._hint_readahead(self.order)
        for name in self.order:           # read all
            ts = time.perf_counter()
            # mmap=False: the ncnn-like baseline's read op must move the
            # layer's bytes off the disk — a lazy mmap view would make the
            # 'read' trace metadata-only and silently shift the disk cost
            # into transform/stage, corrupting the breakdown
            weights[name] = (self.store.read_raw(name, mmap=False)
                             if self.specs[name].weight_shapes else {})
            traces.append(OpTrace(name, "read", "big", ts - t0, time.perf_counter() - t0))
        for name in self.order:           # transform all
            if not self.specs[name].weight_shapes:
                continue
            ts = time.perf_counter()
            weights[name] = kernels[name].transform(weights[name], self.specs[name])
            traces.append(OpTrace(name, "transform", "big", ts - t0, time.perf_counter() - t0))
        for name in self.order:           # stage all (host -> device)
            ts = time.perf_counter()
            weights[name] = self._device_put(weights[name])
            traces.append(OpTrace(name, "stage", "big", ts - t0, time.perf_counter() - t0))
        y = x
        for name in self.order:           # execute all (device-resident weights)
            ts = time.perf_counter()
            y = self.jitted[name](weights[name], y)
            jax.block_until_ready(y)
            traces.append(OpTrace(name, "execute", "big", ts - t0, time.perf_counter() - t0))
        st = getattr(self.store, "readahead_stats", None)
        return RunResult(output=y, total_s=time.perf_counter() - t0,
                         traces=traces,
                         readahead=({"mode": "madvise", **st}
                                    if st is not None else None))
