"""Online pipelined runtime — §3.1.3 / §3.3 at execution time.

Executes a scheduling Plan with real threads and real work:
  * one worker thread per (simulated) little core, each draining its queue of
    preparation ops (disk read + weights transform — numpy releases the GIL
    for the heavy parts);
  * the caller's thread plays the big-core cluster: it runs any big-core
    preps first, then the execution chain e_1..e_N, blocking on each layer's
    prep-completion event;
  * work stealing: an idle worker steals the head of the longest remaining
    queue (§3.3 'dealing with hardware dynamics').

Every op's (start, end) is recorded for the benchmark breakdowns.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.registry import Kernel, LayerSpec
from repro.core.scheduler import Plan


@dataclass
class OpTrace:
    layer: str
    kind: str
    core: str
    start: float
    end: float


@dataclass
class RunResult:
    output: Any
    total_s: float
    traces: List[OpTrace] = field(default_factory=list)
    weights: Optional[Dict[str, Any]] = None  # resident post-run weights

    def stage_seconds(self) -> Dict[str, float]:
        agg: Dict[str, float] = {}
        for t in self.traces:
            agg[t.kind] = agg.get(t.kind, 0.0) + (t.end - t.start)
        return agg


class PipelineRuntime:
    def __init__(
        self,
        specs: List[LayerSpec],
        kernels: Dict[str, Kernel],       # layer name -> chosen kernel
        use_cache: Dict[str, bool],
        store,
        jitted: Dict[str, Callable],      # layer name -> jitted exec fn
        n_little: int,
        work_stealing: bool = True,
    ):
        self.specs = {s.name: s for s in specs}
        self.order = [s.name for s in specs]
        self.kernels = kernels
        self.use_cache = use_cache
        self.store = store
        self.jitted = jitted
        self.n_little = n_little
        self.work_stealing = work_stealing

    # -- one preparation op (read [+ transform]) ----------------------------
    def _prepare(self, layer: str, weights_out: Dict[str, Any],
                 traces: List[OpTrace], core: str, t0: float, lock):
        spec = self.specs[layer]
        kern = self.kernels[layer]
        if not spec.weight_shapes:
            with lock:
                weights_out[layer] = {}
            return
        if self.use_cache.get(layer, False):
            ts = time.perf_counter()
            w = self.store.read_cached(layer, kern.name)
            te = time.perf_counter()
            traces.append(OpTrace(layer, "read", core, ts - t0, te - t0))
        else:
            ts = time.perf_counter()
            raw = self.store.read_raw(layer)
            tm = time.perf_counter()
            w = kern.transform(raw, spec)
            te = time.perf_counter()
            traces.append(OpTrace(layer, "read", core, ts - t0, tm - t0))
            traces.append(OpTrace(layer, "transform", core, tm - t0, te - t0))
        with lock:
            weights_out[layer] = w

    def run(self, x, plan: Plan) -> RunResult:
        t0 = time.perf_counter()
        weights: Dict[str, Any] = {}
        traces: List[OpTrace] = []
        lock = threading.Lock()
        done_events = {name: threading.Event() for name in self.order}

        queues = [[self.order[i] for i in q] for q in plan.little_queues]
        qlock = threading.Lock()

        def steal() -> Optional[str]:
            with qlock:
                donor = max(queues, key=lambda q: len(q), default=None)
                if donor:
                    return donor.pop(0) if donor else None
            return None

        def worker(j: int):
            core = f"little{j}"
            while True:
                with qlock:
                    layer = queues[j].pop(0) if queues[j] else None
                if layer is None and self.work_stealing:
                    layer = steal()
                if layer is None:
                    return
                self._prepare(layer, weights, traces, core, t0, lock)
                done_events[layer].set()

        threads = [threading.Thread(target=worker, args=(j,), daemon=True)
                   for j in range(len(queues))]
        for th in threads:
            th.start()

        # big cores: preps first, then the execution chain
        for i in plan.big_prep:
            layer = self.order[i]
            self._prepare(layer, weights, traces, "big", t0, lock)
            done_events[layer].set()

        y = x
        for name in self.order:
            done_events[name].wait()
            with lock:
                w = weights[name]
            wj = {k: jnp.asarray(v) for k, v in w.items()}
            ts = time.perf_counter()
            y = self.jitted[name](wj, y)
            jax.block_until_ready(y)
            te = time.perf_counter()
            traces.append(OpTrace(name, "execute", "big", ts - t0, te - t0))
        for th in threads:
            th.join()
        return RunResult(output=y, total_s=time.perf_counter() - t0,
                         traces=traces, weights=weights)

    # -- baseline: fully sequential cold inference (ncnn-like) --------------
    def run_sequential(self, x, kernels: Optional[Dict[str, Kernel]] = None) -> RunResult:
        kernels = kernels or self.kernels
        t0 = time.perf_counter()
        traces: List[OpTrace] = []
        weights: Dict[str, Any] = {}
        for name in self.order:           # read all
            ts = time.perf_counter()
            weights[name] = self.store.read_raw(name) if self.specs[name].weight_shapes else {}
            traces.append(OpTrace(name, "read", "big", ts - t0, time.perf_counter() - t0))
        for name in self.order:           # transform all
            if not self.specs[name].weight_shapes:
                continue
            ts = time.perf_counter()
            weights[name] = kernels[name].transform(weights[name], self.specs[name])
            traces.append(OpTrace(name, "transform", "big", ts - t0, time.perf_counter() - t0))
        y = x
        for name in self.order:           # execute all
            wj = {k: jnp.asarray(v) for k, v in weights[name].items()}
            ts = time.perf_counter()
            y = self.jitted[name](wj, y)
            jax.block_until_ready(y)
            traces.append(OpTrace(name, "execute", "big", ts - t0, time.perf_counter() - t0))
        return RunResult(output=y, total_s=time.perf_counter() - t0, traces=traces)
