"""Online pipelined runtime — §3.1.3 / §3.3 at execution time.

Executes a scheduling Plan with real threads and real work:
  * one worker thread per (simulated) little core, each draining its queue of
    preparation ops (disk read + weights transform + device staging — numpy
    and the device transfer release the GIL for the heavy parts);
  * the caller's thread plays the big-core cluster: it runs any big-core
    preps first, then the execution chain e_1..e_N, blocking on each layer's
    prep-completion event;
  * work stealing: an idle worker steals from the *tail* of the queue with
    the most remaining preparation time (§3.3 'dealing with hardware
    dynamics') — the same rule the scheduler's simulator models.

Preparation now ends with an explicit *stage* op (``jax.device_put``): the
weights arrive on device as part of prep, off the critical exec chain, so
execute ops run with device-resident weights and contain no host→device
conversion. With ``stage_in_prep=False`` staging is deferred to the big
cores, where ``prefetch=True`` overlaps layer i+1's device transfer with
layer i's execution.

Every op's (start, end) is recorded for the benchmark breakdowns; trace
kinds are ``read`` / ``transform`` / ``stage`` / ``execute``.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp

from repro.core.registry import Kernel, LayerSpec
from repro.core.scheduler import Plan
from repro.core.staging import stage_weights


@dataclass
class OpTrace:
    layer: str
    kind: str
    core: str
    start: float
    end: float


@dataclass
class RunResult:
    output: Any
    total_s: float
    traces: List[OpTrace] = field(default_factory=list)
    weights: Optional[Dict[str, Any]] = None  # resident post-run weights

    def stage_seconds(self) -> Dict[str, float]:
        agg: Dict[str, float] = {}
        for t in self.traces:
            agg[t.kind] = agg.get(t.kind, 0.0) + (t.end - t.start)
        return agg


class PipelineRuntime:
    def __init__(
        self,
        specs: List[LayerSpec],
        kernels: Dict[str, Kernel],       # layer name -> chosen kernel
        use_cache: Dict[str, bool],
        store,
        jitted: Dict[str, Callable],      # layer name -> jitted exec fn
        n_little: int,
        work_stealing: bool = True,
        stage_in_prep: bool = True,
        prefetch: bool = True,
        prep_costs: Optional[Dict[str, float]] = None,
    ):
        self.specs = {s.name: s for s in specs}
        self.order = [s.name for s in specs]
        self.kernels = kernels
        self.use_cache = use_cache
        self.store = store
        self.jitted = jitted
        self.n_little = n_little
        self.work_stealing = work_stealing
        self.stage_in_prep = stage_in_prep
        self.prefetch = prefetch
        # per-layer prep-cost estimates drive donor selection when stealing;
        # weight bytes are the fallback proxy when no profile is plumbed in
        self.prep_costs = prep_costs or {
            s.name: float(s.weight_bytes) for s in specs}

    # -- device staging (the new prep tail) ---------------------------------
    _device_put = staticmethod(stage_weights)

    def _hint_readahead(self, layers: List[str]):
        """Super-bundle stores can madvise(WILLNEED) the extents the plan
        touches first, so kernel readahead runs ahead of the prep threads."""
        ra = getattr(self.store, "readahead", None)
        if ra is None:
            return
        seen, first = set(), []
        for n in layers:
            if n not in seen:
                seen.add(n)
                first.append(n)
        ra(first)

    # -- one preparation op (read [+ transform] + stage) --------------------
    def _prepare(self, layer: str, weights_out: Dict[str, Any],
                 traces: List[OpTrace], core: str, t0: float, lock,
                 staged: Optional[Dict[str, threading.Event]] = None):
        spec = self.specs[layer]
        kern = self.kernels[layer]
        if not spec.weight_shapes:
            with lock:
                weights_out[layer] = {}
            if staged is not None:
                staged[layer].set()
            return
        if self.use_cache.get(layer, False):
            ts = time.perf_counter()
            w = self.store.read_cached(layer, kern.name)
            if not w:
                # the entry was dropped under the plan's feet (journal
                # recovery / checksum audit tore it out): fall back to
                # raw + transform rather than executing with no weights
                w = kern.transform(self.store.read_raw(layer), spec)
            te = time.perf_counter()
            traces.append(OpTrace(layer, "read", core, ts - t0, te - t0))
        else:
            ts = time.perf_counter()
            raw = self.store.read_raw(layer)
            tm = time.perf_counter()
            w = kern.transform(raw, spec)
            te = time.perf_counter()
            traces.append(OpTrace(layer, "read", core, ts - t0, tm - t0))
            traces.append(OpTrace(layer, "transform", core, tm - t0, te - t0))
        if self.stage_in_prep and staged is not None:
            ts = time.perf_counter()
            w = self._device_put(w)
            traces.append(OpTrace(layer, "stage", core, ts - t0,
                                  time.perf_counter() - t0))
            with lock:
                weights_out[layer] = w
            staged[layer].set()
        else:
            with lock:
                weights_out[layer] = w

    def run(self, x, plan: Plan) -> RunResult:
        t0 = time.perf_counter()
        weights: Dict[str, Any] = {}
        traces: List[OpTrace] = []
        lock = threading.Lock()
        done_events = {name: threading.Event() for name in self.order}
        staged = {name: threading.Event() for name in self.order}
        stage_started: Dict[str, bool] = {}

        queues = [[self.order[i] for i in q] for q in plan.little_queues]
        qlock = threading.Lock()
        stagers: List[threading.Thread] = []
        self._hint_readahead(
            [q[0] for q in queues if q]
            + [self.order[i] for i in plan.big_prep]
            + self.order[: 2 * (len(queues) + 1)])

        def stage(name: str, core: str):
            """Stage one prepped layer onto the device (idempotent)."""
            with lock:
                if stage_started.get(name):
                    return
                stage_started[name] = True
                w = weights[name]
            ts = time.perf_counter()
            wd = self._device_put(w)
            te = time.perf_counter()
            with lock:
                weights[name] = wd
            traces.append(OpTrace(name, "stage", core, ts - t0, te - t0))
            staged[name].set()

        def steal() -> Optional[str]:
            # §3.3: steal the TAIL (the layer the exec chain needs last) of
            # the donor queue with the most remaining prep time — mirrors
            # scheduler.simulate's work-stealing rule.
            with qlock:
                donor = max(
                    queues, default=None,
                    key=lambda q: sum(self.prep_costs.get(n, 0.0) for n in q))
                if donor:
                    return donor.pop()
            return None

        def worker(j: int):
            core = f"little{j}"
            while True:
                with qlock:
                    layer = queues[j].pop(0) if queues[j] else None
                if layer is None and self.work_stealing:
                    layer = steal()
                if layer is None:
                    return
                self._prepare(layer, weights, traces, core, t0, lock, staged)
                done_events[layer].set()

        threads = [threading.Thread(target=worker, args=(j,), daemon=True)
                   for j in range(len(queues))]
        for th in threads:
            th.start()

        # big cores: preps first, then the execution chain
        for i in plan.big_prep:
            layer = self.order[i]
            self._prepare(layer, weights, traces, "big", t0, lock, staged)
            done_events[layer].set()

        y = x
        for i, name in enumerate(self.order):
            done_events[name].wait()
            if not staged[name].is_set():
                stage(name, "big")      # deferred staging (stage_in_prep=False)
            if self.prefetch and i + 1 < len(self.order):
                nxt = self.order[i + 1]
                if done_events[nxt].is_set() and not staged[nxt].is_set():
                    # overlap layer i+1's device transfer with e_i; tracked
                    # so its 'stage' trace lands before RunResult is built
                    th = threading.Thread(target=stage, args=(nxt, "stager"),
                                          daemon=True)
                    stagers.append(th)
                    th.start()
            staged[name].wait()
            with lock:
                w = weights[name]
            ts = time.perf_counter()
            y = self.jitted[name](w, y)
            jax.block_until_ready(y)
            te = time.perf_counter()
            traces.append(OpTrace(name, "execute", "big", ts - t0, te - t0))
        for th in threads:
            th.join()
        for th in stagers:
            th.join()
        return RunResult(output=y, total_s=time.perf_counter() - t0,
                         traces=traces, weights=weights)

    # -- baseline: fully sequential cold inference (ncnn-like) --------------
    def run_sequential(self, x, kernels: Optional[Dict[str, Kernel]] = None) -> RunResult:
        kernels = kernels or self.kernels
        t0 = time.perf_counter()
        traces: List[OpTrace] = []
        weights: Dict[str, Any] = {}
        self._hint_readahead(self.order)
        for name in self.order:           # read all
            ts = time.perf_counter()
            # mmap=False: the ncnn-like baseline's read op must move the
            # layer's bytes off the disk — a lazy mmap view would make the
            # 'read' trace metadata-only and silently shift the disk cost
            # into transform/stage, corrupting the breakdown
            weights[name] = (self.store.read_raw(name, mmap=False)
                             if self.specs[name].weight_shapes else {})
            traces.append(OpTrace(name, "read", "big", ts - t0, time.perf_counter() - t0))
        for name in self.order:           # transform all
            if not self.specs[name].weight_shapes:
                continue
            ts = time.perf_counter()
            weights[name] = kernels[name].transform(weights[name], self.specs[name])
            traces.append(OpTrace(name, "transform", "big", ts - t0, time.perf_counter() - t0))
        for name in self.order:           # stage all (host -> device)
            ts = time.perf_counter()
            weights[name] = self._device_put(weights[name])
            traces.append(OpTrace(name, "stage", "big", ts - t0, time.perf_counter() - t0))
        y = x
        for name in self.order:           # execute all (device-resident weights)
            ts = time.perf_counter()
            y = self.jitted[name](weights[name], y)
            jax.block_until_ready(y)
            traces.append(OpTrace(name, "execute", "big", ts - t0, time.perf_counter() - t0))
        return RunResult(output=y, total_s=time.perf_counter() - t0, traces=traces)
