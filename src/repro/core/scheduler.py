"""The kernel scheduler — §3.2 formulation + §3.3 Algorithm 1.

Problem (Eq. 1–2): pick per layer (i) a kernel, (ii) raw-vs-cached weights,
(iii) a core and start time for each operation (read/transform/execute),
minimizing E_{e_N} subject to dependency and single-occupancy constraints.
Nonlinear integer programming — NP-hard — so the paper's heuristic:

  * execution ops always occupy all big cores, in layer order
    (assumption 1; Fig. 6 shows exec multithreads near-linearly);
  * read+transform of a layer are bundled as one *preparation* op placed on
    little cores, one op per core, no multithreading (assumption 2);
  * Algorithm 1: outer loop over Pareto-filtered kernel combinations; inner
    big-core loop (move early preps onto big cores while they idle) and
    little-core balancing loop.

We add two validation baselines beyond the paper: a brute-force optimal
search (small N) over kernel × cache × core-assignment, and a simulated-
annealing search — both used in tests/benchmarks to show Algorithm 1 is
near-optimal at a fraction of the cost.

All decisions are evaluated with ``simulate`` — a deterministic event-driven
executor over profiled per-op costs and a ``CoreModel`` (big.LITTLE factors),
including optional per-core background-load slowdowns and the work-stealing
runtime rule (§3.3 "dealing with hardware dynamics").
"""
from __future__ import annotations

import itertools
import math
import random
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.profiler import CoreModel, OpProfile


@dataclass(frozen=True)
class Choice:
    """Kernel + cache decision for one layer."""
    kernel: str
    use_cache: bool


@dataclass
class Plan:
    choices: List[Choice]                 # per layer
    big_prep: List[int]                   # layer indices prepped on big cores
    little_queues: List[List[int]]        # per little core: layer indices
    est_makespan: float
    est_breakdown: Dict[str, float] = field(default_factory=dict)
    # I/O queue depth for the async engine's read submissions (planned by
    # plan_read_depth from the same profiled costs as the read-vs-stage
    # split; 1 = sync-equivalent)
    read_depth: int = 1

    def to_dict(self):
        return {
            "choices": [(c.kernel, c.use_cache) for c in self.choices],
            "big_prep": self.big_prep,
            "little_queues": self.little_queues,
            "est_makespan": self.est_makespan,
            "read_depth": self.read_depth,
        }

    @staticmethod
    def from_dict(d):
        return Plan(
            choices=[Choice(k, c) for k, c in d["choices"]],
            big_prep=list(d["big_prep"]),
            little_queues=[list(q) for q in d["little_queues"]],
            est_makespan=d["est_makespan"],
            # plan.json written before the async engine landed: depth 1
            read_depth=int(d.get("read_depth", 1)),
        )


def plan_read_depth(
    read_costs: Sequence[float],
    other_prep_costs: Sequence[float],
    *,
    io_interference: float = 1.0,
    max_depth: int = 8,
) -> int:
    """Queue depth the async engine should keep reads at, from the same
    profiled per-layer costs the read-vs-stage split is planned from.

    The prep pipeline alternates read (disk) with transform+stage (CPU)
    per layer.  When total read time dominates the CPU-side prep work,
    the disk goes idle between submissions unless reads run ahead at
    depth; when CPU work dominates, depth buys nothing — one outstanding
    read is always ready before the CPU needs it.  So the planned depth
    is the ratio of (interference-scaled) read time to the CPU time that
    can overlap it, clamped to [1, max_depth].  §3.2's measured
    ``io_interference`` factor scales the read side: co-running preps
    slow each other's I/O down, which *raises* the depth needed to keep
    the device saturated.  Deterministic, so plan.json round-trips it.
    """
    total_read = float(sum(read_costs)) * max(float(io_interference), 1.0)
    total_other = float(sum(other_prep_costs))
    if total_read <= 0.0:
        return 1
    floor = total_read / max(int(max_depth), 1)
    depth = math.ceil(total_read / max(total_other, floor, 1e-12))
    return max(1, min(int(max_depth), int(depth)))


#: conservative default for an unmeasured peer link — loopback and LAN both
#: clear it comfortably, so an unwarmed estimate only *under*-claims transfer
DEFAULT_LINK_BYTES_PER_S = 200e6


def transfer_estimate(resident_bytes: int, link_bytes_per_s: float = 0.0,
                      *, rtt_s: float = 0.0) -> float:
    """Seconds to stream ``resident_bytes`` of warm state from a peer.

    The peer-transfer cost model, deliberately as simple as
    ``plan_read_depth``'s: one setup round-trip plus bytes over measured
    link bandwidth.  Used in two places with the SAME arithmetic —
    ``FrontDoor`` routing (prefer a non-resident worker when its peer
    fetch beats the local cold estimate) and the per-cold-start decision
    to arm ``fetch_remote`` race tasks at all — so routing and execution
    never disagree about whether a transfer is worth it.  Bandwidth is an
    EWMA measured from completed transfers; before any transfer has
    completed, ``DEFAULT_LINK_BYTES_PER_S`` applies.  Deterministic: no
    wall-clock sampling in here.
    """
    bw = float(link_bytes_per_s) if link_bytes_per_s > 0.0 \
        else DEFAULT_LINK_BYTES_PER_S
    return max(float(rtt_s), 0.0) + max(int(resident_bytes), 0) / bw


# ---------------------------------------------------------------------------
# candidate filtering (Algorithm 1, line 1)
# ---------------------------------------------------------------------------
def pareto_filter(cands: List[Tuple[Choice, float, float]]) -> List[Tuple[Choice, float, float]]:
    """cands: (choice, prep_s, exec_s). Keep the Pareto frontier — drop any
    candidate that is no faster than another in BOTH preparation and
    execution (paper: 'filter out the kernel candidates that exhibit no
    faster operation')."""
    keep = []
    for c in cands:
        dominated = any(
            (o[1] <= c[1] and o[2] <= c[2]) and (o[1] < c[1] or o[2] < c[2])
            for o in cands
        )
        if not dominated:
            keep.append(c)
    # dedupe exact ties
    seen, out = set(), []
    for c in keep:
        key = (round(c[1], 9), round(c[2], 9))
        if key not in seen:
            seen.add(key)
            out.append(c)
    return out


# ---------------------------------------------------------------------------
# deterministic simulation (also the runtime model for work stealing)
# ---------------------------------------------------------------------------
def pick_steal_donor(remaining: Dict, costs: Callable[[object], float]):
    """§3.3 work-stealing donor rule, shared by ``simulate`` and the
    executor's ``CorePool``: an idle core steals from the queue with the
    most *remaining preparation time* (and takes that queue's TAIL — the
    layer the exec chain needs last). ``remaining`` maps a queue key to its
    outstanding items; ``costs`` prices one item. Returns the donor key, or
    None when every queue is empty."""
    donor = None
    best = 0.0
    for key, items in remaining.items():
        c = sum(costs(i) for i in items)
        if items and (donor is None or c > best):
            donor, best = key, c
    return donor
def simulate(
    prep_little: Sequence[float],   # per layer: prep time ON A LITTLE CORE
    prep_big: Sequence[float],      # per layer: prep time ON BIG CORES
    exec_big: Sequence[float],      # per layer: exec time ON BIG CORES
    big_prep: Sequence[int],
    little_queues: Sequence[Sequence[int]],
    *,
    core_load: Optional[Dict[int, float]] = None,  # little core -> slowdown ≥1
    big_load: float = 1.0,
    work_stealing: bool = False,
) -> Tuple[float, Dict[str, float]]:
    """Event-driven makespan. Big cores run [big preps in order] then the
    exec chain e_1..e_N (each e_i waits for prep_i and e_{i-1}). Little core
    j runs its queue in order. With work_stealing, an idle little core steals
    the TAIL of the queue with the most remaining prep time (the layer the
    exec chain needs last) — the same rule ``PipelineRuntime`` applies."""
    N = len(exec_big)
    core_load = core_load or {}
    prep_done = [None] * N  # completion time of layer's prep

    queues = [list(q) for q in little_queues]
    t_little = [0.0] * len(queues)
    ptr = [0] * len(queues)

    # big core timeline: preps first
    t_big = 0.0
    for i in big_prep:
        t_big += prep_big[i] * big_load
        prep_done[i] = t_big

    # little cores process queues; with stealing, rebalance dynamically
    if not work_stealing:
        for j, q in enumerate(queues):
            t = 0.0
            for i in q:
                t += prep_little[i] * core_load.get(j, 1.0)
                prep_done[i] = t
    else:
        remaining = {j: list(q) for j, q in enumerate(queues)}
        t_cores = {j: 0.0 for j in remaining}
        while any(remaining.values()):
            # next core to become free takes its own head, or steals
            j = min(t_cores, key=lambda j: t_cores[j])
            if remaining[j]:
                i = remaining[j].pop(0)
            else:
                donor = pick_steal_donor(remaining,
                                         lambda i2: prep_little[i2])
                if donor is None:
                    break
                i = remaining[donor].pop()  # steal the tail
            t_cores[j] += prep_little[i] * core_load.get(j, 1.0)
            prep_done[i] = t_cores[j]
        t_little = list(t_cores.values())

    # exec chain on big cores
    t = t_big
    wait = 0.0
    for i in range(N):
        pd = prep_done[i]
        if pd is None:
            raise ValueError(f"layer {i} was never prepped")
        start = max(t, pd)
        wait += start - t
        t = start + exec_big[i] * big_load
    makespan = t
    return makespan, {
        "big_prep_s": t_big,
        "exec_wait_s": wait,
        "exec_s": sum(exec_big),
        "little_max_s": max([0.0, *[sum(prep_little[i] for i in q) for q in queues]]),
    }


# ---------------------------------------------------------------------------
# Algorithm 1 inner scheduler
# ---------------------------------------------------------------------------
def inner_schedule(
    prep_little: Sequence[float],
    prep_big: Sequence[float],
    exec_big: Sequence[float],
    M_l: int,
    eps: float = 1e-4,
) -> Tuple[List[int], List[List[int]], float]:
    """Algorithm 1 lines 3–20 for one kernel combination."""
    N = len(exec_big)
    if M_l <= 0:
        # no little cores: everything on big
        big_prep = list(range(N))
        return big_prep, [], simulate(
            prep_little, prep_big, exec_big, big_prep, [])[0]

    # line 3: first layer's prep + all exec on big cores
    big_prep = [0]
    s = 1

    # big-core loop (lines 6-11): while little cores are the bottleneck and
    # the big cores can absorb another early prep, move it there. The
    # provisional round-robin totals are maintained incrementally — when s
    # advances, core j's queue becomes core j+1's and the last core takes
    # core 0's minus the promoted layer — so each step is O(M), not O(N).
    totals = [0.0] * M_l
    for i in range(s, N):
        totals[(i - s) % M_l] += prep_little[i]
    T_big = sum(prep_big[i] for i in big_prep)
    for _ in range(N):
        T_little = max(totals) if s < N else 0.0
        if s < N and (prep_big[s] + prep_little[s]) < (T_little - T_big):
            head = totals[0] - prep_little[s]
            totals = totals[1:] + [head]
            big_prep.append(s)
            T_big += prep_big[s]
            s += 1
        else:
            break

    rest = list(range(s, N))
    qs = [rest[j::M_l] for j in range(M_l)]

    # little-core balancing loop (lines 13-20); per-core totals updated in
    # place on each move instead of re-summed
    totals = [sum(prep_little[i] for i in q) for q in qs]
    for _ in range(4 * N):
        if not rest or max(totals) - min(totals) <= eps:
            break
        jmax = max(range(M_l), key=lambda j: totals[j])
        jmin = min(range(M_l), key=lambda j: totals[j])
        gap = totals[jmax] - totals[jmin]
        moved = False
        for i in sorted(qs[jmax], key=lambda i: -prep_little[i]):
            if prep_little[i] < gap / 2:
                qs[jmax].remove(i)
                qs[jmin].append(i)
                totals[jmax] -= prep_little[i]
                totals[jmin] += prep_little[i]
                moved = True
                break
        if not moved:
            break
    for q in qs:
        q.sort()  # earliest layers first: the exec chain needs them first
    mk, _ = simulate(prep_little, prep_big, exec_big, big_prep, qs)
    return big_prep, qs, mk


# ---------------------------------------------------------------------------
# outer search over kernel combinations (Algorithm 1 line 2 & 21-22)
# ---------------------------------------------------------------------------
@dataclass
class LayerCandidates:
    layer: str
    options: List[Tuple[Choice, float, float, float]]
    # (choice, prep_little_s, prep_big_s, exec_big_s)


def _plan_for(combo: Sequence[int], layer_cands: List[LayerCandidates],
              M_l: int) -> Plan:
    pl = [lc.options[k][1] for lc, k in zip(layer_cands, combo)]
    pb = [lc.options[k][2] for lc, k in zip(layer_cands, combo)]
    ex = [lc.options[k][3] for lc, k in zip(layer_cands, combo)]
    big_prep, qs, mk = inner_schedule(pl, pb, ex, M_l)
    return Plan(
        choices=[lc.options[k][0] for lc, k in zip(layer_cands, combo)],
        big_prep=big_prep, little_queues=qs, est_makespan=mk,
    )


def candidate_groups(layer_cands: List[LayerCandidates]) -> List[List[int]]:
    """Indices of layers whose candidate option values are identical —
    shape-class equivalent layers whose profiles were shared (or measured
    equal). Grouping is by VALUE, so per-layer-measured graphs with truly
    identical numbers group the same way as fanned-out shared profiles."""
    by_key: Dict[tuple, List[int]] = {}
    for i, lc in enumerate(layer_cands):
        key = tuple((c.kernel, c.use_cache, pl, pb, ex)
                    for c, pl, pb, ex in lc.options)
        by_key.setdefault(key, []).append(i)
    return [g for g in by_key.values() if len(g) > 1]


def schedule(
    layer_cands: List[LayerCandidates],
    M_l: int,
    *,
    exhaustive_limit: int = 4096,
    memoize: bool = True,
) -> Plan:
    """Outer search. Exact enumeration when the (post-Pareto) combination
    space is small; otherwise greedy coordinate descent from the per-layer
    cold-best choice — each move re-runs the inner scheduler, mirroring the
    paper's 'keeps calibrating through re-profiling' loop.

    Incremental at LLM scale: inner-schedule results are memoized per combo
    (revisited combos across descent rounds are O(1); ``memoize=False``
    runs the identical search without the cache, for parity tests), and
    shape-class-equivalent layers move TOGETHER first — one group move per
    candidate option replaces |group| single-layer probes per round, which
    is what lets hundreds of identical decoder blocks converge in a few
    inner-schedule calls instead of thousands."""
    sizes = [len(lc.options) for lc in layer_cands]
    total = math.prod(sizes)
    if total <= exhaustive_limit:
        best = None
        for combo in itertools.product(*[range(s) for s in sizes]):
            p = _plan_for(combo, layer_cands, M_l)
            if best is None or p.est_makespan < best.est_makespan:
                best = p
        return best

    memo: Optional[Dict[tuple, Plan]] = {} if memoize else None

    def plan_for(combo: Sequence[int]) -> Plan:
        key = tuple(combo)
        if memo is not None:
            p = memo.get(key)
            if p is None:
                memo[key] = p = _plan_for(key, layer_cands, M_l)
            return p
        return _plan_for(key, layer_cands, M_l)

    # greedy start: per-layer min(prep+exec)
    combo = [
        min(range(s), key=lambda k: lc.options[k][1] + lc.options[k][3])
        for s, lc in zip(sizes, layer_cands)
    ]
    best = plan_for(combo)
    groups = candidate_groups(layer_cands)
    improved = True
    while improved:
        improved = False
        # group moves: all members of a shape-class group switch together
        for g in groups:
            for k in range(sizes[g[0]]):
                if all(combo[i] == k for i in g):
                    continue
                trial = list(combo)
                for i in g:
                    trial[i] = k
                p = plan_for(trial)
                if p.est_makespan < best.est_makespan - 1e-9:
                    best, combo, improved = p, trial, True
        # single-layer refinement (position in the chain still matters:
        # e.g. only the tail blocks may afford the cached variant)
        for li in range(len(layer_cands)):
            for k in range(sizes[li]):
                if k == combo[li]:
                    continue
                trial = list(combo)
                trial[li] = k
                p = plan_for(trial)
                if p.est_makespan < best.est_makespan - 1e-9:
                    best, combo, improved = p, trial, True
    return best


def schedule_annealed(
    layer_cands: List[LayerCandidates], M_l: int, *,
    iters: int = 2000, seed: int = 0, t0: float = 0.1,
) -> Plan:
    """Simulated-annealing baseline (beyond-paper, for validation)."""
    rng = random.Random(seed)
    sizes = [len(lc.options) for lc in layer_cands]
    combo = [rng.randrange(s) for s in sizes]
    cur = _plan_for(combo, layer_cands, M_l)
    best = cur
    for it in range(iters):
        li = rng.randrange(len(sizes))
        if sizes[li] == 1:
            continue
        k = rng.randrange(sizes[li])
        trial = list(combo)
        trial[li] = k
        p = _plan_for(trial, layer_cands, M_l)
        temp = t0 * (1 - it / iters) * max(cur.est_makespan, 1e-9)
        if (p.est_makespan < cur.est_makespan or
                rng.random() < math.exp(-(p.est_makespan - cur.est_makespan) / max(temp, 1e-12))):
            cur, combo = p, trial
        if p.est_makespan < best.est_makespan:
            best = p
    return best


def brute_force_optimal(
    layer_cands: List[LayerCandidates], M_l: int,
) -> Plan:
    """Exhaustive optimum over kernel combo × per-layer core assignment
    (big-prefix or little core j), honoring the paper's structural
    assumptions. Exponential — for tests with N ≤ 6 only."""
    N = len(layer_cands)
    assert N <= 7, "brute force is for tiny graphs"
    sizes = [len(lc.options) for lc in layer_cands]
    best = None
    for combo in itertools.product(*[range(s) for s in sizes]):
        pl = [lc.options[k][1] for lc, k in zip(layer_cands, combo)]
        pb = [lc.options[k][2] for lc, k in zip(layer_cands, combo)]
        ex = [lc.options[k][3] for lc, k in zip(layer_cands, combo)]
        for assign in itertools.product(range(M_l + 1), repeat=N):
            big_prep = [i for i in range(N) if assign[i] == 0]
            qs = [[i for i in range(N) if assign[i] == j + 1] for j in range(M_l)]
            mk, _ = simulate(pl, pb, ex, big_prep, qs)
            if best is None or mk < best.est_makespan:
                best = Plan(
                    choices=[lc.options[k][0] for lc, k in zip(layer_cands, combo)],
                    big_prep=big_prep, little_queues=qs, est_makespan=mk,
                )
    return best
