"""Operator/kernel registry — §3.1.1 "one operator, many kernels".

A *kernel* is one concrete implementation of an operator, with its own
weights-transformation stage. Mirroring ncnn's 28 conv kernels, each operator
type registers several kernels with different (transform cost, execution
cost, transformed size) trade-offs; the scheduler picks per layer.

Kernels expose:
  transform(raw)        raw weight dict -> execution-format weight dict
  execute(w, x)         jnp forward (jitted once per shape by the engine)
  supports(spec)        static applicability predicate
"""
from __future__ import annotations

import dataclasses
import enum
import hashlib
import json
import math
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class OpKind(enum.Enum):
    READ = "read"
    TRANSFORM = "transform"
    STAGE = "stage"      # host -> device weight transfer (device_put)
    EXECUTE = "execute"
    COMPILE = "compile"  # GPU-analogue stage: jit/"shader" compilation


@dataclass(frozen=True)
class LayerSpec:
    """One schedulable unit of the model (a layer, in the paper's terms)."""
    name: str
    op_type: str                  # 'conv2d' | 'linear' | 'stateless' | ...
    config: Dict[str, Any] = field(default_factory=dict)
    # weight name -> shape; empty for stateless units (e.g. attention core)
    weight_shapes: Dict[str, Tuple[int, ...]] = field(default_factory=dict)

    @property
    def weight_bytes(self) -> int:
        return sum(4 * math.prod(s) for s in self.weight_shapes.values())


@dataclass(frozen=True)
class Operation:
    """One stage of one layer's kernel — the scheduler's unit of work."""
    layer: str
    kind: OpKind
    index: int  # layer index in the chain


# ---------------------------------------------------------------------------
# shape classes — profile/compile equivalence between layers
# ---------------------------------------------------------------------------
def _canon(v: Any) -> Any:
    """Deterministic, JSON-stable canonicalization of config values."""
    if isinstance(v, bool) or v is None or isinstance(v, (int, float, str)):
        return v
    if isinstance(v, (list, tuple)):
        return [_canon(x) for x in v]
    if isinstance(v, dict):
        return [[str(k), _canon(v[k])] for k in sorted(v, key=str)]
    if dataclasses.is_dataclass(v) and not isinstance(v, type):
        return [type(v).__name__, _canon(dataclasses.asdict(v))]
    if isinstance(v, np.dtype):
        return str(v)
    return repr(v)


def shape_class_key(
    spec: LayerSpec,
    *,
    input_shape: Optional[Tuple[int, ...]] = None,
    input_dtype: Optional[str] = None,
    weight_dtypes: Optional[Dict[str, str]] = None,
) -> str:
    """Canonical shape-class identity of a layer: two layers with the same
    key are interchangeable for profiling and compilation — same op_type,
    same weight shapes/dtypes, same kernel-relevant config, and (when
    given) same input avatar. Byte-identical decoder blocks of an LLM graph
    all land in one class, so ``decide()`` profiles/compiles ONE
    representative and fans the result out.

    Stateless units wrap arbitrary Python callables whose identity the spec
    cannot see, so they never share: their key includes the layer name.
    """
    if spec.op_type == "stateless":
        payload: List[Any] = ["stateless", spec.name]
    else:
        payload = [
            spec.op_type,
            [[k, list(spec.weight_shapes[k])] for k in sorted(spec.weight_shapes)],
            _canon(spec.config),
        ]
    payload.append([
        list(input_shape) if input_shape is not None else None,
        input_dtype,
        _canon(weight_dtypes) if weight_dtypes else None,
    ])
    blob = json.dumps(payload, sort_keys=False, separators=(",", ":"))
    return hashlib.sha1(blob.encode()).hexdigest()[:20]


def shape_class_sibling_key(
    spec: LayerSpec,
    *,
    input_shape: Optional[Tuple[int, ...]] = None,
    input_dtype: Optional[str] = None,
    weight_dtypes: Optional[Dict[str, str]] = None,
) -> Optional[str]:
    """Batch-agnostic relative of :func:`shape_class_key`: the leading
    (batch) dim of the input avatar is replaced by a sentinel, so classes
    identical up to batch size share one sibling key. The ProfileDB uses it
    for *approximate* profile fan-out (``approx=True``): a layer profiled
    at batch 1 seeds the candidate costs for the same layer at batch 4 —
    per-element op costs barely shift with batch on these graphs, and a
    stale estimate only mis-ranks candidates, never breaks correctness.

    ``None`` when there is no input avatar to widen (nothing to
    approximate over) or for stateless units (never shared)."""
    if spec.op_type == "stateless" or input_shape is None or not input_shape:
        return None
    payload: List[Any] = [
        spec.op_type,
        [[k, list(spec.weight_shapes[k])] for k in sorted(spec.weight_shapes)],
        _canon(spec.config),
    ]
    payload.append([
        ["B"] + list(input_shape[1:]),
        input_dtype,
        _canon(weight_dtypes) if weight_dtypes else None,
    ])
    blob = json.dumps(payload, sort_keys=False, separators=(",", ":"))
    return "~" + hashlib.sha1(blob.encode()).hexdigest()[:20]


class Kernel:
    name: str = "base"
    op_type: str = "generic"

    def supports(self, spec: LayerSpec) -> bool:
        return True

    def transform(self, raw: Dict[str, np.ndarray], spec: LayerSpec) -> Dict[str, np.ndarray]:
        """Raw -> execution-ready weights. Runs on host (little cores)."""
        return raw

    def execute(self, w: Dict[str, jnp.ndarray], x: jnp.ndarray, spec: LayerSpec) -> jnp.ndarray:
        raise NotImplementedError

    def __repr__(self):
        return f"<Kernel {self.op_type}/{self.name}>"


# ---------------------------------------------------------------------------
# linear kernels
# ---------------------------------------------------------------------------
class LinearDirect(Kernel):
    """Plain x @ W — zero transform (the paper's '3x3s1'/'general' analogue)."""
    name = "direct"
    op_type = "linear"

    def execute(self, w, x, spec):
        y = x @ w["w"]
        if "b" in w:
            y = y + w["b"]
        return y


class LinearPacked(Kernel):
    """MXU block-tiled layout: W (K,N) -> (N/bn, K/bk, bk, bn), padded to
    multiples of 128. Fast execution on the Pallas blocked-matmul kernel
    (repro.kernels.matmul) but the packing pass is a real transformation cost
    — the sgemm_pack4 analogue."""
    name = "packed"
    op_type = "linear"
    bk = 128
    bn = 128

    def transform(self, raw, spec):
        w = raw["w"]
        K, N = w.shape
        bk, bn = self.bk, self.bn
        Kp = (K + bk - 1) // bk * bk
        Np = (N + bn - 1) // bn * bn
        wp = np.zeros((Kp, Np), w.dtype)
        wp[:K, :N] = w
        packed = np.ascontiguousarray(
            wp.reshape(Kp // bk, bk, Np // bn, bn).transpose(2, 0, 1, 3)
        )
        out = {"w_packed": packed, "orig_kn": np.array([K, N], np.int64)}
        if "b" in raw:
            out["b"] = raw["b"]
        return out

    def execute(self, w, x, spec):
        packed = w["w_packed"]  # (nN, nK, bk, bn)
        K, N = spec.config["in_features"], spec.config["out_features"]
        lead = x.shape[:-1]
        xf = x.reshape(-1, x.shape[-1])
        M = xf.shape[0]
        Kp = packed.shape[1] * packed.shape[2]
        if Kp != K:
            xf = jnp.pad(xf, ((0, 0), (0, Kp - K)))
        xb = xf.reshape(M, packed.shape[1], packed.shape[2])
        # blocked contraction consuming the packed layout directly
        y = jnp.einsum("mkc,nkcd->mnd", xb, packed)
        y = y.reshape(M, packed.shape[0] * packed.shape[3])[:, :N]
        if "b" in w:
            y = y + w["b"]
        return y.reshape(*lead, N)


class LinearLowPrecision(Kernel):
    """bf16-converted weights: halves the bytes read back from the
    transformed-weights cache (a disk-I/O/exec trade, like the paper's pack4
    variants). Matmul runs in bf16 with f32 accumulation — bitwise-identical
    outputs are NOT guaranteed, so this kernel is only eligible when the
    engine is configured with ``allow_lossy`` (off by default: the paper's
    zero-accuracy-loss principle)."""
    name = "bf16"
    op_type = "linear"

    def transform(self, raw, spec):
        out = {"w": np.asarray(jnp.asarray(raw["w"], jnp.bfloat16))}
        if "b" in raw:
            out["b"] = raw["b"]
        return out

    def execute(self, w, x, spec):
        y = jnp.dot(x.astype(jnp.bfloat16), w["w"],
                    preferred_element_type=jnp.float32)
        if "b" in w:
            y = y + w["b"]
        return y


class LinearInt8(Kernel):
    """Per-channel symmetric int8 cache entry (``repro.quant`` companion
    keys): ~4x fewer cold cache bytes than f32, ~2x fewer than bf16. The
    matmul consumes the int8 tensor directly and the per-output-channel
    scale is factored out of the K loop (``(x @ q) * scale``) — the jnp
    twin of the fused Pallas kernel ``repro.kernels.quant
    .matmul_dequant_int8``. Lossy (bounded by scale/2 per weight), so
    gated behind ``allow_lossy`` like the bf16 kernel."""
    name = "int8"
    op_type = "linear"
    bits = 8

    def transform(self, raw, spec):
        from repro import quant

        out = quant.quantize_weight("w", np.asarray(raw["w"], np.float32),
                                    bits=self.bits)
        if "b" in raw:
            out["b"] = raw["b"]
        return out

    def execute(self, w, x, spec):
        y = jnp.dot(x, w["w:q8"].astype(jnp.float32),
                    preferred_element_type=jnp.float32) * w["w:qscale"]
        if "b" in w:
            y = y + w["b"]
        return y


class LinearInt4(LinearInt8):
    """Nibble-packed int4 cache entry: ~8x fewer cold cache bytes than f32.
    Unpacks in-graph (the jnp twin of ``matmul_dequant_int4``) then runs
    the same scale-factored matmul. Coarser than int8 — last rung of the
    read-bytes ladder."""
    name = "int4"
    bits = 4

    def execute(self, w, x, spec):
        p = w["w:q4"].astype(jnp.int32)
        lo = p & 0x0F
        hi = (p >> 4) & 0x0F
        lo = jnp.where(lo >= 8, lo - 16, lo)
        hi = jnp.where(hi >= 8, hi - 16, hi)
        K = spec.weight_shapes["w"][0]
        q = jnp.stack([lo, hi], axis=1).reshape(
            2 * p.shape[0], p.shape[1])[:K].astype(jnp.float32)
        y = jnp.dot(x, q, preferred_element_type=jnp.float32) * w["w:qscale"]
        if "b" in w:
            y = y + w["b"]
        return y


# ---------------------------------------------------------------------------
# conv2d kernels (NHWC, filters OIHW in raw checkpoints — ncnn-style)
# ---------------------------------------------------------------------------
def _conv_dims(spec):
    c = spec.config
    return c["kernel"], c.get("stride", 1), c.get("padding", "SAME")


class ConvDirect(Kernel):
    """lax.conv_general_dilated on raw OIHW filters — zero transform."""
    name = "direct"
    op_type = "conv2d"

    def execute(self, w, x, spec):
        k, s, p = _conv_dims(spec)
        y = jax.lax.conv_general_dilated(
            x, w["w"], window_strides=(s, s), padding=p,
            dimension_numbers=("NHWC", "OIHW", "NHWC"),
        )
        if "b" in w:
            y = y + w["b"]
        return y


class ConvIm2col(Kernel):
    """im2col + sgemm: filters reshaped (O,I,kh,kw) -> (I*kh*kw, O). Cheap
    transform, fast-ish exec (the paper's sgemm kernels)."""
    name = "im2col_sgemm"
    op_type = "conv2d"

    def transform(self, raw, spec):
        w = raw["w"]  # (O, I, kh, kw)
        O, I, kh, kw = w.shape
        wt = np.ascontiguousarray(w.transpose(2, 3, 1, 0).reshape(kh * kw * I, O))
        out = {"w_mat": wt}
        if "b" in raw:
            out["b"] = raw["b"]
        return out

    def execute(self, w, x, spec):
        k, s, p = _conv_dims(spec)
        N, C = x.shape[0], x.shape[-1]
        patches = jax.lax.conv_general_dilated_patches(
            x, (k, k), (s, s), p, dimension_numbers=("NHWC", "OIHW", "NHWC"))
        Ho, Wo = patches.shape[1], patches.shape[2]
        # conv_general_dilated_patches returns features ordered (C, kh, kw)
        pm = patches.reshape(N * Ho * Wo, C, k, k).transpose(0, 2, 3, 1)
        pm = pm.reshape(N * Ho * Wo, k * k * C)
        y = pm @ w["w_mat"]
        y = y.reshape(N, Ho, Wo, -1)
        if "b" in w:
            y = y + w["b"]
        return y


class ConvWinograd(Kernel):
    """Winograd F(2x2, 3x3): filter transform (O,I,3,3) -> (16, I, O) done
    offline/on little cores (the paper's flagship heavy transform, Fig. 3);
    execution is 16 batched (I,O) matmuls over 4x4 input tiles — maps onto
    the MXU (Pallas kernel: repro.kernels.conv_winograd)."""
    name = "winograd_f2x3"
    op_type = "conv2d"

    G = np.array(
        [[1.0, 0.0, 0.0],
         [0.5, 0.5, 0.5],
         [0.5, -0.5, 0.5],
         [0.0, 0.0, 1.0]], np.float32)
    Bt = np.array(
        [[1, 0, -1, 0],
         [0, 1, 1, 0],
         [0, -1, 1, 0],
         [0, 1, 0, -1]], np.float32)
    At = np.array(
        [[1, 1, 1, 0],
         [0, 1, -1, -1]], np.float32)

    def supports(self, spec):
        k, s, _ = _conv_dims(spec)
        return k == 3 and s == 1

    def transform(self, raw, spec):
        w = raw["w"]  # (O, I, 3, 3)
        O, I, _, _ = w.shape
        # U = G g G^T per (O, I): g (O,I,3,3) -> (O,I,4,4)
        U = np.einsum("ab,oibc,dc->oiad", self.G, w, self.G, optimize=True)
        Ut = np.ascontiguousarray(U.transpose(2, 3, 1, 0).reshape(16, I, O))
        out = {"w_wino": Ut}
        if "b" in raw:
            out["b"] = raw["b"]
        return out

    def execute(self, w, x, spec):
        U = w["w_wino"]  # (16, I, O)
        N, H, W_, C = x.shape
        pad_h = (-H) % 2 + 1
        pad_w = (-W_) % 2 + 1
        xp = jnp.pad(x, ((0, 0), (1, pad_h), (1, pad_w), (0, 0)))
        Hp, Wp = xp.shape[1], xp.shape[2]
        nth, ntw = (Hp - 2) // 2, (Wp - 2) // 2
        # extract overlapping 4x4 tiles with stride 2
        idx_h = (jnp.arange(nth) * 2)[:, None] + jnp.arange(4)[None, :]
        idx_w = (jnp.arange(ntw) * 2)[:, None] + jnp.arange(4)[None, :]
        tiles = xp[:, idx_h][:, :, :, idx_w]        # (N, nth, 4, ntw, 4, C)
        tiles = tiles.transpose(0, 1, 3, 2, 4, 5)   # (N, nth, ntw, 4, 4, C)
        Bt = jnp.asarray(self.Bt)
        At = jnp.asarray(self.At)
        V = jnp.einsum("ab,nhwbcq,dc->nhwadq", Bt, tiles, Bt)  # (N,h,w,4,4,C)
        V = V.reshape(N * nth * ntw, 16, C).transpose(1, 0, 2)  # (16, T, C)
        M = jnp.einsum("ktc,kco->kto", V, U)                    # (16, T, O)
        O_ = M.shape[-1]
        M = M.transpose(1, 0, 2).reshape(N, nth, ntw, 4, 4, O_)
        Y = jnp.einsum("ab,nhwbcq,dc->nhwadq", At, M, At)       # (N,h,w,2,2,O)
        Y = Y.transpose(0, 1, 3, 2, 4, 5).reshape(N, nth * 2, ntw * 2, O_)
        Y = Y[:, :H, :W_, :]
        if "b" in w:
            Y = Y + w["b"]
        return Y


# ---------------------------------------------------------------------------
# stateless units (attention core, pooling, activations…): execute only
# ---------------------------------------------------------------------------
class StatelessKernel(Kernel):
    name = "fn"
    op_type = "stateless"

    def __init__(self, fn: Callable, name: str = "fn"):
        self.fn = fn
        self.name = name

    def execute(self, w, x, spec):
        return self.fn(x)


KERNEL_REGISTRY: Dict[str, List[Kernel]] = {
    "linear": [LinearDirect(), LinearPacked()],
    "conv2d": [ConvDirect(), ConvIm2col(), ConvWinograd()],
}

LOSSY_KERNELS: Dict[str, List[Kernel]] = {
    "linear": [LinearLowPrecision(), LinearInt8(), LinearInt4()],
}


def registry_for(op_type: str, *, allow_lossy: bool = False) -> List[Kernel]:
    ks = list(KERNEL_REGISTRY.get(op_type, []))
    if allow_lossy:
        ks += LOSSY_KERNELS.get(op_type, [])
    return ks
