"""Training step: grad accumulation over microbatches + AdamW.

``num_microbatches`` splits the global batch along dim 0 and scans, keeping
live activation memory at 1/num_microbatches of the full batch — this is what
lets 27B–76B configs fit the 16GB/chip budget in the dry-run. Gradients
accumulate in f32.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import transformer as T
from repro.optim import adamw_update, cosine_lr


def make_train_step(
    cfg: ArchConfig,
    *,
    lr: float = 3e-4,
    warmup: int = 100,
    total_steps: int = 10_000,
    num_microbatches: int = 1,
    remat: bool = True,
    remat_group: int = 1,
):
    schedule = cosine_lr(lr, warmup, total_steps)

    def grads_of(params, mb):
        (loss, metrics), grads = jax.value_and_grad(
            T.loss_fn, has_aux=True
        )(params, mb, cfg, remat=remat, remat_group=remat_group)
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return grads, metrics

    def train_step(params, opt_state, batch: Dict[str, jax.Array]):
        """``batch`` leaves are (num_microbatches, B/num_microbatches, ...)
        when num_microbatches > 1 — the data pipeline delivers them in that
        layout so the per-microbatch batch dim stays sharded over the data
        axes (an in-jit reshape of the sharded batch dim would force SPMD to
        replicate)."""
        if num_microbatches == 1:
            grads, metrics = grads_of(params, batch)
        else:
            n = num_microbatches
            mbs = batch

            def body(acc, mb):
                g, metrics = grads_of(params, mb)
                return jax.tree.map(jnp.add, acc, g), metrics

            zero = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            grads, ms = jax.lax.scan(body, zero, mbs)
            grads = jax.tree.map(lambda g: g / n, grads)
            metrics = jax.tree.map(jnp.mean, ms)
        params, opt_state, opt_metrics = adamw_update(
            grads, opt_state, params, lr=schedule
        )
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return params, opt_state, metrics

    return train_step
