"""Execution-strategy flags (not architecture config): toggled by the
dry-run/benchmarks to compare baseline vs optimized lowerings (§Perf)."""

FLAGS = {
    # shard_map flash-decoding for sequence-sharded KV caches: partial
    # softmax per seq shard + tiny (B,H,hd) psum combine, instead of letting
    # XLA all-gather the full cache per layer. Default ON (it is the correct
    # TPU-native design); the §Perf baseline measurements set it to False.
    "decode_flash": True,
    # sequence-parallel attention (shard_map): when an arch's head count
    # doesn't divide the model axis (smollm 15H, granite 24H/8KV, musicgen
    # 24H), baseline TP replicates the whole attention computation on every
    # model shard. With seqpar the query sequence dim is sharded over
    # `model` (K/V stay full — they are GQA-small), cutting per-device
    # attention compute and score memory by the model-axis size.
    # OFF by default: it is a §Perf hillclimb change, measured against the
    # replicated baseline in EXPERIMENTS.md.
    "seqpar_attn": False,
    # larger online-softmax chunk for long prefill (reduces the number of
    # (m,l,acc) carry read/write sweeps); §Perf knob.
    "attn_chunk": 1024,
    # int8-quantized KV cache (per-entry-per-head absmax scales): halves
    # cache HBM residency and reads vs bf16 for the decode pairs. Lossy
    # (standard serving practice) — OFF by default; a §Perf iteration.
    # Uniform-attention families only.
    "kv_cache_int8": False,
}
