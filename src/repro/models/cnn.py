"""CNN model zoo for the paper-faithful experiments (Table 4 models).

The paper evaluates on CNNs (ResNet/MobileNet/...); we provide scaled CNN
chains expressed as ColdEngine layer graphs (conv2d / linear / stateless
units) plus random ImageNet-style weights. These drive the Table 2
kernel-comparison, Fig. 13 ablation, and Fig. 8-analogue end-to-end benches
on this host.
"""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.engine import LayerDef
from repro.core.registry import LayerSpec


def _conv(name, cin, cout, k, stride, rng) -> LayerDef:
    w = (rng.standard_normal((cout, cin, k, k)) / np.sqrt(cin * k * k)).astype(np.float32)
    b = np.zeros((cout,), np.float32)
    return LayerDef(
        spec=LayerSpec(
            name=name, op_type="conv2d",
            config={"kernel": k, "stride": stride, "padding": "SAME",
                    "in_channels": cin, "out_channels": cout},
            weight_shapes={"w": w.shape, "b": b.shape},
        ),
        weights={"w": w, "b": b},
    )


def _relu(name) -> LayerDef:
    return LayerDef(
        spec=LayerSpec(name=name, op_type="stateless"),
        fn=jax.nn.relu,
    )


def _pool(name, k=2) -> LayerDef:
    def fn(x):
        return jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")
    return LayerDef(spec=LayerSpec(name=name, op_type="stateless"), fn=fn)


def _gap_linear(name, cin, classes, rng) -> List[LayerDef]:
    gap = LayerDef(
        spec=LayerSpec(name=f"{name}_gap", op_type="stateless"),
        fn=lambda x: jnp.mean(x, axis=(1, 2)),
    )
    w = (rng.standard_normal((cin, classes)) / np.sqrt(cin)).astype(np.float32)
    b = np.zeros((classes,), np.float32)
    fc = LayerDef(
        spec=LayerSpec(
            name=f"{name}_fc", op_type="linear",
            config={"in_features": cin, "out_features": classes},
            weight_shapes={"w": w.shape, "b": b.shape},
        ),
        weights={"w": w, "b": b},
    )
    return [gap, fc]


def build_cnn(name: str, *, image: int = 64, classes: int = 100,
              width: float = 1.0, seed: int = 0) -> Tuple[List[LayerDef], np.ndarray]:
    """Returns (layers, example_input NHWC)."""
    rng = np.random.default_rng(seed)
    W = lambda c: max(8, int(c * width))
    layers: List[LayerDef] = []

    if name in ("resnet18", "resnet50"):
        depths = {"resnet18": [2, 2, 2], "resnet50": [3, 4, 5]}[name]
        chans = [W(64), W(128), W(256)]
        layers.append(_conv("stem", 3, chans[0], 3, 1, rng))
        layers.append(_relu("stem_relu"))
        cin = chans[0]
        for si, (d, c) in enumerate(zip(depths, chans)):
            for bi in range(d):
                stride = 2 if (bi == 0 and si > 0) else 1
                layers.append(_conv(f"s{si}b{bi}_conv1", cin, c, 3, stride, rng))
                layers.append(_relu(f"s{si}b{bi}_relu1"))
                layers.append(_conv(f"s{si}b{bi}_conv2", c, c, 3, 1, rng))
                layers.append(_relu(f"s{si}b{bi}_relu2"))
                cin = c
        layers += _gap_linear("head", cin, classes, rng)
    elif name == "mobilenet":
        cfg = [(W(32), 1), (W(64), 1), (W(128), 2), (W(128), 1), (W(256), 2)]
        cin = 3
        for i, (c, s) in enumerate(cfg):
            layers.append(_conv(f"conv{i}", cin, c, 3, s, rng))
            layers.append(_relu(f"relu{i}"))
            cin = c
        layers += _gap_linear("head", cin, classes, rng)
    elif name == "squeezenet":
        layers.append(_conv("stem", 3, W(64), 3, 2, rng))
        layers.append(_relu("stem_relu"))
        cin = W(64)
        for i, c in enumerate([W(64), W(128), W(128)]):
            layers.append(_conv(f"squeeze{i}", cin, max(8, c // 4), 1, 1, rng))
            layers.append(_relu(f"srelu{i}"))
            layers.append(_conv(f"expand{i}", max(8, c // 4), c, 3, 1, rng))
            layers.append(_relu(f"erelu{i}"))
            cin = c
        layers += _gap_linear("head", cin, classes, rng)
    elif name == "alexnet":
        specs = [(W(64), 5, 2), (W(192), 3, 2), (W(384), 3, 1),
                 (W(256), 3, 1), (W(256), 3, 1)]
        cin = 3
        for i, (c, k, s) in enumerate(specs):
            layers.append(_conv(f"conv{i}", cin, c, min(k, 3) if k > 3 else k, s, rng))
            layers.append(_relu(f"relu{i}"))
            cin = c
        layers += _gap_linear("head", cin, classes, rng)
    else:
        raise KeyError(name)

    x = rng.standard_normal((1, image, image, 3)).astype(np.float32)
    return layers, x


CNN_NAMES = ["resnet18", "resnet50", "mobilenet", "squeezenet", "alexnet"]
