"""Core layer library: norms, RoPE, attention (full / sliding / chunked), MLP.

All functions are pure; parameters are plain dict pytrees. Computation is done
in the config dtype (bf16 by default) with f32 softmax/norm reductions.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38  # large-negative float that survives bf16/f32 casts


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# rotary embeddings
# ---------------------------------------------------------------------------
def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Apply rotary embedding.

    x: (..., S, H, D) ; positions: broadcastable to (..., S)
    """
    d = x.shape[-1]
    half = d // 2
    freq = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    angle = positions.astype(jnp.float32)[..., None] * freq  # (..., S, half)
    cos = jnp.cos(angle)[..., None, :]  # (..., S, 1, half)
    sin = jnp.sin(angle)[..., None, :]
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _softcap(x: jax.Array, cap: Optional[float]) -> jax.Array:
    if cap is None:
        return x
    return jnp.tanh(x / cap) * cap


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------
def _repeat_kv(k: jax.Array, n_rep: int) -> jax.Array:
    if n_rep == 1:
        return k
    return jnp.repeat(k, n_rep, axis=2)


def attention_scores_mask(
    q_pos: jax.Array, k_pos: jax.Array, window: Optional[int]
) -> jax.Array:
    """Boolean mask (..., Sq, Sk): causal, optionally sliding-window."""
    m = k_pos[..., None, :] <= q_pos[..., :, None]
    if window is not None:
        m &= k_pos[..., None, :] > (q_pos[..., :, None] - window)
    return m


def full_attention(
    q: jax.Array,  # (B, S, H, D)
    k: jax.Array,  # (B, S, KV, D)
    v: jax.Array,
    q_pos: jax.Array,  # (B, S)
    k_pos: jax.Array,  # (B, S)
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
) -> jax.Array:
    """Materialized masked attention — used for short sequences (training)."""
    B, S, H, D = q.shape
    kv = k.shape[2]
    k = _repeat_kv(k, H // kv)
    v = _repeat_kv(v, H // kv)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    scores = scores / math.sqrt(D)
    scores = _softcap(scores, softcap)
    mask = attention_scores_mask(q_pos, k_pos, window)[:, None]  # (B,1,Sq,Sk)
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def chunked_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    q_pos: jax.Array,
    k_pos: jax.Array,
    *,
    window: Optional[int] = None,
    softcap: Optional[float] = None,
    chunk: int = 1024,
) -> jax.Array:
    """Online-softmax attention scanned over key chunks.

    Keeps live memory O(S * chunk) instead of O(S^2) — this is the pure-jnp
    flash-attention analogue used for 32k prefill. Numerically identical to
    ``full_attention`` (same f32 softmax).
    """
    B, S, H, D = q.shape
    kv_heads = k.shape[2]
    Sk = k.shape[1]
    assert Sk % chunk == 0, (Sk, chunk)
    n_chunks = Sk // chunk
    k = k.reshape(B, n_chunks, chunk, kv_heads, D).transpose(1, 0, 2, 3, 4)
    v = v.reshape(B, n_chunks, chunk, kv_heads, D).transpose(1, 0, 2, 3, 4)
    kp = k_pos.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    scale = 1.0 / math.sqrt(D)

    def body(carry, xs):
        # named_scope marks this traffic as VMEM-resident under the Pallas
        # flash kernel (repro.kernels.attention) — the roofline's modeled-
        # kernel iteration classifies HLO ops by this scope (§Perf B).
        with jax.named_scope("flashable_attn"):
            m, l, acc = carry  # (B,H,S), (B,H,S), (B,S,H,D)
            kc, vc, kpc = xs
            kc = _repeat_kv(kc, H // kv_heads)
            vc = _repeat_kv(vc, H // kv_heads)
            s = jnp.einsum("bqhd,bkhd->bhqk", q, kc,
                           preferred_element_type=jnp.float32) * scale
            s = _softcap(s, softcap)
            mask = attention_scores_mask(q_pos, kpc, window)[:, None]
            s = jnp.where(mask, s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr.transpose(0, 2, 1)[..., None] + jnp.einsum(
                "bhqk,bkhd->bqhd", p.astype(q.dtype), vc
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, S), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, H, S), jnp.float32)
    a0 = jnp.zeros((B, S, H, D), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (k, v, kp))
    out = acc / jnp.maximum(l, 1e-37).transpose(0, 2, 1)[..., None]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# parameter init
# ---------------------------------------------------------------------------
def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0]
    scale = scale if scale is not None else 1.0 / math.sqrt(fan_in)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def attn_init(key, cfg) -> dict:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, H * hd), dt),
        "wk": _dense_init(ks[1], (d, KV * hd), dt),
        "wv": _dense_init(ks[2], (d, KV * hd), dt),
        "wo": _dense_init(ks[3], (H * hd, d), dt),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), dt)
        p["k_norm"] = jnp.zeros((hd,), dt)
    return p


def mlp_init(key, cfg, d_ff=None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d, ff), dt),
        "w_up": _dense_init(ks[1], (d, ff), dt),
        "w_down": _dense_init(ks[2], (ff, d), dt),
    }


def mlp_apply(p: dict, x: jax.Array) -> jax.Array:
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_down"]


# ---------------------------------------------------------------------------
# attention apply (sequence mode: train / prefill)
# ---------------------------------------------------------------------------
def attn_qkv(p: dict, x: jax.Array, cfg, positions: jax.Array):
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(B, S, H, hd)
    k = (x @ p["wk"]).reshape(B, S, KV, hd)
    v = (x @ p["wv"]).reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply_seq(
    p: dict,
    x: jax.Array,
    cfg,
    positions: jax.Array,
    *,
    window: Optional[int] = None,
    chunked: bool = False,
    chunk: int = 1024,
):
    """Self-attention over a full sequence. Returns (out, (k, v)) so callers
    can keep the KV for cache initialisation (prefill)."""
    B, S, _ = x.shape
    q, k, v = attn_qkv(p, x, cfg, positions)

    out = _maybe_seqpar_attention(q, k, v, positions, cfg, window, chunked, chunk)
    if out is None:
        fn = chunked_attention if chunked else full_attention
        kwargs = dict(window=window, softcap=cfg.attn_softcap)
        if chunked:
            kwargs["chunk"] = chunk
        out = fn(q, k, v, positions, positions, **kwargs)
    out = out.reshape(B, S, cfg.num_heads * cfg.head_dim) @ p["wo"]
    return out, (k, v)


def _maybe_seqpar_attention(q, k, v, positions, cfg, window, chunked, chunk):
    """Sequence-parallel attention (runtime flag `seqpar_attn`): shard the
    query sequence over `model` when the head count can't be — K/V stay full
    per shard (GQA keeps them small). Returns None when not applicable."""
    from repro.models.runtime_flags import FLAGS

    if not FLAGS.get("seqpar_attn", False):
        return None
    mesh = _mesh_ctx()
    if mesh is None:
        return None
    names = dict(mesh.shape)
    msize = names.get("model", 1)
    B, S, H, hd = q.shape
    if msize <= 1 or H % msize == 0 or S % msize != 0:
        return None  # heads shard fine (or seq can't) — use baseline TP
    if chunked and (S // msize) % chunk != 0:
        chunk = max(128, (S // msize) // 4)
    db = tuple(a for a in ("pod", "data") if a in names)
    import math as _math

    dsize = _math.prod(names[a] for a in db) if db else 1
    bax = db if db and B % dsize == 0 and dsize > 1 else None

    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    def body(q_loc, k_full, v_full, qpos_loc, kpos_full):
        fn = chunked_attention if chunked else full_attention
        kwargs = dict(window=window, softcap=cfg.attn_softcap)
        if chunked:
            kwargs["chunk"] = chunk
        return fn(q_loc, k_full, v_full, qpos_loc, kpos_full, **kwargs)

    return shard_map(
        body, mesh=mesh,
        in_specs=(
            P(bax, "model", None, None),
            P(bax, None, None, None),
            P(bax, None, None, None),
            P(bax, "model"),
            P(bax, None),
        ),
        out_specs=P(bax, "model", None, None),
        check_vma=False,
    )(q, k, v, positions, positions)


# ---------------------------------------------------------------------------
# attention decode step with ring-buffer KV cache
# ---------------------------------------------------------------------------
def _mesh_ctx():
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


def _decode_shard_axes(B: int, W: int, mesh):
    """(batch_axes, seq_axes) mirroring sharding.decode_state_specs."""
    import math as _math

    names = dict(mesh.shape)
    db = tuple(a for a in ("pod", "data") if a in names)
    dsize = _math.prod(names[a] for a in db) if db else 1
    msize = names.get("model", 1)
    if db and B % dsize == 0 and dsize > 1:
        if msize > 1 and W % msize == 0:
            return db, ("model",)
        return db, None
    seqs = tuple(a for a in (*db, "model") if names.get(a, 1) > 1)
    if seqs and W % _math.prod(names[a] for a in seqs) == 0:
        return None, seqs
    return None, None


def _flash_decode_sharded(
    q: jax.Array,        # (B, H, hd)
    cache_k: jax.Array,  # (B, W, KV, hd)
    cache_v: jax.Array,
    pos: jax.Array,
    W: int,
    *,
    window: Optional[int],
    softcap: Optional[float],
    mesh,
    batch_axes,
    seq_axes,
    k_scale=None,
    v_scale=None,
):
    """Flash-decoding over a sequence-sharded cache: each seq shard computes
    a partial (m, l, acc), combined with pmax/psum over the seq axes — the
    wire cost per layer is O(B·H·hd), not O(B·W·KV·hd). Supports the int8
    cache (per-entry scales dequantized in-shard)."""
    from jax import shard_map
    from jax.sharding import PartitionSpec as P

    B, H, hd = q.shape
    KV = cache_k.shape[2]
    names = dict(mesh.shape)
    scale = 1.0 / math.sqrt(hd)
    quant = k_scale is not None

    def body(q, kc, vc, pos, ks, vs):
        Wl = kc.shape[1]
        idx = jnp.int32(0)
        for ax in seq_axes or ():
            idx = idx * names[ax] + jax.lax.axis_index(ax)
        slots = idx * Wl + jnp.arange(Wl, dtype=jnp.int32)
        entry_pos = pos - jnp.mod(pos - slots, W)
        valid = entry_pos >= 0
        if window is not None:
            valid &= entry_pos > pos - window
        if quant:
            kc = kc.astype(q.dtype) * ks[..., None].astype(q.dtype)
            vc = vc.astype(q.dtype) * vs[..., None].astype(q.dtype)
        kk = _repeat_kv(kc, H // KV)
        vv = _repeat_kv(vc, H // KV)
        # preferred_element_type keeps the dot's operands bf16 (mixed-
        # precision HLO dot) — an explicit .astype(f32) on the operands would
        # make XLA carry the whole cache in f32 across the layer loop
        s = jnp.einsum("bhd,bkhd->bhk", q, kk,
                       preferred_element_type=jnp.float32) * scale
        s = _softcap(s, softcap)
        s = jnp.where(valid[None, None, :], s, NEG_INF)
        m = s.max(axis=-1)                                    # (B, H)
        p = jnp.exp(s - m[..., None]) * valid[None, None, :]
        l = p.sum(axis=-1)
        acc = jnp.einsum("bhk,bkhd->bhd", p.astype(vv.dtype), vv).astype(jnp.float32)
        if seq_axes:
            mg = jax.lax.pmax(m, seq_axes)
            corr = jnp.exp(m - mg)
            l = jax.lax.psum(l * corr, seq_axes)
            acc = jax.lax.psum(acc * corr[..., None], seq_axes)
        out = acc / jnp.maximum(l, 1e-37)[..., None]
        return out.astype(q.dtype)

    bax = batch_axes if batch_axes else None
    if not quant:
        # dummy scalar placeholders keep one body signature
        k_scale = jnp.zeros((), jnp.float32)
        v_scale = jnp.zeros((), jnp.float32)
        scale_spec = P()
    else:
        scale_spec = P(bax, seq_axes, None)
    return shard_map(
        body, mesh=mesh,
        in_specs=(
            P(bax, None, None),
            P(bax, seq_axes, None, None),
            P(bax, seq_axes, None, None),
            P(),
            scale_spec,
            scale_spec,
        ),
        out_specs=P(bax, None, None),
        check_vma=False,
    )(q, cache_k, cache_v, pos, k_scale, v_scale)



def _quantize_kv(k: jax.Array):
    """(B, 1, KV, hd) -> (int8 values, f32 scale (B,1,KV))."""
    amax = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(k.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def attn_decode_step(
    p: dict,
    x: jax.Array,        # (B, 1, d)
    cache_k: jax.Array,  # (B, W, KV, hd)  bf16, or int8 when quantized
    cache_v: jax.Array,
    pos: jax.Array,      # scalar int32 — position of the new token
    cfg,
    *,
    window: Optional[int] = None,
    k_scale: Optional[jax.Array] = None,  # (B, W, KV) f32 when int8 cache
    v_scale: Optional[jax.Array] = None,
):
    """One decode step. The cache is a ring buffer of length W; for full
    attention W == max_len and no entry is ever overwritten. Returns
    (out, (cache_k, cache_v[, k_scale, v_scale]))."""
    B, _, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    W = cache_k.shape[1]
    quant = k_scale is not None
    q = (x @ p["wq"]).reshape(B, 1, H, hd)
    k = (x @ p["wk"]).reshape(B, 1, KV, hd)
    v = (x @ p["wv"]).reshape(B, 1, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    posb = jnp.full((B, 1), pos, jnp.int32)
    q = rope(q, posb, cfg.rope_theta)
    k = rope(k, posb, cfg.rope_theta)
    slot = jnp.mod(pos, W)
    if quant:
        kq, ks = _quantize_kv(k)
        vq, vs = _quantize_kv(v)
        cache_k = jax.lax.dynamic_update_slice(cache_k, kq, (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, vq, (0, slot, 0, 0))
        k_scale = jax.lax.dynamic_update_slice(k_scale, ks, (0, slot, 0))
        v_scale = jax.lax.dynamic_update_slice(v_scale, vs, (0, slot, 0))
    else:
        cache_k = jax.lax.dynamic_update_slice(cache_k, k, (0, slot, 0, 0))
        cache_v = jax.lax.dynamic_update_slice(cache_v, v, (0, slot, 0, 0))

    # --- sharded read path: flash-decoding over a seq-sharded cache --------
    from repro.models.runtime_flags import FLAGS

    mesh = _mesh_ctx() if FLAGS.get("decode_flash", True) else None
    if mesh is not None:
        bax, sax = _decode_shard_axes(B, W, mesh)
        if sax is not None:
            out = _flash_decode_sharded(
                q[:, 0], cache_k, cache_v, pos, W,
                window=window, softcap=cfg.attn_softcap,
                mesh=mesh, batch_axes=bax, seq_axes=sax,
                k_scale=k_scale, v_scale=v_scale,
            )
            out = out.reshape(B, 1, H * hd) @ p["wo"]
            caches = ((cache_k, cache_v, k_scale, v_scale) if quant
                      else (cache_k, cache_v))
            return out, caches

    # --- unsharded / XLA-auto read path -------------------------------------
    # reconstruct absolute position of each slot
    slots = jnp.arange(W, dtype=jnp.int32)
    entry_pos = pos - jnp.mod(pos - slots, W)   # in (pos-W, pos]
    valid = entry_pos >= 0
    if window is not None:
        valid &= entry_pos > pos - window
    if quant:
        kk = _repeat_kv(cache_k.astype(x.dtype)
                        * k_scale[..., None].astype(x.dtype), H // KV)
        vv = _repeat_kv(cache_v.astype(x.dtype)
                        * v_scale[..., None].astype(x.dtype), H // KV)
    else:
        kk = _repeat_kv(cache_k, H // KV)
        vv = _repeat_kv(cache_v, H // KV)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, kk,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(hd)
    scores = _softcap(scores, cfg.attn_softcap)
    scores = jnp.where(valid[None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, vv)
    out = out.reshape(B, 1, H * hd) @ p["wo"]
    caches = ((cache_k, cache_v, k_scale, v_scale) if quant
              else (cache_k, cache_v))
    return out, caches
