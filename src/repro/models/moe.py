"""Mixture-of-Experts layer: top-k router + sort-based grouped matmul.

Dropless dispatch: tokens are sorted by assigned expert and pushed through
``jax.lax.ragged_dot`` (the lax grouped-matmul primitive — the natural TPU
mapping of MegaBlocks-style grouped GEMM). Compute is proportional to the
*active* expert parameters only; no capacity-factor token dropping, no giant
one-hot dispatch tensors.

Baseline sharding (see DESIGN.md §5): expert weights are sharded over the
``model`` mesh axis along the per-expert ffn dimension (expert tensor
parallelism) which lowers for any expert count; expert-parallel all_to_all is
explored as a hillclimb variant in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init


# ---------------------------------------------------------------------------
# grouped matmul with a memory-sane VJP
# ---------------------------------------------------------------------------
# The default ragged_dot transpose rule materializes a dense (groups, m, k)
# tensor for the weight gradient (7.5 GiB/device for granite train_4k). Both
# cotangents are themselves grouped matmuls, so express them that way:
#   dx[i]  = dy[i] @ w[g(i)]^T          -> ragged_dot with transposed rhs
#   dw[g]  = x_g^T @ dy_g               -> ragged_dot_general, ragged dim
#                                          contracting (MegaBlocks dsd/sdd).
@jax.custom_vjp
def grouped_matmul(x: jax.Array, w: jax.Array, group_sizes: jax.Array):
    return jax.lax.ragged_dot(x, w, group_sizes)


def _gm_fwd(x, w, group_sizes):
    return jax.lax.ragged_dot(x, w, group_sizes), (x, w, group_sizes)


def _gm_bwd(res, dy):
    x, w, gs = res
    dx = jax.lax.ragged_dot(dy, jnp.swapaxes(w, 1, 2), gs)
    dn = jax.lax.RaggedDotDimensionNumbers(
        dot_dimension_numbers=(((0,), (0,)), ((), ())),
        lhs_ragged_dimensions=[0],
        rhs_group_dimensions=[],
    )
    dw = jax.lax.ragged_dot_general(
        x, dy.astype(x.dtype), gs, dn
    ).astype(w.dtype)
    return dx.astype(x.dtype), dw, None


grouped_matmul.defvjp(_gm_fwd, _gm_bwd)


def moe_init(key, cfg) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    scale = 1.0 / math.sqrt(d)
    return {
        "router": _dense_init(ks[0], (d, E), jnp.float32, scale),
        "w_gate": (jax.random.normal(ks[1], (E, d, ff), jnp.float32) * scale).astype(dt),
        "w_up": (jax.random.normal(ks[2], (E, d, ff), jnp.float32) * scale).astype(dt),
        "w_down": (jax.random.normal(ks[3], (E, ff, d), jnp.float32) / math.sqrt(ff)).astype(dt),
    }


def _local_moe(xf: jax.Array, router, w_gate, w_up, w_down, cfg):
    """Token-local MoE over a flat token block (T, d). Used directly on CPU
    and as the shard_map body on a mesh — the sort over tokens then stays
    *per data shard* (a global argsort over a sharded dim would force SPMD
    to all-gather every token)."""
    T, d = xf.shape
    E, k = cfg.num_experts, cfg.top_k

    logits = (xf.astype(jnp.float32) @ router)  # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)  # (T, k)
    top_p = top_p / jnp.sum(top_p, axis=-1, keepdims=True)

    # load-balance aux loss
    frac = jnp.mean(
        jax.nn.one_hot(top_e[:, 0], E, dtype=jnp.float32), axis=0
    )
    aux = jnp.sum(frac * jnp.mean(probs, axis=0)) * E

    # ---- sort + capacity-sliced grouped GEMM ------------------------------
    # (jax.lax.ragged_dot lowers to a dense masked einsum on both CPU and
    # TPU-XLA — an (E, T·k, d) monster. The sorted/sliced scan below lowers
    # to E blockwise (C,d)x(d,ff) matmuls, which is what the Pallas gmm
    # kernel implements natively on TPU.)
    flat_e = top_e.reshape(T * k)
    perm = jnp.argsort(flat_e)                      # stable sort by expert id
    token_of = perm // k                            # original token index
    xs = xf[token_of]                               # (T*k, d), expert-sorted
    group_sizes = jnp.bincount(flat_e, length=E).astype(jnp.int32)

    cap = getattr(cfg, "moe_capacity_factor", 2.0)
    C = int(math.ceil(T * k / E * cap / 8.0)) * 8
    C = max(8, min(C, T * k))
    ys = _grouped_ffn(xs, group_sizes, w_gate, w_up, w_down, C)

    inv = jnp.argsort(perm)
    y = ys[inv].reshape(T, k, d)
    y = jnp.sum(y * top_p[..., None].astype(y.dtype), axis=1)
    return y, aux


from functools import partial


@partial(jax.custom_vjp, nondiff_argnums=(5,))
def _grouped_ffn(xs, group_sizes, w_gate, w_up, w_down, C: int):
    """Expert-blocked SwiGLU over expert-sorted tokens.

    xs (M, d) sorted by expert; each expert e owns rows
    [offset_e, offset_e + size_e). A scan over experts dynamic-slices a
    static-capacity block of C rows, runs the expert FFN, masks rows beyond
    size_e, and accumulates back. Tokens beyond capacity are dropped
    (standard capacity-factor semantics; cfg.moe_capacity_factor sizes C).

    Custom VJP: the autodiff transpose of the block dynamic-slice would add a
    full (M,d) cotangent buffer per expert iteration (O(E·M·d) traffic); the
    hand-written backward recomputes each block (flash-style) and accumulates
    the cotangent through the same C-row window.
    """
    y, _ = _grouped_ffn_fwd(xs, group_sizes, w_gate, w_up, w_down, C)
    return y


def _gffn_blocks(xs_pad, offsets, group_sizes, w_gate, w_up, w_down, C):
    M_pad, d = xs_pad.shape
    d_out = w_down.shape[-1]
    E = group_sizes.shape[0]

    def body(_, inp):
        off, size, wg, wu, wd = inp
        blk = jax.lax.dynamic_slice(xs_pad, (off, 0), (C, d))
        h = jax.nn.silu(blk @ wg) * (blk @ wu)
        yb = h @ wd
        mask = (jnp.arange(C) < size)[:, None]
        return None, jnp.where(mask, yb, 0)

    _, ys = jax.lax.scan(body, None, (offsets, group_sizes, w_gate, w_up, w_down))
    rows = (offsets[:, None] + jnp.arange(C)[None, :]).reshape(-1)
    y = jnp.zeros((M_pad, d_out), xs_pad.dtype).at[rows].add(
        ys.reshape(E * C, d_out))
    return y


def _grouped_ffn_fwd(xs, group_sizes, w_gate, w_up, w_down, C):
    M, d = xs.shape
    offsets = jnp.cumsum(group_sizes) - group_sizes
    xs_pad = jnp.pad(xs, ((0, C), (0, 0)))
    y = _gffn_blocks(xs_pad, offsets, group_sizes, w_gate, w_up, w_down, C)[:M]
    return y, (xs, group_sizes, w_gate, w_up, w_down)


def _grouped_ffn_bwd(C, res, dy):
    xs, group_sizes, w_gate, w_up, w_down = res
    M, d = xs.shape
    offsets = jnp.cumsum(group_sizes) - group_sizes
    xs_pad = jnp.pad(xs, ((0, C), (0, 0)))
    dy_pad = jnp.pad(dy, ((0, C), (0, 0)))

    def body(dxs, inp):
        off, size, wg, wu, wd = inp
        mask = (jnp.arange(C) < size)[:, None]
        blk = jax.lax.dynamic_slice(xs_pad, (off, 0), (C, d))
        dyb = jax.lax.dynamic_slice(dy_pad, (off, 0), (C, dy.shape[1]))
        dyb = jnp.where(mask, dyb, 0)
        g = blk @ wg
        u = blk @ wu
        sg = jax.nn.sigmoid(g.astype(jnp.float32))
        silu_g = (g.astype(jnp.float32) * sg).astype(g.dtype)
        h = silu_g * u
        dh = dyb @ wd.T
        dwd = h.T @ dyb
        du = dh * silu_g
        dsilu = (sg * (1 + g.astype(jnp.float32) * (1 - sg))).astype(g.dtype)
        dg = dh * u * dsilu
        dwg = blk.T @ dg
        dwu = blk.T @ du
        dblk = dg @ wg.T + du @ wu.T
        cur = jax.lax.dynamic_slice(dxs, (off, 0), (C, d))
        dxs = jax.lax.dynamic_update_slice(dxs, cur + dblk, (off, 0))
        return dxs, (dwg, dwu, dwd)

    dxs0 = jnp.zeros_like(xs_pad)
    dxs, (dwg, dwu, dwd) = jax.lax.scan(
        body, dxs0, (offsets, group_sizes, w_gate, w_up, w_down))
    return dxs[:M], None, dwg, dwu, dwd


_grouped_ffn.defvjp(_grouped_ffn_fwd, _grouped_ffn_bwd)


def _mesh_ctx():
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None


# per-shard token block size: longer streams are processed in sequential
# blocks so the sorted/sliced buffers stay bounded (32k-prefill MoE would
# otherwise hold (T·k, d) + (E, C, d) live at once)
MOE_TOKEN_BLOCK = 16_384


def _blocked_local_moe(xf, router, wg, wu, wd, cfg):
    T = xf.shape[0]
    if T <= MOE_TOKEN_BLOCK:
        return _local_moe(xf, router, wg, wu, wd, cfg)
    nb = (T + MOE_TOKEN_BLOCK - 1) // MOE_TOKEN_BLOCK
    while T % nb != 0:
        nb += 1
    blk = T // nb
    xb = xf.reshape(nb, blk, xf.shape[1])

    def body(_, xs):
        y, aux = _local_moe(xs, router, wg, wu, wd, cfg)
        return None, (y, aux)

    _, (ys, auxs) = jax.lax.scan(body, None, xb)
    return ys.reshape(T, -1), jnp.mean(auxs)


def moe_apply(p: dict, x: jax.Array, cfg) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (y, aux_loss).

    aux_loss is the standard switch-transformer load-balance loss
    (mean_e frac_tokens_e * mean_router_prob_e * E).

    On a mesh, tokens are routed *per data shard* under shard_map (expert
    weights ff-sharded over `model` — expert tensor parallelism) with a psum
    over `model` for the down-projection partial sums.
    """
    B, S, d = x.shape
    xf = x.reshape(B * S, d)
    mesh = _mesh_ctx()
    if mesh is None:
        y, aux = _blocked_local_moe(xf, p["router"], p["w_gate"], p["w_up"],
                                    p["w_down"], cfg)
        return y.reshape(B, S, d), aux

    from jax.sharding import PartitionSpec as P
    from jax import shard_map

    names = dict(mesh.shape)
    db = tuple(a for a in ("pod", "data") if a in names)
    ff_ok = cfg.d_ff % names.get("model", 1) == 0
    mdl = "model" if ff_ok and "model" in names else None
    dsize = math.prod(names[a] for a in db) if db else 1
    tok_axes = db if db and (B * S) % dsize == 0 and dsize > 1 else None

    def body(xl, router, wg, wu, wd):
        y, aux = _blocked_local_moe(xl, router, wg, wu, wd, cfg)
        if mdl is not None:
            y = jax.lax.psum(y, mdl)
        if tok_axes:
            aux = jax.lax.pmean(aux, tok_axes)
        return y, aux

    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(
            P(tok_axes, None),
            P(None, None),
            P(None, None, mdl), P(None, None, mdl), P(None, mdl, None),
        ),
        out_specs=(P(tok_axes, None), P()),
        check_vma=False,
    )(xf, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y.reshape(B, S, d), aux
