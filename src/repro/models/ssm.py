"""Mamba2 (SSD — state-space duality, arXiv:2405.21060) mixer.

Sequence mode uses the chunked SSD algorithm (block-diagonal intra-chunk
attention-like term + low-rank inter-chunk state recurrence) scanned over
chunks with ``lax.scan`` so live memory is O(S/chunk * chunk^2) per head —
this is also the structure the Pallas kernel in ``repro.kernels.ssd``
implements on-TPU. Decode mode is the O(1) recurrent step.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.layers import _dense_init, rms_norm


# ---------------------------------------------------------------------------
# causal depthwise conv1d
# ---------------------------------------------------------------------------
def causal_conv(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """x: (B, S, C); w: (K, C). Returns (y, new_state=(B, K-1, C))."""
    B, S, C = x.shape
    K = w.shape[0]
    if state is None:
        state = jnp.zeros((B, K - 1, C), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+K-1, C)
    y = sum(xp[:, k : k + S] * w[k] for k in range(K))
    return y, xp[:, S:]


# ---------------------------------------------------------------------------
# SSD core
# ---------------------------------------------------------------------------
def ssd_chunked(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H)  post-softplus
    A: jax.Array,    # (H,)  negative
    Bm: jax.Array,   # (B, S, G, N)
    Cm: jax.Array,   # (B, S, G, N)
    D: jax.Array,    # (H,)
    *,
    chunk: int,
    init_state: jax.Array | None = None,  # (B, H, P, N)
):
    """Chunked SSD. Returns (y (B,S,H,P), final_state (B,H,P,N))."""
    B, S, H, P = x.shape
    G, N = Bm.shape[2], Bm.shape[3]
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk
    f32 = jnp.float32

    xc = x.reshape(B, nc, chunk, H, P)
    dtc = dt.reshape(B, nc, chunk, H).astype(f32)
    Bc = Bm.reshape(B, nc, chunk, G, N)
    Cc = Cm.reshape(B, nc, chunk, G, N)
    # broadcast groups over heads
    hpg = H // G
    a = dtc * A.astype(f32)                      # (B, nc, Q, H) log-decay
    cum = jnp.cumsum(a, axis=2)                  # within-chunk cumulative

    xs = jnp.moveaxis(xc, 1, 0)    # (nc, B, Q, H, P)
    dts = jnp.moveaxis(dtc, 1, 0)
    Bs = jnp.moveaxis(Bc, 1, 0)
    Cs = jnp.moveaxis(Cc, 1, 0)
    cums = jnp.moveaxis(cum, 1, 0)

    if init_state is None:
        init_state = jnp.zeros((B, H, P, N), f32)

    def body(state, inp):
        x_, dt_, B_, C_, cum_ = inp  # per-chunk slices
        Q = x_.shape[1]
        # heads -> groups index
        Bh = jnp.repeat(B_, hpg, axis=2) if G > 1 else B_[:, :, 0]
        Ch = jnp.repeat(C_, hpg, axis=2) if G > 1 else C_[:, :, 0]
        if G > 1:  # (B,Q,H,N)
            pass
        else:      # (B,Q,N) shared across heads
            Bh = Bh[:, :, None, :].astype(f32)
            Ch = Ch[:, :, None, :].astype(f32)
            Bh = jnp.broadcast_to(Bh, (B, Q, H, N))
            Ch = jnp.broadcast_to(Ch, (B, Q, H, N))
        xdt = x_.astype(f32) * dt_[..., None]    # (B,Q,H,P)

        # --- intra-chunk (quadratic within chunk) --------------------------
        # L[i,j] = exp(cum_i - cum_j) for i >= j
        diff = cum_[:, :, None, :] - cum_[:, None, :, :]      # (B,Qi,Qj,H)
        tri = jnp.tril(jnp.ones((Q, Q), bool))
        Lmat = jnp.where(tri[None, :, :, None], jnp.exp(diff), 0.0)
        CB = jnp.einsum("bihn,bjhn->bijh", Ch, Bh)            # (B,Qi,Qj,H)
        y_diag = jnp.einsum("bijh,bijh,bjhp->bihp", CB, Lmat, xdt)

        # --- inter-chunk state ---------------------------------------------
        last = cum_[:, -1:, :]                                # (B,1,H)
        decay_out = jnp.exp(last - cum_)                      # (B,Q,H)
        new_contrib = jnp.einsum("bjhn,bjh,bjhp->bhpn", Bh, decay_out, xdt)
        chunk_decay = jnp.exp(last[:, 0])                     # (B,H)
        y_off = jnp.einsum("bihn,bhpn,bih->bihp", Ch, state, jnp.exp(cum_))
        state = state * chunk_decay[..., None, None] + new_contrib
        y = y_diag + y_off
        return state, y

    state, ys = jax.lax.scan(body, init_state, (xs, dts, Bs, Cs, cums))
    y = jnp.moveaxis(ys, 0, 1).reshape(B, S, H, P)
    y = y + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), state


def ssd_decode_step(
    x: jax.Array,    # (B, H, P)
    dt: jax.Array,   # (B, H)
    A: jax.Array,    # (H,)
    Bm: jax.Array,   # (B, G, N)
    Cm: jax.Array,   # (B, G, N)
    D: jax.Array,    # (H,)
    state: jax.Array,  # (B, H, P, N) f32
):
    f32 = jnp.float32
    B_, H, P = x.shape
    G, N = Bm.shape[1], Bm.shape[2]
    Bh = jnp.broadcast_to(Bm[:, 0][:, None].astype(f32), (B_, H, N)) if G == 1 \
        else jnp.repeat(Bm.astype(f32), H // G, axis=1)
    Ch = jnp.broadcast_to(Cm[:, 0][:, None].astype(f32), (B_, H, N)) if G == 1 \
        else jnp.repeat(Cm.astype(f32), H // G, axis=1)
    dtf = dt.astype(f32)
    decay = jnp.exp(dtf * A.astype(f32))                     # (B,H)
    upd = jnp.einsum("bhp,bhn->bhpn", x.astype(f32) * dtf[..., None], Bh)
    state = state * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bhn->bhp", state, Ch) + x.astype(f32) * D.astype(f32)[None, :, None]
    return y.astype(x.dtype), state


# ---------------------------------------------------------------------------
# full Mamba2 block
# ---------------------------------------------------------------------------
def mamba_init(key, cfg) -> dict:
    d = cfg.d_model
    di = cfg.ssm_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    K = cfg.ssm_conv_width
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 10)
    return {
        "in_x": _dense_init(ks[0], (d, di), dt),
        "in_z": _dense_init(ks[1], (d, di), dt),
        "in_B": _dense_init(ks[2], (d, N), dt),
        "in_C": _dense_init(ks[3], (d, N), dt),
        "in_dt": _dense_init(ks[4], (d, H), dt),
        "conv_x": (jax.random.normal(ks[5], (K, di), jnp.float32) / math.sqrt(K)).astype(dt),
        "conv_B": (jax.random.normal(ks[6], (K, N), jnp.float32) / math.sqrt(K)).astype(dt),
        "conv_C": (jax.random.normal(ks[7], (K, N), jnp.float32) / math.sqrt(K)).astype(dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H, dtype=jnp.float32)),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.full((H,), -2.0, jnp.float32),
        "gate_norm": jnp.zeros((di,), dt),
        "out": _dense_init(ks[8], (di, d), dt),
    }


def mamba_apply_seq(p: dict, x: jax.Array, cfg, conv_states=None, ssm_state=None):
    """Sequence mode. Returns (y, (conv_states, ssm_state))."""
    B, S, d = x.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = x @ p["in_z"]
    xi = x @ p["in_x"]
    Bm = x @ p["in_B"]
    Cm = x @ p["in_C"]
    dtr = x @ p["in_dt"]
    cs = conv_states or (None, None, None)
    xi, sx = causal_conv(xi, p["conv_x"], cs[0])
    Bm, sB = causal_conv(Bm, p["conv_B"], cs[1])
    Cm, sC = causal_conv(Cm, p["conv_C"], cs[2])
    xi, Bm, Cm = jax.nn.silu(xi), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus(dtr.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, state = ssd_chunked(
        xi.reshape(B, S, H, P), dt, A,
        Bm[:, :, None, :], Cm[:, :, None, :], p["D"],
        chunk=cfg.ssm_chunk, init_state=ssm_state,
    )
    y = y.reshape(B, S, cfg.ssm_inner)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out"], ((sx, sB, sC), state)


def mamba_decode_step(p: dict, x: jax.Array, cfg, conv_states, ssm_state):
    """x: (B, 1, d). Returns (y (B,1,d), (conv_states, ssm_state))."""
    B = x.shape[0]
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    z = x @ p["in_z"]
    xi = x @ p["in_x"]
    Bm = x @ p["in_B"]
    Cm = x @ p["in_C"]
    dtr = x @ p["in_dt"]
    xi, sx = causal_conv(xi, p["conv_x"], conv_states[0])
    Bm, sB = causal_conv(Bm, p["conv_B"], conv_states[1])
    Cm, sC = causal_conv(Cm, p["conv_C"], conv_states[2])
    xi, Bm, Cm = jax.nn.silu(xi), jax.nn.silu(Bm), jax.nn.silu(Cm)
    dt = jax.nn.softplus(dtr[:, 0].astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    y, state = ssd_decode_step(
        xi[:, 0].reshape(B, H, P), dt, A,
        Bm[:, 0][:, None, :], Cm[:, 0][:, None, :], p["D"], ssm_state,
    )
    y = y.reshape(B, 1, cfg.ssm_inner)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    return y @ p["out"], ((sx, sB, sC), state)


def mamba_state_init(cfg, batch: int, dtype) -> dict:
    K = cfg.ssm_conv_width
    return {
        "conv_x": jnp.zeros((batch, K - 1, cfg.ssm_inner), dtype),
        "conv_B": jnp.zeros((batch, K - 1, cfg.ssm_state), dtype),
        "conv_C": jnp.zeros((batch, K - 1, cfg.ssm_state), dtype),
        "ssm": jnp.zeros((batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state), jnp.float32),
    }
