"""Config-driven decoder covering all assigned families.

One ``init_params`` / ``forward`` / ``prefill`` / ``decode_step`` implements
dense, moe, ssm (Mamba2), hybrid (Zamba2), vlm and audio architectures, driven
entirely by ``ArchConfig``. Layers are stacked and scanned with
``jax.lax.scan`` so the lowered HLO is O(1) in depth — essential for the
40-pair × 2-mesh multi-pod dry-run.

Layer layout per family:
  dense/moe/vlm/audio : blocks stacked (L, ...); gemma2 scans (L/2, 2, ...)
                        pairs of (local-window, global) layers.
  ssm                 : mamba blocks stacked (L, ...).
  hybrid (zamba2)     : mamba blocks scanned in groups of
                        ``shared_attn_every``; one *shared* attention+mlp
                        block (single copy of weights) applied between groups.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import ssm as SSM

Params = Dict[str, Any]

CHUNKED_ATTN_THRESHOLD = 8192  # prefill longer than this uses online-softmax
ATTN_CHUNK = 1024


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------
def _attn_block_init(key, cfg: ArchConfig) -> Params:
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    k1, k2 = jax.random.split(key)
    p = {
        "ln1": jnp.zeros((d,), dt),
        "ln2": jnp.zeros((d,), dt),
        "attn": L.attn_init(k1, cfg),
    }
    if cfg.is_moe:
        p["moe"] = MOE.moe_init(k2, cfg)
    else:
        p["mlp"] = L.mlp_init(k2, cfg)
    return p


def _mamba_block_init(key, cfg: ArchConfig) -> Params:
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.dtype(cfg.dtype)),
        "mamba": SSM.mamba_init(key, cfg),
    }


def init_params(key, cfg: ArchConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    d, V = cfg.d_model, cfg.vocab_size
    kemb, khead, kblocks, kshared = jax.random.split(key, 4)
    params: Params = {}
    if cfg.input_mode in ("tokens", "vlm"):
        params["embed"] = (jax.random.normal(kemb, (V, d), jnp.float32) * 0.02).astype(dt)
    if not cfg.tie_embeddings or cfg.input_mode == "embeddings":
        params["lm_head"] = (
            jax.random.normal(khead, (d, V), jnp.float32) / math.sqrt(d)
        ).astype(dt)

    lkeys = jax.random.split(kblocks, cfg.num_layers)
    if cfg.family in ("ssm", "hybrid"):
        params["blocks"] = jax.vmap(lambda k: _mamba_block_init(k, cfg))(lkeys)
    else:
        params["blocks"] = jax.vmap(lambda k: _attn_block_init(k, cfg))(lkeys)
    if cfg.family == "hybrid":
        params["shared"] = _attn_block_init(kshared, cfg)
    params["final_norm"] = jnp.zeros((d,), dt)
    return params


# ---------------------------------------------------------------------------
# block bodies
# ---------------------------------------------------------------------------
def _attn_block_seq(bp, x, cfg, positions, window, chunked, collect_kv):
    from repro.models.runtime_flags import FLAGS

    h, kv = L.attn_apply_seq(
        bp["attn"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), cfg, positions,
        window=window, chunked=chunked,
        chunk=int(FLAGS.get("attn_chunk", ATTN_CHUNK)),
    )
    x = x + h
    xn = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    if "moe" in bp:
        h2, aux = MOE.moe_apply(bp["moe"], xn, cfg)
    else:
        h2, aux = L.mlp_apply(bp["mlp"], xn), jnp.zeros((), jnp.float32)
    return x + h2, aux, (kv if collect_kv else None)


def _mamba_block_seq(bp, x, cfg, conv_states=None, ssm_state=None):
    h, states = SSM.mamba_apply_seq(
        bp["mamba"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), cfg,
        conv_states=conv_states, ssm_state=ssm_state,
    )
    return x + h, states


def _embed_input(params, cfg, batch) -> tuple[jax.Array, jax.Array]:
    """Returns (x (B,S,d), loss_mask (B,S))."""
    if cfg.input_mode == "tokens":
        x = params["embed"][batch["tokens"]]
        mask = jnp.ones(batch["tokens"].shape, jnp.float32)
    elif cfg.input_mode == "embeddings":
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
        mask = jnp.ones(x.shape[:2], jnp.float32)
    elif cfg.input_mode == "vlm":
        tok = params["embed"][batch["tokens"]]
        pre = batch["prefix_embeds"].astype(jnp.dtype(cfg.dtype))
        x = jnp.concatenate([pre, tok], axis=1)
        mask = jnp.concatenate(
            [jnp.zeros(pre.shape[:2], jnp.float32),
             jnp.ones(tok.shape[:2], jnp.float32)], axis=1,
        )
    else:
        raise ValueError(cfg.input_mode)
    from repro.models.sharding import constrain_batch
    return constrain_batch(x), mask


def _lm_logits(params, cfg, x) -> jax.Array:
    h = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if cfg.tie_embeddings and cfg.input_mode != "embeddings":
        logits = h @ params["embed"].T
    else:
        logits = h @ params["lm_head"]
    logits = logits.astype(jnp.float32)
    if cfg.final_softcap:
        logits = jnp.tanh(logits / cfg.final_softcap) * cfg.final_softcap
    return logits


# ---------------------------------------------------------------------------
# sequence forward (train / prefill)
# ---------------------------------------------------------------------------
def forward(
    params: Params,
    batch: Dict[str, jax.Array],
    cfg: ArchConfig,
    *,
    remat: bool = False,
    remat_group: int = 1,
    collect_cache: bool = False,
):
    """Full-sequence forward. Returns (logits, aux_loss, cache_or_None).

    cache (when collect_cache): family-specific pytree usable to seed
    ``decode_step`` at position S.
    """
    x, loss_mask = _embed_input(params, cfg, batch)
    B, S, d = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    chunked = S > CHUNKED_ATTN_THRESHOLD
    aux_total = jnp.zeros((), jnp.float32)
    cache = None
    blocks = params["blocks"]

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        window = cfg.sliding_window

        if cfg.local_global_pattern:
            def body(carry, bp2):
                x = carry
                bpl = jax.tree.map(lambda a: a[0], bp2)
                bpg = jax.tree.map(lambda a: a[1], bp2)
                x, a1, kv1 = _attn_block_seq(bpl, x, cfg, positions, window, chunked, collect_cache)
                x, a2, kv2 = _attn_block_seq(bpg, x, cfg, positions, None, chunked, collect_cache)
                return x, (a1 + a2, (kv1, kv2))
            blocks2 = jax.tree.map(
                lambda a: a.reshape(cfg.num_layers // 2, 2, *a.shape[1:]), blocks
            )
            if remat:
                body = jax.checkpoint(body)
            x, (auxs, kvs) = jax.lax.scan(body, x, blocks2)
            aux_total = auxs.sum()
            if collect_cache:
                cache = {"local": kvs[0], "global": kvs[1]}
        else:
            def body(x, bp):
                x, a, kv = _attn_block_seq(bp, x, cfg, positions, window, chunked, collect_cache)
                return x, (a, kv)
            g = remat_group if (remat and cfg.num_layers % max(remat_group, 1) == 0) else 1
            if g > 1:
                # hierarchical remat: checkpoint GROUPS of g layers so the
                # saved residual stack is L/g deep (trades one extra forward
                # of the inner layers for g× less activation memory)
                def gbody(x, gbp):
                    def inner(x, bp):
                        x, a, kv = _attn_block_seq(bp, x, cfg, positions, window, chunked, collect_cache)
                        return x, (a, kv)
                    return jax.lax.scan(inner, x, gbp)
                gbody = jax.checkpoint(gbody)
                gblocks = jax.tree.map(
                    lambda a: a.reshape(cfg.num_layers // g, g, *a.shape[1:]),
                    blocks)
                x, (auxs, kvs) = jax.lax.scan(gbody, x, gblocks)
                if collect_cache and kvs is not None:
                    kvs = jax.tree.map(
                        lambda a: a.reshape(cfg.num_layers, *a.shape[2:]), kvs)
            else:
                if remat:
                    body = jax.checkpoint(body)
                x, (auxs, kvs) = jax.lax.scan(body, x, blocks)
            aux_total = auxs.sum()
            if collect_cache:
                cache = {"kv": kvs}

    elif cfg.family == "ssm":
        def body(x, bp):
            x, states = _mamba_block_seq(bp, x, cfg)
            return x, (states if collect_cache else None)
        if remat:
            body = jax.checkpoint(body)
        x, states = jax.lax.scan(body, x, blocks)
        if collect_cache:
            cache = {"mamba": states}

    elif cfg.family == "hybrid":
        every = cfg.shared_attn_every
        G = cfg.num_layers // every
        shared = params["shared"]

        def group(carry, gbp):
            x = carry
            def inner(x, bp):
                x, states = _mamba_block_seq(bp, x, cfg)
                return x, (states if collect_cache else None)
            x, mstates = jax.lax.scan(inner, x, gbp)
            x, a, kv = _attn_block_seq(shared, x, cfg, positions, cfg.sliding_window, chunked, collect_cache)
            return x, (a, mstates, kv)
        gblocks = jax.tree.map(lambda a: a.reshape(G, every, *a.shape[1:]), blocks)
        if remat:
            group = jax.checkpoint(group)
        x, (auxs, mstates, kvs) = jax.lax.scan(group, x, gblocks)
        aux_total = auxs.sum()
        if collect_cache:
            cache = {"mamba": mstates, "shared_kv": kvs}
    else:
        raise ValueError(cfg.family)

    logits = _lm_logits(params, cfg, x)
    return logits, aux_total, (cache, loss_mask) if collect_cache else (None, loss_mask)


def loss_fn(params, batch, cfg: ArchConfig, *, remat: bool = False,
            remat_group: int = 1):
    """Next-token cross-entropy. Returns (loss, metrics)."""
    logits, aux, (_, mask) = forward(params, batch, cfg, remat=remat,
                                     remat_group=remat_group)
    if cfg.input_mode == "vlm":
        labels = batch["tokens"]
        P = cfg.num_prefix_embeds
        logits_text = logits[:, P:, :]
        lg = logits_text[:, :-1]
        lb = labels[:, 1:]
        m = mask[:, P + 1:]
    elif cfg.input_mode == "embeddings":
        lg = logits[:, :-1]
        lb = batch["labels"][:, 1:]
        m = mask[:, 1:]
    else:
        lg = logits[:, :-1]
        lb = batch["tokens"][:, 1:]
        m = mask[:, 1:]
    logz = jax.nn.logsumexp(lg, axis=-1)
    # label log-prob via a masked reduction over the vocab dim: unlike
    # take_along_axis (a gather), this stays partitionable when the vocab
    # dim is sharded over the model axis — a gather would force SPMD to
    # replicate the full logits tensor on every device.
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, lg.shape, len(lg.shape) - 1)
    ll = jnp.sum(jnp.where(vocab_iota == lb[..., None], lg, 0.0), axis=-1)
    nll = (logz - ll) * m
    loss = nll.sum() / jnp.maximum(m.sum(), 1.0)
    total = loss + 0.01 * aux
    return total, {"loss": loss, "aux_loss": aux}


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------
def init_decode_state(cfg: ArchConfig, batch: int, context_len: int) -> Params:
    """Zero-initialised decode caches sized for ``context_len`` history."""
    dt = jnp.dtype(cfg.dtype)
    KV, hd, Lr = cfg.num_kv_heads, cfg.head_dim, cfg.num_layers

    def kv(n, W):
        return jnp.zeros((n, batch, W, KV, hd), dt)

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        if cfg.local_global_pattern:
            Wl = min(cfg.sliding_window, context_len)
            return {
                "k_local": kv(Lr // 2, Wl), "v_local": kv(Lr // 2, Wl),
                "k_global": kv(Lr // 2, context_len), "v_global": kv(Lr // 2, context_len),
            }
        W = min(cfg.sliding_window, context_len) if cfg.sliding_window else context_len
        from repro.models.runtime_flags import FLAGS
        if FLAGS.get("kv_cache_int8", False):
            return {
                "k": jnp.zeros((Lr, batch, W, KV, hd), jnp.int8),
                "v": jnp.zeros((Lr, batch, W, KV, hd), jnp.int8),
                "k_scale": jnp.zeros((Lr, batch, W, KV), jnp.float32),
                "v_scale": jnp.zeros((Lr, batch, W, KV), jnp.float32),
            }
        return {"k": kv(Lr, W), "v": kv(Lr, W)}
    if cfg.family == "ssm":
        s = SSM.mamba_state_init(cfg, batch, dt)
        return {k: jnp.zeros((Lr, *v.shape), v.dtype) for k, v in s.items()}
    if cfg.family == "hybrid":
        G = cfg.num_layers // cfg.shared_attn_every
        s = SSM.mamba_state_init(cfg, batch, dt)
        mamba = {k: jnp.zeros((G, cfg.shared_attn_every, *v.shape), v.dtype) for k, v in s.items()}
        W = min(cfg.sliding_window, context_len) if cfg.sliding_window else context_len
        mamba["shared_k"] = kv(G, W)
        mamba["shared_v"] = kv(G, W)
        return mamba
    raise ValueError(cfg.family)


def _attn_block_decode(bp, x, ck, cv, pos, cfg, window):
    h, (ck, cv) = L.attn_decode_step(
        bp["attn"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), ck, cv, pos, cfg,
        window=window,
    )
    x = x + h
    xn = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
    if "moe" in bp:
        h2, _ = MOE.moe_apply(bp["moe"], xn, cfg)
    else:
        h2 = L.mlp_apply(bp["mlp"], xn)
    return x + h2, ck, cv


def _mamba_block_decode(bp, x, st, cfg):
    h, ((sx, sB, sC), ssm) = SSM.mamba_decode_step(
        bp["mamba"], L.rms_norm(x, bp["ln1"], cfg.norm_eps), cfg,
        (st["conv_x"], st["conv_B"], st["conv_C"]), st["ssm"],
    )
    return x + h, {"conv_x": sx, "conv_B": sB, "conv_C": sC, "ssm": ssm}


def decode_step(
    params: Params,
    state: Params,
    batch: Dict[str, jax.Array],
    pos: jax.Array,  # scalar int32: position of the incoming token
    cfg: ArchConfig,
):
    """One token decode for a batch. Returns (logits (B,1,V), new_state)."""
    if cfg.input_mode == "embeddings":
        x = batch["embeds"].astype(jnp.dtype(cfg.dtype))
    else:
        x = params["embed"][batch["tokens"]]
    from repro.models.sharding import constrain_batch
    x = constrain_batch(x)
    blocks = params["blocks"]

    if cfg.family in ("dense", "moe", "vlm", "audio"):
        if cfg.local_global_pattern:
            def body(x, xs):
                bp2, kl, vl, kg, vg = xs
                bpl = jax.tree.map(lambda a: a[0], bp2)
                bpg = jax.tree.map(lambda a: a[1], bp2)
                x, kl, vl = _attn_block_decode(bpl, x, kl, vl, pos, cfg, cfg.sliding_window)
                x, kg, vg = _attn_block_decode(bpg, x, kg, vg, pos, cfg, None)
                return x, (kl, vl, kg, vg)
            blocks2 = jax.tree.map(
                lambda a: a.reshape(cfg.num_layers // 2, 2, *a.shape[1:]), blocks
            )
            x, (kl, vl, kg, vg) = jax.lax.scan(
                body, x, (blocks2, state["k_local"], state["v_local"],
                          state["k_global"], state["v_global"]))
            state = {"k_local": kl, "v_local": vl, "k_global": kg, "v_global": vg}
        else:
            window = cfg.sliding_window
            quant = "k_scale" in state

            if quant:
                def body(x, xs):
                    bp, ck, cv, ks, vs = xs
                    h, (ck, cv, ks, vs) = L.attn_decode_step(
                        bp["attn"], L.rms_norm(x, bp["ln1"], cfg.norm_eps),
                        ck, cv, pos, cfg, window=window,
                        k_scale=ks, v_scale=vs)
                    x = x + h
                    xn = L.rms_norm(x, bp["ln2"], cfg.norm_eps)
                    if "moe" in bp:
                        h2, _ = MOE.moe_apply(bp["moe"], xn, cfg)
                    else:
                        h2 = L.mlp_apply(bp["mlp"], xn)
                    return x + h2, (ck, cv, ks, vs)
                x, (ck, cv, ks, vs) = jax.lax.scan(
                    body, x, (blocks, state["k"], state["v"],
                              state["k_scale"], state["v_scale"]))
                state = {"k": ck, "v": cv, "k_scale": ks, "v_scale": vs}
            else:
                def body(x, xs):
                    bp, ck, cv = xs
                    x, ck, cv = _attn_block_decode(bp, x, ck, cv, pos, cfg, window)
                    return x, (ck, cv)
                x, (ck, cv) = jax.lax.scan(body, x, (blocks, state["k"], state["v"]))
                state = {"k": ck, "v": cv}

    elif cfg.family == "ssm":
        def body(x, xs):
            bp, st = xs
            x, st = _mamba_block_decode(bp, x, cfg=cfg, st=st)
            return x, st
        mamba_state = {k: state[k] for k in ("conv_x", "conv_B", "conv_C", "ssm")}
        x, new_state = jax.lax.scan(body, x, (blocks, mamba_state))
        state = new_state

    elif cfg.family == "hybrid":
        shared = params["shared"]
        every = cfg.shared_attn_every
        G = cfg.num_layers // every

        def group(x, xs):
            gbp, mst, sk, sv = xs
            def inner(x, ys):
                bp, st = ys
                x, st = _mamba_block_decode(bp, x, cfg=cfg, st=st)
                return x, st
            x, mst = jax.lax.scan(inner, x, (gbp, mst))
            x, sk, sv = _attn_block_decode(shared, x, sk, sv, pos, cfg, cfg.sliding_window)
            return x, (mst, sk, sv)
        gblocks = jax.tree.map(lambda a: a.reshape(G, every, *a.shape[1:]), blocks)
        mamba_state = {k: state[k] for k in ("conv_x", "conv_B", "conv_C", "ssm")}
        x, (mst, sk, sv) = jax.lax.scan(
            group, x, (gblocks, mamba_state, state["shared_k"], state["shared_v"]))
        state = dict(mst)
        state["shared_k"] = sk
        state["shared_v"] = sv
    else:
        raise ValueError(cfg.family)

    logits = _lm_logits(params, cfg, x)
    return logits, state
