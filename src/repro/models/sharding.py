"""Sharding rules: map every parameter / activation / cache to a PartitionSpec.

Logical dimension kinds are resolved per-leaf from the parameter name, then
mapped to mesh axes by a *strategy* table. The baseline strategy is
megatron-style tensor parallelism on the ``model`` axis + FSDP (ZeRO-3-like)
sharding of the other matrix dimension over the batch axes; XLA SPMD inserts
the all-gathers. Alternative strategies (used by the §Perf hillclimb) override
individual kind→axis entries, e.g. expert-parallel MoE.

Divisibility is checked per leaf: a dim that does not divide evenly over the
assigned axes falls back to replication (e.g. smollm's 15 query heads, or
granite's 49155 vocab on a 16-way model axis).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ArchConfig

Axis = Any  # None | str | tuple[str, ...]


# name -> logical kinds of the trailing dims (leading stack dims padded None)
_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    "embed": ("vocab", "dm"),
    "lm_head": ("dm", "vocab"),
    "wq": ("dm", "q_heads"),
    "wk": ("dm", "kv_heads"),
    "wv": ("dm", "kv_heads"),
    "wo": ("q_heads", "dm"),
    "q_norm": (None,),
    "k_norm": (None,),
    "ln1": (None,),
    "ln2": (None,),
    "final_norm": (None,),
    "router": ("dm", None),
    # dense mlp (2D) and moe experts (3D) share names; disambiguated by ndim
    "w_gate": ("dm", "ff"),
    "w_up": ("dm", "ff"),
    "w_down": ("ff", "dm"),
    "w_gate@moe": ("exp", "dm", "ff"),
    "w_up@moe": ("exp", "dm", "ff"),
    "w_down@moe": ("exp", "ff", "dm"),
    # mamba
    "in_x": ("dm", "inner"),
    "in_z": ("dm", "inner"),
    "in_B": ("dm", None),
    "in_C": ("dm", None),
    "in_dt": ("dm", "sheads"),
    "conv_x": (None, "inner"),
    "conv_B": (None, None),
    "conv_C": (None, None),
    "A_log": ("sheads",),
    "D": ("sheads",),
    "dt_bias": ("sheads",),
    "gate_norm": ("inner",),
    "out": ("inner", "dm"),
}


def default_strategy(
    *,
    fsdp_axes: Optional[Tuple[str, ...]] = ("data",),
    model_axis: str = "model",
) -> Dict[str, Axis]:
    return {
        "dm": fsdp_axes,
        "vocab": model_axis,
        "q_heads": model_axis,
        "kv_heads": model_axis,
        "ff": model_axis,
        "exp": None,
        "inner": model_axis,
        "sheads": model_axis,
    }


def _axis_size(mesh_shape: Dict[str, int], axis: Axis) -> int:
    if axis is None:
        return 1
    if isinstance(axis, str):
        return mesh_shape.get(axis, 1)
    return math.prod(mesh_shape.get(a, 1) for a in axis)


def _head_aligned(kind: Optional[str], cfg: ArchConfig, dim: int, shards: int) -> bool:
    """Sharding must not split a head for head-structured dims."""
    if shards <= 1:
        return True
    if dim % shards != 0:
        return False
    heads = {
        "q_heads": cfg.num_heads,
        "kv_heads": cfg.num_kv_heads,
        "inner": cfg.ssm_heads if cfg.ssm_state else 0,
        "sheads": cfg.ssm_heads if cfg.ssm_state else 0,
    }.get(kind)
    if heads:
        return heads % shards == 0
    return True


def spec_for(
    name: str,
    shape: Tuple[int, ...],
    cfg: ArchConfig,
    mesh_shape: Dict[str, int],
    strategy: Dict[str, Axis],
    *,
    in_moe: bool = False,
) -> P:
    key = f"{name}@moe" if in_moe and f"{name}@moe" in _RULES and len(shape) >= 3 else name
    kinds = _RULES.get(key)
    if kinds is None:
        return P()
    pad = len(shape) - len(kinds)
    assert pad >= 0, (name, shape, kinds)
    axes: list[Axis] = [None] * pad
    for kind, dim in zip(kinds, shape[pad:]):
        ax = strategy.get(kind) if kind else None
        if ax is not None:
            size = _axis_size(mesh_shape, ax)
            if not _head_aligned(kind, cfg, dim, size):
                ax = None
        axes.append(ax)
    return P(*axes)


def param_specs(
    params_shape: Any,
    cfg: ArchConfig,
    mesh_shape: Dict[str, int],
    strategy: Optional[Dict[str, Axis]] = None,
) -> Any:
    """PartitionSpec pytree matching ``jax.eval_shape(init_params)`` output."""
    strategy = strategy or default_strategy()

    def leaf(path, x):
        name = None
        in_moe = False
        for k in path:
            if isinstance(k, jax.tree_util.DictKey):
                if k.key == "moe":
                    in_moe = True
                name = k.key
        return spec_for(name, x.shape, cfg, mesh_shape, strategy, in_moe=in_moe)

    return jax.tree_util.tree_map_with_path(leaf, params_shape)


# ---------------------------------------------------------------------------
# activations / batch / decode state
# ---------------------------------------------------------------------------
def batch_axes(mesh_shape: Dict[str, int]) -> Tuple[str, ...]:
    return tuple(a for a in ("pod", "data") if a in mesh_shape)


def batch_specs(
    batch_shape: Any, mesh_shape: Dict[str, int], *, microbatched: bool = False
) -> Any:
    """Batch dim sharded over the data axes. With ``microbatched`` the leaves
    are (n_micro, B/n_micro, ...) and the *second* dim is the batch dim."""
    db = batch_axes(mesh_shape)
    bdim = 1 if microbatched else 0

    def leaf(x):
        B = x.shape[bdim]
        ax = db if B % _axis_size(mesh_shape, db) == 0 else None
        axes = [None] * len(x.shape)
        axes[bdim] = ax
        return P(*axes)

    return jax.tree.map(leaf, batch_shape)


def decode_state_specs(
    state_shape: Any, cfg: ArchConfig, mesh_shape: Dict[str, int],
    model_axis: str = "model",
) -> Any:
    """Decode caches: batch over data axes when divisible, else the sequence /
    window dim is sharded over (data×model) flash-decoding style."""
    db = batch_axes(mesh_shape)
    dsize = _axis_size(mesh_shape, db)
    msize = _axis_size(mesh_shape, model_axis)

    def leaf(path, x):
        name = [k.key for k in path if isinstance(k, jax.tree_util.DictKey)][-1]
        shape = x.shape
        if name in ("k_scale", "v_scale"):
            # (L, B, W, KV): shard like the int8 cache minus the head-dim
            _, B, W, KV = shape
            if B % dsize == 0 and dsize > 1:
                seq_ax = model_axis if W % msize == 0 else None
                return P(None, db, seq_ax, None)
            seq_shards = (*db, model_axis)
            if W % _axis_size(mesh_shape, seq_shards) == 0:
                return P(None, None, seq_shards, None)
            return P(None, None, None, None)
        if name in ("k", "v", "k_local", "v_local", "k_global", "v_global",
                    "shared_k", "shared_v"):
            # (L, B, W, KV, hd)
            _, B, W, KV, hd = shape
            if B % dsize == 0 and dsize > 1:
                seq_ax = model_axis if W % msize == 0 else None
                return P(None, db, seq_ax, None, None)
            seq_shards = (*db, model_axis)
            if W % _axis_size(mesh_shape, seq_shards) == 0:
                return P(None, None, seq_shards, None, None)
            return P(None, None, None, None, None)
        if name == "ssm":
            # (L|G[,every], B, H, P, N)
            B, H = shape[-4], shape[-3]
            bax = db if B % dsize == 0 and dsize > 1 else None
            hax = model_axis if H % msize == 0 else None
            return P(*([None] * (len(shape) - 4)), bax, hax, None, None)
        if name.startswith("conv_"):
            # (L[,every], B, K-1, C)
            B, C = shape[-3], shape[-1]
            bax = db if B % dsize == 0 and dsize > 1 else None
            cax = model_axis if C % msize == 0 else None
            return P(*([None] * (len(shape) - 3)), bax, None, cax)
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(leaf, state_shape)


def prefill_cache_specs(
    cache_shape: Any, cfg: ArchConfig, mesh_shape: Dict[str, int],
    model_axis: str = "model",
) -> Any:
    """Specs for the cache pytree returned by ``forward(collect_cache=True)``.

    KV leaves are (L, B, S, KV, hd); mamba conv states (L, B, K-1, C); ssm
    states (L, B, H, P, N). KV is sharded batch-over-data and seq-over-model
    (flash-decoding layout) so a 32k×32-way prefill cache fits per-chip HBM.
    """
    db = batch_axes(mesh_shape)
    dsize = _axis_size(mesh_shape, db)
    msize = _axis_size(mesh_shape, model_axis)

    def leaf(path, x):
        names = [k.key for k in path if isinstance(k, jax.tree_util.DictKey)]
        shape = x.shape
        if names and names[0] == "mamba":
            if len(shape) == 5:  # ssm state (L,B,H,P,N)
                B, H = shape[1], shape[2]
                return P(None,
                         db if B % dsize == 0 and dsize > 1 else None,
                         model_axis if H % msize == 0 else None, None, None)
            # conv state (L,B,K-1,C)
            B, C = shape[1], shape[3]
            return P(None,
                     db if B % dsize == 0 and dsize > 1 else None,
                     None, model_axis if C % msize == 0 else None)
        # kv: (L, B, S, KV, hd)
        _, B, S = shape[0], shape[1], shape[2]
        bax = db if B % dsize == 0 and dsize > 1 else None
        sax = model_axis if S % msize == 0 else None
        return P(None, bax, sax, None, None)

    return jax.tree_util.tree_map_with_path(leaf, cache_shape)


def constrain_batch(x: jax.Array) -> jax.Array:
    """with_sharding_constraint(P(batch_axes, None, ...)) on dim 0, resolving
    the mesh from the ambient context; no-op outside a mesh (CPU tests) or
    when the batch dim doesn't divide. Re-anchors batch sharding after ops
    whose SPMD propagation drops it (e.g. the embedding gather)."""
    try:
        from jax._src import mesh as mesh_lib

        m = mesh_lib.thread_resources.env.physical_mesh
        if m.empty:
            return x
        names = m.shape  # OrderedDict axis->size
    except Exception:
        return x
    db = tuple(a for a in ("pod", "data") if a in names)
    if not db:
        return x
    size = math.prod(names[a] for a in db)
    if size <= 1 or x.shape[0] % size != 0:
        return x
    spec = P(db, *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def to_named(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda s: isinstance(s, P),
    )
