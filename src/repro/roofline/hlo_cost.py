"""Loop-aware cost analysis over optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop *body* once,
ignoring trip counts — useless for scan-over-layers programs where ~all
compute lives inside loops. This module re-derives per-device costs by
walking the HLO computation graph:

  flops       : 2·|out|·K for dots (K = contracted extent), |out| for
                elementwise ops, window-aware for convolutions;
  hbm bytes   : per top-level instruction, operands + results (fusion
                internals are free — they never touch HBM);
  wire bytes  : ring-cost model per collective (see analysis.py);

each weighted by the product of enclosing while-loop trip counts. Trip
counts are parsed from the loop condition's ROOT compare constant.

Validated against ``cost_analysis()`` on loop-free programs (tests).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "f16": 2, "bf16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "token": 0,
    "s2": 1, "u2": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "negate", "abs", "rsqrt", "sqrt", "select",
    "compare", "and", "or", "not", "convert", "floor", "ceil", "sign",
    "cosine", "sine", "logistic", "clamp", "remainder", "atan2", "expm1",
    "log1p", "cbrt",
}

_COLLECTIVES = {
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
}


def _shape_elems(dims: str) -> int:
    if not dims:
        return 1
    n = 1
    for d in dims.split(","):
        n *= int(d)
    return n


def _bytes_of(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt in _DTYPE_BYTES:
            total += _shape_elems(dims) * _DTYPE_BYTES[dt]
    return total


def _elems_of(shape_text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_text):
        if dt in _DTYPE_BYTES and dt != "token":
            total += _shape_elems(dims)
    return total


@dataclass
class Instr:
    name: str
    shape: str            # raw result-shape text
    op: str
    operands: List[str]
    line: str
    is_root: bool = False


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    by_name: Dict[str, Instr] = field(default_factory=dict)


_COMP_HEAD_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*(?:\([^)]*\))?.*\{\s*$")
_INSTR_HEAD_RE = re.compile(r"^\s*(ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
_SCALAR_SHAPE_RE = re.compile(r"[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?")
_OP_NAME_RE = re.compile(r"([\w\-]+)\((.*)$")


def _split_shape_op(rest: str):
    """Split '<shape> <op>(<operands...>' handling nested tuple shapes."""
    rest = rest.lstrip()
    if rest.startswith("("):
        depth = 0
        end = -1
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        if end < 0:
            return None
        shape, tail = rest[: end + 1], rest[end + 1:].lstrip()
    else:
        m = _SCALAR_SHAPE_RE.match(rest)
        if not m:
            return None
        shape, tail = m.group(0), rest[m.end():].lstrip()
    m = _OP_NAME_RE.match(tail)
    if not m:
        return None
    return shape, m.group(1), m.group(2)
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if cur is None:
            m = _COMP_HEAD_RE.match(line.strip())
            if m and ("->" in line or line.strip().startswith(("ENTRY", "%"))) and line.endswith("{"):
                cur = Computation(m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
            continue
        if line.strip() == "}":
            comps[cur.name] = cur
            cur = None
            continue
        m = _INSTR_HEAD_RE.match(line)
        if not m:
            continue
        root, name, remainder = m.groups()
        parsed = _split_shape_op(remainder)
        if parsed is None:
            continue
        shape, op, rest = parsed
        # operand names: the %refs inside the parens before any attr section
        paren = rest.split("), ")[0]
        operands = _OPERAND_RE.findall(paren)
        ins = Instr(name=name, shape=shape, op=op, operands=operands,
                    line=line, is_root=bool(root))
        cur.instrs.append(ins)
        cur.by_name[name] = ins
    if cur is not None:
        comps[cur.name] = cur
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """Parse the loop bound from the condition computation.

    Scan-lowered conditions compare the induction variable against a scalar
    constant (possibly via a wrapped fusion), with init 0 / step 1, so the
    largest scalar integer constant in the condition is the trip count."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant" and ins.shape.startswith(("s32[]", "s64[]", "u32[]", "u64[]")):
            m = re.search(r"constant\((-?\d+)\)", ins.line)
            if m:
                best = max(best, int(m.group(1)))
    return best


def _dot_flops(instr: Instr, comp: Computation) -> float:
    out_elems = _elems_of(instr.shape)
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", instr.line)
    if not m or not instr.operands:
        return 2.0 * out_elems  # degenerate
    cdims = [int(d) for d in m.group(1).split(",") if d]
    lhs = comp.by_name.get(instr.operands[0])
    if lhs is None:
        return 2.0 * out_elems
    sm = _SHAPE_RE.search(lhs.shape)
    if not sm:
        return 2.0 * out_elems
    ldims = [int(d) for d in sm.group(2).split(",")] if sm.group(2) else []
    K = 1
    for c in cdims:
        if c < len(ldims):
            K *= ldims[c]
    return 2.0 * out_elems * K


def _conv_flops(instr: Instr, comp: Computation) -> float:
    out_elems = _elems_of(instr.shape)
    if len(instr.operands) < 2:
        return 2.0 * out_elems
    rhs = comp.by_name.get(instr.operands[1])
    if rhs is None:
        return 2.0 * out_elems
    sm = _SHAPE_RE.search(rhs.shape)
    kdims = [int(d) for d in sm.group(2).split(",")] if sm and sm.group(2) else []
    kernel_elems = math.prod(kdims) if kdims else 1
    # flops ~= 2 * out_elems * kernel_elems / out_channels
    if not kdims:
        return 2.0 * out_elems
    # kernel shape already holds ic/groups on its input-feature dim, so
    # flops = 2 * out_elems * (spatial * ic/groups) = 2*out*kernel_elems/oc
    m = re.search(r"dim_labels=([\w?]+)_([\w?]+)->", instr.line)
    oc = 1
    if m:
        rhs_labels = m.group(2)
        if "o" in rhs_labels and rhs_labels.index("o") < len(kdims):
            oc = kdims[rhs_labels.index("o")]
    return 2.0 * out_elems * kernel_elems / max(oc, 1)


@dataclass
class HloCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    wire_bytes: float = 0.0
    wire_by_kind: Dict[str, float] = field(default_factory=dict)
    transcendentals: float = 0.0
    hbm_contrib: Dict[str, float] = field(default_factory=dict)
    flop_contrib: Dict[str, float] = field(default_factory=dict)
    # HBM traffic inside jax.named_scope("flashable_attn") regions — buffers
    # the Pallas flash-attention kernel keeps in VMEM on TPU
    flashable_hbm: float = 0.0

    def top_hbm(self, n=10):
        return sorted(self.hbm_contrib.items(), key=lambda kv: -kv[1])[:n]

    def top_flops(self, n=10):
        return sorted(self.flop_contrib.items(), key=lambda kv: -kv[1])[:n]

    def add_wire(self, kind: str, b: float):
        self.wire_bytes += b
        self.wire_by_kind[kind] = self.wire_by_kind.get(kind, 0.0) + b


_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _participants(line: str) -> Optional[int]:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return None


def _collective_wire(instr: Instr, comp: Computation) -> Tuple[str, float]:
    kind = instr.op.replace("-start", "").replace("-done", "")
    result_b = _bytes_of(instr.shape)
    n = _participants(instr.line)
    frac = (n - 1) / n if n and n > 1 else 1.0
    if kind == "all-reduce":
        return kind, 2.0 * result_b * frac
    if kind == "reduce-scatter":
        # operand is n× the result
        return kind, result_b * (n - 1 if n else 1.0)
    if kind == "collective-permute":
        return kind, float(result_b)
    return kind, result_b * frac  # all-gather / all-to-all


_GTE_IDX_RE = re.compile(r"index=(\d+)")


def f32_carry_artifact_bytes(text: str) -> float:
    """Bytes of f32 while-loop carries that are convert-roundtrips of bf16
    values — an XLA:CPU artifact: CPU dots convert bf16 operands to f32 and
    the compiler hoists those converts into the loop carry, materializing an
    f32 copy of (e.g.) the whole KV cache, the stacked bf16 weights, or the
    saved residual stack. A TPU compile feeds bf16 to the MXU directly, so
    these buffers don't exist there.

    Detection: for every while, walk each f32 element of the body's ROOT
    tuple back to its defining value, following unary ops, fusion roots,
    get-tuple-element through *nested whiles* (via the inner body root) and
    through the loop parameter (via the while init tuple in the caller). An
    element is an artifact iff the chain reaches convert(bf16->f32). Genuine
    f32 state (optimizer moments, softmax stats) never converts from bf16
    and is not counted."""
    comps, _ = parse_hlo(text)
    # map body-computation name -> (while instr, calling comp)
    callers: Dict[str, Tuple[Instr, "Computation"]] = {}
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "while":
                bm = _BODY_RE.search(ins.line)
                if bm:
                    callers[bm.group(1)] = (ins, comp)

    def resolve(comp: Computation, name: str, depth: int) -> bool:
        """True if value `name` in `comp` derives from convert(bf16)."""
        if depth > 12:
            return False
        src = comp.by_name.get(name)
        if src is None:
            return False
        if src.op == "convert" and src.operands:
            prev = comp.by_name.get(src.operands[0])
            return prev is not None and "bf16[" in prev.shape
        if src.op in ("copy", "bitcast", "dynamic-update-slice",
                      "transpose", "reshape"):
            return bool(src.operands) and resolve(comp, src.operands[0], depth + 1)
        if src.op == "fusion":
            m = _CALLS_RE.search(src.line)
            called = comps.get(m.group(1)) if m else None
            if called is None:
                return False
            froot = next((i for i in called.instrs if i.is_root), None)
            return froot is not None and resolve(called, froot.name, depth + 1)
        if src.op == "get-tuple-element" and src.operands:
            mi = _GTE_IDX_RE.search(src.line)
            idx = int(mi.group(1)) if mi else 0
            base = comp.by_name.get(src.operands[0])
            if base is None:
                return False
            if base.op == "while":
                bm = _BODY_RE.search(base.line)
                inner = comps.get(bm.group(1)) if bm else None
                if inner is None:
                    return False
                iroot = next((i for i in inner.instrs if i.is_root), None)
                if iroot is None or iroot.op != "tuple" or idx >= len(iroot.operands):
                    return False
                return resolve(inner, iroot.operands[idx], depth + 1)
            if base.op == "parameter":
                # loop param: resolve the while INIT value in the caller
                info = callers.get(comp.name)
                if info is None:
                    return False
                wins, caller = info
                if not wins.operands:
                    return False
                init = caller.by_name.get(wins.operands[0])
                if init is None or init.op != "tuple" or idx >= len(init.operands):
                    return False
                return resolve(caller, init.operands[idx], depth + 1)
        return False

    total = 0.0
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op != "while":
                continue
            bm = _BODY_RE.search(ins.line)
            body = comps.get(bm.group(1)) if bm else None
            if body is None:
                continue
            root = next((i for i in body.instrs if i.is_root), None)
            if root is None or root.op != "tuple":
                continue
            counted = set()
            for opn in root.operands:
                src = body.by_name.get(opn)
                if (src is None or not src.shape.startswith("f32[")
                        or opn in counted):
                    continue
                if _bytes_of(src.shape) < 64 * 2**20:
                    continue  # only material buffers
                if resolve(body, opn, 0):
                    counted.add(opn)
                    total += _bytes_of(src.shape)
    return total


def analyze(text: str) -> HloCost:
    comps, entry = parse_hlo(text)
    if entry is None:
        # fall back: biggest computation
        entry = max(comps, key=lambda k: len(comps[k].instrs)) if comps else None
    cost = HloCost()
    if entry is None:
        return cost

    def _acc_hbm(key: str, v: float, line: str = ""):
        cost.hbm_bytes += v
        cost.hbm_contrib[key] = cost.hbm_contrib.get(key, 0.0) + v
        if "flashable_attn" in line:
            cost.flashable_hbm += v

    def _acc_flops(key: str, v: float):
        cost.flops += v
        cost.flop_contrib[key] = cost.flop_contrib.get(key, 0.0) + v

    def visit(comp_name: str, weight: float, in_fusion: bool):
        comp = comps.get(comp_name)
        if comp is None:
            return
        for ins in comp.instrs:
            op = ins.op
            if op == "while":
                bm = _BODY_RE.search(ins.line)
                cm = _COND_RE.search(ins.line)
                trip = _trip_count(comps[cm.group(1)]) if cm and cm.group(1) in comps else 1
                if bm and bm.group(1) in comps:
                    visit(bm.group(1), weight * trip, False)
                continue
            if op in ("fusion", "call", "async-start"):
                m = _CALLS_RE.search(ins.line)
                called = m.group(1) if m and m.group(1) in comps else None
                if called:
                    # flops from inside; hbm only at the fusion boundary
                    visit(called, weight, True)
                if not in_fusion:
                    _acc_hbm(f"fusion {ins.shape[:48]}",
                             weight * _fusion_hbm(ins, comp, comps.get(called)),
                             ins.line)
                continue
            if op == "conditional":
                for m in re.finditer(r"(?:true_computation|false_computation|branch_computations)=\{?%?([\w.\-]+)", ins.line):
                    if m.group(1) in comps:
                        visit(m.group(1), weight, in_fusion)
                continue
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES:
                if op.endswith("-done"):
                    continue
                kind, wire = _collective_wire(ins, comp)
                cost.add_wire(kind, weight * wire)
                if not in_fusion:
                    _acc_hbm(f"{kind} {ins.shape[:48]}",
                             weight * _instr_hbm(ins, comp), ins.line)
                continue
            if op == "dot":
                _acc_flops(f"dot {ins.shape[:48]}", weight * _dot_flops(ins, comp))
            elif op == "convolution":
                _acc_flops(f"conv {ins.shape[:48]}", weight * _conv_flops(ins, comp))
            elif op in _ELEMENTWISE:
                _acc_flops(f"ew {op}", weight * _elems_of(ins.shape))
                if op in ("exponential", "log", "tanh", "power", "rsqrt",
                          "sqrt", "logistic", "cosine", "sine"):
                    cost.transcendentals += weight * _elems_of(ins.shape)
            elif op == "reduce":
                _acc_flops("reduce", weight * _elems_of(ins.shape))
            if not in_fusion and op not in (
                "parameter", "constant", "get-tuple-element", "tuple",
                "bitcast", "after-all",
            ):
                _acc_hbm(f"{op} {ins.shape[:48]}",
                         weight * _instr_hbm(ins, comp), ins.line)

    def _instr_hbm(ins: Instr, comp: Computation) -> float:
        out_b = _bytes_of(ins.shape)
        # slicing ops only touch the sliced window, not the whole operand
        if ins.op in ("dynamic-slice", "slice", "gather"):
            return float(2 * out_b)
        if ins.op in ("dynamic-update-slice", "scatter"):
            upd = comp.by_name.get(ins.operands[1]) if len(ins.operands) > 1 else None
            upd_b = _bytes_of(upd.shape) if upd is not None else out_b
            return float(3 * upd_b)  # read+write window + update read
        b = out_b
        for opn in ins.operands:
            src = comp.by_name.get(opn)
            if src is not None:
                b += _bytes_of(src.shape)
        return float(b)

    def _fusion_hbm(ins: Instr, comp: Computation,
                    called: Optional[Computation]) -> float:
        """Fusion boundary traffic with slicing/in-place awareness:
        - a fusion whose ROOT is dynamic-update-slice/scatter writes only the
          update window (XLA aliases the destination buffer in-place);
        - operands consumed inside the fusion *only through slicing ops*
          (dynamic-slice/slice/gather) contribute their windows, not their
          full extent."""
        if called is None:
            return float(_bytes_of(ins.shape)) + sum(
                _bytes_of(comp.by_name[o].shape)
                for o in ins.operands if o in comp.by_name
            )
        root = next((i for i in called.instrs if i.is_root), None)
        # see through unary-root wrappers (XLA-CPU inserts f32<->bf16 convert
        # roundtrips around loop-carry updates that a TPU compile aliases)
        hops = 0
        while (root is not None and root.op in ("convert", "copy", "bitcast")
               and root.operands and hops < 4):
            nxt = called.by_name.get(root.operands[0])
            if nxt is None:
                break
            root = nxt
            hops += 1
        dus_dests: set = set()
        if root is not None and root.op in ("dynamic-update-slice", "scatter"):
            upd = called.by_name.get(root.operands[1]) if len(root.operands) > 1 else None
            b = 2.0 * (_bytes_of(upd.shape) if upd is not None else _bytes_of(root.shape))
            # follow unary chains (convert/copy/bitcast) from the destination
            # back to the aliased loop-carry parameter — on TPU the carry is
            # updated in place, so only the window counts.
            frontier = [root.operands[0]] if root.operands else []
            while frontier:
                nm = frontier.pop()
                if nm in dus_dests:
                    continue
                dus_dests.add(nm)
                src = called.by_name.get(nm)
                if src is not None and src.op in ("convert", "copy", "bitcast",
                                                  "broadcast", "negate"):
                    frontier.extend(src.operands[:1])
        else:
            b = float(_bytes_of(ins.shape))
        params = [i for i in called.instrs if i.op == "parameter"]
        pidx = {}
        for p in params:
            mm = re.search(r"parameter\((\d+)\)", p.line)
            if mm:
                pidx[int(mm.group(1))] = p.name
        for k, opn in enumerate(ins.operands):
            src = comp.by_name.get(opn)
            if src is None:
                continue
            full = _bytes_of(src.shape)
            pname = pidx.get(k)
            if pname is None:
                b += full
                continue
            if pname in dus_dests:
                continue  # in-place destination: write already counted
            consumers = [i for i in called.instrs if pname in i.operands]
            if consumers and all(
                (c.op in ("dynamic-slice", "slice", "gather") and
                 (not c.operands or c.operands[0] == pname)) or
                (c.op in ("dynamic-update-slice", "scatter") and
                 c.operands and c.operands[0] == pname)
                for c in consumers
            ):
                b += sum(
                    _bytes_of(c.shape) for c in consumers
                    if c.op in ("dynamic-slice", "slice", "gather")
                )
            else:
                b += full
        return b

    visit(entry, 1.0, False)
    return cost
