"""Roofline terms derived from a compiled (AOT) executable.

This container is CPU-only; TPU v5e is the *target*. We therefore derive the
three roofline terms structurally from the compiled artifact:

  compute    = HLO_FLOPs_per_device / peak_FLOP/s
  memory     = HLO_bytes_per_device / HBM_bw
  collective = wire_bytes_per_device / link_bw

``cost_analysis()`` on an SPMD-partitioned executable reports *per-device*
flops and bytes. Collective bytes are not in cost_analysis, so we parse the
optimized HLO and sum per-op wire-byte estimates using ring-algorithm costs:

  all-gather        : result_bytes × (n-1)/n          (each device receives it)
  reduce-scatter    : operand_bytes × (n-1)/n
  all-reduce        : 2 × operand_bytes × (n-1)/n     (RS + AG)
  all-to-all        : operand_bytes × (n-1)/n
  collective-permute: operand_bytes

n (participants) is parsed from replica_groups when present, else assumed
large ((n-1)/n ≈ 1).
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field, asdict
from typing import Dict, Optional

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1, "u4": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)
# e.g. "  %x = bf16[8,128]{1,0} all-gather(...)" or tuple results
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\((.*)$"
)
_GROUPS_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def _participants(line: str) -> Optional[int]:
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota tile format [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return None


@dataclass
class CollectiveStats:
    wire_bytes: float = 0.0
    by_kind: Dict[str, float] = field(default_factory=dict)
    op_count: int = 0

    def add(self, kind: str, b: float):
        self.wire_bytes += b
        self.by_kind[kind] = self.by_kind.get(kind, 0.0) + b
        self.op_count += 1


def collective_bytes(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        if "-done" in line.split("=")[1][:200] and "(" in line:
            # -done ops carry the same shape as -start; only count one of them
            if re.search(r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)-done", line):
                continue
        result_text, kind, operand_text = m.groups()
        result_b = _shape_bytes(result_text)
        operand_b = _shape_bytes(operand_text.split("),")[0] + ")")
        n = _participants(line)
        frac = (n - 1) / n if n and n > 1 else 1.0
        if kind == "all-gather":
            wire = result_b * frac
        elif kind == "reduce-scatter":
            wire = operand_b * frac
        elif kind == "all-reduce":
            wire = 2.0 * result_b * frac
        elif kind == "all-to-all":
            wire = result_b * frac
        else:  # collective-permute
            wire = result_b
        stats.add(kind, wire)
    return stats


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    hbm_bytes_per_device: float
    wire_bytes_per_device: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float            # 6 * N_active * tokens (global)
    useful_flops_ratio: float     # model_flops / (flops_per_device * chips)
    peak_memory_bytes: float = 0.0
    collective_by_kind: Dict[str, float] = field(default_factory=dict)
    # traffic inside named_scope("flashable_attn") — VMEM-resident under the
    # Pallas flash kernel; memory_s_flash models the kernel's memory term
    flashable_hbm_bytes: float = 0.0
    memory_s_flash: float = 0.0

    def to_dict(self):
        return asdict(self)


def roofline_terms(
    *,
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    hlo_text: str,
    model_flops: float,
    peak_flops: float,
    hbm_bw: float,
    link_bw: float,
    peak_memory_bytes: float = 0.0,
) -> RooflineReport:
    # loop-aware HLO walk (XLA's cost_analysis ignores while trip counts)
    from repro.roofline.hlo_cost import analyze

    hc = analyze(hlo_text)
    flops = hc.flops
    hbm = hc.hbm_bytes

    class _Coll:  # adapt HloCost to the summary fields below
        wire_bytes = hc.wire_bytes
        by_kind = hc.wire_by_kind

    coll = _Coll()
    compute_s = flops / peak_flops
    memory_s = hbm / hbm_bw
    collective_s = coll.wire_bytes / link_bw
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)
    total_hw_flops = flops * chips
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops,
        hbm_bytes_per_device=hbm,
        wire_bytes_per_device=coll.wire_bytes,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        bottleneck=bottleneck,
        model_flops=model_flops,
        useful_flops_ratio=(model_flops / total_hw_flops) if total_hw_flops else 0.0,
        peak_memory_bytes=peak_memory_bytes,
        collective_by_kind=dict(coll.by_kind),
        flashable_hbm_bytes=hc.flashable_hbm,
        memory_s_flash=(hbm - hc.flashable_hbm) / hbm_bw,
    )
