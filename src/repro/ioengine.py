"""Pluggable async I/O engine for the cold prep path.

Cold inference is I/O bound: the prep pipeline must keep the disk at
queue depth while big cores transform (NNV12 §3.1-§3.3).  The executor's
``read`` task used to issue one synchronous mmap page-fault read at a
time, so the device never saw more than depth 1.  This module owns the
asynchrony: reads become *submit/reap* pairs against one of three
backends, selected at probe time exactly like the CRC-32C backends
(candidates are self-checked against known bytes before being trusted,
``REPRO_IO_ENGINE`` forces one):

  uring   raw io_uring via ctypes syscalls (``io_uring_setup``/
          ``io_uring_enter``, mmap'd SQ/CQ rings, ``IORING_OP_READ``) —
          true kernel async, no thread per request; requires a kernel
          that exposes the syscalls (probe falls back on EPERM/ENOSYS,
          e.g. under seccomp).
  aio     portable thread-pool fallback: N workers draining a queue of
          ``os.preadv`` requests — async to the caller, sync inside each
          worker.
  sync    ``os.pread`` inline at submit time.  The forced-sync override
          and the reference arm: every byte the async backends return is
          gated bit-identical against it in ``benchmarks/io_formats.py``.

Reads land in buffers from a :class:`PinnedBufferPool` — pre-registered
anonymous slabs, ``mlock``-pinned where the RLIMIT allows (recorded, not
required) and recycled by size class.  Reaped views are returned
**read-only** so the existing staging contract applies unchanged:
``stage_weights`` materializes read-only views into anonymous memory
before ``jax.device_put``, which is exactly what makes buffer recycling
safe — a recycled slab can never alias a device-resident weight.  Pool
buffers are released back per *job* (the executor holds task values until
the job completes for retry idempotency, so views stay valid across
bounded transient retries).

The engine also owns the live byte counters (`bytes_in_flight`) that
drive admission control: ``submit`` blocks while a configured
``max_bytes_in_flight`` budget is exceeded (a single oversized request is
admitted alone, so the gate can never wedge), and idle callbacks fire on
the in-flight -> 0 transition — ``ColdServer`` uses them for bounded
incremental compaction ticks.

Fault injection: ``submit``/``reap`` arm the deterministic injector at
sites ``ioengine.submit`` and ``ioengine.reap`` (typed ``ReadFault``,
bounded retries by the executor's existing policy), alongside the
store-level ``store.read_raw``/``store.read_cached`` sites, so the chaos
and crash gates run unchanged with the engine active.

Staging has the same split: :class:`StageEngine` routes the ``stage`` op
through a dedicated DMA queue thread on accelerators (host->device copies
issue from pinned bounce buffers, serialized so they never contend with
the exec chain's own transfers) and falls back to the inline host path
(``stage_weights``) on CPU hosts, where ``jax.device_put`` may zero-copy
alias host memory and a bounce buffer would be aliasing hazard, not a
win.  ``REPRO_STAGE_ENGINE`` overrides.
"""
from __future__ import annotations

import ctypes
import ctypes.util
import mmap
import os
import queue
import struct
import tempfile
import threading
import time
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.faults import ReadFault, StageFault, classify

__all__ = [
    "IOEngine", "ReadTicket", "ReadAbandoned", "TransferCharge",
    "PinnedBufferPool", "PinnedBuffer", "StageEngine", "get_io_engine",
    "reset_io_engine", "get_stage_engine", "reset_stage_engine",
    "available_backends",
]


class ReadAbandoned(Exception):
    """The waiter's read was abandoned mid-wait (e.g. a warm-state fetch
    won the race for its layer). Control-flow signal, not a fault: the
    caller bails out of the chain instead of retrying."""

ENV_ENGINE = "REPRO_IO_ENGINE"
ENV_STAGE = "REPRO_STAGE_ENGINE"

# ---------------------------------------------------------------------------
# pinned buffer pool
# ---------------------------------------------------------------------------

_PAGE = mmap.PAGESIZE
_MIN_CLASS = 4096

_libc = None


def _get_libc():
    global _libc
    if _libc is None:
        _libc = ctypes.CDLL(None, use_errno=True)
    return _libc


def _try_mlock(addr: int, nbytes: int) -> bool:
    try:
        libc = _get_libc()
        if libc.mlock(ctypes.c_void_p(addr), ctypes.c_size_t(nbytes)) == 0:
            return True
    except Exception:
        pass
    return False


def _try_munlock(addr: int, nbytes: int) -> None:
    try:
        _get_libc().munlock(ctypes.c_void_p(addr), ctypes.c_size_t(nbytes))
    except Exception:
        pass


class PinnedBuffer:
    """One slab from the pool: a writable uint8 array plus its address.

    ``view(nbytes)`` hands out a **read-only** view of the filled prefix;
    ``release()`` returns the slab to its pool (caller contract: only
    after every view into it has been consumed or copied).
    """

    __slots__ = ("pool", "arr", "capacity", "addr", "pinned", "pooled",
                 "_released")

    def __init__(self, pool: "PinnedBufferPool", arr: np.ndarray,
                 pinned: bool, pooled: bool):
        self.pool = pool
        self.arr = arr
        self.capacity = arr.nbytes
        self.addr = arr.ctypes.data
        self.pinned = pinned
        self.pooled = pooled
        self._released = False

    def view(self, nbytes: int) -> np.ndarray:
        v = self.arr[:nbytes].view()
        v.flags.writeable = False
        return v

    def release(self) -> None:
        self.pool._release(self)


class PinnedBufferPool:
    """Size-class recycling pool of mlock-pinned anonymous slabs.

    Slabs are pre-registered once (allocated + pinned) and reused across
    reads; beyond ``max_bytes`` of retained slabs, extra requests get
    one-shot unpooled buffers so a burst can never pin unbounded memory.
    mlock failures (RLIMIT_MEMLOCK, containers) degrade to unpinned slabs
    and are counted, never raised.
    """

    def __init__(self, max_bytes: int = 64 << 20, pin: bool = True,
                 prealloc_bytes: int = 0):
        self.max_bytes = int(max_bytes)
        self.pin = pin
        self._lock = threading.Lock()
        self._free: Dict[int, List[PinnedBuffer]] = {}
        self._retained = 0          # bytes held by the pool (free + leased)
        self.stats = {"acquires": 0, "reuses": 0, "allocs": 0,
                      "overflow_allocs": 0, "mlock_failures": 0,
                      "pinned_bytes": 0, "retained_bytes": 0}
        if prealloc_bytes > 0:
            # pre-register a working set so first reads never pay
            # allocate+mlock on the critical path
            cls = self._size_class(256 << 10)
            bufs = []
            while prealloc_bytes > 0:
                bufs.append(self.acquire(cls))
                prealloc_bytes -= cls
            for b in bufs:
                b.release()

    @staticmethod
    def _size_class(nbytes: int) -> int:
        c = _MIN_CLASS
        while c < nbytes:
            c <<= 1
        return c

    def acquire(self, nbytes: int) -> PinnedBuffer:
        nbytes = max(1, int(nbytes))
        cls = self._size_class(nbytes)
        with self._lock:
            self.stats["acquires"] += 1
            free = self._free.get(cls)
            if free:
                buf = free.pop()
                buf._released = False
                self.stats["reuses"] += 1
                return buf  # noqa: released flag cleared under the lock
            pooled = self._retained + cls <= self.max_bytes
            if pooled:
                self._retained += cls
                self.stats["retained_bytes"] = self._retained
                self.stats["allocs"] += 1
            else:
                self.stats["overflow_allocs"] += 1
        arr = np.empty(cls, dtype=np.uint8)
        pinned = False
        if self.pin and pooled:
            pinned = _try_mlock(arr.ctypes.data, cls)
            with self._lock:
                if pinned:
                    self.stats["pinned_bytes"] += cls
                else:
                    self.stats["mlock_failures"] += 1
        return PinnedBuffer(self, arr, pinned=pinned, pooled=pooled)

    def _release(self, buf: PinnedBuffer) -> None:
        # idempotent under the pool lock: release() may race between a
        # caller abandoning a ticket and the backend finishing it
        with self._lock:
            if buf._released:
                return
            buf._released = True
            if buf.pooled:
                self._free.setdefault(buf.capacity, []).append(buf)
            # overflow slabs just drop to the allocator

    def close(self) -> None:
        with self._lock:
            free, self._free = self._free, {}
            self._retained = 0
            self.stats["retained_bytes"] = 0
        for bufs in free.values():
            for b in bufs:
                if b.pinned:
                    _try_munlock(b.addr, b.capacity)


# ---------------------------------------------------------------------------
# requests / tickets
# ---------------------------------------------------------------------------

class _Request:
    __slots__ = ("fd", "offset", "nbytes", "buf", "key", "event", "error",
                 "engine", "token", "abandoned", "ready_at")

    def __init__(self, engine: "IOEngine", fd: int, offset: int, nbytes: int,
                 buf: PinnedBuffer, key: Optional[str]):
        self.engine = engine
        self.fd = fd
        self.offset = offset
        self.nbytes = nbytes
        self.buf = buf
        self.key = key
        self.event = threading.Event()
        self.error: Optional[BaseException] = None
        self.token = 0
        self.abandoned = False
        self.ready_at = 0.0    # disk-emulation pacing (sim_read_bytes_per_s)

    def finish(self, error: Optional[BaseException] = None) -> None:
        self.error = error
        self.engine._on_complete(self)
        self.event.set()
        if self.abandoned:
            self.buf.release()  # idempotent; see PinnedBufferPool._release


def _read_fully(req: _Request) -> Optional[BaseException]:
    """Blocking pread loop into the request's buffer (aio/sync backends,
    and the short-read top-up path for uring)."""
    return _fill(req, 0)


def _fill(req: _Request, got: int) -> Optional[BaseException]:
    mv = memoryview(req.buf.arr)
    try:
        while got < req.nbytes:
            n = os.preadv(req.fd, [mv[got:req.nbytes]], req.offset + got)
            if n == 0:
                return OSError(
                    f"short read: wanted {req.nbytes}B at offset "
                    f"{req.offset}, got {got}B (EOF)")
            got += n
    except OSError as e:
        return e
    return None


class ReadTicket:
    """Handle for one in-flight read.  ``wait()`` returns a **read-only**
    uint8 view of the reaped bytes; ``release()`` recycles the buffer
    (call only once every view has been consumed or copied — the executor
    does this per job)."""

    __slots__ = ("_req", "_injector")

    def __init__(self, req: _Request, injector=None):
        self._req = req
        self._injector = injector

    @property
    def key(self) -> Optional[str]:
        return self._req.key

    @property
    def nbytes(self) -> int:
        return self._req.nbytes

    def done(self) -> bool:
        return self._req.event.is_set()

    def wait(self, timeout: Optional[float] = None) -> np.ndarray:
        if self._injector is not None:
            self._injector.maybe_fault("ioengine.reap", self._req.key)
        if not self._req.event.wait(timeout):
            raise TimeoutError(
                f"ioengine read {self._req.key!r} not complete "
                f"after {timeout}s")
        if self._req.error is not None:
            err = self._req.error
            raise ReadFault(
                f"async read failed ({self._req.key!r}, "
                f"{self._req.nbytes}B @ {self._req.offset}): {err}") from err
        if self._req.ready_at:
            # edge-disk emulation: the bytes are here, but a slow flash
            # device would not have served them yet — pace the reap to the
            # simulated device's shared bandwidth. Sliced: a read already
            # issued to a real device cannot be recalled, but the EMULATED
            # remainder of its service time can — an abandoned race-loser
            # frees its pool slot now instead of sleeping out the device
            while True:
                if self._req.abandoned:
                    raise ReadAbandoned(
                        f"read {self._req.key!r} abandoned mid-pace")
                delay = self._req.ready_at - time.monotonic()
                if delay <= 0:
                    break
                time.sleep(min(delay, 0.002))
        return self._req.buf.view(self._req.nbytes)

    def release(self) -> None:
        self._req.buf.release()

    def interrupt(self) -> None:
        """Flag the read abandoned WITHOUT touching its buffer: a waiter
        parked in the emulated-disk pacing loop raises ``ReadAbandoned``
        (and its own cleanup releases the buffer); a waiter already past
        pacing completes normally. Safe to call from another thread —
        unlike ``abandon()``, this can never recycle a buffer someone is
        still reading."""
        self._req.abandoned = True

    def abandon(self) -> None:
        """Give up on this read: recycle the buffer now if the request is
        complete, else the moment the backend finishes it — never while
        the kernel may still be writing into it."""
        req = self._req
        req.abandoned = True
        if req.event.is_set():
            req.buf.release()


class TransferCharge:
    """One peer-transfer byte charge against the engine's admission budget.

    Peer warm-state fetches (``executor/warmstate.py``) read no local fd,
    but their payloads still land in pinned pool slabs and still count
    against ``max_bytes_in_flight`` — the budget is a statement about host
    memory pressure during prep, not about the disk specifically.  The
    charge is taken at receive time and held until the payload has been
    copied out (CRC-checked and materialized), then ``release()`` returns
    the bytes to the budget and the slab to the pool.  Release is
    idempotent, mirroring the ticket/abandon contract above, because a
    lost race may release from both the fetch path and the job-done
    cleanup."""

    __slots__ = ("engine", "buf", "nbytes", "key", "_released")

    def __init__(self, engine: "IOEngine", buf: PinnedBuffer, nbytes: int,
                 key: Optional[str]):
        self.engine = engine
        self.buf = buf
        self.nbytes = nbytes
        self.key = key
        self._released = False

    def view(self, nbytes: Optional[int] = None) -> np.ndarray:
        return self.buf.view(self.nbytes if nbytes is None else nbytes)

    def release(self) -> None:
        with self.engine._cond:
            if self._released:
                return
            self._released = True
        self.engine._on_transfer_done(self)
        self.buf.release()


# ---------------------------------------------------------------------------
# backends
# ---------------------------------------------------------------------------

class SyncBackend:
    """``os.pread`` inline at submit: depth-1 reference implementation and
    the ``REPRO_IO_ENGINE=sync`` forced override."""

    name = "sync"

    def submit(self, req: _Request) -> None:
        req.finish(_read_fully(req))

    def close(self) -> None:
        pass


class AioBackend:
    """Portable async fallback: N worker threads draining a queue of
    ``os.preadv`` requests.  Async to the submitter, sync per worker —
    depth is bounded by the worker count times one outstanding syscall."""

    name = "aio"

    def __init__(self, workers: int = 4):
        if not hasattr(os, "preadv"):
            raise RuntimeError("os.preadv unavailable")
        self._q: "queue.Queue[Optional[_Request]]" = queue.Queue()
        self._threads = []
        for i in range(max(1, workers)):
            t = threading.Thread(target=self._worker,
                                 name=f"repro-aio-{i}", daemon=True)
            t.start()
            self._threads.append(t)

    def _worker(self) -> None:
        while True:
            req = self._q.get()
            if req is None:
                return
            req.finish(_read_fully(req))

    def submit(self, req: _Request) -> None:
        self._q.put(req)

    def close(self) -> None:
        for _ in self._threads:
            self._q.put(None)
        for t in self._threads:
            t.join(timeout=5.0)
        self._threads = []


# -- raw io_uring via ctypes -------------------------------------------------

_NR_IO_URING_SETUP = 425
_NR_IO_URING_ENTER = 426
_IORING_OFF_SQ_RING = 0
_IORING_OFF_SQES = 0x10000000
_IORING_ENTER_GETEVENTS = 1
_IORING_FEAT_SINGLE_MMAP = 1
_IORING_OP_NOP = 0
_IORING_OP_READ = 22
_SQE_SIZE = 64
_CQE_SIZE = 16


class _SqringOffsets(ctypes.Structure):
    _fields_ = [("head", ctypes.c_uint32), ("tail", ctypes.c_uint32),
                ("ring_mask", ctypes.c_uint32),
                ("ring_entries", ctypes.c_uint32),
                ("flags", ctypes.c_uint32), ("dropped", ctypes.c_uint32),
                ("array", ctypes.c_uint32), ("resv1", ctypes.c_uint32),
                ("user_addr", ctypes.c_uint64)]


class _CqringOffsets(ctypes.Structure):
    _fields_ = [("head", ctypes.c_uint32), ("tail", ctypes.c_uint32),
                ("ring_mask", ctypes.c_uint32),
                ("ring_entries", ctypes.c_uint32),
                ("overflow", ctypes.c_uint32), ("cqes", ctypes.c_uint32),
                ("flags", ctypes.c_uint32), ("resv1", ctypes.c_uint32),
                ("user_addr", ctypes.c_uint64)]


class _UringParams(ctypes.Structure):
    _fields_ = [("sq_entries", ctypes.c_uint32),
                ("cq_entries", ctypes.c_uint32),
                ("flags", ctypes.c_uint32),
                ("sq_thread_cpu", ctypes.c_uint32),
                ("sq_thread_idle", ctypes.c_uint32),
                ("features", ctypes.c_uint32),
                ("wq_fd", ctypes.c_uint32),
                ("resv", ctypes.c_uint32 * 3),
                ("sq_off", _SqringOffsets),
                ("cq_off", _CqringOffsets)]


def _syscall(*args) -> int:
    libc = _get_libc()
    libc.syscall.restype = ctypes.c_long
    ret = libc.syscall(*[ctypes.c_long(a) if isinstance(a, int) else a
                         for a in args])
    if ret < 0:
        e = ctypes.get_errno()
        raise OSError(e, os.strerror(e))
    return ret


class UringBackend:
    """Minimal io_uring reader: setup + mmap'd SQ/CQ rings, one submitter
    lock, one reaper thread parked in ``io_uring_enter(GETEVENTS)``.

    A bounded semaphore sized to the SQ guarantees the rings can never
    overflow (the kernel sizes the CQ at 2x SQ).  Short completions are
    topped up with a synchronous pread before the request is finished, so
    callers always see all-or-error.
    """

    name = "uring"

    def __init__(self, entries: int = 64):
        params = _UringParams()
        self._ring_fd = _syscall(_NR_IO_URING_SETUP, entries,
                                 ctypes.byref(params))
        try:
            if not params.features & _IORING_FEAT_SINGLE_MMAP:
                raise RuntimeError("io_uring without SINGLE_MMAP unsupported")
            self.entries = params.sq_entries
            sq, cq = params.sq_off, params.cq_off
            ring_sz = max(sq.array + params.sq_entries * 4,
                          cq.cqes + params.cq_entries * _CQE_SIZE)
            self._ring = mmap.mmap(
                self._ring_fd, ring_sz, flags=mmap.MAP_SHARED | mmap.MAP_POPULATE,
                prot=mmap.PROT_READ | mmap.PROT_WRITE,
                offset=_IORING_OFF_SQ_RING)
            self._sqes = mmap.mmap(
                self._ring_fd, params.sq_entries * _SQE_SIZE,
                flags=mmap.MAP_SHARED | mmap.MAP_POPULATE,
                prot=mmap.PROT_READ | mmap.PROT_WRITE,
                offset=_IORING_OFF_SQES)
            self._sq_tail_off = sq.tail
            self._sq_mask = struct.unpack_from("<I", self._ring,
                                               sq.ring_mask)[0]
            self._sq_array_off = sq.array
            self._cq_head_off = cq.head
            self._cq_tail_off = cq.tail
            self._cq_mask = struct.unpack_from("<I", self._ring,
                                               cq.ring_mask)[0]
            self._cqes_off = cq.cqes
        except BaseException:
            os.close(self._ring_fd)
            raise
        self._sub_lock = threading.Lock()
        self._slots = threading.BoundedSemaphore(self.entries)
        self._pending: Dict[int, _Request] = {}
        self._next_token = 1
        self._closing = False
        self._reaper = threading.Thread(target=self._reap_loop,
                                        name="repro-uring-reaper", daemon=True)
        self._reaper.start()

    def _push_sqe(self, opcode: int, fd: int, offset: int, addr: int,
                  nbytes: int, token: int) -> None:
        """Write one SQE and publish it.  Caller holds ``_sub_lock`` and a
        ring slot."""
        tail = struct.unpack_from("<I", self._ring, self._sq_tail_off)[0]
        idx = tail & self._sq_mask
        sqe = struct.pack("<BBHiQQIIQ", opcode, 0, 0, fd, offset, addr,
                          nbytes, 0, token)
        self._sqes[idx * _SQE_SIZE:(idx + 1) * _SQE_SIZE] = (
            sqe + b"\0" * (_SQE_SIZE - len(sqe)))
        struct.pack_into("<I", self._ring, self._sq_array_off + idx * 4, idx)
        struct.pack_into("<I", self._ring, self._sq_tail_off,
                         (tail + 1) & 0xFFFFFFFF)
        _syscall(_NR_IO_URING_ENTER, self._ring_fd, 1, 0, 0, 0, 0)

    def submit(self, req: _Request) -> None:
        self._slots.acquire()
        try:
            with self._sub_lock:
                if self._closing:
                    raise RuntimeError("uring backend closed")
                token = self._next_token
                self._next_token += 1
                self._pending[token] = req
                req.token = token
                try:
                    self._push_sqe(_IORING_OP_READ, req.fd, req.offset,
                                   req.buf.addr, req.nbytes, token)
                except BaseException:
                    self._pending.pop(token, None)
                    raise
        except BaseException:
            self._slots.release()
            raise

    def _reap_loop(self) -> None:
        while True:
            try:
                _syscall(_NR_IO_URING_ENTER, self._ring_fd, 0, 1,
                         _IORING_ENTER_GETEVENTS, 0, 0)
            except OSError as e:
                import errno as _errno
                if e.errno == _errno.EINTR:
                    continue
                if self._closing:
                    return
                raise
            head = struct.unpack_from("<I", self._ring, self._cq_head_off)[0]
            tail = struct.unpack_from("<I", self._ring, self._cq_tail_off)[0]
            stop = False
            while head != tail:
                idx = head & self._cq_mask
                user_data, res = struct.unpack_from(
                    "<Qi", self._ring, self._cqes_off + idx * _CQE_SIZE)
                head = (head + 1) & 0xFFFFFFFF
                struct.pack_into("<I", self._ring, self._cq_head_off, head)
                if user_data == 0:  # shutdown NOP
                    stop = True
                    continue
                with self._sub_lock:
                    req = self._pending.pop(user_data, None)
                self._slots.release()
                if req is None:
                    continue
                if res < 0:
                    req.finish(OSError(-res, os.strerror(-res)))
                elif res < req.nbytes:
                    # regular-file short completion: top up synchronously
                    req.finish(_fill(req, res))
                else:
                    req.finish(None)
            if stop and not self._pending:
                return

    def close(self) -> None:
        with self._sub_lock:
            if self._closing:
                return
            self._closing = True
        try:
            self._slots.acquire()
            with self._sub_lock:
                self._push_sqe(_IORING_OP_NOP, -1, 0, 0, 0, 0)
        except Exception:
            pass
        self._reaper.join(timeout=5.0)
        self._sqes.close()
        self._ring.close()
        os.close(self._ring_fd)


# ---------------------------------------------------------------------------
# engine facade
# ---------------------------------------------------------------------------

_BACKENDS = {"uring": UringBackend, "aio": AioBackend, "sync": SyncBackend}
_PROBE_ORDER = ("uring", "aio", "sync")


def _self_check(backend, pool: PinnedBufferPool) -> None:
    """Trust no backend before it reproduces known bytes: sequential,
    offset, and unaligned-length reads against a temp file (the CRC
    backends set this precedent)."""
    data = (np.arange(192 * 1024, dtype=np.int64) % 251).astype(np.uint8)
    fd = None
    path = None
    try:
        f, path = tempfile.mkstemp(prefix="repro_ioengine_probe_")
        os.write(f, data.tobytes())
        os.close(f)
        fd = os.open(path, os.O_RDONLY)
        cases = [(0, len(data)), (4096, 64 * 1024), (100_003, 31_337)]
        reqs = []
        for off, n in cases:
            req = _Request(_NullEngine, fd, off, n, pool.acquire(n), "probe")
            backend.submit(req)
            reqs.append((off, n, req))
        for off, n, req in reqs:
            if not req.event.wait(5.0):
                raise RuntimeError(f"{backend.name} probe timed out")
            if req.error is not None:
                raise req.error
            if not np.array_equal(req.buf.view(n), data[off:off + n]):
                raise RuntimeError(
                    f"{backend.name} probe returned wrong bytes "
                    f"({n}B @ {off})")
            req.buf.release()
    finally:
        if fd is not None:
            os.close(fd)
        if path is not None:
            os.unlink(path)


class _NullEngineCls:
    """Stand-in engine for probe requests: no counters, no callbacks."""

    @staticmethod
    def _on_complete(req) -> None:
        pass


_NullEngine = _NullEngineCls()


def available_backends() -> List[str]:
    """Names of backends that construct AND pass the self-check on this
    host (probe is cheap; used by tests and the benchmark matrix)."""
    out = []
    pool = PinnedBufferPool(max_bytes=4 << 20)
    for name in _PROBE_ORDER:
        try:
            b = _BACKENDS[name]()
            try:
                _self_check(b, pool)
                out.append(name)
            finally:
                b.close()
        except Exception:
            continue
    pool.close()
    return out


class IOEngine:
    """Facade over one probed backend: submit/reap reads, live byte
    counters, byte-budget admission, idle-transition callbacks."""

    def __init__(self, backend: Optional[str] = None, *,
                 depth: int = 64, aio_workers: int = 4,
                 max_bytes_in_flight: Optional[int] = None,
                 pool: Optional[PinnedBufferPool] = None,
                 pool_bytes: int = 64 << 20):
        forced = backend or os.environ.get(ENV_ENGINE) or None
        self.pool = pool or PinnedBufferPool(max_bytes=pool_bytes)
        self._owns_pool = pool is None
        self._cond = threading.Condition()
        self._in_flight = 0
        self._bytes_in_flight = 0
        self.max_bytes_in_flight = max_bytes_in_flight
        # edge-disk emulation: when set, read reaps are paced by a shared
        # token bucket to this many bytes/s (one simulated device, shared
        # by every in-flight read — NOT per-request).  CI hosts serve the
        # store from page cache at memory speed; the paper's subject is
        # edge flash at ~100-400 MB/s, and benchmarks that depend on disk
        # time being real (e.g. the warm-transfer race) set this knob.
        self.sim_read_bytes_per_s: Optional[float] = None
        self._sim_next_free = 0.0
        self._idle_callbacks: List[Callable[[], None]] = []
        self._closed = False
        self.stats = {"submitted": 0, "reaped": 0, "errors": 0,
                      "bytes_submitted": 0, "bytes_reaped": 0,
                      "transfer_charges": 0, "transfer_bytes": 0,
                      "budget_waits": 0, "idle_transitions": 0,
                      "probe_rejected": []}
        self.backend = self._probe(forced, depth, aio_workers)
        self.name = self.backend.name

    def _probe(self, forced: Optional[str], depth: int, aio_workers: int):
        order = (forced,) if forced else _PROBE_ORDER
        last_err: Optional[BaseException] = None
        for name in order:
            if name not in _BACKENDS:
                raise ValueError(
                    f"unknown I/O engine {name!r} "
                    f"(choices: {sorted(_BACKENDS)})")
            try:
                kw: Dict[str, Any] = {}
                if name == "uring":
                    kw["entries"] = depth
                elif name == "aio":
                    kw["workers"] = aio_workers
                b = _BACKENDS[name](**kw)
                try:
                    _self_check(b, self.pool)
                except BaseException:
                    b.close()
                    raise
                return b
            except Exception as e:
                last_err = e
                self.stats["probe_rejected"].append(f"{name}: {e}")
        raise RuntimeError(
            f"I/O engine backend {forced!r} failed its self-check: "
            f"{last_err}") from last_err

    # -- submit / reap ------------------------------------------------------
    def submit(self, fd: int, offset: int, nbytes: int, *,
               key: Optional[str] = None, injector=None) -> ReadTicket:
        """Queue one read.  Blocks while the bytes-in-flight budget is
        exhausted (an oversized single request is admitted when the
        engine is otherwise empty, so the gate can never wedge)."""
        if injector is not None:
            injector.maybe_fault("ioengine.submit", key)
        nbytes = int(nbytes)
        with self._cond:
            if self._closed:
                raise RuntimeError("IOEngine is closed")
            budget = self.max_bytes_in_flight
            if budget is not None:
                waited = False
                while (self._bytes_in_flight > 0
                       and self._bytes_in_flight + nbytes > budget):
                    waited = True
                    self._cond.wait()
                if waited:
                    self.stats["budget_waits"] += 1
            self._in_flight += 1
            self._bytes_in_flight += nbytes
            self.stats["submitted"] += 1
            self.stats["bytes_submitted"] += nbytes
            ready_at = 0.0
            if self.sim_read_bytes_per_s:
                start = max(time.monotonic(), self._sim_next_free)
                self._sim_next_free = (
                    start + nbytes / self.sim_read_bytes_per_s)
                ready_at = self._sim_next_free
        buf = self.pool.acquire(nbytes)
        req = _Request(self, fd, offset, nbytes, buf, key)
        req.ready_at = ready_at
        try:
            self.backend.submit(req)
        except BaseException as e:
            buf.release()
            self._on_complete(req)
            if isinstance(e, OSError):
                raise classify(e) from e
            raise
        return ReadTicket(req, injector=injector)

    def charge(self, nbytes: int, *, key: Optional[str] = None,
               injector=None) -> TransferCharge:
        """Admit ``nbytes`` of peer-transfer payload.

        Blocks under the same bytes-in-flight budget as :meth:`submit`
        (with the same oversized-alone escape so the gate can never
        wedge) and hands back a pool slab for the receive path to fill.
        Counted under ``transfer_charges``/``transfer_bytes`` — NOT
        ``bytes_submitted`` — so disk reads and peer transfers stay
        separately observable (the warm-transfer CI gate depends on
        this)."""
        if injector is not None:
            injector.maybe_fault("ioengine.charge", key)
        nbytes = int(nbytes)
        with self._cond:
            if self._closed:
                raise RuntimeError("IOEngine is closed")
            budget = self.max_bytes_in_flight
            if budget is not None:
                waited = False
                while (self._bytes_in_flight > 0
                       and self._bytes_in_flight + nbytes > budget):
                    waited = True
                    self._cond.wait()
                if waited:
                    self.stats["budget_waits"] += 1
            self._in_flight += 1
            self._bytes_in_flight += nbytes
            self.stats["transfer_charges"] += 1
            self.stats["transfer_bytes"] += nbytes
        buf = self.pool.acquire(nbytes)
        return TransferCharge(self, buf, nbytes, key)

    def _on_transfer_done(self, charge: TransferCharge) -> None:
        with self._cond:
            self._in_flight -= 1
            self._bytes_in_flight -= charge.nbytes
            idle = self._in_flight == 0
            if idle:
                self.stats["idle_transitions"] += 1
            callbacks = list(self._idle_callbacks) if idle else []
            self._cond.notify_all()
        for cb in callbacks:
            try:
                cb()
            except Exception:
                pass  # idle ticks are advisory; never poison the receiver

    def _on_complete(self, req: _Request) -> None:
        with self._cond:
            self._in_flight -= 1
            self._bytes_in_flight -= req.nbytes
            self.stats["reaped"] += 1
            self.stats["bytes_reaped"] += req.nbytes
            if req.error is not None:
                self.stats["errors"] += 1
            idle = self._in_flight == 0
            if idle:
                self.stats["idle_transitions"] += 1
            callbacks = list(self._idle_callbacks) if idle else []
            self._cond.notify_all()
        for cb in callbacks:
            try:
                cb()
            except Exception:
                pass  # idle ticks are advisory; never poison the reaper

    # -- admission plumbing -------------------------------------------------
    def bytes_in_flight(self) -> int:
        with self._cond:
            return self._bytes_in_flight

    def reads_in_flight(self) -> int:
        with self._cond:
            return self._in_flight

    def set_max_bytes_in_flight(self, budget: Optional[int]) -> None:
        with self._cond:
            self.max_bytes_in_flight = budget
            self._cond.notify_all()

    def set_sim_read_bandwidth(self, bytes_per_s: Optional[float]) -> None:
        """Enable (or disable, with None/0) the edge-disk read-bandwidth
        emulation; see the ``sim_read_bytes_per_s`` note in ``__init__``."""
        with self._cond:
            self.sim_read_bytes_per_s = (
                float(bytes_per_s) if bytes_per_s else None)
            self._sim_next_free = 0.0

    def add_idle_callback(self, fn: Callable[[], None]) -> None:
        with self._cond:
            self._idle_callbacks.append(fn)

    def remove_idle_callback(self, fn: Callable[[], None]) -> None:
        with self._cond:
            try:
                self._idle_callbacks.remove(fn)
            except ValueError:
                pass

    def snapshot(self) -> Dict[str, Any]:
        with self._cond:
            snap = dict(self.stats)
            snap["probe_rejected"] = list(self.stats["probe_rejected"])
            snap["backend"] = getattr(self, "name", None)
            snap["in_flight"] = self._in_flight
            snap["bytes_in_flight"] = self._bytes_in_flight
            snap["max_bytes_in_flight"] = self.max_bytes_in_flight
            snap["sim_read_bytes_per_s"] = self.sim_read_bytes_per_s
        snap["pool"] = dict(self.pool.stats)
        return snap

    def drain(self, timeout: float = 10.0) -> bool:
        """Wait until nothing is in flight (tests / shutdown barrier)."""
        deadline = time.monotonic() + timeout
        with self._cond:
            while self._in_flight > 0:
                left = deadline - time.monotonic()
                if left <= 0:
                    return False
                self._cond.wait(left)
        return True

    def close(self) -> None:
        with self._cond:
            if self._closed:
                return
            self._closed = True
        self.backend.close()
        if self._owns_pool:
            self.pool.close()


# ---------------------------------------------------------------------------
# stage engine (host | dma)
# ---------------------------------------------------------------------------

class StageEngine:
    """Backend-pluggable ``stage`` op.

    host  inline ``stage_weights`` — the reference path, and the only
          safe one on CPU hosts where ``jax.device_put`` may zero-copy
          alias writable host buffers (a pinned bounce buffer would be
          recycled under a live alias).
    dma   dedicated DMA-queue thread: weights are copied into a pinned
          bounce buffer and ``device_put`` issues from it, serialized so
          staging transfers never contend with the exec chain's own
          copies.  Auto-selected only when the default jax device is a
          real accelerator; ``REPRO_STAGE_ENGINE`` overrides.
    """

    def __init__(self, backend: Optional[str] = None,
                 pool: Optional[PinnedBufferPool] = None):
        forced = backend or os.environ.get(ENV_STAGE) or None
        if forced is None:
            forced = "dma" if self._accelerator_present() else "host"
        if forced not in ("host", "dma"):
            raise ValueError(f"unknown stage engine {forced!r} "
                             f"(choices: ['dma', 'host'])")
        self.name = forced
        self.pool = pool or PinnedBufferPool(max_bytes=32 << 20)
        self.stats = {"staged": 0, "bytes_staged": 0, "dma_queue_peak": 0}
        self._q: Optional["queue.Queue"] = None
        self._thread: Optional[threading.Thread] = None
        if self.name == "dma":
            self._q = queue.Queue()
            self._thread = threading.Thread(
                target=self._dma_loop, name="repro-stage-dma", daemon=True)
            self._thread.start()

    @staticmethod
    def _accelerator_present() -> bool:
        try:
            import jax
            return jax.devices()[0].platform not in ("cpu",)
        except Exception:
            return False

    # -- host path ----------------------------------------------------------
    def _stage_host(self, w: Dict[str, Any]) -> Dict[str, Any]:
        from repro.core.staging import stage_weights
        return stage_weights(w)

    # -- dma path -----------------------------------------------------------
    def _dma_loop(self) -> None:
        import jax
        while True:
            item = self._q.get()
            if item is None:
                return
            w, out, done = item
            try:
                staged = {}
                for k, v in w.items():
                    arr = np.asarray(v)
                    buf = self.pool.acquire(arr.nbytes)
                    try:
                        bounce = buf.arr[:arr.nbytes].view(arr.dtype).reshape(
                            arr.shape)
                        np.copyto(bounce, arr)
                        # device_put copies across the bus on accelerators;
                        # the bounce buffer is free to recycle right after
                        staged[k] = jax.device_put(bounce)
                        jax.block_until_ready(staged[k])
                    finally:
                        buf.release()
                out["staged"] = staged
            except BaseException as e:
                out["error"] = e
            finally:
                done.set()

    def stage(self, w: Dict[str, Any]) -> Dict[str, Any]:
        if not w:
            return {}
        if self.name == "host" or self._q is None:
            staged = self._stage_host(w)
        else:
            out: Dict[str, Any] = {}
            done = threading.Event()
            self._q.put((w, out, done))
            self.stats["dma_queue_peak"] = max(
                self.stats["dma_queue_peak"], self._q.qsize())
            done.wait()
            if "error" in out:
                err = out["error"]
                if isinstance(err, BaseException):
                    raise StageFault(f"dma stage failed: {err}") from err
            staged = out["staged"]
        self.stats["staged"] += 1
        self.stats["bytes_staged"] += sum(
            int(getattr(v, "nbytes", 0)) for v in w.values())
        return staged

    def close(self) -> None:
        if self._q is not None and self._thread is not None:
            self._q.put(None)
            self._thread.join(timeout=5.0)
            self._thread = None
        self.pool.close()


# ---------------------------------------------------------------------------
# process-wide singleton (mirrors executor.pool.get_core_pool)
# ---------------------------------------------------------------------------

_engine_lock = threading.Lock()
_engine: Optional[IOEngine] = None


def get_io_engine(**kw) -> IOEngine:
    """Process-wide engine: one ring / worker set serves every model, so
    the byte counters admission control reads are global truth."""
    global _engine
    with _engine_lock:
        if _engine is None:
            _engine = IOEngine(**kw)
        return _engine


def reset_io_engine() -> None:
    global _engine
    with _engine_lock:
        eng, _engine = _engine, None
    if eng is not None:
        eng.close()


_stage_engine: Optional[StageEngine] = None


def get_stage_engine(**kw) -> StageEngine:
    global _stage_engine
    with _engine_lock:
        if _stage_engine is None:
            _stage_engine = StageEngine(**kw)
        return _stage_engine


def reset_stage_engine() -> None:
    global _stage_engine
    with _engine_lock:
        eng, _stage_engine = _stage_engine, None
    if eng is not None:
        eng.close()
