"""AdamW with global-norm clipping and cosine schedule (pure pytree, no deps).

Moments are f32 regardless of parameter dtype; the update is computed in f32
and cast back, so bf16 training remains stable. Optimizer state inherits each
parameter's sharding (the dry-run passes the param specs for m/v).
"""
from __future__ import annotations

from typing import Any, Dict, NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params: Any) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def cosine_lr(base_lr: float, warmup: int, total: int):
    def lr(step):
        step = step.astype(jnp.float32)
        warm = base_lr * step / max(warmup, 1)
        frac = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return jnp.where(step < warmup, warm, cos)
    return lr


def global_norm(tree: Any) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(sum(jax.tree.leaves(sq)))


def adamw_update(
    grads: Any,
    state: AdamWState,
    params: Any,
    *,
    lr,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
):
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    lr_t = lr(step) if callable(lr) else lr
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m, v

    out = jax.tree.map(upd, params, grads, state.m, state.v)
    new_params = jax.tree.map(lambda t: t[0], out, is_leaf=lambda t: isinstance(t, tuple))
    new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda t: isinstance(t, tuple))
    new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda t: isinstance(t, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v), {
        "grad_norm": gnorm, "lr": lr_t if not callable(lr) else lr_t,
    }
