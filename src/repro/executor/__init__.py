"""Persistent asymmetric executor — the online runtime subsystem.

Three layers:

  * ``graph``  — typed task DAGs (``read → transform → stage → execute``,
    per-layer deps, core-affinity tags) compiled from a scheduler ``Plan``;
    the same representation the plan simulator models.
  * ``pool``   — one process-wide ``CorePool`` of persistent big/little
    worker threads that executes task graphs with work stealing by
    remaining prep cost; reused across runs *and models*, with per-job
    trace accounting.
  * ``server`` — ``ColdServer``: multi-model cold serving on one shared
    pool (admission control on co-running preps, LRU residency under a
    memory budget, one shared ProfileDB); ``llm_bridge`` turns a cold LLM
    start into first-token serving that overlaps later-layer prep with
    prefill of already-staged early layers.

``server``/``llm_bridge`` import the engine (which imports the pipeline
facade, which imports ``graph``/``pool``), so they are exposed lazily to
keep ``repro.core.pipeline -> repro.executor`` cycle-free.
"""
from repro.executor.graph import (  # noqa: F401
    OpTrace, PREP_KINDS, Task, TaskGraph, compile_plan, simulate_graph,
)
from repro.executor.pool import (  # noqa: F401
    CorePool, Job, get_core_pool, reset_core_pool,
)

_LAZY = {
    "ColdServer": ("repro.executor.server", "ColdServer"),
    "ColdStart": ("repro.executor.server", "ColdStart"),
    "ColdLLMResult": ("repro.executor.llm_bridge", "ColdLLMResult"),
    "cold_start_llm": ("repro.executor.llm_bridge", "cold_start_llm"),
}


def __getattr__(name):
    try:
        mod, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(name) from None
    import importlib

    return getattr(importlib.import_module(mod), attr)
