"""Peer-to-peer warm-state transfer — cold-start from a sibling's RAM.

Once a model has cold-started *anywhere* in the fleet, every other worker
holds the single most expensive cold-path resource — the post-transform
staged weights — one hop away in a sibling's memory.  This module moves
them: a :class:`WarmStateServer` on each worker serves its ``ColdServer``'s
resident layer state over the same length-prefixed pickle channel the
front door already speaks, and a :class:`PeerFetcher` on the requesting
side streams it in, racing the local ``read→transform→stage`` chains.
The drain runs on the fetcher's OWN background thread
(:meth:`PeerFetcher.start_stream`) so it never occupies a pool worker:
each layer is handed to a callback the moment it lands, which stages it
and cancels the local chain it beat (``CorePool.cancel_tasks``) — first
finisher wins per layer.  The executor graph's ``fetch_remote`` tasks
are the race's instant, cancellable markers: running one (backstop-)
starts the stream, and a local chain that finishes first retires its
layer's still-pending marker.

Protocol (all frames are length-prefixed pickled dicts):

  client → server   ``{"type": "fetch", "model", "layers": [...] | None,
                       "packed": bool}``
  server → client   ``{"type": "refuse", "model", "reason"}``               or
                    ``{"type": "accept", "model", "layers": [...],
                       "total_bytes": int}``
                    then per layer, per tensor key:
                    ``{"type": "chunk", "layer", "key", "dtype", "shape",
                       "data": bytes, "crc": int}``   (CRC-32C over data)
                    ``{"type": "layer_done", "layer", "nkeys": int}``
                    and finally ``{"type": "done", "model"}``

The server refuses — rather than serves a partial answer — whenever the
model is not resident, the server is draining, or its residency budget is
over-committed (memory pressure): a refusal costs the requester one RTT
and the local chain proceeds, while an evicted-mid-stream layer would
cost a stall.  Packed decode params (the LLM bridge's ``BatchedServer``
params) ride the same stream under the reserved layer name
``__packed__`` when the serving worker has registered them.

Client-side integrity and accounting: every chunk's payload is copied
into an ``IOEngine`` pinned-pool slab under a :class:`TransferCharge`
(counts against ``max_read_bytes_in_flight`` — budget pressure
back-pressures the socket), CRC-32C-verified in place, and only then
materialized.  Any mismatch, refusal, disconnect, or timeout raises a
typed :class:`~repro.faults.FetchFault` (a ``TransientFault``): the
executor's fetch task swallows it and the local chain — always racing —
remains authoritative, bit-identical by construction.
"""
from __future__ import annotations

import socket
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Tuple

import numpy as np

from repro.checkpoint.integrity import crc32c
from repro.executor.frontdoor import recv_msg, send_msg
from repro.faults import FetchFault, TransientFault

#: reserved pseudo-layer name for packed decode params
PACKED_LAYER = "__packed__"


def _crc(data) -> int:
    return int(crc32c(np.frombuffer(data, dtype=np.uint8)))


# ---------------------------------------------------------------------------
# server side
# ---------------------------------------------------------------------------

class WarmStateServer:
    """Serves one ``ColdServer``'s resident warm state to sibling workers.

    ``cold_server`` only needs ``resident_state_for_transfer(model,
    packed=...)`` returning ``(state, reason)`` — ``state`` is
    ``{layer: {key: array}}`` (None = refusal with ``reason``).  One
    daemon accept thread, one daemon thread per peer session; sessions
    are short-lived (one per cold start on the fetching side).
    """

    def __init__(self, cold_server, host: str = "127.0.0.1", port: int = 0):
        self.server = cold_server
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.bind((host, port))
        self._listener.listen(8)
        self.host, self.port = self._listener.getsockname()[:2]
        self._closed = False
        self._lock = threading.Lock()
        self.stats = {"sessions": 0, "fetches": 0, "refusals": 0,
                      "layers_served": 0, "bytes_served": 0}
        # test hook: corrupt the payload of the first N chunks AFTER the
        # CRC is computed — the client-side integrity gate's chaos lever
        self.corrupt_chunks = 0
        self._accept_thread = threading.Thread(
            target=self._accept_loop, name="repro-warmstate-accept",
            daemon=True)
        self._accept_thread.start()

    # -- lifecycle -----------------------------------------------------------
    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
        try:
            self._listener.close()
        except OSError:
            pass

    @property
    def endpoint(self) -> Tuple[str, int]:
        return (self.host, self.port)

    # -- serving -------------------------------------------------------------
    def _accept_loop(self) -> None:
        while not self._closed:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return
            with self._lock:
                self.stats["sessions"] += 1
            threading.Thread(target=self._session, args=(sock,),
                             name="repro-warmstate-session",
                             daemon=True).start()

    def _session(self, sock: socket.socket) -> None:
        try:
            while True:
                msg = recv_msg(sock)
                if msg is None or msg.get("type") == "close":
                    return
                if msg.get("type") == "fetch":
                    self._serve_fetch(sock, msg)
        except OSError:
            pass    # peer gone mid-stream: its fetcher raises FetchFault
        finally:
            try:
                sock.close()
            except OSError:
                pass

    def _serve_fetch(self, sock: socket.socket, msg: Dict[str, Any]) -> None:
        model = msg.get("model")
        with self._lock:
            self.stats["fetches"] += 1
        state, reason = self.server.resident_state_for_transfer(
            model, packed=bool(msg.get("packed")))
        if state is None:
            with self._lock:
                self.stats["refusals"] += 1
            send_msg(sock, {"type": "refuse", "model": model,
                            "reason": reason})
            return
        wanted = msg.get("layers")
        if wanted is not None:
            wanted = [n for n in wanted if n in state]
            state = {n: state[n] for n in wanted}
        layers = [n for n, kv in state.items() if kv]
        total = sum(int(np.asarray(a).nbytes)
                    for kv in state.values() for a in kv.values())
        send_msg(sock, {"type": "accept", "model": model,
                        "layers": layers, "total_bytes": total})
        for layer in layers:
            for key, arr in state[layer].items():
                a = np.asarray(arr)
                data = a.tobytes()
                crc = _crc(data)
                if self.corrupt_chunks > 0:
                    self.corrupt_chunks -= 1
                    b = bytearray(data)
                    b[len(b) // 2] ^= 0xFF
                    data = bytes(b)
                send_msg(sock, {"type": "chunk", "layer": layer, "key": key,
                                "dtype": str(a.dtype), "shape": a.shape,
                                "data": data, "crc": crc})
                with self._lock:
                    self.stats["bytes_served"] += len(data)
            send_msg(sock, {"type": "layer_done", "layer": layer,
                            "nkeys": len(state[layer])})
            with self._lock:
                self.stats["layers_served"] += 1
        send_msg(sock, {"type": "done", "model": model})


# ---------------------------------------------------------------------------
# client side
# ---------------------------------------------------------------------------

class PeerFetcher:
    """One cold start's peer-transfer session.

    Two drain modes share the same connection machinery:

    * :meth:`start_stream` — the racing cold path.  A dedicated daemon
      thread opens the connection, requests the whole model, and hands
      each layer's completed state to ``on_layer`` the moment its last
      chunk verifies, so the race against the local disk chains starts
      at submit time and never occupies a pool worker.  ``should_stop``
      (checked between layers) ends the drain early once every layer is
      decided; any wire failure fires ``on_error`` exactly once and the
      local chains — always racing — take over.
    * :meth:`fetch` — synchronous pull of one layer (tests, the packed-
      params side channel).  Callers take turns draining the stream
      under one lock, buffering other layers' completed state until
      their own lands.

    Every failure mode maps to a typed :class:`FetchFault`; after the
    first failure the session is dead and every subsequent ``fetch``
    fails fast (the race never waits on a broken wire).
    """

    def __init__(self, model: str, endpoints: Iterable[Tuple[str, int]], *,
                 io_engine=None, injector=None, timeout_s: float = 30.0):
        self.model = model
        self.endpoints = list(endpoints)
        self.io_engine = io_engine
        self.injector = injector
        self.timeout_s = timeout_s
        self._lock = threading.Lock()       # serializes the stream drain
        self._sock: Optional[socket.socket] = None
        self._started = False
        self._t_connect = 0.0
        self._failed: Optional[BaseException] = None
        self._accepted: Optional[List[str]] = None
        self._stream_done = False
        self._ready: Dict[str, Dict[str, np.ndarray]] = {}
        self._partial: Dict[str, Dict[str, np.ndarray]] = {}
        self._closed = False
        self._streaming = False
        self._stream_thread: Optional[threading.Thread] = None
        self.stats = {"layers_fetched": 0, "bytes_fetched": 0,
                      "crc_failures": 0, "refused": 0,
                      "measured_bytes_per_s": 0.0}

    # -- session -------------------------------------------------------------
    def _fail(self, err: BaseException) -> BaseException:
        self._failed = err
        self._close_sock()
        return err

    def _close_sock(self) -> None:
        if self._sock is not None:
            try:
                self._sock.close()
            except OSError:
                pass
            self._sock = None

    def close(self) -> None:
        with self._lock:
            self._closed = True
            self._close_sock()

    def _start_locked(self, packed: bool) -> None:
        if self._started:
            return
        self._started = True
        if not self.endpoints:
            raise self._fail(FetchFault(
                f"no peer endpoints for {self.model!r}",
                site="warmstate.fetch"))
        host, port = self.endpoints[0]
        try:
            self._sock = socket.create_connection(
                (host, port), timeout=self.timeout_s)
            self._sock.settimeout(self.timeout_s)
            send_msg(self._sock, {"type": "fetch", "model": self.model,
                                  "layers": None, "packed": packed})
        except OSError as e:
            raise self._fail(FetchFault(
                f"cannot reach peer {host}:{port} for {self.model!r}: {e}",
                site="warmstate.fetch")) from e
        self._t_connect = time.monotonic()
        msg = self._recv()
        if msg.get("type") == "refuse":
            self.stats["refused"] += 1
            raise self._fail(FetchFault(
                f"peer refused {self.model!r}: {msg.get('reason')}",
                site="warmstate.fetch"))
        if msg.get("type") != "accept":
            raise self._fail(FetchFault(
                f"unexpected frame {msg.get('type')!r} from peer",
                site="warmstate.fetch"))
        self._accepted = list(msg.get("layers") or [])

    def _recv(self) -> Dict[str, Any]:
        try:
            msg = recv_msg(self._sock)
        except OSError as e:
            raise self._fail(FetchFault(
                f"peer connection lost mid-stream ({self.model!r}): {e}",
                site="warmstate.fetch")) from e
        if msg is None:
            raise self._fail(FetchFault(
                f"peer closed mid-stream ({self.model!r})",
                site="warmstate.fetch"))
        return msg

    # -- stream draining -----------------------------------------------------
    def _materialize(self, msg: Dict[str, Any]) -> np.ndarray:
        """Chunk payload → array, through the pinned pool + CRC gate."""
        data = msg["data"]
        n = len(data)
        layer = msg.get("layer")
        if self.io_engine is not None:
            charge = self.io_engine.charge(
                n, key=f"{self.model}:{layer}", injector=self.injector)
            try:
                charge.buf.arr[:n] = np.frombuffer(data, dtype=np.uint8)
                view = charge.view(n)
                if int(crc32c(view)) != int(msg["crc"]):
                    self.stats["crc_failures"] += 1
                    raise FetchFault(
                        f"chunk CRC mismatch ({layer}/{msg.get('key')})",
                        site="warmstate.chunk", layer=layer)
                raw = view.tobytes()
            finally:
                charge.release()
        else:
            if _crc(data) != int(msg["crc"]):
                self.stats["crc_failures"] += 1
                raise FetchFault(
                    f"chunk CRC mismatch ({layer}/{msg.get('key')})",
                    site="warmstate.chunk", layer=layer)
            raw = data
        self.stats["bytes_fetched"] += n
        return np.frombuffer(raw, dtype=np.dtype(msg["dtype"])).reshape(
            msg["shape"])

    def _drain_one_locked(self) -> None:
        msg = self._recv()
        t = msg.get("type")
        if t == "chunk":
            try:
                arr = self._materialize(msg)
            except FetchFault as e:
                raise self._fail(e)
            self._partial.setdefault(msg["layer"], {})[msg["key"]] = arr
        elif t == "layer_done":
            self._ready[msg["layer"]] = self._partial.pop(msg["layer"], {})
            self.stats["layers_fetched"] += 1
        elif t == "done":
            self._stream_done = True
            dt = max(time.monotonic() - self._t_connect, 1e-9)
            self.stats["measured_bytes_per_s"] = (
                self.stats["bytes_fetched"] / dt)
            self._close_sock()
        else:
            raise self._fail(FetchFault(
                f"unexpected frame {t!r} mid-stream", site="warmstate.fetch"))

    # -- background streaming (the racing cold path) -------------------------
    def start_stream(self, on_layer, *, on_error=None,
                     should_stop=None) -> bool:
        """Drain the whole model on a background thread.

        ``on_layer(name, {key: array})`` fires (on the stream thread) the
        moment a layer's last chunk verifies; ``should_stop()`` is polled
        between layers and ends the drain early (e.g. every layer already
        decided locally); ``on_error(FetchFault)`` fires at most once for
        any wire failure — a ``close()``d session reports nothing.
        Idempotent: only the first call starts the thread (returns True);
        a dead/closed/already-streaming session returns False."""
        with self._lock:
            if self._closed or self._failed is not None or self._streaming:
                return False
            self._streaming = True
        th = threading.Thread(
            target=self._stream_loop, args=(on_layer, on_error, should_stop),
            name="repro-warmstate-stream", daemon=True)
        self._stream_thread = th
        th.start()
        return True

    def _stream_loop(self, on_layer, on_error, should_stop) -> None:
        err: Optional[BaseException] = None
        try:
            while True:
                delivered: List[Tuple[str, Dict[str, np.ndarray]]] = []
                with self._lock:
                    if self._closed or self._failed is not None:
                        return
                    self._start_locked(False)
                    if self._stream_done:
                        break
                    self._drain_one_locked()
                    for name in list(self._ready):
                        delivered.append((name, self._ready.pop(name)))
                for name, state in delivered:
                    if self.injector is not None:
                        # per-layer chaos point, same site/key scheme as
                        # the synchronous fetch path
                        self.injector.maybe_fault(
                            "warmstate.fetch", f"{self.model}:{name}")
                    on_layer(name, state)
                if delivered and should_stop is not None and should_stop():
                    with self._lock:
                        self._close_sock()
                    return
        except TransientFault as e:
            with self._lock:
                if self._failed is None:
                    self._fail(e)
                suppressed = self._closed
            err = e
            if not suppressed and on_error is not None:
                on_error(e)
        finally:
            if err is None:
                with self._lock:
                    self._close_sock()

    def fetch(self, layer: str, *, packed: bool = False
              ) -> Dict[str, np.ndarray]:
        """Block until ``layer``'s state has streamed in; returns its
        ``{key: array}`` dict.  Raises :class:`FetchFault` on refusal,
        CRC mismatch, disconnect, timeout, or a layer the peer does not
        hold."""
        if self.injector is not None:
            self.injector.maybe_fault(
                "warmstate.fetch", f"{self.model}:{layer}")
        with self._lock:
            if self._closed:
                raise FetchFault(
                    f"fetch session for {self.model!r} already closed",
                    site="warmstate.fetch")
            if self._failed is not None:
                raise FetchFault(
                    f"fetch session for {self.model!r} already failed: "
                    f"{self._failed}", site="warmstate.fetch",
                    layer=layer) from self._failed
            self._start_locked(packed)
            if layer in self._ready:
                return self._ready.pop(layer)
            if self._accepted is not None and layer not in self._accepted:
                raise FetchFault(
                    f"peer does not hold {layer!r} of {self.model!r}",
                    site="warmstate.fetch", layer=layer)
            while not self._stream_done:
                self._drain_one_locked()
                if layer in self._ready:
                    return self._ready.pop(layer)
            raise self._fail(FetchFault(
                f"stream ended without {layer!r} of {self.model!r}",
                site="warmstate.fetch", layer=layer))

    def fetch_packed(self) -> Dict[str, np.ndarray]:
        """Packed decode params (``__packed__``), when the peer has them."""
        return self.fetch(PACKED_LAYER, packed=True)
