"""Cold-LLM bridge: engine-streamed prefill → BatchedServer decode.

A cold LLM start becomes a first-token-optimal pipeline:

  1. the cold task graph streams block weights from disk and *executes the
     prefill as layers stage* (execute-as-you-load): early blocks compute
     the prompt while later blocks are still being read/transformed — the
     first token is sampled from the streamed prefill's logits;
  2. per-layer ``pack`` tasks — appended to the same task graph — convert
     each block's staged weights into the ``BatchedServer``'s decode param
     layout (deployed dtype, T-format pytree). A layer's pack depends on
     its *execute*, never just its stage: decode-path packing must not
     compete with the critical exec chain for the first token, so the last
     layer's decode prep always completes after the first token is out;
  3. once every pack landed, the stacked decode params feed a
     ``BatchedServer`` that replays the prompt (+ the already-emitted first
     token) into a KV slot and continues decoding.

The result records the first-token timestamp against the job clock next to
the prep/pack trace ends, so serving benchmarks can gate the headline
claim: the first token is emitted before the last layer's (decode-path)
prep completes, with prefill overlapping weight preparation layer-
granularly.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import quant
from repro.configs.base import ArchConfig
from repro.core.engine import ColdEngine
from repro.core.pipeline import RunResult
from repro.executor.graph import PREP_KINDS
from repro.serving.server import BatchedServer, Request


@dataclass
class ColdLLMResult:
    tokens: List[int]                 # first token + decoded continuation
    first_token: int
    first_token_s: float              # job clock: streamed-prefill logits out
    last_weight_prep_s: float         # last read/transform/stage trace end
    decode_prep_s: float              # last 'pack' end (per-layer decode prep)
    decode_ready_s: float             # params stacked + KV slot prefilled
    overlapped_layers: int            # preps still unfinished at first execute
    overlapped_packs: int             # packs started before the exec chain ended
    run: RunResult = field(repr=False, default=None)

    @property
    def first_token_before_last_prep(self) -> bool:
        """Token 1 precedes the completion of the last layer's decode-path
        prep. NOTE: this holds *by scheduling policy* (each pack depends on
        its layer's execute, so packing can never delay the exec chain) —
        it documents the policy, it is not evidence of overlap. The
        overlap evidence is ``overlapped_layers`` (weight preps in flight
        when the exec chain started) and ``overlapped_packs`` (decode-path
        packs running concurrently with the exec chain)."""
        return self.first_token_s < self.decode_prep_s


def _expand_quantized(w: Dict[str, Any],
                      logical_shapes: Dict[str, tuple]) -> Dict[str, Any]:
    """Quantized cache entries stage as companion groups (``base:q8`` /
    ``base:q4`` + ``base:qscale``). The BatchedServer decode path wants the
    logical tensors, so packing dequantizes them here; the quantized form
    only serves the cold read + streamed prefill. ``logical_shapes`` (from
    the layer spec) recovers an odd K that int4 packing rounded up."""
    if not quant.is_quantized(w):
        return w
    groups, rest = quant.split_groups(w)
    for base in groups:
        rest[base] = quant.dequantize_weight(w, base,
                                             logical_shapes.get(base))
    return rest


def _pack_params(cfg: ArchConfig, packed: Dict[str, Dict[str, Any]]):
    """Stack per-layer packed weights into the T-format decode pytree."""
    blocks = []
    for i in range(cfg.num_layers):
        w = packed[f"block{i:03d}"]
        attn = {k: w[k] for k in ("wq", "wk", "wv", "wo")}
        if cfg.qk_norm:
            attn["q_norm"], attn["k_norm"] = w["q_norm"], w["k_norm"]
        blocks.append({"ln1": w["ln1"], "ln2": w["ln2"], "attn": attn,
                       "mlp": {k: w[k]
                               for k in ("w_gate", "w_up", "w_down")}})
    params: Dict[str, Any] = {
        "embed": packed["embed"]["embed"],
        "final_norm": packed["lm_head"]["final_norm"],
        "blocks": jax.tree.map(lambda *xs: jnp.stack(xs), *blocks),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = packed["lm_head"]["w"]
    return params


def cold_start_llm(
    engine: ColdEngine,
    cfg: ArchConfig,
    prompt: np.ndarray,               # (S,) int32 token ids
    *,
    max_new_tokens: int = 8,
    n_little: int = 3,
    server: Optional[Any] = None,     # ColdServer for admission (optional)
    model_name: Optional[str] = None,
) -> ColdLLMResult:
    """Cold-start a ``build_llm_graph`` engine and serve ``max_new_tokens``
    greedily; see the module docstring for the pipeline."""
    assert engine.plan is not None, "decide() first"
    prompt = np.asarray(prompt, np.int32).reshape(-1)
    x = prompt[None, :]
    dtype = jnp.dtype(cfg.dtype)
    packed: Dict[str, Dict[str, Any]] = {}
    shapes = {l.spec.name: l.spec.weight_shapes for l in engine.layers}

    def hook(graph, weights, lock):
        # decode-path packing: one task per weighted layer, scheduled after
        # the layer's execute so it never delays the exec chain; 'any'
        # affinity — idle littles pack early blocks while later blocks
        # still prep/execute
        for t in [t for t in graph.tasks if t.kind == "execute"]:
            name = t.layer

            def fn(name=name):
                with lock:
                    w = weights.get(name) or {}
                w = _expand_quantized(w, shapes.get(name) or {})
                packed[name] = {k: jnp.asarray(v, dtype)
                                for k, v in w.items()}

            if graph.task(name, "stage") is not None:   # weighted layers only
                graph.add(name, "pack", affinity="any", deps=(t.tid,), fn=fn)

    if server is not None:
        ticket = server.cold_start(model_name, x, n_little=n_little,
                                   graph_hook=hook)
        job, res = ticket.job, ticket.result()
    else:
        job = engine.submit_cold(x, n_little=n_little, graph_hook=hook)
        res = job.result()

    logits = np.asarray(res.output)                  # (1, S, V) float32
    first_token = int(np.argmax(logits[0, -1]))
    exec_traces = [t for t in res.traces if t.kind == "execute"]
    first_token_s = max(t.end for t in exec_traces)
    first_exec_start = min(t.start for t in exec_traces)
    prep_traces = [t for t in res.traces if t.kind in PREP_KINDS]
    last_weight_prep_s = max(t.end for t in prep_traces)
    pack_traces = [t for t in res.traces if t.kind == "pack"]
    decode_prep_s = max(t.end for t in pack_traces)
    overlapped = sum(1 for t in prep_traces if t.end > first_exec_start)
    overlapped_packs = sum(1 for t in pack_traces if t.start < first_token_s)

    # packed decode params are now "present" on this worker: register them
    # with the ColdServer so sibling workers' warm-state fetches can ride
    # them over the transfer stream (the ``__packed__`` pseudo-layer),
    # flattened to "layer/key" so they cross the wire as plain arrays
    if server is not None and model_name is not None:
        flat = {f"{lname}/{k}": np.asarray(v)
                for lname, kv in packed.items() for k, v in kv.items()}
        server.register_packed_state(model_name, flat)

    # decode continuation: stack params, replay prompt + token 1 into a KV
    # slot, decode the rest greedily; the KV allocation draws from the
    # ColdServer's shared memory budget when one is serving this request
    params = _pack_params(cfg, packed)
    srv = BatchedServer(params, cfg, max_batch=1,
                        max_len=int(prompt.size + max_new_tokens + 2),
                        budget=(server.budget if server is not None
                                else None))
    tokens = [first_token]
    if max_new_tokens > 1:
        req = Request(rid=0,
                      prompt=np.concatenate([prompt, [first_token]]),
                      max_new_tokens=max_new_tokens - 1)
        srv.submit(req)
        srv.step()       # admit: replays the prompt into the KV slot
        # decode-ready = params stacked + KV slot prefilled (NOT the full
        # decode drain — that scales with max_new_tokens)
        decode_ready_s = time.perf_counter() - job.t0
        srv.run_until_drained()
        assert req.done_s is not None, "decode did not drain"
        tokens += [int(tk) for tk in req.out_tokens]
    else:
        decode_ready_s = time.perf_counter() - job.t0
    srv.close()     # return the KV reservation to the shared budget

    return ColdLLMResult(
        tokens=tokens, first_token=first_token,
        first_token_s=first_token_s,
        last_weight_prep_s=last_weight_prep_s,
        decode_prep_s=decode_prep_s, decode_ready_s=decode_ready_s,
        overlapped_layers=overlapped, overlapped_packs=overlapped_packs,
        run=res,
    )
