"""Front-door worker entrypoint — one supervised ColdServer per process.

``python -m repro.executor.worker --host H --port P --worker-id W ...``
connects back to the front door's listener, says hello, and serves the
RPC protocol from :mod:`repro.executor.frontdoor`: ``add_model`` builds
the model from its ``module:function`` builder spec and registers it
(reloading the shared profile DB first, so every worker resolves the
same plan the first worker measured), ``cold_start`` serves a request
(warm path first, then an admitted cold start under the propagated
deadline), and a background thread heartbeats the server's serializable
``health()`` snapshot. Faults cross back typed via ``describe()``.

Two serving refinements live here rather than in the ColdServer:

  * **warm-run coalescing** — same-model requests that queue up while a
    warm drain is running are batched into ONE ``warm_run_many`` sweep
    (one per-layer walk serves all of them) instead of N serial runs;
  * **peer warm-state transfer** — a ``WarmStateServer`` listens on its
    own port (reported in the hello and every heartbeat) serving this
    worker's resident staged weights to siblings, and the ``peers`` list
    the front door attaches to a ``cold_start`` is handed to
    ``ColdServer.cold_start``, which races a peer fetch against the
    local disk chains when the transfer estimate wins
    (``docs/warm_transfer.md``).

The process is designed to be killed: all state it owns (store, plan,
profile entries) is either re-derivable or persisted, and the front door
replays in-flight requests on a sibling.
"""
from __future__ import annotations

import argparse
import importlib
import os
import socket
import sys
import threading
from pathlib import Path

from repro.executor.frontdoor import recv_msg, send_msg
from repro.faults import Fault


def _build(spec):
    mod_name, _, fn_name = spec["builder"].partition(":")
    fn = getattr(importlib.import_module(mod_name), fn_name)
    return fn(**(spec.get("kwargs") or {}))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--host", default="127.0.0.1")
    ap.add_argument("--port", type=int, required=True)
    ap.add_argument("--worker-id", required=True)
    ap.add_argument("--root", required=True)
    ap.add_argument("--profile-db", default=None)
    ap.add_argument("--heartbeat-interval", type=float, default=0.2)
    ap.add_argument("--n-little", type=int, default=2)
    ap.add_argument("--n-big", type=int, default=1)
    ap.add_argument("--max-concurrent-preps", type=int, default=2)
    ap.add_argument("--pin-cores", action="store_true")
    ap.add_argument("--store-fmt", default=None,
                    help="layer-store format for registered models "
                         "(e.g. 'super' to get measured local-read-bytes "
                         "accounting; default: the engine's default)")
    ap.add_argument("--sim-disk-bytes-per-s", type=float, default=None,
                    help="emulate an edge flash device: pace local store "
                         "reads to this shared bandwidth (CI hosts serve "
                         "the store from page cache at memory speed; the "
                         "warm-transfer gate needs disk time to be real)")
    args = ap.parse_args(argv)

    # imports deferred past argparse so --help stays instant
    import numpy as np

    from repro.core.profiler import ProfileDB
    from repro.executor.pool import CorePool
    from repro.executor.server import ColdServer
    from repro.executor.warmstate import WarmStateServer

    if args.sim_disk_bytes_per_s:
        from repro.ioengine import get_io_engine
        get_io_engine().set_sim_read_bandwidth(args.sim_disk_bytes_per_s)

    # warm the JAX backend now, not inside the first request: lazy backend
    # init costs ~300ms and would otherwise land inside the first cold
    # start's submit path — dwarfing the job itself and skewing the
    # warm-state race (the peer stream would start ~300ms late)
    import jax.numpy as jnp
    jnp.zeros(()).block_until_ready()

    pool = CorePool(n_little=args.n_little, n_big=args.n_big,
                    pin_cores=args.pin_cores)
    server = ColdServer(args.root, pool=pool, n_little=args.n_little,
                        max_concurrent_preps=args.max_concurrent_preps,
                        share_profile_db=args.profile_db is None)
    # peer warm-state transfer endpoint: siblings cold-start this worker's
    # resident models straight out of our RAM (docs/warm_transfer.md)
    warm = WarmStateServer(server)
    sock = socket.create_connection((args.host, args.port))
    sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    send_lock = threading.Lock()
    send_msg(sock, {"type": "hello", "worker": args.worker_id,
                    "pid": os.getpid(), "warm_port": warm.port}, send_lock)

    examples = {}          # model -> x_example (for restart-side decide)
    stop = threading.Event()

    def heartbeat():
        while not stop.wait(args.heartbeat_interval):
            try:
                health = server.health()
                health["warm_port"] = warm.port
                health["warmstate"] = dict(warm.stats)
                send_msg(sock, {"type": "heartbeat",
                                "worker": args.worker_id,
                                "health": health}, send_lock)
            except OSError:
                return  # front door gone: exit quietly

    threading.Thread(target=heartbeat, name="worker-heartbeat",
                     daemon=True).start()

    def handle_add_model(msg):
        name = msg["name"]
        try:
            if args.profile_db is not None:
                # reload the SHARED db so measurements a sibling saved
                # since our startup are visible — this is what makes every
                # worker resolve the same plan (bit-identical failover)
                server.profile_db = ProfileDB(Path(args.profile_db))
            layers, x = _build(msg)
            examples[name] = x
            if name not in server.engines:
                engine_kw = ({"store_fmt": args.store_fmt}
                             if args.store_fmt else {})
                server.add_model(name, layers, **engine_kw)
            plan_path = server.root / name / "plan.json"
            if plan_path.exists():   # restart: reuse the persisted plan
                server.engines[name].ensure_plan(x, n_little=args.n_little)
            else:
                server.decide(name, x)
            send_msg(sock, {"type": "model_ready", "name": name}, send_lock)
        except Exception as e:
            send_msg(sock, {"type": "error", "rid": None, "name": name,
                            "fault": _fault_dict(e)}, send_lock)

    def _send_result(msg, res, *, warm, batched=1):
        send_msg(sock, {"type": "result", "rid": msg["rid"],
                        "worker": args.worker_id, "warm": warm,
                        "batched": batched,
                        "output": np.asarray(res.output),
                        "total_s": res.total_s}, send_lock)

    def _send_error(msg, e):
        try:
            send_msg(sock, {"type": "error", "rid": msg["rid"],
                            "fault": _fault_dict(e)}, send_lock)
        except OSError:
            pass

    def _cold_one(msg):
        """One admitted cold start; ``peers`` (attached by the front door)
        arms the warm-state fetch race when the transfer estimate wins."""
        try:
            res = server.cold_start(
                msg["model"], msg["x"],
                deadline_s=msg.get("deadline_s"),
                peers=msg.get("peers")).result()
            _send_result(msg, res, warm=False)
        except Exception as e:
            _send_error(msg, e)

    # warm-run coalescing: requests for a model with an active drainer
    # enqueue and return — the drainer serves every queued same-model
    # request in ONE warm_run_many sweep (the BatchedServer drain pattern)
    warm_pending = {}      # model -> [msg, ...]
    warm_draining = set()  # models with an active drainer thread
    warm_lock = threading.Lock()

    def handle_cold_start(msg):
        model = msg["model"]
        with warm_lock:
            warm_pending.setdefault(model, []).append(msg)
            if model in warm_draining:
                return
            warm_draining.add(model)
        while True:
            with warm_lock:
                batch = warm_pending.pop(model, [])
                if not batch:
                    warm_draining.discard(model)
                    return
            try:
                results = server.warm_run_many(model,
                                               [m["x"] for m in batch])
            except Exception as e:
                for m in batch:
                    _send_error(m, e)
                continue
            if results is not None:
                for m, res in zip(batch, results):
                    try:
                        _send_result(m, res, warm=True,
                                     batched=len(batch))
                    except OSError:
                        pass
                continue
            # not resident: each request cold-starts on its own thread
            # (admission blocks; the drainer must keep draining)
            for m in batch:
                threading.Thread(target=_cold_one, args=(m,),
                                 name=f"worker-req-{m.get('rid')}",
                                 daemon=True).start()

    def _fault_dict(e):
        if isinstance(e, Fault):
            return e.describe()
        return {"type": type(e).__name__, "msg": f"{type(e).__name__}: {e}"}

    while True:
        try:
            msg = recv_msg(sock)
        except Exception:
            msg = None
        if msg is None:
            break   # front door hung up
        t = msg.get("type")
        if t == "add_model":
            handle_add_model(msg)
        elif t == "cold_start":
            # own thread: cold starts block at admission and must not
            # stall the recv loop (or each other)
            threading.Thread(target=handle_cold_start, args=(msg,),
                             name=f"worker-req-{msg.get('rid')}",
                             daemon=True).start()
        elif t == "drain":
            ok = server.drain(timeout=msg.get("timeout_s"))
            try:
                send_msg(sock, {"type": "drained", "ok": ok}, send_lock)
            except OSError:
                break
        elif t == "shutdown":
            break
    stop.set()
    warm.close()
    try:
        sock.close()
    except OSError:
        pass
    pool.shutdown()
    return 0


if __name__ == "__main__":
    sys.exit(main())
