"""ColdServer — multi-model cold serving on one persistent core pool.

The server owns N ``ColdEngine``s (one per model, each with its own store
under the server root) and shares across all of them:

  * the process-wide ``CorePool`` — one set of big/little workers serves
    every model's prep chains and exec chains, with per-job accounting;
  * one user-level ``ProfileDB`` — a second model whose layers fall into
    already-measured shape classes performs zero profile calls;
  * an **admission controller**: §3.2 measures I/O interference between
    co-running preparation ops *per host*, so the number of cold starts
    simultaneously in their prep phase is capped (``max_concurrent_preps``);
    further cold starts queue at admission and enter as slots free
    (released the moment a job's last read/transform/stage finishes —
    its exec tail does not hold the slot);
  * an **LRU residency budget**: finished cold starts leave their staged
    weights device-resident for warm reuse; when the total exceeds
    ``memory_budget_bytes`` the least-recently-used model's weights are
    evicted (its next request is simply cold again);
  * the process-wide **async I/O engine** (``repro.ioengine``): every
    engine's prep reads flow through one submit/reap queue, so the server
    can cap *bytes in flight* across all co-admitted cold starts
    (``max_read_bytes_in_flight``) — the byte-granular complement to the
    job-granular prep-slot semaphore — and use the engine's idle signal
    (no reads in flight) to run bounded incremental store compaction
    exactly when the disk has nothing better to do.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence

import jax
import numpy as np

from repro.core.engine import ColdEngine, LayerDef
from repro.core.pipeline import PipelineJob, RunResult
from repro.core.profiler import ProfileDB
from repro.core.scheduler import transfer_estimate
from repro.executor.pool import CorePool, get_core_pool
from repro.executor.warmstate import PACKED_LAYER, PeerFetcher
from repro.faults import DeadlineExceeded, ModelQuarantined


def _weights_nbytes(weights: Optional[Dict[str, Any]]) -> int:
    total = 0
    for w in (weights or {}).values():
        for v in w.values():
            total += int(getattr(v, "nbytes", 0))
    return total


class MemoryBudget:
    """One accounted device-memory pool shared by every consumer.

    The ColdServer's staged-weight LRU and the LLM ``BatchedServer``'s
    KV-cache allocator both draw from this single pool: each ``reserve``
    is tagged, and when a reservation would overflow ``total_bytes`` the
    registered evictors (the ColdServer's LRU) free least-recently-used
    staged weights first.  ``reserve`` never refuses — a KV allocation is
    a correctness requirement — it evicts what it can and returns whether
    the pool is still within budget, so callers (and the warm-state
    transfer server's memory-pressure refusal) can see the overcommit.
    ``total_bytes=None`` disables the cap but keeps the accounting."""

    def __init__(self, total_bytes: Optional[int] = None):
        self.total = (None if total_bytes is None else int(total_bytes))
        self._lock = threading.Lock()
        self._used: Dict[str, int] = {}
        self._evictors: List[Callable[[int], int]] = []

    def add_evictor(self, cb: Callable[[int], int]) -> None:
        """``cb(need_bytes) -> freed_bytes``; must not call ``reserve``."""
        self._evictors.append(cb)

    def used(self) -> int:
        with self._lock:
            return sum(self._used.values())

    def used_by(self, tag: str) -> int:
        with self._lock:
            return int(self._used.get(tag, 0))

    def over_budget(self) -> bool:
        return self.total is not None and self.used() > self.total

    def charge(self, tag: str, nbytes: int) -> None:
        """Unconditional accounting (no eviction)."""
        with self._lock:
            self._used[tag] = self._used.get(tag, 0) + int(nbytes)

    def release(self, tag: str, nbytes: Optional[int] = None) -> None:
        with self._lock:
            if nbytes is None:
                self._used.pop(tag, None)
            else:
                left = self._used.get(tag, 0) - int(nbytes)
                if left > 0:
                    self._used[tag] = left
                else:
                    self._used.pop(tag, None)

    def reserve(self, tag: str, nbytes: int) -> bool:
        """Charge ``nbytes`` to ``tag``, evicting LRU state to make room.
        True = within budget afterwards; False = overcommitted (charged
        anyway — the evictors could not free enough)."""
        nbytes = int(nbytes)
        if self.total is None:
            self.charge(tag, nbytes)
            return True
        while True:
            with self._lock:
                if sum(self._used.values()) + nbytes <= self.total:
                    self._used[tag] = self._used.get(tag, 0) + nbytes
                    return True
                need = sum(self._used.values()) + nbytes - self.total
            freed = 0
            for ev in self._evictors:
                try:
                    freed += ev(need - freed)
                except Exception:
                    continue
                if freed >= need:
                    break
            if freed <= 0:
                self.charge(tag, nbytes)
                return False

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {"total": self.total,
                    "used": sum(self._used.values()),
                    "by_tag": dict(self._used)}


class ColdStart:
    """Handle for one admitted cold-start request."""

    def __init__(self, server: "ColdServer", model: str, job: PipelineJob):
        self.server = server
        self.model = model
        self.job = job

    @property
    def traces(self):
        return self.job.traces

    def done(self) -> bool:
        return self.job.done()

    def result(self, timeout: Optional[float] = None) -> RunResult:
        try:
            res = self.job.result(timeout)
        except TimeoutError:
            raise  # caller-side wait timeout (JobTimeout), not a model
            #        failure — the admission slot releases when the job's
            #        prep phase ends on its own
        except DeadlineExceeded:
            raise  # deadline pressure (watchdog expiry), not model
            #        sickness: quarantining here would punish a healthy
            #        model for an over-tight budget
        except Exception as e:
            self.server._record_model_failure(self.model, e)
            raise
        self.server._register_resident(self.model, res)
        self.server._clear_model_failure(self.model)
        return res


class ColdServer:
    def __init__(
        self,
        root,
        *,
        pool: Optional[CorePool] = None,
        n_little: int = 3,
        n_big: int = 2,
        max_concurrent_preps: int = 2,
        memory_budget_bytes: Optional[int] = None,
        share_profile_db: bool = True,
        quarantine_base_s: float = 0.5,
        quarantine_max_s: float = 30.0,
        io_engine: Any = "auto",
        max_read_bytes_in_flight: Optional[int] = None,
        idle_compaction: bool = True,
        idle_compaction_min_interval_s: float = 0.25,
        budget: Optional[MemoryBudget] = None,
    ):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.pool = pool or get_core_pool(n_little=n_little, n_big=n_big)
        self.n_little = n_little
        self.max_concurrent_preps = max_concurrent_preps
        # one accounted device-memory pool: staged-weight residency (this
        # server's LRU), packed decode params, and — when the same budget
        # is handed to a BatchedServer — KV-cache growth all draw from it
        self.budget = budget if budget is not None \
            else MemoryBudget(memory_budget_bytes)
        self.budget.add_evictor(self._evict_for_budget)
        # one user-level profile DB shared by every managed engine: sibling
        # models with equivalent shape classes skip profiling entirely
        # (NOTE: ``memory_budget_bytes`` is a live property over
        # ``budget.total`` — assigning it retunes the shared pool)
        self.profile_db: Optional[ProfileDB] = (
            ProfileDB(self.root / "profile_db.json") if share_profile_db
            else None)
        self.engines: Dict[str, ColdEngine] = {}
        self._admission = threading.Semaphore(max_concurrent_preps)
        self._lock = threading.Lock()
        self._resident: "OrderedDict[str, int]" = OrderedDict()  # name->bytes
        self._resident_weights: Dict[str, Dict[str, Any]] = {}
        # per-model quarantine after failed cold starts: exponential backoff
        # keeps a sick model from burning admission slots on doomed retries
        self.quarantine_base_s = quarantine_base_s
        self.quarantine_max_s = quarantine_max_s
        self._model_quarantine: Dict[str, Dict[str, float]] = {}
        self.stats = {"admitted": 0, "evictions": 0, "active_preps": 0,
                      "max_active_preps": 0, "cold_starts": 0,
                      "load_failures": 0, "quarantined": 0,
                      "idle_compactions": 0, "idle_compaction_bytes": 0,
                      "idle_reprofiles": 0, "warm_runs": 0,
                      "warm_batches": 0, "peer_races": 0,
                      "peer_races_declined": 0, "peer_layers_fetched": 0,
                      "peer_bytes_fetched": 0, "peer_crc_failures": 0,
                      "peer_refusals": 0, "transfers_served": 0,
                      "transfer_refusals": 0}
        # packed decode params (LLM bridge) by model — servable over the
        # warm-state channel under the reserved ``__packed__`` pseudo-layer
        self._packed_state: Dict[str, Dict[str, Any]] = {}
        # peer link bandwidth EWMA, seeded by the first measured transfer;
        # feeds the same transfer_estimate the front door routes with
        self._link_bw: Optional[float] = None
        # test/operator lever: refuse every warm-state transfer request
        self.refuse_transfers = False
        # graceful drain (front-door worker handoff): _draining refuses new
        # admissions; _outstanding counts in-flight cold starts end-to-end
        # (admission -> job done), so drain() can wait the tail out
        self._draining = False
        self._outstanding = 0
        self._drain_cv = threading.Condition(self._lock)
        self._served: Dict[str, int] = {}   # model -> completed requests
        # shared async I/O engine: byte-budget admission + idle compaction.
        # "auto" binds the process-wide engine; False/None runs without one
        # (engines fall back to their own resolution / the sync path).
        if io_engine == "auto":
            from repro.ioengine import get_io_engine

            self.io_engine = get_io_engine()
        else:
            self.io_engine = io_engine or None
        if self.io_engine is not None and max_read_bytes_in_flight is not None:
            self.io_engine.set_max_bytes_in_flight(max_read_bytes_in_flight)
        # idle-tick incremental compaction: when the engine's read queue
        # drains, give ONE store (round-robin) one bounded background
        # maintain() pass — dead super-bundle extents get reclaimed in the
        # gaps between cold starts instead of stalling a decide()
        self._idle_min_interval = float(idle_compaction_min_interval_s)
        self._idle_last = 0.0
        self._idle_rr = 0
        self._idle_busy = False
        self._idle_compaction = bool(idle_compaction)
        if self.io_engine is not None and idle_compaction:
            self.io_engine.add_idle_callback(self._on_io_idle)

    # -- model management ---------------------------------------------------
    def add_model(self, name: str, layers: List[LayerDef],
                  **engine_kw) -> ColdEngine:
        if name in self.engines:
            raise ValueError(f"model {name!r} already added")
        engine_kw.setdefault("pool", self.pool)
        if self.profile_db is not None:
            engine_kw.setdefault("profile_db", self.profile_db)
        if self.io_engine is not None:
            engine_kw.setdefault("io_engine", self.io_engine)
        eng = ColdEngine(layers, self.root / name, **engine_kw)
        self.engines[name] = eng
        return eng

    def decide(self, name: str, x_example, **kw) -> Dict[str, Any]:
        kw.setdefault("n_little", self.n_little)
        return self.engines[name].decide(x_example, **kw)

    # -- serving ------------------------------------------------------------
    def cold_start(self, name: str, x, *, n_little: Optional[int] = None,
                   graph_hook=None, deadline_s: Optional[float] = None,
                   peers: Optional[Sequence[Dict[str, Any]]] = None,
                   ) -> ColdStart:
        """Admit one cold-start request (blocks while ``max_concurrent_preps``
        jobs are in their prep phase) and submit its task graph.

        ``deadline_s`` is the request's remaining end-to-end budget — it
        becomes the job's watchdog deadline (typed ``DeadlineExceeded``
        once blown), and a budget already too small to cover the queue is
        shed HERE, before the admission semaphore is touched.

        ``peers`` lists sibling workers holding this model resident
        (``{"host", "port", "resident_bytes", "link_bytes_per_s"?}``).
        When the best peer's ``transfer_estimate`` beats the plan's local
        cold estimate, the job is armed with a :class:`PeerFetcher` and
        every local prep chain races a ``fetch_remote`` task — see
        ``docs/warm_transfer.md``."""
        eng = self.engines[name]
        now = time.monotonic()
        with self._lock:
            if self._draining:
                raise RuntimeError(f"server draining: {name!r} refused")
            q = self._model_quarantine.get(name)
            if q is not None and now < q["until"]:
                self.stats["quarantined"] += 1
                retry_after = q["until"] - now
                raise ModelQuarantined(
                    f"model {name!r} quarantined after "
                    f"{int(q['fails'])} failed cold start(s); retry in "
                    f"{retry_after:.2f}s", retry_after=retry_after)
        if deadline_s is not None and deadline_s <= 0:
            raise DeadlineExceeded(
                f"request for {name!r} arrived with no budget left "
                f"({deadline_s:.3f}s) — shed before admission")
        # degradation ladder: a missing/corrupt offline decision falls back
        # to a validated plan.json reload or the default heuristic plan —
        # the request proceeds degraded instead of failing admission
        eng.ensure_plan(x, n_little=n_little or self.n_little)
        t_admit = time.monotonic()
        self._admission.acquire()
        # the admission wait itself consumed budget; what reaches the pool
        # watchdog is the REMAINING slice (shed typed if it went negative)
        if deadline_s is not None:
            deadline_s -= time.monotonic() - t_admit
            if deadline_s <= 0:
                self._admission.release()
                raise DeadlineExceeded(
                    f"request for {name!r} spent its whole budget queued "
                    f"at admission — shed before its prep started")
        with self._lock:
            self.stats["admitted"] += 1
            self.stats["cold_starts"] += 1
            self.stats["active_preps"] += 1
            self.stats["max_active_preps"] = max(
                self.stats["max_active_preps"], self.stats["active_preps"])
            self._outstanding += 1
            self._served[name] = self._served.get(name, 0) + 1
        peer_fetch = self._maybe_peer_fetch(name, peers) if peers else None
        try:
            job = eng.submit_cold(x, n_little=n_little or self.n_little,
                                  graph_hook=graph_hook,
                                  deadline_s=deadline_s,
                                  peer_fetch=peer_fetch)
        except BaseException:
            if peer_fetch is not None:
                peer_fetch.close()
            self._release_prep_slot()
            self._request_done()
            raise
        job.job.add_preps_callback(lambda _job: self._release_prep_slot())
        job.job.add_done_callback(lambda _job: self._request_done())
        if peer_fetch is not None:
            job.job.add_done_callback(
                lambda _job: self._note_fetch_stats(peer_fetch))
        return ColdStart(self, name, job)

    # -- peer warm-state transfer (docs/warm_transfer.md) --------------------
    def _maybe_peer_fetch(self, name: str,
                          peers: Sequence[Dict[str, Any]]
                          ) -> Optional[PeerFetcher]:
        """Arm the fetch race iff the best peer's transfer estimate beats
        the plan's local cold estimate — the SAME ``transfer_estimate``
        arithmetic the front door routes with, so routing and execution
        never disagree about when a transfer is worth it."""
        eng = self.engines[name]
        with self._lock:
            link_bw = self._link_bw
        best = None
        for p in peers:
            bw = float(p.get("link_bytes_per_s") or link_bw or 0.0)
            est = transfer_estimate(int(p.get("resident_bytes") or 0), bw)
            if best is None or est < best[0]:
                best = (est, p)
        if best is None:
            return None
        # local cold estimate: the plan's simulated makespan (read +
        # transform + stage + exec). 0.0 = fallback/degraded plan, cost
        # unknown — peer RAM almost always beats cold disk, so arm.
        local_est = float(eng.plan.est_makespan) if eng.plan else 0.0
        if local_est > 0.0 and best[0] >= local_est:
            with self._lock:
                self.stats["peer_races_declined"] += 1
            return None
        with self._lock:
            self.stats["peer_races"] += 1
        host, port = best[1]["host"], int(best[1]["port"])
        return PeerFetcher(name, [(host, port)], io_engine=self.io_engine,
                           injector=eng.fault_injector)

    def _note_fetch_stats(self, pf: PeerFetcher) -> None:
        """Job-done hook: fold the race's outcome into the server stats and
        the link-bandwidth EWMA the next routing decision uses."""
        s = pf.stats
        with self._lock:
            self.stats["peer_layers_fetched"] += int(s["layers_fetched"])
            self.stats["peer_bytes_fetched"] += int(s["bytes_fetched"])
            self.stats["peer_crc_failures"] += int(s["crc_failures"])
            self.stats["peer_refusals"] += int(s["refused"])
            bw = float(s.get("measured_bytes_per_s") or 0.0)
            if bw > 0.0:
                self._link_bw = (bw if self._link_bw is None
                                 else 0.7 * self._link_bw + 0.3 * bw)

    def _request_done(self):
        with self._drain_cv:
            self._outstanding -= 1
            self._drain_cv.notify_all()

    # -- graceful drain (front-door worker handoff) --------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Refuse new admissions and wait for every in-flight cold start to
        finish. True = fully drained; False = requests still running at
        ``timeout`` (the supervisor escalates to a hard stop). Idempotent;
        ``resume()`` reopens admission."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._drain_cv:
            self._draining = True
            while self._outstanding > 0:
                left = (None if deadline is None
                        else deadline - time.monotonic())
                if left is not None and left <= 0:
                    return False
                self._drain_cv.wait(left)
        return True

    def resume(self) -> None:
        with self._lock:
            self._draining = False

    def _release_prep_slot(self):
        with self._lock:
            self.stats["active_preps"] -= 1
        self._admission.release()
        # the engine's idle edge usually lands while this job's transform/
        # stage tail is still running (active_preps > 0, tick skipped) —
        # re-check when the prep phase itself ends
        if self.io_engine is not None and self._idle_compaction \
                and self.io_engine.reads_in_flight() == 0:
            self._on_io_idle()

    # -- idle-tick incremental compaction ------------------------------------
    def _on_io_idle(self):
        """Engine idle signal (reads in flight hit zero): run ONE bounded
        background ``maintain()`` pass on the next store, round-robin, that
        has reclaimable dead extents. Rate-limited so a bursty
        submit/drain/submit pattern cannot thrash compactions; skipped
        entirely while a previous idle compaction is still running or any
        cold start is mid-prep (its reads resume in a moment — the disk is
        not actually idle)."""
        now = time.monotonic()
        with self._lock:
            if (self._idle_busy or self.stats["active_preps"] > 0
                    or now - self._idle_last < self._idle_min_interval):
                return
            self._idle_busy = True
            names = list(self.engines)
            rr = self._idle_rr
        # off the engine's completion thread: a compaction must never delay
        # the reap of reads submitted right after the idle edge
        threading.Thread(target=self._idle_tick, args=(names, rr),
                         name="repro-idle-compact", daemon=True).start()

    def _idle_tick(self, names: List[str], rr: int):
        reclaimed = 0
        ticked = False
        reprofiled = 0
        try:
            for off in range(len(names)):
                name = names[(rr + off) % len(names)]
                store = self.engines[name].store
                try:
                    out = store.maintain(background=True)
                    # bounded per tick: at most one store's compaction, and
                    # we join it here so "busy" covers the whole pass
                    joined = store.maintain_wait()
                except Exception:
                    continue  # sick store: quarantine handles it elsewhere
                if out.get("compacted"):
                    reclaimed = int((joined or out).get(
                        "reclaimed_bytes", 0))
                    ticked = True
                    rr = (rr + off + 1) % len(names)
                    break
            # host-fingerprint drift: re-measure ONE stale shape class per
            # idle tick (round-robin over engines) — profiling happens in
            # the gaps between cold starts, never on the request path
            for off in range(len(names)):
                eng = self.engines[names[(rr + off) % len(names)]]
                try:
                    reprofiled = eng.reprofile_stale(max_classes=1)
                except Exception:
                    continue  # advisory refresh; the stale estimate serves
                if reprofiled:
                    break
        finally:
            with self._lock:
                self._idle_busy = False
                self._idle_last = time.monotonic()
                self._idle_rr = rr
                if ticked:
                    self.stats["idle_compactions"] += 1
                    self.stats["idle_compaction_bytes"] += reclaimed
                if reprofiled:
                    self.stats["idle_reprofiles"] += reprofiled

    # -- model quarantine ---------------------------------------------------
    def _record_model_failure(self, name: str, exc: BaseException) -> None:
        """A cold start failed past all retries: quarantine the model with
        exponential backoff so repeated doomed loads neither burn admission
        slots nor poison the LRU."""
        with self._lock:
            q = self._model_quarantine.setdefault(
                name, {"fails": 0, "until": 0.0})
            q["fails"] += 1
            backoff = min(self.quarantine_max_s,
                          self.quarantine_base_s * (2 ** (q["fails"] - 1)))
            q["until"] = time.monotonic() + backoff
            fails = int(q["fails"])
            self.stats["load_failures"] += 1
        eng = self.engines.get(name)
        if eng is not None:
            eng.repairs.record("model_quarantined", model=name, fails=fails,
                               backoff_s=backoff, reason=repr(exc))

    def _clear_model_failure(self, name: str) -> None:
        with self._lock:
            self._model_quarantine.pop(name, None)

    def health(self) -> Dict[str, Any]:
        """One machine-readable snapshot of the server's fault domain AND
        its residency — plain dict/list/scalar values only, so the snapshot
        serializes over the front-door heartbeat channel and feeds its
        cache-aware routing cost estimate (``resident`` = staged weights
        device-resident → near-free warm run; ``served`` = this worker has
        cold-started the model before → store/page cache warm)."""
        with self._lock:
            snap = {
                "stats": dict(self.stats),
                "quarantine": {n: dict(q) for n, q
                               in self._model_quarantine.items()},
                "resident": list(self._resident),
                "resident_bytes": sum(self._resident.values()),
                "resident_model_bytes": dict(self._resident),
                "models": list(self.engines),
                "served": dict(self._served),
                "outstanding": int(self._outstanding),
                "draining": bool(self._draining),
                "link_bytes_per_s": float(self._link_bw or 0.0),
            }
        snap["pool"] = dict(getattr(self.pool, "health", {}) or {})
        snap["budget"] = self.budget.snapshot()
        # bytes this worker's engines pulled off the LOCAL disk — the CI
        # warm-transfer gate's numerator (peer-transferred bytes count in
        # stats["peer_bytes_fetched"] instead, never here)
        total_read = 0
        for eng in self.engines.values():
            try:
                total_read += int(eng.store.bytes_served())
            except Exception:
                pass
        snap["local_read_bytes"] = total_read
        if self.io_engine is not None:
            snap["io_engine"] = self.io_engine.snapshot()
        return snap

    def run(self, name: str, x) -> RunResult:
        """Serve one request: resident weights (warm) if available, else a
        full admitted cold start."""
        warm = self.warm_run(name, x)
        if warm is not None:
            return warm
        return self.cold_start(name, x).result()

    def warm_run(self, name: str, x) -> Optional[RunResult]:
        """Execute against resident (post-cold) weights; None if evicted or
        never cold-started."""
        with self._lock:
            weights = self._resident_weights.get(name)
            if weights is None:
                return None
            self._resident.move_to_end(name)    # LRU touch
            self.stats["warm_runs"] += 1
            self._served[name] = self._served.get(name, 0) + 1
        eng = self.engines[name]
        rt = eng._runtime(n_little=self.n_little, work_stealing=True)
        t0 = time.perf_counter()
        y = jax.numpy.asarray(x)
        for lname in rt.order:
            y = rt.jitted[lname](weights.get(lname, {}), y)
        jax.block_until_ready(y)
        return RunResult(output=y, total_s=time.perf_counter() - t0,
                         weights=weights)

    # -- residency / eviction ----------------------------------------------
    def _register_resident(self, name: str, res: RunResult):
        nbytes = _weights_nbytes(res.weights)
        if not nbytes:
            return
        with self._lock:
            old = self._resident.pop(name, None)
            self._resident[name] = nbytes
            self._resident_weights[name] = res.weights
        if old:
            self.budget.release(f"staged:{name}", old)
        # reserve OUTSIDE self._lock: the budget's evictors re-enter the
        # server lock to pop LRU victims (dropping the dict refs is the
        # eviction; XLA frees the buffers)
        self.budget.reserve(f"staged:{name}", nbytes)

    def _evict_for_budget(self, need: int) -> int:
        """MemoryBudget evictor: free least-recently-used staged weights
        (always keeping the newest model) until ``need`` bytes are freed
        or nothing evictable remains. Returns bytes freed."""
        freed = 0
        while freed < need:
            with self._lock:
                if len(self._resident) <= 1:
                    break
                victim, nb = self._resident.popitem(last=False)
                self._resident_weights.pop(victim, None)
                self.stats["evictions"] += 1
            self.budget.release(f"staged:{victim}", nb)
            freed += nb
        return freed

    @property
    def memory_budget_bytes(self) -> Optional[int]:
        """Live view over the shared pool's cap: assigning retunes
        ``budget.total`` (residency, packed params, and KV all share it),
        so operator code that always adjusted this attribute keeps
        working against the pooled accounting."""
        return self.budget.total

    @memory_budget_bytes.setter
    def memory_budget_bytes(self, v: Optional[int]) -> None:
        self.budget.total = None if v is None else int(v)

    def resident_models(self) -> List[str]:
        with self._lock:
            return list(self._resident)

    def resident_bytes(self) -> int:
        with self._lock:
            return sum(self._resident.values())

    def evict(self, name: str) -> bool:
        with self._lock:
            self._resident_weights.pop(name, None)
            nb = self._resident.pop(name, None)
        if nb is not None:
            self.budget.release(f"staged:{name}", nb)
        return nb is not None

    # -- warm-state transfer serving (docs/warm_transfer.md) -----------------
    def resident_state_for_transfer(self, name: str, *, packed: bool = False):
        """The ``WarmStateServer``'s data source: ``(state, reason)`` where
        ``state`` is ``{layer: {key: array}}`` or None (refusal).

        Refuses rather than serves a partial answer when the model is not
        resident, the server is draining, the shared memory budget is
        overcommitted (serving a transfer materializes ``tobytes`` copies
        — exactly the wrong moment to add pressure), or the operator flag
        ``refuse_transfers`` is set.  ``packed=True`` additionally rides
        the registered packed decode params under ``__packed__``."""
        with self._lock:
            if self.refuse_transfers:
                self.stats["transfer_refusals"] += 1
                return None, "refused by operator"
            if self._draining:
                self.stats["transfer_refusals"] += 1
                return None, "draining"
            weights = self._resident_weights.get(name)
            if weights is None:
                self.stats["transfer_refusals"] += 1
                return None, "not resident"
            state = {lname: dict(kv) for lname, kv in weights.items() if kv}
            if packed:
                pk = self._packed_state.get(name)
                if pk:
                    state[PACKED_LAYER] = dict(pk)
            self._resident.move_to_end(name)    # a transfer is a warm use
            self.stats["transfers_served"] += 1
        if self.budget.over_budget():
            with self._lock:
                self.stats["transfers_served"] -= 1
                self.stats["transfer_refusals"] += 1
            return None, "memory pressure"
        return state, "ok"

    def register_packed_state(self, name: str, params: Dict[str, Any]):
        """Packed decode-path params (the LLM bridge's ``pack`` output):
        kept servable over the warm-state channel under the reserved
        ``__packed__`` pseudo-layer, charged to the shared budget."""
        flat = {k: np.asarray(v) for k, v in params.items()
                if getattr(v, "nbytes", None) is not None}
        if not flat:
            return
        nbytes = sum(int(v.nbytes) for v in flat.values())
        with self._lock:
            old = self._packed_state.pop(name, None)
            self._packed_state[name] = flat
        if old is not None:
            self.budget.release(f"packed:{name}")
        self.budget.reserve(f"packed:{name}", nbytes)

    # -- warm-run batching (front-door worker coalescing) --------------------
    def warm_run_many(self, name: str, xs: Sequence[Any]
                      ) -> Optional[List[RunResult]]:
        """Serve N queued same-model warm requests in ONE per-layer sweep:
        layer i's compiled executable runs N times back-to-back against the
        resident weights before moving to layer i+1 — the ``BatchedServer``
        drain pattern applied to warm CNN serving (icache/weight locality,
        one LRU touch, one stats update) instead of N serial ``warm_run``
        walks.  None = not resident (callers fall back to cold starts)."""
        if not xs:
            return []
        with self._lock:
            weights = self._resident_weights.get(name)
            if weights is None:
                return None
            self._resident.move_to_end(name)
            self.stats["warm_runs"] += len(xs)
            self.stats["warm_batches"] += 1
            self._served[name] = self._served.get(name, 0) + len(xs)
        eng = self.engines[name]
        rt = eng._runtime(n_little=self.n_little, work_stealing=True)
        t0 = time.perf_counter()
        ys = [jax.numpy.asarray(x) for x in xs]
        for lname in rt.order:
            fn = rt.jitted[lname]
            w = weights.get(lname, {})
            ys = [fn(w, y) for y in ys]
        jax.block_until_ready(ys)
        total = time.perf_counter() - t0
        return [RunResult(output=y, total_s=total, weights=weights)
                for y in ys]
