"""ColdServer — multi-model cold serving on one persistent core pool.

The server owns N ``ColdEngine``s (one per model, each with its own store
under the server root) and shares across all of them:

  * the process-wide ``CorePool`` — one set of big/little workers serves
    every model's prep chains and exec chains, with per-job accounting;
  * one user-level ``ProfileDB`` — a second model whose layers fall into
    already-measured shape classes performs zero profile calls;
  * an **admission controller**: §3.2 measures I/O interference between
    co-running preparation ops *per host*, so the number of cold starts
    simultaneously in their prep phase is capped (``max_concurrent_preps``);
    further cold starts queue at admission and enter as slots free
    (released the moment a job's last read/transform/stage finishes —
    its exec tail does not hold the slot);
  * an **LRU residency budget**: finished cold starts leave their staged
    weights device-resident for warm reuse; when the total exceeds
    ``memory_budget_bytes`` the least-recently-used model's weights are
    evicted (its next request is simply cold again);
  * the process-wide **async I/O engine** (``repro.ioengine``): every
    engine's prep reads flow through one submit/reap queue, so the server
    can cap *bytes in flight* across all co-admitted cold starts
    (``max_read_bytes_in_flight``) — the byte-granular complement to the
    job-granular prep-slot semaphore — and use the engine's idle signal
    (no reads in flight) to run bounded incremental store compaction
    exactly when the disk has nothing better to do.
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict
from pathlib import Path
from typing import Any, Dict, List, Optional

import jax

from repro.core.engine import ColdEngine, LayerDef
from repro.core.pipeline import PipelineJob, RunResult
from repro.core.profiler import ProfileDB
from repro.executor.pool import CorePool, get_core_pool
from repro.faults import DeadlineExceeded, ModelQuarantined


def _weights_nbytes(weights: Optional[Dict[str, Any]]) -> int:
    total = 0
    for w in (weights or {}).values():
        for v in w.values():
            total += int(getattr(v, "nbytes", 0))
    return total


class ColdStart:
    """Handle for one admitted cold-start request."""

    def __init__(self, server: "ColdServer", model: str, job: PipelineJob):
        self.server = server
        self.model = model
        self.job = job

    @property
    def traces(self):
        return self.job.traces

    def done(self) -> bool:
        return self.job.done()

    def result(self, timeout: Optional[float] = None) -> RunResult:
        try:
            res = self.job.result(timeout)
        except TimeoutError:
            raise  # caller-side wait timeout (JobTimeout), not a model
            #        failure — the admission slot releases when the job's
            #        prep phase ends on its own
        except DeadlineExceeded:
            raise  # deadline pressure (watchdog expiry), not model
            #        sickness: quarantining here would punish a healthy
            #        model for an over-tight budget
        except Exception as e:
            self.server._record_model_failure(self.model, e)
            raise
        self.server._register_resident(self.model, res)
        self.server._clear_model_failure(self.model)
        return res


class ColdServer:
    def __init__(
        self,
        root,
        *,
        pool: Optional[CorePool] = None,
        n_little: int = 3,
        n_big: int = 2,
        max_concurrent_preps: int = 2,
        memory_budget_bytes: Optional[int] = None,
        share_profile_db: bool = True,
        quarantine_base_s: float = 0.5,
        quarantine_max_s: float = 30.0,
        io_engine: Any = "auto",
        max_read_bytes_in_flight: Optional[int] = None,
        idle_compaction: bool = True,
        idle_compaction_min_interval_s: float = 0.25,
    ):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.pool = pool or get_core_pool(n_little=n_little, n_big=n_big)
        self.n_little = n_little
        self.max_concurrent_preps = max_concurrent_preps
        self.memory_budget_bytes = memory_budget_bytes
        # one user-level profile DB shared by every managed engine: sibling
        # models with equivalent shape classes skip profiling entirely
        self.profile_db: Optional[ProfileDB] = (
            ProfileDB(self.root / "profile_db.json") if share_profile_db
            else None)
        self.engines: Dict[str, ColdEngine] = {}
        self._admission = threading.Semaphore(max_concurrent_preps)
        self._lock = threading.Lock()
        self._resident: "OrderedDict[str, int]" = OrderedDict()  # name->bytes
        self._resident_weights: Dict[str, Dict[str, Any]] = {}
        # per-model quarantine after failed cold starts: exponential backoff
        # keeps a sick model from burning admission slots on doomed retries
        self.quarantine_base_s = quarantine_base_s
        self.quarantine_max_s = quarantine_max_s
        self._model_quarantine: Dict[str, Dict[str, float]] = {}
        self.stats = {"admitted": 0, "evictions": 0, "active_preps": 0,
                      "max_active_preps": 0, "cold_starts": 0,
                      "load_failures": 0, "quarantined": 0,
                      "idle_compactions": 0, "idle_compaction_bytes": 0,
                      "idle_reprofiles": 0, "warm_runs": 0}
        # graceful drain (front-door worker handoff): _draining refuses new
        # admissions; _outstanding counts in-flight cold starts end-to-end
        # (admission -> job done), so drain() can wait the tail out
        self._draining = False
        self._outstanding = 0
        self._drain_cv = threading.Condition(self._lock)
        self._served: Dict[str, int] = {}   # model -> completed requests
        # shared async I/O engine: byte-budget admission + idle compaction.
        # "auto" binds the process-wide engine; False/None runs without one
        # (engines fall back to their own resolution / the sync path).
        if io_engine == "auto":
            from repro.ioengine import get_io_engine

            self.io_engine = get_io_engine()
        else:
            self.io_engine = io_engine or None
        if self.io_engine is not None and max_read_bytes_in_flight is not None:
            self.io_engine.set_max_bytes_in_flight(max_read_bytes_in_flight)
        # idle-tick incremental compaction: when the engine's read queue
        # drains, give ONE store (round-robin) one bounded background
        # maintain() pass — dead super-bundle extents get reclaimed in the
        # gaps between cold starts instead of stalling a decide()
        self._idle_min_interval = float(idle_compaction_min_interval_s)
        self._idle_last = 0.0
        self._idle_rr = 0
        self._idle_busy = False
        self._idle_compaction = bool(idle_compaction)
        if self.io_engine is not None and idle_compaction:
            self.io_engine.add_idle_callback(self._on_io_idle)

    # -- model management ---------------------------------------------------
    def add_model(self, name: str, layers: List[LayerDef],
                  **engine_kw) -> ColdEngine:
        if name in self.engines:
            raise ValueError(f"model {name!r} already added")
        engine_kw.setdefault("pool", self.pool)
        if self.profile_db is not None:
            engine_kw.setdefault("profile_db", self.profile_db)
        if self.io_engine is not None:
            engine_kw.setdefault("io_engine", self.io_engine)
        eng = ColdEngine(layers, self.root / name, **engine_kw)
        self.engines[name] = eng
        return eng

    def decide(self, name: str, x_example, **kw) -> Dict[str, Any]:
        kw.setdefault("n_little", self.n_little)
        return self.engines[name].decide(x_example, **kw)

    # -- serving ------------------------------------------------------------
    def cold_start(self, name: str, x, *, n_little: Optional[int] = None,
                   graph_hook=None,
                   deadline_s: Optional[float] = None) -> ColdStart:
        """Admit one cold-start request (blocks while ``max_concurrent_preps``
        jobs are in their prep phase) and submit its task graph.

        ``deadline_s`` is the request's remaining end-to-end budget — it
        becomes the job's watchdog deadline (typed ``DeadlineExceeded``
        once blown), and a budget already too small to cover the queue is
        shed HERE, before the admission semaphore is touched."""
        eng = self.engines[name]
        now = time.monotonic()
        with self._lock:
            if self._draining:
                raise RuntimeError(f"server draining: {name!r} refused")
            q = self._model_quarantine.get(name)
            if q is not None and now < q["until"]:
                self.stats["quarantined"] += 1
                retry_after = q["until"] - now
                raise ModelQuarantined(
                    f"model {name!r} quarantined after "
                    f"{int(q['fails'])} failed cold start(s); retry in "
                    f"{retry_after:.2f}s", retry_after=retry_after)
        if deadline_s is not None and deadline_s <= 0:
            raise DeadlineExceeded(
                f"request for {name!r} arrived with no budget left "
                f"({deadline_s:.3f}s) — shed before admission")
        # degradation ladder: a missing/corrupt offline decision falls back
        # to a validated plan.json reload or the default heuristic plan —
        # the request proceeds degraded instead of failing admission
        eng.ensure_plan(x, n_little=n_little or self.n_little)
        t_admit = time.monotonic()
        self._admission.acquire()
        # the admission wait itself consumed budget; what reaches the pool
        # watchdog is the REMAINING slice (shed typed if it went negative)
        if deadline_s is not None:
            deadline_s -= time.monotonic() - t_admit
            if deadline_s <= 0:
                self._admission.release()
                raise DeadlineExceeded(
                    f"request for {name!r} spent its whole budget queued "
                    f"at admission — shed before its prep started")
        with self._lock:
            self.stats["admitted"] += 1
            self.stats["cold_starts"] += 1
            self.stats["active_preps"] += 1
            self.stats["max_active_preps"] = max(
                self.stats["max_active_preps"], self.stats["active_preps"])
            self._outstanding += 1
            self._served[name] = self._served.get(name, 0) + 1
        try:
            job = eng.submit_cold(x, n_little=n_little or self.n_little,
                                  graph_hook=graph_hook,
                                  deadline_s=deadline_s)
        except BaseException:
            self._release_prep_slot()
            self._request_done()
            raise
        job.job.add_preps_callback(lambda _job: self._release_prep_slot())
        job.job.add_done_callback(lambda _job: self._request_done())
        return ColdStart(self, name, job)

    def _request_done(self):
        with self._drain_cv:
            self._outstanding -= 1
            self._drain_cv.notify_all()

    # -- graceful drain (front-door worker handoff) --------------------------
    def drain(self, timeout: Optional[float] = None) -> bool:
        """Refuse new admissions and wait for every in-flight cold start to
        finish. True = fully drained; False = requests still running at
        ``timeout`` (the supervisor escalates to a hard stop). Idempotent;
        ``resume()`` reopens admission."""
        deadline = None if timeout is None else time.monotonic() + timeout
        with self._drain_cv:
            self._draining = True
            while self._outstanding > 0:
                left = (None if deadline is None
                        else deadline - time.monotonic())
                if left is not None and left <= 0:
                    return False
                self._drain_cv.wait(left)
        return True

    def resume(self) -> None:
        with self._lock:
            self._draining = False

    def _release_prep_slot(self):
        with self._lock:
            self.stats["active_preps"] -= 1
        self._admission.release()
        # the engine's idle edge usually lands while this job's transform/
        # stage tail is still running (active_preps > 0, tick skipped) —
        # re-check when the prep phase itself ends
        if self.io_engine is not None and self._idle_compaction \
                and self.io_engine.reads_in_flight() == 0:
            self._on_io_idle()

    # -- idle-tick incremental compaction ------------------------------------
    def _on_io_idle(self):
        """Engine idle signal (reads in flight hit zero): run ONE bounded
        background ``maintain()`` pass on the next store, round-robin, that
        has reclaimable dead extents. Rate-limited so a bursty
        submit/drain/submit pattern cannot thrash compactions; skipped
        entirely while a previous idle compaction is still running or any
        cold start is mid-prep (its reads resume in a moment — the disk is
        not actually idle)."""
        now = time.monotonic()
        with self._lock:
            if (self._idle_busy or self.stats["active_preps"] > 0
                    or now - self._idle_last < self._idle_min_interval):
                return
            self._idle_busy = True
            names = list(self.engines)
            rr = self._idle_rr
        # off the engine's completion thread: a compaction must never delay
        # the reap of reads submitted right after the idle edge
        threading.Thread(target=self._idle_tick, args=(names, rr),
                         name="repro-idle-compact", daemon=True).start()

    def _idle_tick(self, names: List[str], rr: int):
        reclaimed = 0
        ticked = False
        reprofiled = 0
        try:
            for off in range(len(names)):
                name = names[(rr + off) % len(names)]
                store = self.engines[name].store
                try:
                    out = store.maintain(background=True)
                    # bounded per tick: at most one store's compaction, and
                    # we join it here so "busy" covers the whole pass
                    joined = store.maintain_wait()
                except Exception:
                    continue  # sick store: quarantine handles it elsewhere
                if out.get("compacted"):
                    reclaimed = int((joined or out).get(
                        "reclaimed_bytes", 0))
                    ticked = True
                    rr = (rr + off + 1) % len(names)
                    break
            # host-fingerprint drift: re-measure ONE stale shape class per
            # idle tick (round-robin over engines) — profiling happens in
            # the gaps between cold starts, never on the request path
            for off in range(len(names)):
                eng = self.engines[names[(rr + off) % len(names)]]
                try:
                    reprofiled = eng.reprofile_stale(max_classes=1)
                except Exception:
                    continue  # advisory refresh; the stale estimate serves
                if reprofiled:
                    break
        finally:
            with self._lock:
                self._idle_busy = False
                self._idle_last = time.monotonic()
                self._idle_rr = rr
                if ticked:
                    self.stats["idle_compactions"] += 1
                    self.stats["idle_compaction_bytes"] += reclaimed
                if reprofiled:
                    self.stats["idle_reprofiles"] += reprofiled

    # -- model quarantine ---------------------------------------------------
    def _record_model_failure(self, name: str, exc: BaseException) -> None:
        """A cold start failed past all retries: quarantine the model with
        exponential backoff so repeated doomed loads neither burn admission
        slots nor poison the LRU."""
        with self._lock:
            q = self._model_quarantine.setdefault(
                name, {"fails": 0, "until": 0.0})
            q["fails"] += 1
            backoff = min(self.quarantine_max_s,
                          self.quarantine_base_s * (2 ** (q["fails"] - 1)))
            q["until"] = time.monotonic() + backoff
            fails = int(q["fails"])
            self.stats["load_failures"] += 1
        eng = self.engines.get(name)
        if eng is not None:
            eng.repairs.record("model_quarantined", model=name, fails=fails,
                               backoff_s=backoff, reason=repr(exc))

    def _clear_model_failure(self, name: str) -> None:
        with self._lock:
            self._model_quarantine.pop(name, None)

    def health(self) -> Dict[str, Any]:
        """One machine-readable snapshot of the server's fault domain AND
        its residency — plain dict/list/scalar values only, so the snapshot
        serializes over the front-door heartbeat channel and feeds its
        cache-aware routing cost estimate (``resident`` = staged weights
        device-resident → near-free warm run; ``served`` = this worker has
        cold-started the model before → store/page cache warm)."""
        with self._lock:
            snap = {
                "stats": dict(self.stats),
                "quarantine": {n: dict(q) for n, q
                               in self._model_quarantine.items()},
                "resident": list(self._resident),
                "resident_bytes": sum(self._resident.values()),
                "models": list(self.engines),
                "served": dict(self._served),
                "outstanding": int(self._outstanding),
                "draining": bool(self._draining),
            }
        snap["pool"] = dict(getattr(self.pool, "health", {}) or {})
        if self.io_engine is not None:
            snap["io_engine"] = self.io_engine.snapshot()
        return snap

    def run(self, name: str, x) -> RunResult:
        """Serve one request: resident weights (warm) if available, else a
        full admitted cold start."""
        warm = self.warm_run(name, x)
        if warm is not None:
            return warm
        return self.cold_start(name, x).result()

    def warm_run(self, name: str, x) -> Optional[RunResult]:
        """Execute against resident (post-cold) weights; None if evicted or
        never cold-started."""
        with self._lock:
            weights = self._resident_weights.get(name)
            if weights is None:
                return None
            self._resident.move_to_end(name)    # LRU touch
            self.stats["warm_runs"] += 1
            self._served[name] = self._served.get(name, 0) + 1
        eng = self.engines[name]
        rt = eng._runtime(n_little=self.n_little, work_stealing=True)
        t0 = time.perf_counter()
        y = jax.numpy.asarray(x)
        for lname in rt.order:
            y = rt.jitted[lname](weights.get(lname, {}), y)
        jax.block_until_ready(y)
        return RunResult(output=y, total_s=time.perf_counter() - t0,
                         weights=weights)

    # -- residency / eviction ----------------------------------------------
    def _register_resident(self, name: str, res: RunResult):
        nbytes = _weights_nbytes(res.weights)
        if not nbytes:
            return
        evict: List[str] = []
        with self._lock:
            self._resident_weights[name] = res.weights
            self._resident.pop(name, None)
            self._resident[name] = nbytes
            if self.memory_budget_bytes is not None:
                while (sum(self._resident.values()) > self.memory_budget_bytes
                       and len(self._resident) > 1):
                    victim, _ = self._resident.popitem(last=False)
                    self._resident_weights.pop(victim, None)
                    evict.append(victim)
                    self.stats["evictions"] += 1
        # dropping the dict refs is the eviction; XLA frees the buffers

    def resident_models(self) -> List[str]:
        with self._lock:
            return list(self._resident)

    def resident_bytes(self) -> int:
        with self._lock:
            return sum(self._resident.values())

    def evict(self, name: str) -> bool:
        with self._lock:
            self._resident_weights.pop(name, None)
            return self._resident.pop(name, None) is not None
