"""Persistent asymmetric core pools.

One process-wide ``CorePool`` holds the big/little worker threads the
pipelined runtime used to spawn per run. Threads are created once (the pool
grows on demand) and reused across runs *and models*: the steady cold-serving
path performs zero thread creation. Jobs — compiled ``TaskGraph``s — are
submitted concurrently; every task records an ``OpTrace`` against its own
job's clock, so traces and benchmark breakdowns stay strictly per-run.

Scheduling rules (mirroring the plan simulator, §3.3):

  * a little worker drains its own lane in order; when idle it *steals* —
    donor = the lane with the most remaining prep cost (the shared
    ``scheduler.pick_steal_donor`` rule), item = the donor's TAIL layer,
    whose whole prep chain is retargeted to the thief's lane;
  * big workers run ``big``-affinity tasks in tid order (the plan's big
    preps first, then the exec chain as its deps release);
  * ``any``-affinity tasks (deferred staging, background packing) go to
    whoever idles first — an idle little core prefetch-stages layer i+1
    while the big core executes layer i, without a dedicated stager thread.

A failing task cancels the rest of its job (other jobs are untouched) and
re-raises from ``Job.result()``/``wait()``.
"""
from __future__ import annotations

import itertools
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from repro.core.scheduler import pick_steal_donor
from repro.executor.graph import OpTrace, PREP_KINDS, TaskGraph

_PENDING, _READY, _RUNNING, _DONE, _CANCELLED = range(5)


_JOB_SEQ = itertools.count(1)


class Job:
    """One submitted task graph: per-run traces, completion event, error."""

    def __init__(self, graph: TaskGraph, name: str, t0: Optional[float],
                 allow_steal: bool):
        self.seq = next(_JOB_SEQ)
        self.graph = graph
        self.name = name
        self.t0 = time.perf_counter() if t0 is None else t0
        self.allow_steal = allow_steal
        self.traces: List[OpTrace] = []
        self.total_s: float = 0.0
        self.done = threading.Event()
        self.error: Optional[BaseException] = None
        self.on_preps_done: List[Callable[["Job"], None]] = []
        self._cb_lock = threading.Lock()

        n = len(graph.tasks)
        self._state = [_PENDING] * n
        self._pending = [len(t.deps) for t in graph.tasks]
        self._children: List[List[int]] = [[] for _ in range(n)]
        for t in graph.tasks:
            for d in t.deps:
                self._children[d].append(t.tid)
        self._done_count = 0
        self._prep_left = sum(
            1 for t in graph.tasks if t.kind in PREP_KINDS)
        # prep-free jobs have no worker to fire preps-done: treat the prep
        # phase as already over, so late-registered callbacks run inline
        self._preps_fired = self._prep_left == 0
        self._preps_cb_fired = self._preps_fired
        # ready lists per affinity; little lanes also track layer order and
        # remaining (unstarted) cost for the steal-donor rule
        self._ready_big: List[int] = []
        self._ready_any: List[int] = []
        self._ready_little: Dict[int, List[int]] = {}
        self._lane_layers: Dict[int, List[str]] = {}
        self._layer_chain: Dict[str, List[int]] = {}
        for t in graph.tasks:
            if t.affinity == "little" and t.kind in PREP_KINDS:
                lane = self._lane_layers.setdefault(t.lane, [])
                if t.layer not in lane:
                    lane.append(t.layer)
                self._layer_chain.setdefault(t.layer, []).append(t.tid)
        # a job is served by exactly the little lanes its plan scheduled —
        # a wider pool must not hand a run more little cores than the
        # plan's makespan modeled (extra workers still help with 'any'
        # tasks and other jobs)
        lanes = graph.lanes()
        self.n_lanes = (max(lanes) + 1) if lanes else 0
        for t in graph.tasks:
            if self._pending[t.tid] == 0:
                self._mark_ready(t.tid)

    # -- internal (all called under the pool lock) --------------------------
    def _mark_ready(self, tid: int):
        t = self.graph.tasks[tid]
        self._state[tid] = _READY
        if t.affinity == "big":
            self._ready_big.append(tid)
        elif t.affinity == "any":
            self._ready_any.append(tid)
        else:
            self._ready_little.setdefault(t.lane, []).append(tid)

    def _lane_remaining(self) -> Dict[int, List[str]]:
        """Per lane: layers whose prep chain has not started (stealable)."""
        out: Dict[int, List[str]] = {}
        for lane, layers in self._lane_layers.items():
            ls = [n for n in layers
                  if self._state[self._layer_chain[n][0]] == _READY]
            if ls:
                out[lane] = ls
        return out

    def _chain_cost(self, layer: str) -> float:
        return self.graph.tasks[self._layer_chain[layer][0]].cost

    def _move_layer(self, layer: str, to_lane: int):
        """Retarget one layer's unstarted prep chain to ``to_lane``."""
        for tid in self._layer_chain[layer]:
            t = self.graph.tasks[tid]
            if self._state[tid] == _READY:
                self._ready_little[t.lane].remove(tid)
                self._ready_little.setdefault(to_lane, []).append(tid)
            t.lane = to_lane
        for lane, layers in self._lane_layers.items():
            if layer in layers and lane != to_lane:
                layers.remove(layer)
                break
        self._lane_layers.setdefault(to_lane, []).append(layer)

    def _finished(self) -> bool:
        return self._done_count >= len(self.graph.tasks)

    # -- public -------------------------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> "Job":
        if not self.done.wait(timeout):
            raise TimeoutError(f"job {self.name!r} still running")
        if self.error is not None:
            raise self.error
        return self

    def preps_done(self) -> bool:
        return self._preps_fired

    def add_preps_callback(self, cb: Callable[["Job"], None]) -> None:
        """Register a preps-done callback; runs immediately if the job's
        prep phase already finished (registration is race-free w.r.t. the
        worker that fires the callbacks)."""
        with self._cb_lock:
            if not self._preps_cb_fired:
                self.on_preps_done.append(cb)
                return
        cb(self)

    def _fire_preps_callbacks(self):
        with self._cb_lock:
            self._preps_cb_fired = True
            cbs = list(self.on_preps_done)
        for cb in cbs:
            cb(self)


def _pop_min(lst: List[int]) -> int:
    k = min(range(len(lst)), key=lst.__getitem__)
    return lst.pop(k)


class CorePool:
    """Persistent big.LITTLE worker pools executing task graphs."""

    def __init__(self, n_big: int = 1, n_little: int = 3,
                 name: str = "corepool"):
        self.name = name
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._jobs: List[Job] = []
        self._shutdown = False
        self.threads_created = 0
        self.jobs_completed = 0
        self.steals = 0
        self._big: List[threading.Thread] = []
        self._little: List[threading.Thread] = []
        self.ensure(n_little=n_little, n_big=n_big)

    @property
    def n_big(self) -> int:
        return len(self._big)

    @property
    def n_little(self) -> int:
        return len(self._little)

    def ensure(self, n_little: Optional[int] = None,
               n_big: Optional[int] = None) -> "CorePool":
        """Grow (never shrink) the worker sets. Idempotent; the steady
        serving path calls this with sizes the pool already has, creating
        zero threads."""
        with self._lock:
            if self._shutdown:
                raise RuntimeError("pool is shut down")
            while n_big is not None and len(self._big) < n_big:
                i = len(self._big)
                th = threading.Thread(
                    target=self._big_loop, args=(i,), daemon=True,
                    name=f"{self.name}-big{i}")
                self._big.append(th)
                self.threads_created += 1
                th.start()
            while n_little is not None and len(self._little) < n_little:
                j = len(self._little)
                th = threading.Thread(
                    target=self._little_loop, args=(j,), daemon=True,
                    name=f"{self.name}-little{j}")
                self._little.append(th)
                self.threads_created += 1
                th.start()
        return self

    def submit(self, graph: TaskGraph, *, name: str = "job",
               allow_steal: bool = True, t0: Optional[float] = None) -> Job:
        graph.validate()
        for t in graph.tasks:
            if t.fn is None:
                raise ValueError(
                    f"task {t.layer}/{t.kind} has no bound fn")
        lanes = graph.lanes()
        self.ensure(n_little=(max(lanes) + 1 if lanes else None), n_big=1)
        job = Job(graph, name, t0, allow_steal)
        with self._cv:
            if self._shutdown:
                raise RuntimeError("pool is shut down")
            if job._finished():          # empty graph
                job.total_s = time.perf_counter() - job.t0
                job.done.set()
                self.jobs_completed += 1
            else:
                self._jobs.append(job)
                self._cv.notify_all()
        return job

    def shutdown(self):
        with self._cv:
            self._shutdown = True
            self._cv.notify_all()
        for th in self._big + self._little:
            th.join(timeout=5.0)

    # -- worker internals ----------------------------------------------------
    def _next_for_little(self, j: int) -> Optional[Tuple[Job, int]]:
        for job in self._jobs:
            rl = job._ready_little.get(j)
            if rl:
                return job, _pop_min(rl)
        # steal: donor lane (any job that allows it) with most remaining
        # prep cost; take its tail layer's whole chain
        best: Optional[Tuple[Job, int, List[str]]] = None
        best_cost = 0.0
        for job in self._jobs:
            if not job.allow_steal or j >= job.n_lanes:
                continue
            remaining = job._lane_remaining()
            remaining.pop(j, None)      # own lane is empty (checked above)
            donor = pick_steal_donor(remaining, job._chain_cost)
            if donor is None:
                continue
            cost = sum(job._chain_cost(n) for n in remaining[donor])
            if best is None or cost > best_cost:
                best, best_cost = (job, donor, remaining[donor]), cost
        if best is not None:
            job, donor, layers = best
            job._move_layer(layers[-1], j)   # steal the tail
            self.steals += 1
            rl = job._ready_little.get(j)
            if rl:
                return job, _pop_min(rl)
        for job in self._jobs:
            if job._ready_any:
                return job, _pop_min(job._ready_any)
        return None

    def _next_for_big(self) -> Optional[Tuple[Job, int]]:
        for job in self._jobs:
            if job._ready_big:
                return job, _pop_min(job._ready_big)
        for job in self._jobs:
            if job._ready_any:
                return job, _pop_min(job._ready_any)
        return None

    def _worker_loop(self, core: str,
                     pick: Callable[[], Optional[Tuple[Job, int]]]):
        while True:
            with self._cv:
                item = None
                while item is None:
                    if self._shutdown:
                        return
                    item = pick()
                    if item is None:
                        self._cv.wait()
                job, tid = item
                job._state[tid] = _RUNNING
            self._run(job, tid, core)

    def _big_loop(self, i: int):
        self._worker_loop("big" if i == 0 else f"big{i}", self._next_for_big)

    def _little_loop(self, j: int):
        self._worker_loop(f"little{j}",
                          lambda: self._next_for_little(j))

    def _run(self, job: Job, tid: int, core: str):
        task = job.graph.tasks[tid]
        err: Optional[BaseException] = None
        ts = time.perf_counter()
        try:
            task.fn()
        except BaseException as e:      # noqa: BLE001 — forwarded to caller
            err = e
        te = time.perf_counter()
        if err is None:
            job.traces.append(OpTrace(task.layer, task.kind, core,
                                      ts - job.t0, te - job.t0))
        fire_preps = False
        with self._cv:
            if err is not None:
                job.error = err
                for t2 in job.graph.tasks:
                    if job._state[t2.tid] in (_PENDING, _READY):
                        job._state[t2.tid] = _CANCELLED
                        job._done_count += 1
                job._ready_big.clear()
                job._ready_any.clear()
                job._ready_little.clear()
                # a failed job must still release its admission slot:
                # cancelled preps will never complete, so fire preps-done now
                if not job._preps_fired:
                    job._preps_fired = True
                    fire_preps = True
            job._state[tid] = _DONE
            job._done_count += 1
            if task.kind in PREP_KINDS:
                job._prep_left -= 1
                if job._prep_left == 0 and not job._preps_fired:
                    job._preps_fired = True
                    fire_preps = True
            if err is None:
                for child in job._children[tid]:
                    job._pending[child] -= 1
                    if job._pending[child] == 0 \
                            and job._state[child] == _PENDING:
                        job._mark_ready(child)
            finished = job._finished()
            if finished:
                self._jobs.remove(job)
                self.jobs_completed += 1
                job.total_s = te - job.t0
            self._cv.notify_all()
        # callbacks and the done event fire outside the pool lock so they
        # may submit follow-up work without deadlocking
        if fire_preps:
            job._fire_preps_callbacks()
        if finished:
            job.done.set()


# ---------------------------------------------------------------------------
# the process-wide pool
# ---------------------------------------------------------------------------
_GLOBAL: Optional[CorePool] = None
_GLOBAL_LOCK = threading.Lock()


def get_core_pool(n_little: int = 3, n_big: int = 2) -> CorePool:
    """The process-wide persistent pool, created on first use and grown on
    demand — every runtime and every model share it."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        if _GLOBAL is None:
            _GLOBAL = CorePool(n_big=n_big, n_little=n_little, name="global")
            return _GLOBAL
    return _GLOBAL.ensure(n_little=n_little, n_big=n_big)


def reset_core_pool() -> None:
    """Shut the global pool down (tests only — the whole point of the pool
    is that production never does this)."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        if _GLOBAL is not None:
            _GLOBAL.shutdown()
            _GLOBAL = None
