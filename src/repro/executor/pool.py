"""Persistent asymmetric core pools.

One process-wide ``CorePool`` holds the big/little worker threads the
pipelined runtime used to spawn per run. Threads are created once (the pool
grows on demand) and reused across runs *and models*: the steady cold-serving
path performs zero thread creation. Jobs — compiled ``TaskGraph``s — are
submitted concurrently; every task records an ``OpTrace`` against its own
job's clock, so traces and benchmark breakdowns stay strictly per-run.

Scheduling rules (mirroring the plan simulator, §3.3):

  * a little worker drains its own lane in order; when idle it *steals* —
    donor = the lane with the most remaining prep cost (the shared
    ``scheduler.pick_steal_donor`` rule), item = the donor's TAIL layer,
    whose whole prep chain is retargeted to the thief's lane;
  * big workers run ``big``-affinity tasks in tid order (the plan's big
    preps first, then the exec chain as its deps release);
  * ``any``-affinity tasks (deferred staging, background packing) go to
    whoever idles first — an idle little core prefetch-stages layer i+1
    while the big core executes layer i, without a dedicated stager thread.

Fault domain (``repro.faults``):

  * a task raising a ``TransientFault`` is retried in place — bounded by the
    job's ``RetryPolicy``, with exponential backoff enforced through a
    per-task ``not_before`` eligibility time (workers skip ineligible tasks
    and sleep until the earliest backoff expires). Any other exception still
    fails the job exactly as before.
  * tasks may carry a deadline (per-task ``Task.deadline_s`` or the job-wide
    ``deadline_s=`` given at submit). A watchdog thread (started lazily the
    first time a deadline is used — the deadline-free steady path never pays
    for it) expires overdue tasks: the stuck worker is retired (quarantined)
    and replaced by a fresh thread for the same lane, the lane's unstarted
    prep chains are rescheduled onto healthy lanes via the steal rule's cost
    metric, and the expired *prep* task is retried on a healthy lane (an
    overdue *execute* task fails the job with ``DeadlineExceeded`` — the
    activation chain is stateful, so re-running it behind a live zombie
    could corrupt ``state["y"]``). Per-task epoch counters make the zombie's
    eventual completion harmlessly discardable.
  * ``shutdown()`` detects workers that never joined (a hung task leaks the
    thread), counts them in ``health["workers_lost"]`` and reports (or
    raises, with ``raise_on_leak=True``) a typed ``WorkerLost``.
  * ``health`` counts retries/expiries/quarantines/leaks pool-wide;
    ``Job.retries`` and ``Job.fault_events`` record the per-run story.

A failing task cancels the rest of its job (other jobs are untouched) and
re-raises from ``Job.result()``/``wait()``.
"""
from __future__ import annotations

import itertools
import os
import threading
import time
from typing import Callable, Dict, List, Optional, Set, Tuple

from repro.core.scheduler import pick_steal_donor
from repro.executor.graph import OpTrace, PREP_KINDS, TaskGraph
from repro.faults import (
    DEFAULT_RETRY, DeadlineExceeded, JobTimeout, RetryPolicy, TransientFault,
    WorkerLost, classify,
)

_PENDING, _READY, _RUNNING, _DONE, _CANCELLED = range(5)

#: platform support for per-thread CPU affinity (Linux). Everything pinning
#: does is gated on this flag so other platforms get a clean no-op.
_HAS_AFFINITY = hasattr(os, "sched_setaffinity") \
    and hasattr(os, "sched_getaffinity")


def _pin_current_thread(cpus: Set[int]) -> bool:
    """Pin the calling thread to ``cpus``; False on any failure (no-op
    fallback — pinning is a locality optimization, never a correctness
    requirement)."""
    if not _HAS_AFFINITY or not cpus:
        return False
    try:
        os.sched_setaffinity(0, cpus)   # tid 0 = the calling thread
        return True
    except OSError:
        return False


_JOB_SEQ = itertools.count(1)


class Job:
    """One submitted task graph: per-run traces, completion event, error."""

    def __init__(self, graph: TaskGraph, name: str, t0: Optional[float],
                 allow_steal: bool, retry: Optional[RetryPolicy] = None,
                 deadline_s: Optional[float] = None,
                 job_deadline_s: Optional[float] = None):
        self.seq = next(_JOB_SEQ)
        self.graph = graph
        self.name = name
        self.t0 = time.perf_counter() if t0 is None else t0
        self.allow_steal = allow_steal
        self.retry = retry if retry is not None else DEFAULT_RETRY
        self.deadline_s = deadline_s  # job-wide default task deadline
        self.job_deadline_s = job_deadline_s  # end-to-end budget for the
        #                                       WHOLE job (measured from t0);
        #                                       the watchdog fails the job
        #                                       typed once it is blown
        self.traces: List[OpTrace] = []
        self.total_s: float = 0.0
        self.done = threading.Event()
        self.error: Optional[BaseException] = None
        self.retries = 0                      # transient-fault retries used
        self.fault_events: List[dict] = []    # per-run fault/retry story
        self.on_preps_done: List[Callable[["Job"], None]] = []
        self.on_done: List[Callable[["Job"], None]] = []
        self._cb_lock = threading.Lock()
        self._done_cb_fired = False

        n = len(graph.tasks)
        self._state = [_PENDING] * n
        self._pending = [len(t.deps) for t in graph.tasks]
        self._children: List[List[int]] = [[] for _ in range(n)]
        for t in graph.tasks:
            for d in t.deps:
                self._children[d].append(t.tid)
        self._done_count = 0
        self._attempts = [0] * n            # transient retries consumed
        self._epoch = [0] * n               # bumped when the watchdog expires
        #                                     a running attempt: the zombie's
        #                                     eventual completion is discarded
        self._not_before: Dict[int, float] = {}  # backoff eligibility times
        self._prep_left = sum(
            1 for t in graph.tasks if t.kind in PREP_KINDS)
        # prep-free jobs have no worker to fire preps-done: treat the prep
        # phase as already over, so late-registered callbacks run inline
        self._preps_fired = self._prep_left == 0
        self._preps_cb_fired = self._preps_fired
        # ready lists per affinity; little lanes also track layer order and
        # remaining (unstarted) cost for the steal-donor rule
        self._ready_big: List[int] = []
        self._ready_any: List[int] = []
        self._ready_little: Dict[int, List[int]] = {}
        self._lane_layers: Dict[int, List[str]] = {}
        self._layer_chain: Dict[str, List[int]] = {}
        for t in graph.tasks:
            if t.affinity == "little" and t.kind in PREP_KINDS:
                lane = self._lane_layers.setdefault(t.lane, [])
                if t.layer not in lane:
                    lane.append(t.layer)
                self._layer_chain.setdefault(t.layer, []).append(t.tid)
        # a job is served by exactly the little lanes its plan scheduled —
        # a wider pool must not hand a run more little cores than the
        # plan's makespan modeled (extra workers still help with 'any'
        # tasks and other jobs)
        lanes = graph.lanes()
        self.n_lanes = (max(lanes) + 1) if lanes else 0
        for t in graph.tasks:
            if self._pending[t.tid] == 0:
                self._mark_ready(t.tid)

    # -- internal (all called under the pool lock) --------------------------
    def _mark_ready(self, tid: int):
        t = self.graph.tasks[tid]
        self._state[tid] = _READY
        if t.affinity == "big":
            self._ready_big.append(tid)
        elif t.affinity == "any":
            self._ready_any.append(tid)
        else:
            self._ready_little.setdefault(t.lane, []).append(tid)

    def _lane_remaining(self, now: Optional[float] = None
                        ) -> Dict[int, List[str]]:
        """Per lane: layers whose prep chain has not started (stealable).
        With ``now`` given, chains whose head is still in retry backoff are
        excluded (not worth stealing yet)."""
        out: Dict[int, List[str]] = {}
        for lane, layers in self._lane_layers.items():
            ls = [n for n in layers
                  if self._state[self._layer_chain[n][0]] == _READY
                  and (now is None
                       or self._not_before.get(
                           self._layer_chain[n][0], 0.0) <= now)]
            if ls:
                out[lane] = ls
        return out

    def _chain_cost(self, layer: str) -> float:
        return self.graph.tasks[self._layer_chain[layer][0]].cost

    def _move_layer(self, layer: str, to_lane: int):
        """Retarget one layer's unstarted prep chain to ``to_lane``."""
        for tid in self._layer_chain[layer]:
            t = self.graph.tasks[tid]
            if self._state[tid] == _READY:
                self._ready_little[t.lane].remove(tid)
                self._ready_little.setdefault(to_lane, []).append(tid)
            t.lane = to_lane
        for lane, layers in self._lane_layers.items():
            if layer in layers and lane != to_lane:
                layers.remove(layer)
                break
        self._lane_layers.setdefault(to_lane, []).append(layer)

    def _requeue_from_lane(self, lane: int) -> int:
        """Move every unstarted prep chain off ``lane`` onto the least-
        loaded other lane — the steal rule's remaining-cost metric, inverted
        (send work to the emptiest healthy lane). Called by the pool
        watchdog when a lane's worker is quarantined."""
        if self.n_lanes <= 1 or lane >= self.n_lanes:
            return 0
        moved = 0
        while True:
            remaining = self._lane_remaining()
            layers = remaining.get(lane)
            if not layers:
                return moved
            loads = {j: sum(self._chain_cost(n)
                            for n in remaining.get(j, []))
                     for j in range(self.n_lanes) if j != lane}
            dest = min(loads, key=lambda j: (loads[j], j))
            self._move_layer(layers[0], dest)
            moved += 1

    def _finished(self) -> bool:
        return self._done_count >= len(self.graph.tasks)

    # -- public -------------------------------------------------------------
    def wait(self, timeout: Optional[float] = None) -> "Job":
        if not self.done.wait(timeout):
            raise JobTimeout(
                f"job {self.name!r} still running after {timeout}s wait")
        if self.error is not None:
            raise self.error
        return self

    def preps_done(self) -> bool:
        return self._preps_fired

    def add_preps_callback(self, cb: Callable[["Job"], None]) -> None:
        """Register a preps-done callback; runs immediately if the job's
        prep phase already finished (registration is race-free w.r.t. the
        worker that fires the callbacks)."""
        with self._cb_lock:
            if not self._preps_cb_fired:
                self.on_preps_done.append(cb)
                return
        cb(self)

    def _fire_preps_callbacks(self):
        with self._cb_lock:
            self._preps_cb_fired = True
            cbs = list(self.on_preps_done)
        for cb in cbs:
            cb(self)

    def add_done_callback(self, cb: Callable[["Job"], None]) -> None:
        """Register a job-completion callback (success, failure, or
        watchdog expiry alike); runs immediately if the job already
        finished. The executor uses this to recycle async-read buffers —
        task values are held until job end for retry idempotency, so this
        is the first moment recycling is safe. Same race-free registration
        discipline as ``add_preps_callback``."""
        with self._cb_lock:
            if not self._done_cb_fired:
                self.on_done.append(cb)
                return
        cb(self)

    def _fire_done(self):
        """Fire done-callbacks then set the event — every completion path
        (worker finish, failure cancel, watchdog expiry, empty graph) goes
        through here, outside the pool lock."""
        with self._cb_lock:
            self._done_cb_fired = True
            cbs = list(self.on_done)
        for cb in cbs:
            try:
                cb(self)
            except Exception:
                pass  # cleanup callbacks must not mask the job's outcome
        self.done.set()


def _pop_eligible(job: Job, lst: List[int], now: float) -> Optional[int]:
    """Pop the lowest eligible tid (backoff ``not_before`` respected)."""
    best = None
    for i, tid in enumerate(lst):
        if job._not_before.get(tid, 0.0) > now:
            continue
        if best is None or tid < lst[best]:
            best = i
    return lst.pop(best) if best is not None else None


class CorePool:
    """Persistent big.LITTLE worker pools executing task graphs."""

    def __init__(self, n_big: int = 1, n_little: int = 3,
                 name: str = "corepool", *,
                 watchdog_interval_s: float = 0.02,
                 pin_cores: bool = False):
        self.name = name
        self._lock = threading.Lock()
        self._cv = threading.Condition(self._lock)
        self._jobs: List[Job] = []
        self._shutdown = False
        self._draining = False
        self.threads_created = 0
        self.jobs_completed = 0
        self.steals = 0
        # big/little lane pinning (sched_setaffinity where available): big
        # workers take the high-numbered cores, little lanes the low ones —
        # the big.LITTLE enumeration convention — wrapping when workers
        # outnumber cores. ``pinned`` records what each worker actually got
        # (None = the clean no-op fallback fired).
        self.pin_cores = bool(pin_cores)
        self.pinned: Dict[str, Optional[List[int]]] = {}
        # fault-domain state
        self.health: Dict[str, int] = {
            "task_retries": 0, "deadline_expired": 0,
            "job_deadline_expired": 0,
            "lanes_quarantined": 0, "workers_replaced": 0,
            "workers_lost": 0, "jobs_failed": 0, "tasks_cancelled": 0,
        }
        self.fault_injector = None  # repro.faults.FaultInjector ("task.*")
        self.watchdog_interval_s = watchdog_interval_s
        self.leak_report: Optional[dict] = None
        self._running: Dict[Tuple[int, int], dict] = {}  # (id(job), tid)
        self._retired: set = set()          # quarantined worker threads
        self._zombies: List[threading.Thread] = []
        self._watchdog: Optional[threading.Thread] = None
        self._big: List[threading.Thread] = []
        self._little: List[threading.Thread] = []
        self.ensure(n_little=n_little, n_big=n_big)

    @property
    def n_big(self) -> int:
        return len(self._big)

    @property
    def n_little(self) -> int:
        return len(self._little)

    def ensure(self, n_little: Optional[int] = None,
               n_big: Optional[int] = None) -> "CorePool":
        """Grow (never shrink) the worker sets. Idempotent; the steady
        serving path calls this with sizes the pool already has, creating
        zero threads."""
        with self._lock:
            if self._shutdown:
                raise RuntimeError("pool is shut down")
            while n_big is not None and len(self._big) < n_big:
                i = len(self._big)
                th = threading.Thread(
                    target=self._big_loop, args=(i,), daemon=True,
                    name=f"{self.name}-big{i}")
                self._big.append(th)
                self.threads_created += 1
                th.start()
            while n_little is not None and len(self._little) < n_little:
                j = len(self._little)
                th = threading.Thread(
                    target=self._little_loop, args=(j,), daemon=True,
                    name=f"{self.name}-little{j}")
                self._little.append(th)
                self.threads_created += 1
                th.start()
        return self

    def submit(self, graph: TaskGraph, *, name: str = "job",
               allow_steal: bool = True, t0: Optional[float] = None,
               retry: Optional[RetryPolicy] = None,
               deadline_s: Optional[float] = None,
               job_deadline_s: Optional[float] = None) -> Job:
        graph.validate()
        for t in graph.tasks:
            if t.fn is None:
                raise ValueError(
                    f"task {t.layer}/{t.kind} has no bound fn")
        lanes = graph.lanes()
        self.ensure(n_little=(max(lanes) + 1 if lanes else None), n_big=1)
        job = Job(graph, name, t0, allow_steal, retry, deadline_s,
                  job_deadline_s)
        needs_watchdog = (deadline_s is not None
                          or job_deadline_s is not None
                          or any(t.deadline_s is not None
                                 for t in graph.tasks))
        with self._cv:
            if self._shutdown:
                raise RuntimeError("pool is shut down")
            if self._draining:
                raise RuntimeError("pool is draining")
            if needs_watchdog and self._watchdog is None:
                self._watchdog = threading.Thread(
                    target=self._watchdog_loop, daemon=True,
                    name=f"{self.name}-watchdog")
                self._watchdog.start()
            empty = job._finished()      # empty graph
            if empty:
                job.total_s = time.perf_counter() - job.t0
                self.jobs_completed += 1
            else:
                self._jobs.append(job)
                self._cv.notify_all()
        if empty:
            job._fire_done()
        return job

    def drain(self, timeout: Optional[float] = None) -> bool:
        """Graceful drain: stop accepting new jobs (``submit`` raises) and
        wait for every in-flight job to finish. Returns True when the pool
        drained inside ``timeout`` (False = something is still running —
        the caller decides whether to escalate to ``shutdown``). Workers
        stay alive; ``resume()`` reopens submission."""
        with self._cv:
            self._draining = True
            jobs = list(self._jobs)
        deadline = (None if timeout is None
                    else time.monotonic() + timeout)
        for job in jobs:
            left = (None if deadline is None
                    else max(deadline - time.monotonic(), 0.0))
            if not job.done.wait(left):
                return False
        return True

    def resume(self) -> None:
        """Reopen submission after a ``drain`` (supervisor restart path)."""
        with self._cv:
            self._draining = False
            self._cv.notify_all()

    def shutdown(self, timeout: float = 5.0, *,
                 raise_on_leak: bool = False) -> dict:
        """Stop the pool. A worker stuck inside a hung task cannot join:
        such leaks are DETECTED (``health["workers_lost"]``, the returned
        report) instead of silently ignored, and raised as a typed
        ``WorkerLost`` when ``raise_on_leak`` is set."""
        with self._cv:
            self._shutdown = True
            self._cv.notify_all()
        threads = list(self._big) + list(self._little) + list(self._zombies)
        if self._watchdog is not None:
            threads.append(self._watchdog)
        deadline = time.monotonic() + timeout
        leaked: List[str] = []
        for th in threads:
            th.join(timeout=max(deadline - time.monotonic(), 0.0))
            if th.is_alive():
                leaked.append(th.name)
        report: dict = {"leaked": leaked}
        if leaked:
            self.health["workers_lost"] += len(leaked)
            report["error"] = WorkerLost(
                f"{len(leaked)} pool worker(s) leaked at shutdown (hung "
                f"task?): {', '.join(leaked)}")
            self.leak_report = report
            if raise_on_leak:
                raise report["error"]
        return report

    # -- worker internals ----------------------------------------------------
    def _next_for_little(self, j: int, now: float
                         ) -> Optional[Tuple[Job, int]]:
        for job in self._jobs:
            rl = job._ready_little.get(j)
            if rl:
                tid = _pop_eligible(job, rl, now)
                if tid is not None:
                    return job, tid
        # steal: donor lane (any job that allows it) with most remaining
        # prep cost; take its tail layer's whole chain
        best: Optional[Tuple[Job, int, List[str]]] = None
        best_cost = 0.0
        for job in self._jobs:
            if not job.allow_steal or j >= job.n_lanes:
                continue
            remaining = job._lane_remaining(now)
            remaining.pop(j, None)      # own lane is empty (checked above)
            donor = pick_steal_donor(remaining, job._chain_cost)
            if donor is None:
                continue
            cost = sum(job._chain_cost(n) for n in remaining[donor])
            if best is None or cost > best_cost:
                best, best_cost = (job, donor, remaining[donor]), cost
        if best is not None:
            job, donor, layers = best
            job._move_layer(layers[-1], j)   # steal the tail
            self.steals += 1
            rl = job._ready_little.get(j)
            if rl:
                tid = _pop_eligible(job, rl, now)
                if tid is not None:
                    return job, tid
        for job in self._jobs:
            if job._ready_any:
                tid = _pop_eligible(job, job._ready_any, now)
                if tid is not None:
                    return job, tid
        return None

    def _next_for_big(self, now: float) -> Optional[Tuple[Job, int]]:
        for job in self._jobs:
            if job._ready_big:
                tid = _pop_eligible(job, job._ready_big, now)
                if tid is not None:
                    return job, tid
        for job in self._jobs:
            if job._ready_any:
                tid = _pop_eligible(job, job._ready_any, now)
                if tid is not None:
                    return job, tid
        return None

    def _wait_timeout(self, now: float) -> Optional[float]:
        """Sleep bound for an idle worker: until the earliest backoff-
        deferred READY task becomes eligible (None = no deferred work)."""
        nxt: Optional[float] = None
        for job in self._jobs:
            for tid, nb in job._not_before.items():
                if nb > now and job._state[tid] == _READY:
                    if nxt is None or nb < nxt:
                        nxt = nb
        return None if nxt is None else max(nxt - now, 1e-4)

    def _worker_loop(self, core: str,
                     pick: Callable[[float], Optional[Tuple[Job, int]]],
                     wkind: str, widx: int):
        me = threading.current_thread()
        while True:
            with self._cv:
                item = None
                while item is None:
                    if self._shutdown or me in self._retired:
                        return
                    now = time.perf_counter()
                    item = pick(now)
                    if item is None:
                        self._cv.wait(self._wait_timeout(now))
                job, tid = item
                job._state[tid] = _RUNNING
            self._run(job, tid, core, wkind, widx)

    # -- big/little lane pinning (satellite: NUMA/core locality) -------------
    def _cpuset_for(self, wkind: str, widx: int) -> Optional[Set[int]]:
        """CPU set for one worker under the big.LITTLE split: the top half
        of the allowed cores (at least one) serves big workers, the bottom
        half the little lanes; indices wrap. None = pinning unavailable or
        disabled (clean no-op)."""
        if not self.pin_cores or not _HAS_AFFINITY:
            return None
        try:
            cpus = sorted(os.sched_getaffinity(0))
        except OSError:
            return None
        if len(cpus) < 2:
            return None     # one core: pinning would only serialize lanes
        n_big_cpus = max(1, len(cpus) // 2)
        big_cpus = cpus[len(cpus) - n_big_cpus:]
        little_cpus = cpus[:len(cpus) - n_big_cpus]
        if wkind == "big":
            return {big_cpus[widx % len(big_cpus)]}
        return {little_cpus[widx % len(little_cpus)]}

    def _apply_pin(self, wkind: str, widx: int) -> None:
        """Called by each worker thread on entry (original and watchdog
        replacements alike); records the outcome in ``self.pinned``."""
        cpus = self._cpuset_for(wkind, widx)
        ok = _pin_current_thread(cpus) if cpus is not None else False
        with self._lock:
            self.pinned[threading.current_thread().name] = (
                sorted(cpus) if ok and cpus is not None else None)

    def _big_loop(self, i: int):
        self._apply_pin("big", i)
        self._worker_loop("big" if i == 0 else f"big{i}",
                          self._next_for_big, "big", i)

    def _little_loop(self, j: int):
        self._apply_pin("little", j)
        self._worker_loop(f"little{j}",
                          lambda now: self._next_for_little(j, now),
                          "little", j)

    def cancel_tasks(self, job: Job, tids: List[int], *,
                     reason: str = "race_lost") -> int:
        """Cancel the given tasks of ``job`` that have not started running.

        The warm-state race's loser-retirement path: when a ``fetch_remote``
        task lands a layer's staged weights first, the local
        read→transform→stage chain is cancelled through here (and when the
        local chain wins, the pending fetch task is).  Accounting mirrors
        ``_fail_job_locked`` — a cancelled task counts done, a cancelled
        prep decrements ``_prep_left`` (so preps-done still fires EXACTLY
        once and the admission slot is released), and each cancelled task's
        children are unblocked (``_mark_ready`` only fires for children
        still ``_PENDING``, so a cancelled sibling is never resurrected) —
        but the job stays healthy: no error, no cancellation of anything
        outside ``tids``.

        Tasks already ``_RUNNING`` are left alone — their normal completion
        path owns the accounting, and task fns are value-idempotent so
        letting a lost racer drain is harmless.  Returns the number
        actually cancelled."""
        fire_preps = False
        finished = False
        cancelled: List[int] = []
        with self._cv:
            for tid in tids:
                if job._state[tid] not in (_PENDING, _READY):
                    continue
                t = job.graph.tasks[tid]
                if job._state[tid] == _READY:
                    if tid in job._ready_big:
                        job._ready_big.remove(tid)
                    elif tid in job._ready_any:
                        job._ready_any.remove(tid)
                    else:
                        for rl in job._ready_little.values():
                            if tid in rl:
                                rl.remove(tid)
                                break
                job._state[tid] = _CANCELLED
                job._done_count += 1
                if t.kind in PREP_KINDS:
                    job._prep_left -= 1
                cancelled.append(tid)
            if cancelled:
                self.health["tasks_cancelled"] += len(cancelled)
                job.fault_events.append({
                    "action": "cancel", "reason": reason,
                    "tasks": [f"{job.graph.tasks[i].layer}/"
                              f"{job.graph.tasks[i].kind}"
                              for i in cancelled]})
                for tid in cancelled:
                    for child in job._children[tid]:
                        job._pending[child] -= 1
                        if job._pending[child] == 0 \
                                and job._state[child] == _PENDING:
                            job._mark_ready(child)
                if job._prep_left == 0 and not job._preps_fired:
                    job._preps_fired = True
                    fire_preps = True
                finished = job._finished()
                if finished and job in self._jobs:
                    self._jobs.remove(job)
                    self.jobs_completed += 1
                    job.total_s = time.perf_counter() - job.t0
                self._cv.notify_all()
        if fire_preps:
            job._fire_preps_callbacks()
        if finished:
            job._fire_done()
        return len(cancelled)

    def _fail_job_locked(self, job: Job, tid: int,
                         err: BaseException) -> Tuple[bool, bool]:
        """Under the pool lock: record ``err``, cancel the job's remaining
        tasks, and account task ``tid`` as done. Returns
        ``(fire_preps, finished)`` for the caller to act on OUTSIDE the
        lock."""
        task = job.graph.tasks[tid]
        if job.error is None:    # a job expired by the watchdog keeps its
            job.error = err      # typed DeadlineExceeded as THE error
            self.health["jobs_failed"] += 1
        job.fault_events.append({
            "layer": task.layer, "kind": task.kind, "action": "fail",
            "error": type(err).__name__})
        fire_preps = False
        for t2 in job.graph.tasks:
            if job._state[t2.tid] in (_PENDING, _READY):
                job._state[t2.tid] = _CANCELLED
                job._done_count += 1
        job._ready_big.clear()
        job._ready_any.clear()
        job._ready_little.clear()
        # a failed job must still release its admission slot:
        # cancelled preps will never complete, so fire preps-done now
        if not job._preps_fired:
            job._preps_fired = True
            fire_preps = True
        job._state[tid] = _DONE
        job._done_count += 1
        if task.kind in PREP_KINDS:
            job._prep_left -= 1
        finished = job._finished()
        if finished:
            self._jobs.remove(job)
            self.jobs_completed += 1
            job.total_s = time.perf_counter() - job.t0
        return fire_preps, finished

    def _run(self, job: Job, tid: int, core: str, wkind: str, widx: int):
        task = job.graph.tasks[tid]
        err: Optional[BaseException] = None
        with self._cv:
            epoch = job._epoch[tid]
            deadline = (task.deadline_s if task.deadline_s is not None
                        else job.deadline_s)
            self._running[(id(job), tid)] = {
                "job": job, "tid": tid, "epoch": epoch,
                "t0": time.perf_counter(), "deadline": deadline,
                "thread": threading.current_thread(),
                "wkind": wkind, "widx": widx}
        ts = time.perf_counter()
        try:
            inj = self.fault_injector
            if inj is not None:
                inj.maybe_fault(f"task.{task.kind}",
                                f"{job.name}:{task.layer}")
            task.fn()
        except BaseException as e:      # noqa: BLE001 — forwarded to caller
            err = classify(e, site=f"task.{task.kind}", layer=task.layer)
        te = time.perf_counter()
        fire_preps = False
        finished = False
        with self._cv:
            self._running.pop((id(job), tid), None)
            if job._epoch[tid] != epoch or job._state[tid] != _RUNNING:
                # the watchdog expired this attempt while it ran: the retry
                # owns the completion accounting now — discard ours (task
                # fns are value-idempotent, so a zombie that got this far
                # did no harm)
                self._cv.notify_all()
                return
            if (err is not None and isinstance(err, TransientFault)
                    and not self._shutdown and job.error is None
                    and job._attempts[tid] + 1 < job.retry.max_attempts):
                # bounded in-place retry with backoff: the task goes back to
                # its ready queue, eligible only after the backoff expires
                job._attempts[tid] += 1
                job.retries += 1
                self.health["task_retries"] += 1
                job._not_before[tid] = (
                    time.perf_counter()
                    + job.retry.delay(job._attempts[tid]))
                job.fault_events.append({
                    "layer": task.layer, "kind": task.kind,
                    "action": "retry", "attempt": job._attempts[tid],
                    "error": type(err).__name__})
                job._mark_ready(tid)
                self._cv.notify_all()
                return
            if err is not None:
                fire_preps, finished = self._fail_job_locked(job, tid, err)
            else:
                job.traces.append(OpTrace(task.layer, task.kind, core,
                                          ts - job.t0, te - job.t0))
                job._state[tid] = _DONE
                job._done_count += 1
                if task.kind in PREP_KINDS:
                    job._prep_left -= 1
                    if job._prep_left == 0 and not job._preps_fired:
                        job._preps_fired = True
                        fire_preps = True
                for child in job._children[tid]:
                    job._pending[child] -= 1
                    if job._pending[child] == 0 \
                            and job._state[child] == _PENDING:
                        job._mark_ready(child)
                finished = job._finished()
                if finished:
                    self._jobs.remove(job)
                    self.jobs_completed += 1
                    job.total_s = te - job.t0
            self._cv.notify_all()
        # callbacks and the done event fire outside the pool lock so they
        # may submit follow-up work without deadlocking
        if fire_preps:
            job._fire_preps_callbacks()
        if finished:
            job._fire_done()

    # -- watchdog ------------------------------------------------------------
    def _watchdog_loop(self):
        while True:
            actions: List[Tuple[Job, bool, bool]] = []
            with self._cv:
                self._cv.wait(timeout=self.watchdog_interval_s)
                if self._shutdown:
                    return
                now = time.perf_counter()
                for key in list(self._running):
                    rec = self._running.get(key)
                    if (rec is None or rec["deadline"] is None
                            or now - rec["t0"] <= rec["deadline"]):
                        continue
                    self._expire_locked(rec, now, actions)
                # end-to-end job deadlines: a job past its total budget
                # fails typed NOW — the client gets its fast answer and
                # (one tier up) the front door can shed or fail over
                for job in list(self._jobs):
                    if (job.job_deadline_s is not None
                            and job.error is None
                            and now - job.t0 > job.job_deadline_s):
                        self._expire_job_locked(job, actions)
                if actions:
                    self._cv.notify_all()
            for job, fire_preps, finished in actions:
                if fire_preps:
                    job._fire_preps_callbacks()
                if finished:
                    job._fire_done()

    def _expire_locked(self, rec: dict, now: float,
                       actions: List[Tuple[Job, bool, bool]]):
        """Under the pool lock: expire one overdue running task. Quarantines
        the stuck worker (retire + like-for-like replacement so the lane
        keeps draining), reschedules the lane's unstarted chains onto
        healthy lanes, and retries the expired prep task there — or fails
        the job for an overdue execute task / exhausted retry budget."""
        job, tid = rec["job"], rec["tid"]
        self._running.pop((id(job), tid), None)
        if job._epoch[tid] != rec["epoch"] or job._state[tid] != _RUNNING:
            return  # that attempt already resolved itself
        task = job.graph.tasks[tid]
        self.health["deadline_expired"] += 1
        # any completion the stuck thread eventually reports is a zombie now
        job._epoch[tid] += 1
        th = rec["thread"]
        if th is not None and th.is_alive() and th not in self._retired:
            self._retired.add(th)
            self._zombies.append(th)
            self.health["workers_replaced"] += 1
            widx, wkind = rec["widx"], rec["wkind"]
            if wkind == "little":
                self.health["lanes_quarantined"] += 1
                nth = threading.Thread(
                    target=self._little_loop, args=(widx,), daemon=True,
                    name=f"{self.name}-little{widx}r")
                self._little[widx] = nth
            else:
                nth = threading.Thread(
                    target=self._big_loop, args=(widx,), daemon=True,
                    name=f"{self.name}-big{widx}r")
                self._big[widx] = nth
            self.threads_created += 1
            nth.start()
            if wkind == "little":
                # reschedule the quarantined lane's unstarted chains onto
                # healthy lanes (inverted steal rule: emptiest lane wins)
                for j2 in self._jobs:
                    j2._requeue_from_lane(widx)
        if (task.kind in PREP_KINDS
                and job._attempts[tid] + 1 < job.retry.max_attempts):
            job._attempts[tid] += 1
            job.retries += 1
            self.health["task_retries"] += 1
            job.fault_events.append({
                "layer": task.layer, "kind": task.kind,
                "action": "deadline-retry", "attempt": job._attempts[tid],
                "error": "DeadlineExceeded"})
            if task.affinity == "little" and job.n_lanes > 1:
                # retarget the whole chain off the stuck lane; siblings are
                # still PENDING (they depend on this task), so updating
                # their lane tag is enough
                dest = (task.lane + 1) % job.n_lanes
                for tid2 in job._layer_chain.get(task.layer, []):
                    job.graph.tasks[tid2].lane = dest
                for lane, layers in job._lane_layers.items():
                    if task.layer in layers and lane != dest:
                        layers.remove(task.layer)
                        break
                if task.layer not in job._lane_layers.setdefault(dest, []):
                    job._lane_layers[dest].append(task.layer)
            job._not_before[tid] = now  # retry immediately, elsewhere
            job._mark_ready(tid)
        else:
            err = DeadlineExceeded(
                f"task {task.layer}/{task.kind} exceeded its "
                f"{rec['deadline']:.3f}s deadline", layer=task.layer)
            fire_preps, finished = self._fail_job_locked(job, tid, err)
            actions.append((job, fire_preps, finished))

    def _expire_job_locked(self, job: Job,
                           actions: List[Tuple[Job, bool, bool]]):
        """Under the pool lock: fail a job whose END-TO-END deadline
        (``job_deadline_s``, measured from ``t0``) is blown. Pending/ready
        tasks are cancelled; tasks already running finish on their own (task
        fns are value-idempotent, so letting them drain is harmless) and the
        job's done event fires once the last one returns."""
        job.error = DeadlineExceeded(
            f"job {job.name!r} exceeded its end-to-end "
            f"{job.job_deadline_s:.3f}s deadline")
        self.health["jobs_failed"] += 1
        self.health["job_deadline_expired"] += 1
        job.fault_events.append({
            "action": "job-deadline-fail", "error": "DeadlineExceeded",
            "deadline_s": job.job_deadline_s})
        for t2 in job.graph.tasks:
            if job._state[t2.tid] in (_PENDING, _READY):
                job._state[t2.tid] = _CANCELLED
                job._done_count += 1
        job._ready_big.clear()
        job._ready_any.clear()
        job._ready_little.clear()
        fire_preps = False
        # cancelled preps will never complete: release the admission slot
        if not job._preps_fired:
            job._preps_fired = True
            fire_preps = True
        finished = job._finished()
        if finished:
            self._jobs.remove(job)
            self.jobs_completed += 1
            job.total_s = time.perf_counter() - job.t0
        actions.append((job, fire_preps, finished))


# ---------------------------------------------------------------------------
# the process-wide pool
# ---------------------------------------------------------------------------
_GLOBAL: Optional[CorePool] = None
_GLOBAL_LOCK = threading.Lock()


def get_core_pool(n_little: int = 3, n_big: int = 2) -> CorePool:
    """The process-wide persistent pool, created on first use and grown on
    demand — every runtime and every model share it."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        if _GLOBAL is None:
            _GLOBAL = CorePool(n_big=n_big, n_little=n_little, name="global")
            return _GLOBAL
    return _GLOBAL.ensure(n_little=n_little, n_big=n_big)


def reset_core_pool() -> None:
    """Shut the global pool down (tests only — the whole point of the pool
    is that production never does this)."""
    global _GLOBAL
    with _GLOBAL_LOCK:
        if _GLOBAL is not None:
            _GLOBAL.shutdown()
            _GLOBAL = None
