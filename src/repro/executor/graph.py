"""Typed task graphs — one representation shared by the plan and the runtime.

A scheduling ``Plan`` (kernel/cache choices + prep placement) compiles into
an explicit DAG of typed tasks:

  * per weighted layer, a *prep chain* ``read [→ transform] → stage`` whose
    tasks carry the lane (little core index) or big-core affinity the plan
    assigned, plus the layer's estimated prep cost (the work stealer's
    donor metric);
  * per layer, an ``execute`` task on the big cores, depending on the
    layer's ``stage`` and the previous layer's ``execute`` (the exec chain);
  * optionally, per weighted layer, a dep-free ``fetch_remote`` task
    (affinity ``any``) that races the local prep chain by streaming the
    layer's staged weights from a sibling worker — first finisher wins,
    the loser is cancelled (``CorePool.cancel_tasks``).  ``fetch_remote``
    is deliberately NOT a ``PREP_KINDS`` member: prep accounting
    (admission slots, preps-done, steal metrics) describes the *local*
    chain, and a fetch win retires that chain through cancellation;
  * arbitrary extra tasks (e.g. the LLM bridge's decode-path ``pack`` ops)
    can be appended with explicit deps before submission.

``simulate_graph`` maps a compiled graph back onto the scheduler's
event-driven ``simulate`` — the plan's makespan model and the executor run
the *same* structure, which the equivalence tests pin down.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from repro.core.scheduler import Plan, simulate

# task kinds that count as "preparation" (admission control + accounting)
PREP_KINDS = ("read", "transform", "stage")

#: affinity tags: ``big`` (big-core workers), ``little`` (the lane's little
#: worker, stealable), ``any`` (whoever idles first — deferred staging,
#: background packing)
AFFINITIES = ("big", "little", "any")


@dataclass
class OpTrace:
    layer: str
    kind: str
    core: str
    start: float
    end: float


@dataclass
class Task:
    tid: int
    layer: str
    kind: str                       # read | transform | stage | execute | ...
    affinity: str                   # big | little | any
    lane: Optional[int] = None      # little lane for affinity == "little"
    deps: Tuple[int, ...] = ()
    cost: float = 0.0               # est. seconds; chain head carries the
                                    # layer's full prep cost (steal metric)
    fn: Optional[Callable[[], None]] = None
    deadline_s: Optional[float] = None  # per-task deadline; None = inherit
                                        # the job-level default (pool watchdog)
    depth: int = 1                  # read tasks: planned I/O queue depth —
                                    # how many lane successors to submit
                                    # alongside this read (Plan.read_depth)


class TaskGraph:
    def __init__(self):
        self.tasks: List[Task] = []
        self._index: Dict[Tuple[str, str], int] = {}

    def add(self, layer: str, kind: str, *, affinity: str,
            lane: Optional[int] = None, deps: Sequence[int] = (),
            cost: float = 0.0, fn: Optional[Callable] = None) -> Task:
        assert affinity in AFFINITIES, affinity
        if affinity == "little" and lane is None:
            # a laneless little task would sit in a queue no worker drains
            # and no steal reaches — the job would hang forever
            raise ValueError(
                f"little-affinity task {layer}/{kind} needs a lane")
        t = Task(tid=len(self.tasks), layer=layer, kind=kind,
                 affinity=affinity, lane=lane, deps=tuple(deps), cost=cost,
                 fn=fn)
        self.tasks.append(t)
        self._index[(layer, kind)] = t.tid
        return t

    def task(self, layer: str, kind: str) -> Optional[Task]:
        tid = self._index.get((layer, kind))
        return None if tid is None else self.tasks[tid]

    def lanes(self) -> List[int]:
        return sorted({t.lane for t in self.tasks
                       if t.affinity == "little" and t.lane is not None})

    def validate(self) -> None:
        """Deps must point backwards (the builder emits topological order)."""
        for t in self.tasks:
            for d in t.deps:
                if not (0 <= d < t.tid):
                    raise ValueError(
                        f"task {t.tid} ({t.layer}/{t.kind}) has forward or "
                        f"dangling dep {d}")

    # -- plan-structure recovery (simulation / introspection) ---------------
    def exec_order(self) -> List[str]:
        return [t.layer for t in self.tasks if t.kind == "execute"]

    def prep_chains(self) -> Dict[str, List[Task]]:
        """Per-layer prep chain (read/transform/stage tasks, tid order)."""
        chains: Dict[str, List[Task]] = {}
        for t in self.tasks:
            if t.kind in PREP_KINDS:
                chains.setdefault(t.layer, []).append(t)
        return chains

    def big_prep_layers(self) -> List[str]:
        seen, out = set(), []
        for t in self.tasks:
            if t.kind in PREP_KINDS and t.affinity == "big" \
                    and t.layer not in seen:
                seen.add(t.layer)
                out.append(t.layer)
        return out

    def lane_queues(self) -> Dict[int, List[str]]:
        queues: Dict[int, List[str]] = {}
        for t in self.tasks:
            if t.kind in PREP_KINDS and t.affinity == "little":
                q = queues.setdefault(t.lane, [])
                if t.layer not in q:
                    q.append(t.layer)
        return queues


def compile_plan(
    order: Sequence[str],
    plan: Plan,
    *,
    weighted: Dict[str, bool],
    use_cache: Dict[str, bool],
    prep_costs: Optional[Dict[str, float]] = None,
    stage_in_prep: bool = True,
    deferred_stage_affinity: str = "any",
    read_depth: Optional[int] = None,
    fetch_layers: Optional[Sequence[str]] = None,
) -> TaskGraph:
    """Compile a scheduling ``Plan`` into a typed task graph.

    ``weighted`` marks layers with on-disk weights (weightless/stateless
    units get only an ``execute`` task, like the runtime always treated
    them). With ``stage_in_prep`` the ``stage`` op is the tail of the prep
    chain on the same core; otherwise it is emitted with
    ``deferred_stage_affinity`` (``any`` = prefetch: whoever idles first,
    including the big core right before the layer's execute; ``big`` =
    strictly inline on the big cores).

    ``read_depth`` (default: the plan's) stamps every read task with the
    I/O queue depth the async engine should sustain — the runtime's read
    op submits that many lane successors before reaping its own layer.

    ``fetch_layers`` names weighted layers for which a ``fetch_remote``
    race task is also emitted: dep-free, affinity ``any``, placed FIRST
    (lowest tids) so idle workers start the peer stream before local
    chains queue up.  The execute chain keeps its dep on ``stage`` only —
    a fetch win satisfies it by cancelling the stage task, a fetch loss
    or fault leaves the local chain authoritative."""
    prep_costs = prep_costs or {}
    depth = max(1, int(plan.read_depth if read_depth is None else read_depth))
    g = TaskGraph()
    for name in (fetch_layers or ()):
        if weighted.get(name, False):
            g.add(name, "fetch_remote", affinity="any")
    placement: Dict[str, Tuple[str, Optional[int]]] = {}
    for i in plan.big_prep:
        placement[order[i]] = ("big", None)
    for j, q in enumerate(plan.little_queues):
        for i in q:
            placement[order[i]] = ("little", j)

    def emit_chain(name: str):
        aff, lane = placement.get(name, ("big", None))
        cost = float(prep_costs.get(name, 0.0))
        head = g.add(name, "read", affinity=aff, lane=lane, cost=cost)
        head.depth = depth
        prev = head
        if not use_cache.get(name, False):
            prev = g.add(name, "transform", affinity=aff, lane=lane,
                         deps=(prev.tid,))
        if stage_in_prep:
            g.add(name, "stage", affinity=aff, lane=lane, deps=(prev.tid,))
        else:
            g.add(name, "stage", affinity=deferred_stage_affinity,
                  lane=None, deps=(prev.tid,))

    # big-core preps first (tid order is the big worker's priority order:
    # the plan's big preps run before the exec chain, as Algorithm 1 lays
    # them out), then the little lanes in queue order, then the exec chain.
    for i in plan.big_prep:
        if weighted.get(order[i], False):
            emit_chain(order[i])
    for q in plan.little_queues:
        for i in q:
            if weighted.get(order[i], False):
                emit_chain(order[i])
    # any weighted layer the plan did not place (defensive): big cores
    for name in order:
        if weighted.get(name, False) and g.task(name, "read") is None:
            placement.setdefault(name, ("big", None))
            emit_chain(name)

    prev_exec: Optional[Task] = None
    for name in order:
        deps = []
        st = g.task(name, "stage")
        if st is not None:
            deps.append(st.tid)
        if prev_exec is not None:
            deps.append(prev_exec.tid)
        prev_exec = g.add(name, "execute", affinity="big", deps=deps)
    g.validate()
    return g


def simulate_graph(
    graph: TaskGraph,
    order: Sequence[str],
    prep_little: Sequence[float],
    prep_big: Sequence[float],
    exec_big: Sequence[float],
    **kw,
) -> Tuple[float, Dict[str, float]]:
    """Deterministic makespan of a compiled graph — recovers the plan
    structure (big preps, lane queues) from the graph's tasks and feeds the
    scheduler's event-driven ``simulate``: proof that the executor and the
    planner model one and the same structure."""
    idx = {n: i for i, n in enumerate(order)}
    big_prep = [idx[n] for n in graph.big_prep_layers()]
    queues = graph.lane_queues()
    lanes = sorted(queues)
    little_queues = [[idx[n] for n in queues[j]] for j in lanes]
    # weightless layers emit no prep chain; account them as (near-zero-cost)
    # big preps so the simulator sees every layer prepared
    placed = set(big_prep) | {i for q in little_queues for i in q}
    big_prep += [i for i in range(len(order)) if i not in placed]
    return simulate(prep_little, prep_big, exec_big, big_prep,
                    little_queues, **kw)
