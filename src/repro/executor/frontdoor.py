"""FrontDoor — supervised multi-worker serving tier above ColdServer.

One front-door process owns N **worker processes**, each running a full
``ColdServer`` (own engines, own store root, own pool) behind a
length-prefixed pickle RPC channel on a localhost socket. The front door
adds the fault/latency tier the single-process server cannot provide:

  * **supervision** — every worker heartbeats its serializable
    ``health()`` snapshot; a missed-heartbeat budget (``HeartbeatPolicy``)
    or a dead pid marks the worker lost, and the supervisor restarts it
    under exponential backoff (``RestartPolicy``);
  * **crash failover** — requests in flight on a lost worker are failed
    over to a sibling at the head of their lane queue. Cold starts are
    idempotent by construction (same seeded weights, plans resolved from
    one shared ``ProfileDB``), so the replayed output is bit-identical to
    an isolated run; only when every sibling is gone does the client see
    a typed ``WorkerLost``;
  * **deadline propagation** — a request's end-to-end budget is decayed
    by its queue wait and an RPC-overhead allowance before it reaches the
    worker, where it becomes the pool watchdog's per-job deadline
    (typed ``DeadlineExceeded`` once blown);
  * **priority lanes + load shedding** — two admission lanes: interactive
    requests always dispatch first and ``interactive_reserve`` worker
    slots are never given to batch work, so an interactive arrival waits
    at most ~one service time behind the reserve. Requests that cannot
    make their deadline (budget below the RPC floor, or the lane's
    estimated queue delay exceeds the remaining budget) and requests for
    quarantined models are shed with typed faults *before* consuming a
    worker slot;
  * **cost-based cache-aware routing** — heartbeat health snapshots carry
    each worker's resident (device-warm) and previously-served
    (page-cache warm) model sets, per-model resident byte counts, and its
    measured peer-link bandwidth; routing scores every capable worker by
    estimated time-to-result, where a non-resident worker's cold cost is
    ``min(local cold estimate, peer transfer_estimate)`` — so the front
    door can deliberately send a request to a *cold* worker when pulling
    the warm state from a sibling's RAM beats that worker's disk. The
    dispatched ``cold_start`` carries the matching ``peers`` list and the
    worker races the transfer against its local prep chains
    (``docs/warm_transfer.md``).

Protocol (length-prefixed pickled dicts; workers connect back to the
front door's listener): ``hello`` → (``add_model`` → ``model_ready``)*,
then ``cold_start`` → ``result``/``error`` interleaved with
``heartbeat``, and ``drain``/``drained`` + ``shutdown`` at the end.
"""
from __future__ import annotations

import os
import pickle
import socket
import struct
import subprocess
import sys
import threading
import time
from collections import OrderedDict, deque
from pathlib import Path
from typing import Any, Deque, Dict, List, Optional, Tuple

import repro
from repro import faults as _faults
from repro.core.scheduler import transfer_estimate
from repro.faults import (
    DeadlineExceeded, Fault, HeartbeatPolicy, JobTimeout, ModelQuarantined,
    RepairLog, RestartPolicy, WorkerLost,
)

# -- wire format -------------------------------------------------------------
# 4-byte big-endian length + pickled dict. Localhost-only, both ends are this
# codebase — pickle is the zero-dependency way to move numpy arrays intact.

_LEN = struct.Struct(">I")


def send_msg(sock: socket.socket, obj: Dict[str, Any],
             lock: Optional[threading.Lock] = None) -> None:
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    data = _LEN.pack(len(payload)) + payload
    if lock is not None:
        with lock:
            sock.sendall(data)
    else:
        sock.sendall(data)


def recv_msg(sock: socket.socket) -> Optional[Dict[str, Any]]:
    """One framed message; None on clean EOF (peer gone)."""
    header = _recv_exact(sock, _LEN.size)
    if header is None:
        return None
    (n,) = _LEN.unpack(header)
    payload = _recv_exact(sock, n)
    if payload is None:
        return None
    return pickle.loads(payload)


def _recv_exact(sock: socket.socket, n: int) -> Optional[bytes]:
    buf = b""
    while len(buf) < n:
        try:
            chunk = sock.recv(n - len(buf))
        except OSError:
            return None
        if not chunk:
            return None
        buf += chunk
    return buf


def rebuild_fault(err: Dict[str, Any]) -> BaseException:
    """Typed fault from a worker's ``describe()``-shaped error dict — the
    taxonomy crosses the process boundary instead of degrading to
    RuntimeError."""
    cls = getattr(_faults, str(err.get("type", "")), None)
    if isinstance(cls, type) and issubclass(cls, Fault):
        return cls(str(err.get("msg", "")),
                   layer=err.get("layer"), kernel=err.get("kernel"),
                   site=err.get("site"), retry_after=err.get("retry_after"))
    return RuntimeError(str(err.get("msg", "")) or repr(err))


# -- request + worker handles ------------------------------------------------

INTERACTIVE = "interactive"
BATCH = "batch"


class FrontDoorRequest:
    """Client-side handle for one front-door request."""

    def __init__(self, rid: int, model: str, x, lane: str,
                 deadline_s: Optional[float],
                 pinned: Optional[str] = None):
        self.rid = rid
        self.model = model
        self.x = x
        self.lane = lane
        self.deadline_s = deadline_s           # end-to-end budget
        self.pinned = pinned                   # routing pin (benchmarks/ops)
        self.t0 = time.monotonic()
        self.attempts = 0                      # dispatch attempts (failovers)
        self.worker: Optional[str] = None
        self._done = threading.Event()
        self._result: Optional[Dict[str, Any]] = None
        self._error: Optional[BaseException] = None

    # budget left right now (None = unbounded)
    def remaining_s(self) -> Optional[float]:
        if self.deadline_s is None:
            return None
        return self.deadline_s - (time.monotonic() - self.t0)

    def _complete(self, result=None, error=None):
        self._result = result
        self._error = error
        self._done.set()

    def done(self) -> bool:
        return self._done.is_set()

    def result(self, timeout: Optional[float] = None) -> Dict[str, Any]:
        if not self._done.wait(timeout):
            raise JobTimeout(
                f"front-door request {self.rid} ({self.model!r}) still "
                f"pending after {timeout}s wait")
        if self._error is not None:
            raise self._error
        return self._result


class _Worker:
    """Supervisor-side state for one worker process."""

    def __init__(self, wid: str):
        self.wid = wid
        self.proc: Optional[subprocess.Popen] = None
        self.sock: Optional[socket.socket] = None
        self.send_lock = threading.Lock()
        self.alive = False
        self.last_heartbeat = 0.0
        self.health: Dict[str, Any] = {}
        self.in_flight: Dict[int, FrontDoorRequest] = {}
        self.warm_port: Optional[int] = None   # warm-state transfer port
        self.restarts = 0                      # completed restarts
        self.down_at: Optional[float] = None   # when it was declared lost
        self.restart_due: Optional[float] = None
        self.last_restart_delay = 0.0
        self.ready_models: set = set()
        self.model_ready_evt: Dict[str, threading.Event] = {}
        self.hello_evt = threading.Event()

    def capacity(self, max_inflight: int) -> int:
        return max(0, max_inflight - len(self.in_flight)) if self.alive else 0


class FrontDoor:
    """Supervised multi-worker front door (see module docstring)."""

    def __init__(
        self,
        root,
        *,
        n_workers: int = 2,
        max_inflight_per_worker: int = 2,
        interactive_reserve: int = 1,
        heartbeat: HeartbeatPolicy = HeartbeatPolicy(),
        restart: RestartPolicy = RestartPolicy(base_s=0.1, max_s=5.0),
        max_failovers: int = 2,
        rpc_overhead_s: float = 0.050,
        spawn_timeout_s: float = 120.0,
        worker_args: Optional[Dict[str, Any]] = None,
    ):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.n_workers = n_workers
        self.max_inflight = max_inflight_per_worker
        self.interactive_reserve = min(interactive_reserve,
                                       n_workers * max_inflight_per_worker)
        self.heartbeat = heartbeat
        self.restart = restart
        self.max_failovers = max_failovers
        self.rpc_overhead_s = rpc_overhead_s
        self.spawn_timeout_s = spawn_timeout_s
        self.worker_args = dict(worker_args or {})
        # one profile DB file shared by every worker: worker 0 measures
        # during model registration, siblings reload and hit — identical
        # plans, hence bit-identical outputs across workers (the failover
        # correctness invariant)
        self.profile_db_path = self.root / "profile_db.json"
        self.repairs = RepairLog(self.root / "frontdoor_repairs.jsonl")

        self._lock = threading.Lock()
        self._dispatch_cv = threading.Condition(self._lock)
        self._workers: "OrderedDict[str, _Worker]" = OrderedDict(
            (f"w{i}", _Worker(f"w{i}")) for i in range(n_workers))
        self._models: "OrderedDict[str, Dict[str, Any]]" = OrderedDict()
        self._queues: Dict[str, Deque[FrontDoorRequest]] = {
            INTERACTIVE: deque(), BATCH: deque()}
        self._rid = 0
        self._quarantine: Dict[str, float] = {}   # model -> retry-at (mono)
        self._svc_ewma: Dict[str, float] = {}     # model -> service time est
        self._batch_in_flight = 0
        self._shutdown = False
        self.stats = {
            "requests": 0, "completed": 0, "failed": 0,
            "shed_deadline": 0, "shed_quarantine": 0,
            "failovers": 0, "failover_lost": 0,
            "worker_restarts": 0, "workers_lost": 0,
            "dispatched_interactive": 0, "dispatched_batch": 0,
            "warm_results": 0,
        }
        self._listener: Optional[socket.socket] = None
        self._threads: List[threading.Thread] = []

    # -- lifecycle -----------------------------------------------------------
    def start(self) -> "FrontDoor":
        self._listener = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._listener.bind(("127.0.0.1", 0))
        self._listener.listen(self.n_workers * 2)
        self._port = self._listener.getsockname()[1]
        self._spawn_thread("fd-accept", self._accept_loop)
        for w in self._workers.values():
            self._spawn_worker(w)
        for w in self._workers.values():
            if not w.hello_evt.wait(self.spawn_timeout_s):
                raise RuntimeError(f"worker {w.wid} never said hello")
        self._spawn_thread("fd-dispatch", self._dispatch_loop)
        self._spawn_thread("fd-supervisor", self._supervise_loop)
        return self

    def _spawn_thread(self, name, target):
        t = threading.Thread(target=target, name=name, daemon=True)
        t.start()
        self._threads.append(t)

    def _spawn_worker(self, w: _Worker) -> None:
        wroot = self.root / w.wid
        wroot.mkdir(parents=True, exist_ok=True)
        # namespace package: __path__[0] is .../src/repro
        src = str(Path(list(repro.__path__)[0]).resolve().parent)
        env = dict(os.environ)
        env["PYTHONPATH"] = src + os.pathsep + env.get("PYTHONPATH", "")
        argv = [sys.executable, "-m", "repro.executor.worker",
                "--host", "127.0.0.1", "--port", str(self._port),
                "--worker-id", w.wid, "--root", str(wroot),
                "--profile-db", str(self.profile_db_path),
                "--heartbeat-interval", str(self.heartbeat.interval_s)]
        for k, v in self.worker_args.items():
            argv += [f"--{k.replace('_', '-')}", str(v)]
        w.hello_evt.clear()
        w.proc = subprocess.Popen(argv, env=env,
                                  stdout=subprocess.DEVNULL,
                                  stderr=subprocess.DEVNULL)

    def _accept_loop(self):
        while not self._shutdown:
            try:
                sock, _ = self._listener.accept()
            except OSError:
                return  # listener closed — shutting down
            try:
                hello = recv_msg(sock)
            except Exception:
                sock.close()
                continue
            if not hello or hello.get("type") != "hello":
                sock.close()
                continue
            wid = hello.get("worker")
            w = self._workers.get(wid)
            if w is None:
                sock.close()
                continue
            with self._lock:
                w.sock = sock
                w.alive = True
                w.last_heartbeat = time.monotonic()
                w.warm_port = hello.get("warm_port")
                w.down_at = None
                w.restart_due = None
            threading.Thread(target=self._recv_loop, args=(w, sock),
                             name=f"fd-recv-{wid}", daemon=True).start()
            w.hello_evt.set()
            with self._dispatch_cv:
                self._dispatch_cv.notify_all()

    # -- model registration --------------------------------------------------
    def add_model(self, name: str, builder: str, /, **kwargs) -> None:
        """Register a model on every worker. ``builder`` is
        ``"module:function"``; calling it with ``kwargs`` must return
        ``(layers, x_example)`` deterministically (seeded) — determinism is
        what makes crash failover bit-identical.

        Registration is **sequential**: the first worker profiles and saves
        into the shared profile DB; each subsequent worker reloads the DB,
        hits every shape class, and lands on the same plan."""
        spec = {"name": name, "builder": builder, "kwargs": kwargs}
        self._models[name] = spec
        for w in self._workers.values():
            self._register_on(w, spec, timeout=self.spawn_timeout_s)

    def _register_on(self, w: _Worker, spec: Dict[str, Any],
                     timeout: float) -> None:
        name = spec["name"]
        evt = threading.Event()
        w.model_ready_evt[name] = evt
        send_msg(w.sock, {"type": "add_model", **spec}, w.send_lock)
        if not evt.wait(timeout):
            raise RuntimeError(
                f"worker {w.wid} did not confirm model {name!r}")

    # -- client API ----------------------------------------------------------
    def request(self, model: str, x, *, deadline_s: Optional[float] = None,
                lane: str = INTERACTIVE,
                worker: Optional[str] = None) -> FrontDoorRequest:
        """Enqueue one request. Sheds with a typed fault — *before* the
        request ever holds a worker slot — when the model is in quarantine
        or the budget cannot survive the queue + RPC floor.
        ``worker`` pins routing to one worker id (benchmark/operator lever
        — e.g. forcing a second worker's cold start to measure the peer
        warm-state transfer); the pin falls back to normal routing if that
        worker is down."""
        if lane not in (INTERACTIVE, BATCH):
            raise ValueError(f"unknown lane {lane!r}")
        if model not in self._models:
            raise KeyError(f"model {model!r} not registered")
        now = time.monotonic()
        with self._lock:
            if self._shutdown:
                raise RuntimeError("front door is shut down")
            until = self._quarantine.get(model)
            if until is not None and now < until:
                self.stats["shed_quarantine"] += 1
                raise ModelQuarantined(
                    f"model {model!r} quarantined fleet-wide; retry in "
                    f"{until - now:.2f}s", retry_after=until - now)
            if deadline_s is not None:
                if deadline_s <= self.rpc_overhead_s:
                    self.stats["shed_deadline"] += 1
                    raise DeadlineExceeded(
                        f"budget {deadline_s:.3f}s below the "
                        f"{self.rpc_overhead_s:.3f}s RPC floor — shed "
                        f"before queuing")
                est = self._queue_delay_est_locked(model, lane)
                if est is not None and est > deadline_s - self.rpc_overhead_s:
                    self.stats["shed_deadline"] += 1
                    raise DeadlineExceeded(
                        f"estimated {lane} queue delay {est:.3f}s exceeds "
                        f"remaining budget {deadline_s:.3f}s — shed before "
                        f"queuing")
            self._rid += 1
            req = FrontDoorRequest(self._rid, model, x, lane, deadline_s,
                                   pinned=worker)
            self.stats["requests"] += 1
            self._queues[lane].append(req)
            self._dispatch_cv.notify_all()
        return req

    def _queue_delay_est_locked(self, model: str,
                                lane: str) -> Optional[float]:
        """Conservative wait estimate: jobs ahead in this lane (plus every
        interactive job, which preempts batch) over live dispatch slots,
        times the model's EWMA service time. None until a completion has
        seeded the EWMA — never shed on zero knowledge."""
        svc = self._svc_ewma.get(model)
        if svc is None:
            return None
        ahead = len(self._queues[lane])
        if lane == BATCH:
            ahead += len(self._queues[INTERACTIVE])
        slots = sum(w.capacity(self.max_inflight)
                    for w in self._workers.values())
        slots = max(1, slots)
        return (ahead // slots) * svc

    # -- dispatcher ----------------------------------------------------------
    def _dispatch_loop(self):
        while True:
            with self._dispatch_cv:
                while not self._shutdown and not self._dispatchable_locked():
                    self._dispatch_cv.wait(0.05)
                if self._shutdown:
                    return
                picks = []
                while True:
                    pick = self._pick_locked()
                    if pick is None:
                        break
                    req, w = pick
                    req.worker = w.wid
                    req.attempts += 1
                    w.in_flight[req.rid] = req
                    if req.lane == BATCH:
                        self._batch_in_flight += 1
                        self.stats["dispatched_batch"] += 1
                    else:
                        self.stats["dispatched_interactive"] += 1
                    picks.append((req, w))
            for req, w in picks:
                self._send_request(req, w)

    def _dispatchable_locked(self) -> bool:
        return bool(self._queues[INTERACTIVE] or self._queues[BATCH])

    def _pick_locked(self):
        """Next (request, worker): interactive lane strictly first; batch
        only while it leaves ``interactive_reserve`` slots free. Routing
        prefers device-resident, then previously-served (cache-warm), then
        least-loaded."""
        total = sum(w.capacity(self.max_inflight)
                    for w in self._workers.values())
        if total <= 0:
            return None
        req = None
        if self._queues[INTERACTIVE]:
            req = self._queues[INTERACTIVE].popleft()
        elif self._queues[BATCH] and total > self.interactive_reserve:
            # the reserve is measured in FREE slots: batch may take this
            # slot only if at least interactive_reserve+1 are free now
            req = self._queues[BATCH].popleft()
        if req is None:
            return None
        w = self._route_locked(req.model, pinned=req.pinned)
        if w is None:                   # lost the race for the last slot
            self._queues[req.lane].appendleft(req)
            return None
        return req, w

    def _transfer_donors_locked(self, model: str
                                ) -> List[Tuple[str, int, float]]:
        """Alive workers holding ``model`` device-resident with a reachable
        warm-state port: ``(wid, resident_bytes, link_bytes_per_s)`` —
        both the routing cost model and the dispatched request's ``peers``
        list come from here, so what routing assumed is what the worker
        actually races against."""
        donors = []
        for w in self._workers.values():
            if not w.alive or w.warm_port is None:
                continue
            h = w.health or {}
            if model not in (h.get("resident") or ()):
                continue
            nbytes = int((h.get("resident_model_bytes") or {})
                         .get(model) or 0)
            if nbytes <= 0:
                nbytes = int(h.get("resident_bytes") or 0)
            donors.append((w.wid, nbytes,
                           float(h.get("link_bytes_per_s") or 0.0)))
        return donors

    def _route_locked(self, model: str, *,
                      pinned: Optional[str] = None) -> Optional[_Worker]:
        """Cost-based routing: pick the worker with the lowest estimated
        time-to-result, where a NON-resident worker's cold cost is
        ``min(local cold estimate, peer transfer estimate)`` — the same
        ``transfer_estimate`` arithmetic the worker's own race-arming
        decision uses (``ColdServer._maybe_peer_fetch``), so the front
        door can deliberately route to a cold worker when a sibling's RAM
        beats that worker's disk:

          resident        → svc                      (warm run)
          served before   → svc + min(svc,  transfer)  (page cache warm)
          never served    → svc + min(3·svc, transfer)  (cold disk)
          queue delay     → + in_flight × svc

        Cost ties (in particular before any completion seeds the model's
        service-time EWMA, when every estimate is 0) break by warmth tier
        (resident > served > cold) and then least-loaded — never a shed,
        never a stall, exactly the pre-cost-model policy."""
        if pinned is not None:
            w = self._workers.get(pinned)
            if w is not None and w.capacity(self.max_inflight) > 0:
                return w
            if w is not None and w.alive:
                return None     # pinned worker is full: wait for its slot
            # pinned worker is down — fall through to normal routing
        svc = self._svc_ewma.get(model) or 0.0
        donors = self._transfer_donors_locked(model)
        best, best_key = None, None
        for w in self._workers.values():
            if w.capacity(self.max_inflight) <= 0:
                continue
            h = w.health or {}
            resident = model in (h.get("resident") or ())
            served = (h.get("served") or {}).get(model, 0) > 0
            if resident:
                prep = 0.0
            else:
                local = svc * (1.0 if served else 3.0)
                transfer = min(
                    (transfer_estimate(nb, bw)
                     for wid, nb, bw in donors if wid != w.wid),
                    default=float("inf"))
                prep = min(local, transfer) if donors else local
            cost = prep + svc + len(w.in_flight) * svc
            tier = 0 if resident else (1 if served else 2)
            key = (cost, tier, len(w.in_flight))
            if best_key is None or key < best_key:
                best, best_key = w, key
        return best

    def _send_request(self, req: FrontDoorRequest, w: _Worker):
        remaining = req.remaining_s()
        if remaining is not None:
            remaining -= self.rpc_overhead_s
            if remaining <= 0:
                self._finish(req, w, error=DeadlineExceeded(
                    f"request {req.rid} ({req.model!r}) spent its budget "
                    f"queued at the front door"))
                with self._lock:
                    self.stats["shed_deadline"] += 1
                return
        # sibling workers holding this model resident: the worker arms a
        # warm-state fetch race against them iff the same transfer estimate
        # routing just used says the peer beats its local disk
        with self._lock:
            peers = [{"host": "127.0.0.1", "port": self._workers[wid].warm_port,
                      "resident_bytes": nb, "link_bytes_per_s": bw}
                     for wid, nb, bw in
                     self._transfer_donors_locked(req.model)
                     if wid != w.wid]
        try:
            send_msg(w.sock, {"type": "cold_start", "rid": req.rid,
                              "model": req.model, "x": req.x,
                              "deadline_s": remaining, "lane": req.lane,
                              "peers": peers},
                     w.send_lock)
        except OSError:
            # socket died under us; the supervisor will fail this over
            pass

    # -- worker receive path -------------------------------------------------
    def _recv_loop(self, w: _Worker, sock: socket.socket):
        while True:
            try:
                msg = recv_msg(sock)
            except Exception:
                msg = None
            if msg is None:
                return  # EOF: supervisor declares the loss
            t = msg.get("type")
            if t == "heartbeat":
                with self._lock:
                    w.last_heartbeat = time.monotonic()
                    w.health = msg.get("health") or {}
            elif t == "model_ready":
                w.ready_models.add(msg.get("name"))
                evt = w.model_ready_evt.get(msg.get("name"))
                if evt is not None:
                    evt.set()
            elif t == "result":
                req = w.in_flight.get(msg.get("rid"))
                if req is not None:
                    with self._lock:
                        svc = float(msg.get("total_s") or 0.0)
                        prev = self._svc_ewma.get(req.model)
                        self._svc_ewma[req.model] = (
                            svc if prev is None else 0.7 * prev + 0.3 * svc)
                        self._quarantine.pop(req.model, None)
                        if msg.get("warm"):
                            self.stats["warm_results"] += 1
                    self._finish(req, w, result=msg)
            elif t == "error":
                req = w.in_flight.get(msg.get("rid"))
                if req is not None:
                    fault = rebuild_fault(msg.get("fault") or {})
                    if isinstance(fault, ModelQuarantined) \
                            and fault.retry_after:
                        with self._lock:
                            self._quarantine[req.model] = (
                                time.monotonic() + fault.retry_after)
                    self._finish(req, w, error=fault)

    def _finish(self, req: FrontDoorRequest, w: Optional[_Worker], *,
                result=None, error=None):
        with self._lock:
            if w is not None:
                w.in_flight.pop(req.rid, None)
            if req.lane == BATCH and req.worker is not None:
                self._batch_in_flight = max(0, self._batch_in_flight - 1)
            self.stats["completed" if error is None else "failed"] += 1
            self._dispatch_cv.notify_all()
        req._complete(result=result, error=error)

    # -- supervisor ----------------------------------------------------------
    def _supervise_loop(self):
        while not self._shutdown:
            time.sleep(self.heartbeat.interval_s / 2)
            now = time.monotonic()
            lost: List[_Worker] = []
            due: List[_Worker] = []
            with self._lock:
                for w in self._workers.values():
                    if w.alive:
                        dead_pid = (w.proc is not None
                                    and w.proc.poll() is not None)
                        stale = (now - w.last_heartbeat
                                 > self.heartbeat.timeout_s)
                        if dead_pid or stale:
                            w.alive = False
                            w.down_at = now
                            w.restarts += 1
                            delay = self.restart.delay(w.restarts)
                            w.last_restart_delay = delay
                            exhausted = (
                                self.restart.max_restarts is not None
                                and w.restarts > self.restart.max_restarts)
                            w.restart_due = None if exhausted else now + delay
                            self.stats["workers_lost"] += 1
                            lost.append(w)
                    elif w.restart_due is not None and now >= w.restart_due:
                        w.restart_due = None
                        due.append(w)
            for w in lost:
                self._on_worker_lost(w)
            for w in due:
                self._restart_worker(w)

    def _on_worker_lost(self, w: _Worker):
        """Close the channel, then fail the lost worker's in-flight requests
        over to siblings (head of their lane queue) — or fail them typed
        ``WorkerLost`` once ``max_failovers`` replays are spent."""
        self.repairs.record("worker_lost", worker=w.wid,
                            restarts=w.restarts,
                            in_flight=len(w.in_flight),
                            backoff_s=w.last_restart_delay)
        if w.sock is not None:
            try:
                w.sock.close()
            except OSError:
                pass
            w.sock = None
        if w.proc is not None and w.proc.poll() is None:
            w.proc.kill()   # stopped heartbeating but pid alive: zombie
        orphans: List[FrontDoorRequest] = []
        with self._lock:
            orphans = list(w.in_flight.values())
            w.in_flight.clear()
        for req in orphans:
            if req.lane == BATCH:
                with self._lock:
                    self._batch_in_flight = max(0, self._batch_in_flight - 1)
            req.worker = None
            if req.attempts > self.max_failovers:
                with self._lock:
                    self.stats["failover_lost"] += 1
                    self.stats["failed"] += 1
                req._complete(error=WorkerLost(
                    f"request {req.rid} ({req.model!r}) lost worker "
                    f"{w.wid} after {req.attempts} attempts"))
                continue
            with self._lock:
                self.stats["failovers"] += 1
                # head of the lane: a failover has already waited once
                self._queues[req.lane].appendleft(req)
                self._dispatch_cv.notify_all()
            self.repairs.record("request_failover", rid=req.rid,
                                model=req.model, lane=req.lane,
                                from_worker=w.wid, attempt=req.attempts)

    def _restart_worker(self, w: _Worker):
        self.stats["worker_restarts"] += 1
        self.repairs.record("worker_restart", worker=w.wid,
                            restarts=w.restarts,
                            backoff_s=w.last_restart_delay)
        try:
            self._spawn_worker(w)
        except Exception:
            with self._lock:   # spawn itself failed: back off again
                w.restart_due = (time.monotonic()
                                 + self.restart.delay(w.restarts + 1))
            return
        if not w.hello_evt.wait(self.spawn_timeout_s):
            return  # supervisor will see the dead pid and re-backoff
        for spec in self._models.values():
            try:
                self._register_on(w, spec, timeout=self.spawn_timeout_s)
            except Exception:
                return

    # -- introspection / control --------------------------------------------
    def health(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "stats": dict(self.stats),
                "queues": {lane: len(q) for lane, q in self._queues.items()},
                "batch_in_flight": self._batch_in_flight,
                "workers": {
                    w.wid: {
                        "alive": w.alive,
                        "pid": (w.proc.pid if w.proc is not None else None),
                        "restarts": w.restarts,
                        "in_flight": len(w.in_flight),
                        "last_restart_delay": w.last_restart_delay,
                        "resident": list((w.health or {}).get(
                            "resident") or []),
                    } for w in self._workers.values()},
            }

    def worker_pid(self, wid: str) -> Optional[int]:
        w = self._workers[wid]
        return w.proc.pid if w.proc is not None else None

    def kill_worker(self, wid: str, sig: int = 9) -> None:
        """Chaos hook: signal a worker process (default SIGKILL)."""
        pid = self.worker_pid(wid)
        if pid is not None:
            os.kill(pid, sig)

    def shutdown(self, drain_timeout_s: float = 5.0) -> None:
        with self._lock:
            if self._shutdown:
                return
            self._shutdown = True
            self._dispatch_cv.notify_all()
        for w in self._workers.values():
            if w.sock is not None and w.alive:
                try:
                    send_msg(w.sock, {"type": "drain",
                                      "timeout_s": drain_timeout_s},
                             w.send_lock)
                    send_msg(w.sock, {"type": "shutdown"}, w.send_lock)
                except OSError:
                    pass
        deadline = time.monotonic() + drain_timeout_s
        for w in self._workers.values():
            if w.proc is None:
                continue
            try:
                w.proc.wait(max(0.1, deadline - time.monotonic()))
            except subprocess.TimeoutExpired:
                w.proc.kill()
                w.proc.wait()
            if w.sock is not None:
                try:
                    w.sock.close()
                except OSError:
                    pass
        if self._listener is not None:
            self._listener.close()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.shutdown()
