"""Grouped matmul (gmm) Pallas kernel — the MoE expert-block GEMM.

Operates on capacity-blocked expert batches: x (E, C, d) — expert-sorted
tokens gathered into fixed-capacity blocks (exactly what
``repro.models.moe._gffn_blocks`` forms) — times per-expert weights
(E, d, n), giving (E, C, n). Grid (E, C/bc, n/bn, d/bk) with an f32 VMEM
accumulator; the per-expert weight tile load is a contiguous block, the
megablox-style mapping of MoE onto the MXU.

Validated in interpret mode against ref.gmm_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gmm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(3) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[0], w_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(3) == nk - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def gmm_blocks(
    x: jax.Array,   # (E, C, d) capacity-blocked expert inputs
    w: jax.Array,   # (E, d, n) per-expert weights
    *,
    bc: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    E, C, d = x.shape
    _, _, n = w.shape
    pad_c, pad_k, pad_n = (-C) % bc, (-d) % bk, (-n) % bn
    if pad_c or pad_k:
        x = jnp.pad(x, ((0, 0), (0, pad_c), (0, pad_k)))
    if pad_k or pad_n:
        w = jnp.pad(w, ((0, 0), (0, pad_k), (0, pad_n)))
    Cp, dp, np_ = C + pad_c, d + pad_k, n + pad_n
    grid = (E, Cp // bc, np_ // bn, dp // bk)
    out = pl.pallas_call(
        functools.partial(_gmm_kernel, nk=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bk), lambda e, c, j, k: (e, c, k)),
            pl.BlockSpec((1, bk, bn), lambda e, c, j, k: (e, k, j)),
        ],
        out_specs=pl.BlockSpec((1, bc, bn), lambda e, c, j, k: (e, c, j)),
        out_shape=jax.ShapeDtypeStruct((E, Cp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:, :C, :n]
