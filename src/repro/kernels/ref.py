"""Pure-jnp oracles for every Pallas kernel. These are the ground truth the
shape/dtype sweep tests assert against (interpret=True on CPU)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def matmul_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)


def matmul_packed_ref(x: jax.Array, w_packed: jax.Array, K: int, N: int) -> jax.Array:
    """w_packed: (N/bn, K/bk, bk, bn) — unpack then matmul."""
    nN, nK, bk, bn = w_packed.shape
    w = w_packed.transpose(1, 2, 0, 3).reshape(nK * bk, nN * bn)[:K, :N]
    return matmul_ref(x[..., :K], w)


def flash_attention_ref(
    q: jax.Array, k: jax.Array, v: jax.Array, *,
    causal: bool = True, window: int | None = None,
    softcap: float | None = None,
) -> jax.Array:
    """q,k,v: (B, S, H, D) (kv may have fewer heads — GQA broadcast)."""
    B, S, H, D = q.shape
    kvh = k.shape[2]
    if kvh != H:
        k = jnp.repeat(k, H // kvh, axis=2)
        v = jnp.repeat(v, H // kvh, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    idx = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= idx[None, :] <= idx[:, None]
    if window is not None:
        mask &= idx[None, :] > idx[:, None] - window
    s = jnp.where(mask[None, None], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32)).astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,        # (B, H, D)
    k: jax.Array,        # (B, S, KV, D)
    v: jax.Array,
    length: jax.Array,   # (B,) valid cache length per row
) -> jax.Array:
    B, S, KV, D = k.shape
    H = q.shape[1]
    if KV != H:
        k = jnp.repeat(k, H // KV, axis=2)
        v = jnp.repeat(v, H // KV, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(D)
    valid = jnp.arange(S)[None, :] < length[:, None]
    s = jnp.where(valid[:, None, :], s, -2.0e38)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bkhd->bhd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x, dt, A, Bm, Cm, D, *, chunk: int):
    """Chunked SSD oracle — delegates to the model-layer implementation
    (itself validated against a naive recurrent scan in tests)."""
    from repro.models.ssm import ssd_chunked

    y, state = ssd_chunked(x, dt, A, Bm, Cm, D, chunk=chunk)
    return y, state


def ssd_naive_ref(x, dt, A, Bm, Cm, D):
    """O(S·N·P) recurrent oracle (slow, exact)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    f32 = jnp.float32
    Bh = jnp.broadcast_to(Bm.astype(f32)[:, :, 0][:, :, None], (B, S, H, N))
    Ch = jnp.broadcast_to(Cm.astype(f32)[:, :, 0][:, :, None], (B, S, H, N))

    def step(state, t):
        xt = x[:, t].astype(f32) * dt[:, t].astype(f32)[..., None]
        decay = jnp.exp(dt[:, t].astype(f32) * A.astype(f32))
        state = state * decay[..., None, None] + jnp.einsum(
            "bhp,bhn->bhpn", xt, Bh[:, t])
        y = jnp.einsum("bhpn,bhn->bhp", state, Ch[:, t])
        return state, y

    state0 = jnp.zeros((B, H, P, N), f32)
    state, ys = jax.lax.scan(step, state0, jnp.arange(S))
    y = jnp.moveaxis(ys, 0, 1) + x.astype(f32) * D.astype(f32)[None, None, :, None]
    return y.astype(x.dtype), state


def gmm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (E, C, d), w: (E, d, n) -> (E, C, n) batched per-expert matmul."""
    return jnp.einsum("ecd,edn->ecn", x.astype(jnp.float32),
                      w.astype(jnp.float32)).astype(x.dtype)


def winograd_tile_matmul_ref(V: jax.Array, U: jax.Array) -> jax.Array:
    """V: (16, T, C), U: (16, C, O) -> (16, T, O) batched matmul."""
    return jnp.einsum("ktc,kco->kto", V.astype(jnp.float32),
                      U.astype(jnp.float32)).astype(V.dtype)


def unpack_int4_ref(packed: jax.Array, k: int) -> jax.Array:
    """((K+1)//2, N) uint8 nibbles -> (k, N) sign-extended int values (f32).
    Row 2i from the low nibble, 2i+1 from the high nibble — the jnp twin of
    ``repro.quant.unpack_int4``."""
    p = packed.astype(jnp.int32)
    lo = p & 0x0F
    hi = (p >> 4) & 0x0F
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    full = jnp.stack([lo, hi], axis=1).reshape(
        2 * packed.shape[0], packed.shape[1])
    return full[:k].astype(jnp.float32)


def dequant_int8_ref(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale.astype(jnp.float32)


def dequant_int4_ref(packed: jax.Array, scale: jax.Array, k: int) -> jax.Array:
    return unpack_int4_ref(packed, k) * scale.astype(jnp.float32)


def matmul_dequant_int8_ref(x: jax.Array, q: jax.Array,
                            scale: jax.Array) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32), dequant_int8_ref(q, scale),
                   preferred_element_type=jnp.float32).astype(x.dtype)


def matmul_dequant_int4_ref(x: jax.Array, packed: jax.Array,
                            scale: jax.Array, k: int) -> jax.Array:
    return jnp.dot(x.astype(jnp.float32),
                   dequant_int4_ref(packed, scale, k),
                   preferred_element_type=jnp.float32).astype(x.dtype)
