"""Jitted public wrappers for the Pallas kernels.

On this CPU container the kernels run with ``interpret=True`` (the kernel
body executes in Python for correctness validation); on a TPU backend they
compile natively. ``use_pallas()`` is the switch the model layer consults.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import attention as _attn
from repro.kernels import conv_winograd as _wino
from repro.kernels import matmul as _mm
from repro.kernels import ssd as _ssd


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, w, *, bm=128, bn=128, bk=128):
    return _mm.matmul(x, w, bm=bm, bn=bn, bk=bk, interpret=_interpret())


@partial(jax.jit, static_argnames=("K", "N", "bm"))
def matmul_packed(x, w_packed, K: int, N: int, *, bm=128):
    return _mm.matmul_packed(x, w_packed, K, N, bm=bm, interpret=_interpret())


@partial(jax.jit, static_argnames=("causal", "window", "softcap", "bq", "bk"))
def flash_attention(q, k, v, *, causal=True, window=None, softcap=None,
                    bq=128, bk=128):
    return _attn.flash_attention(
        q, k, v, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, interpret=_interpret())


@partial(jax.jit, static_argnames=("bs",))
def decode_attention(q, k, v, length, *, bs=256):
    return _attn.decode_attention(q, k, v, length, bs=bs,
                                  interpret=_interpret())


@partial(jax.jit, static_argnames=("chunk",))
def ssd_scan(x, dt, A, Bm, Cm, D, *, chunk=128):
    return _ssd.ssd_scan(x, dt, A, Bm, Cm, D, chunk=chunk,
                         interpret=_interpret())


@partial(jax.jit, static_argnames=("bt", "bc"))
def winograd_tile_matmul(V, U, *, bt=128, bc=128):
    return _wino.winograd_tile_matmul(V, U, bt=bt, bc=bc,
                                      interpret=_interpret())


@partial(jax.jit, static_argnames=("bc", "bn", "bk"))
def gmm_blocks(x, w, *, bc=128, bn=128, bk=128):
    from repro.kernels import gmm as _gmm

    return _gmm.gmm_blocks(x, w, bc=bc, bn=bn, bk=bk,
                           interpret=_interpret())
