"""Mamba2 SSD chunked-scan Pallas kernel.

Grid (B, H, S/Q): the chunk axis is innermost and *sequential*; the running
SSM state (P, N) lives in VMEM scratch and is carried across chunk steps —
the TPU-native mapping of the SSD recurrence (intra-chunk quadratic term on
the MXU, inter-chunk low-rank state update in VMEM). G=1 (shared B/C across
heads), matching the assigned mamba2/zamba2 configs.

Validated in interpret mode against ref.ssd_naive_ref and the model-layer
``ssd_chunked``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(x_ref, dt_ref, A_ref, B_ref, C_ref, D_ref, o_ref,
                state_ref, *, Q: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)        # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)      # (Q, 1)... stored (Q,)
    A = A_ref[0]                               # scalar for this head
    Bm = B_ref[0].astype(jnp.float32)          # (Q, N)
    Cm = C_ref[0].astype(jnp.float32)          # (Q, N)
    D = D_ref[0]

    a = dt * A                                  # (Q,)
    cum = jnp.cumsum(a)                         # (Q,)
    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j
    diff = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(jj <= ii, jnp.exp(diff), 0.0)
    CB = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (Q,Q)
    xdt = x * dt[:, None]
    y = jax.lax.dot_general(CB * L, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)   # (Q,P)
    # inter-chunk: y += exp(cum_i) * C_i · state
    state = state_ref[...]                      # (N, P) layout
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cm, state, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    # state update: state = exp(sum a) * state + sum_j exp(last-cum_j) dt_j B_j x_j
    last = cum[Q - 1]
    decay_out = jnp.exp(last - cum)             # (Q,)
    contrib = jax.lax.dot_general(
        Bm * decay_out[:, None], xdt, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # (N, P)
    state_ref[...] = state * jnp.exp(last) + contrib
    o_ref[0, 0] = (y + D * x).astype(o_ref.dtype)


def ssd_scan(
    x: jax.Array,    # (B, S, H, P)
    dt: jax.Array,   # (B, S, H) post-softplus
    A: jax.Array,    # (H,)
    Bm: jax.Array,   # (B, S, N)  (G=1)
    Cm: jax.Array,   # (B, S, N)
    D: jax.Array,    # (H,)
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = chunk
    assert S % Q == 0, (S, Q)
    nc = S // Q
    xt = x.transpose(0, 2, 1, 3)                  # (B, H, S, P)
    dtt = dt.transpose(0, 2, 1)                   # (B, H, S)
    grid = (B, H, nc)
    out = pl.pallas_call(
        functools.partial(_ssd_kernel, Q=Q),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1,), lambda b, h, c: (h,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, Q, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(xt, dtt, A.astype(jnp.float32), Bm, Cm, D.astype(jnp.float32))
    return out.transpose(0, 2, 1, 3)
