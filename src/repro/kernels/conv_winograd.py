"""Winograd F(2x2,3x3) tile-matmul Pallas kernel.

The winograd transform turns a 3x3/s1 conv into 16 independent (T, C)x(C, O)
matmuls over 4x4 input tiles (T = N·⌈H/2⌉·⌈W/2⌉). The input/output tile
transforms are cheap elementwise/small-matrix work; the 16 batched matmuls
are the MXU hot spot this kernel owns. The filter-side transform
(O,I,3,3)->(16,I,O) is the paper's flagship *weights transformation* (done
offline / on little cores / cached to disk — see ConvWinograd in
repro.core.registry).

Validated in interpret mode against ref.winograd_tile_matmul_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wino_mm_kernel(v_ref, u_ref, o_ref, acc_ref, *, nc: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        v_ref[0], u_ref[0], preferred_element_type=jnp.float32
    )

    @pl.when(ci == nc - 1)
    def _done():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


def winograd_tile_matmul(
    V: jax.Array,   # (16, T, C) transformed input tiles
    U: jax.Array,   # (16, C, O) transformed filters (the cached weights)
    *,
    bt: int = 128, bc: int = 128,
    interpret: bool = False,
) -> jax.Array:
    K16, T, C = V.shape
    _, _, O = U.shape
    pad_t, pad_c = (-T) % bt, (-C) % bc
    if pad_t or pad_c:
        V = jnp.pad(V, ((0, 0), (0, pad_t), (0, pad_c)))
    if pad_c:
        U = jnp.pad(U, ((0, 0), (0, pad_c), (0, 0)))
    Tp, Cp = T + pad_t, C + pad_c
    grid = (K16, Tp // bt, Cp // bc)
    out = pl.pallas_call(
        functools.partial(_wino_mm_kernel, nc=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bt, bc), lambda k, t, c: (k, t, c)),
            pl.BlockSpec((1, bc, O), lambda k, t, c: (k, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, bt, O), lambda k, t, c: (k, t, 0)),
        out_shape=jax.ShapeDtypeStruct((K16, Tp, O), V.dtype),
        scratch_shapes=[pltpu.VMEM((bt, O), jnp.float32)],
        interpret=interpret,
    )(V, U)
    return out[:, :T]
