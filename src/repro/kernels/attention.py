"""Flash-attention Pallas kernels (prefill + decode).

Prefill: grid (B, H, Sq/bq, Sk/bk), online softmax with (m, l, acc) VMEM
scratch persisted over the innermost (kv) grid axis — scores never leave
VMEM, which is exactly what removes the O(S²) HBM traffic the jnp
``chunked_attention`` baseline pays (see EXPERIMENTS.md §Perf).

Decode: grid (B, S/bs) with H folded into the block — one new token against
a long cache, GQA-aware.

Supports causal masking, sliding windows (local attention), logit softcap
(gemma2), and GQA via kv-head index mapping. Validated in interpret mode
against ref.flash_attention_ref / ref.decode_attention_ref.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, nk: int, bq: int, bk: int,
               causal: bool, window, softcap):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0]                      # (bq, D)
    k = k_ref[0, 0]                      # (bk, D)
    v = v_ref[0, 0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                            # (bq, bk)
    if softcap:
        s = jnp.tanh(s / softcap) * softcap
    rows = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    cols = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), bool)
    if causal:
        mask &= cols <= rows
    if window is not None:
        mask &= cols > rows - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0, 0] = (acc_ref[...] /
                       jnp.maximum(l_ref[...], 1e-37)).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,   # (B, S, H, D)
    k: jax.Array,   # (B, S, KV, D)
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    softcap: float | None = None,
    bq: int = 128,
    bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, H, D = q.shape
    KV = k.shape[2]
    rep = H // KV
    scale = 1.0 / math.sqrt(D)
    qt = q.transpose(0, 2, 1, 3)   # (B, H, S, D)
    kt = k.transpose(0, 2, 1, 3)   # (B, KV, S, D)
    vt = v.transpose(0, 2, 1, 3)
    pad = (-S) % bq
    if pad:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    grid = (B, H, Sp // bq, Sp // bk)
    out = pl.pallas_call(
        functools.partial(
            _fa_kernel, scale=scale, nk=grid[3], bq=bq, bk=bk,
            causal=causal, window=window, softcap=softcap,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
            pl.BlockSpec((1, 1, bk, D), lambda b, h, i, j, rep=rep: (b, h // rep, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, D), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sp, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, D), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out[:, :, :S].transpose(0, 2, 1, 3)


# ---------------------------------------------------------------------------
# decode: one token vs a long cache
# ---------------------------------------------------------------------------
def _dec_kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                scale: float, nk: int, bs: int):
    ki = pl.program_id(1)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                          # (H, D)
    k = k_ref[0]                          # (bs, D)  (kv head folded upstream)
    v = v_ref[0]
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32,
    ) * scale                             # (H, bs)
    cols = ki * bs + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = cols < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ki == nk - 1)
    def _done():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-37)).astype(o_ref.dtype)


def decode_attention(
    q: jax.Array,       # (B, H, D)
    k: jax.Array,       # (B, S, KV, D)
    v: jax.Array,
    length: jax.Array,  # (B,) valid prefix length
    *,
    bs: int = 256,
    interpret: bool = False,
) -> jax.Array:
    """GQA decode attention: each kv-head group handled as its own batch row
    (q reshaped to (B·KV, H/KV, D), cache to (B·KV, S, D))."""
    B, S, KV, D = k.shape
    H = q.shape[1]
    g = H // KV
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, KV, g, D).reshape(B * KV, g, D)
    kg = k.transpose(0, 2, 1, 3).reshape(B * KV, S, D)
    vg = v.transpose(0, 2, 1, 3).reshape(B * KV, S, D)
    lg = jnp.repeat(length, KV)
    pad = (-S) % bs
    if pad:
        kg = jnp.pad(kg, ((0, 0), (0, pad), (0, 0)))
        vg = jnp.pad(vg, ((0, 0), (0, pad), (0, 0)))
    Sp = S + pad
    grid = (B * KV, Sp // bs)
    out = pl.pallas_call(
        functools.partial(_dec_kernel, scale=scale, nk=grid[1], bs=bs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1,), lambda b, j: (b,), memory_space=pltpu.SMEM),
            pl.BlockSpec((1, g, D), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, bs, D), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, bs, D), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, g, D), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B * KV, g, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, D), jnp.float32),
        ],
        interpret=interpret,
    )(lg.astype(jnp.int32), qg, kg, vg)
    return out.reshape(B, KV, g, D).reshape(B, H, D)
