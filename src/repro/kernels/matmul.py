"""Blocked MXU matmul Pallas kernels.

Two variants:
  * ``matmul`` — standard (M,K)x(K,N) with (bm,bn,bk)=(128,128,128) VMEM
    tiles and an f32 accumulator scratch; K is the innermost grid axis.
  * ``matmul_packed`` — consumes the LinearPacked execution-format weights
    (N/bn, K/bk, bk, bn) directly: the weight tile load is a contiguous
    block (no strided HBM reads), which is the whole point of the paper's
    weights-transformation stage — transform once, execute fast.

Validated in interpret mode against ref.matmul_ref / matmul_packed_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul(
    x: jax.Array, w: jax.Array, *,
    bm: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    M, K = x.shape
    K2, N = w.shape
    assert K == K2
    pad_m, pad_k, pad_n = (-M) % bm, (-K) % bk, (-N) % bn
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        w = jnp.pad(w, ((0, pad_k), (0, pad_n)))
    Mp, Kp, Np = M + pad_m, K + pad_k, N + pad_n
    grid = (Mp // bm, Np // bn, Kp // bk)
    out = pl.pallas_call(
        functools.partial(_mm_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w)
    return out[:M, :N]


def _mm_packed_kernel(x_ref, w_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], w_ref[0, 0], preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_packed(
    x: jax.Array, w_packed: jax.Array, K: int, N: int, *,
    bm: int = 128, interpret: bool = False,
) -> jax.Array:
    """x: (M, K); w_packed: (N/bn, K/bk, bk, bn) from LinearPacked."""
    nN, nK, bk, bn = w_packed.shape
    M = x.shape[0]
    Kp = nK * bk
    pad_m = (-M) % bm
    if x.shape[1] != Kp or pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, Kp - x.shape[1])))
    Mp = M + pad_m
    grid = (Mp // bm, nN, nK)
    out = pl.pallas_call(
        functools.partial(_mm_packed_kernel, nk=nK),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((1, 1, bk, bn), lambda i, j, k: (j, k, 0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, nN * bn), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_packed)
    return out[:M, :N]
