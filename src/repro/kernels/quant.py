"""Dequantization Pallas kernels for the quantized transform cache.

Two shapes of consumer for a folded int8 / packed-int4 cache extent
(``repro.quant`` companion convention):

  * ``dequant_int8`` / ``dequant_int4`` — dequant-on-the-fly: expand the
    quantized block back to float32 (``q.astype(f32) * scale``), for ops
    that need the full-precision tensor (e.g. feeding an existing fused
    kernel).
  * ``matmul_dequant_int8`` / ``matmul_dequant_int4`` — fused
    dequant-matmul: the MXU consumes the quantized tile directly and the
    per-output-channel scale is factored out of the K loop, applied ONCE
    to the f32 accumulator at flush (``(x @ q) * scale``) — the dequant
    cost is one multiply per output element instead of one per weight.

int4 tiles arrive nibble-packed along K (rows ``2i``/``2i+1`` in the
low/high nibble of one byte — see ``repro.quant.pack_int4``); the kernels
unpack in VMEM, so HBM traffic stays at the packed byte count. Scales are
per-output-channel, keepdims shape ``(1, N)``, symmetric (no zero point —
the asymmetric int8 variant is a numpy-side concern).

Validated in interpret mode against ref.dequant_*_ref /
ref.matmul_dequant_*_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _unpack_tile(p_ref) -> jax.Array:
    """Unpack a (bkp, bn) uint8 nibble tile to (2*bkp, bn) int-valued f32:
    row 2i from the low nibble, 2i+1 from the high nibble, sign-extended."""
    p = p_ref[...].astype(jnp.int32)
    lo = p & 0x0F
    hi = (p >> 4) & 0x0F
    lo = jnp.where(lo >= 8, lo - 16, lo)
    hi = jnp.where(hi >= 8, hi - 16, hi)
    # (bkp, 2, bn) -> (2*bkp, bn) interleaves rows as lo0, hi0, lo1, hi1...
    stacked = jnp.stack([lo, hi], axis=1)
    return stacked.reshape(2 * p.shape[0], p.shape[1]).astype(jnp.float32)


# ---------------------------------------------------------------------------
# dequant-on-the-fly
# ---------------------------------------------------------------------------
def _dq8_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def dequant_int8(
    q: jax.Array, scale: jax.Array, *,
    bk: int = 128, bn: int = 128, interpret: bool = False,
) -> jax.Array:
    """(K, N) int8 + (1, N) f32 scale -> (K, N) f32."""
    K, N = q.shape
    pad_k, pad_n = (-K) % bk, (-N) % bn
    if pad_k or pad_n:
        q = jnp.pad(q, ((0, pad_k), (0, pad_n)))
        scale = jnp.pad(scale, ((0, 0), (0, pad_n)))
    grid = (q.shape[0] // bk, q.shape[1] // bn)
    out = pl.pallas_call(
        _dq8_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        interpret=interpret,
    )(q, scale)
    return out[:K, :N]


def _dq4_kernel(p_ref, s_ref, o_ref):
    o_ref[...] = _unpack_tile(p_ref) * s_ref[...]


def dequant_int4(
    packed: jax.Array, scale: jax.Array, K: int, *,
    bk: int = 128, bn: int = 128, interpret: bool = False,
) -> jax.Array:
    """((K+1)//2, N) packed uint8 + (1, N) scale -> (K, N) f32."""
    assert bk % 2 == 0
    Kp2, N = packed.shape
    pad_kp, pad_n = (-Kp2) % (bk // 2), (-N) % bn
    if pad_kp or pad_n:
        # 0x00 bytes unpack to two zero rows — inert padding
        packed = jnp.pad(packed, ((0, pad_kp), (0, pad_n)))
        scale = jnp.pad(scale, ((0, 0), (0, pad_n)))
    grid = (packed.shape[0] // (bk // 2), packed.shape[1] // bn)
    out = pl.pallas_call(
        _dq4_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bk // 2, bn), lambda i, j: (i, j)),
            pl.BlockSpec((1, bn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((bk, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct(
            (2 * packed.shape[0], packed.shape[1]), jnp.float32),
        interpret=interpret,
    )(packed, scale)
    return out[:K, :N]


# ---------------------------------------------------------------------------
# fused dequant-matmul — scale factored out of the K loop
# ---------------------------------------------------------------------------
def _mm_dq8_kernel(x_ref, q_ref, s_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], q_ref[...].astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        # per-output-channel scale applied once to the finished accumulator
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


def matmul_dequant_int8(
    x: jax.Array, q: jax.Array, scale: jax.Array, *,
    bm: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """x: (M, K) float; q: (K, N) int8; scale: (1, N) f32 -> (M, N)."""
    M, K = x.shape
    K2, N = q.shape
    assert K == K2
    pad_m, pad_k, pad_n = (-M) % bm, (-K) % bk, (-N) % bn
    if pad_m or pad_k:
        x = jnp.pad(x, ((0, pad_m), (0, pad_k)))
    if pad_k or pad_n:
        q = jnp.pad(q, ((0, pad_k), (0, pad_n)))
    if pad_n:
        scale = jnp.pad(scale, ((0, 0), (0, pad_n)))
    Mp, Kp, Np = M + pad_m, K + pad_k, N + pad_n
    grid = (Mp // bm, Np // bn, Kp // bk)
    out = pl.pallas_call(
        functools.partial(_mm_dq8_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, q, scale)
    return out[:M, :N]


def _mm_dq4_kernel(x_ref, p_ref, s_ref, o_ref, acc_ref, *, nk: int):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        x_ref[...], _unpack_tile(p_ref), preferred_element_type=jnp.float32
    )

    @pl.when(pl.program_id(2) == nk - 1)
    def _done():
        o_ref[...] = (acc_ref[...] * s_ref[...]).astype(o_ref.dtype)


def matmul_dequant_int4(
    x: jax.Array, packed: jax.Array, scale: jax.Array, K: int, *,
    bm: int = 128, bn: int = 128, bk: int = 128,
    interpret: bool = False,
) -> jax.Array:
    """x: (M, K) float; packed: ((K+1)//2, N) uint8 nibbles; scale: (1, N).

    The weight tile stays packed through HBM->VMEM (half the bytes of an
    int8 tile); nibbles unpack in VMEM right before the MXU dot.
    """
    assert bk % 2 == 0
    M = x.shape[0]
    Kp2, N = packed.shape
    pad_kp, pad_n = (-Kp2) % (bk // 2), (-N) % bn
    if pad_kp or pad_n:
        packed = jnp.pad(packed, ((0, pad_kp), (0, pad_n)))
    if pad_n:
        scale = jnp.pad(scale, ((0, 0), (0, pad_n)))
    Kp = 2 * packed.shape[0]  # logical K after padding (>= K)
    pad_m = (-M) % bm
    if x.shape[1] != Kp or pad_m:
        x = jnp.pad(x, ((0, pad_m), (0, Kp - x.shape[1])))
    Mp, Np = M + pad_m, packed.shape[1]
    grid = (Mp // bm, Np // bn, Kp // bk)
    out = pl.pallas_call(
        functools.partial(_mm_dq4_kernel, nk=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk // 2, bn), lambda i, j, k: (k, j)),
            pl.BlockSpec((1, bn), lambda i, j, k: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((Mp, Np), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, packed, scale)
    return out[:M, :N]
