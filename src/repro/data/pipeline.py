"""Data pipeline: deterministic synthetic token/embedding streams with the
microbatched layout the train step expects, placed with the batch sharding.

Real deployments swap ``SyntheticPipeline`` for a file-backed loader with the
same ``__iter__`` contract; everything downstream (sharding, microbatch
layout, modality handling) is identical.
"""
from __future__ import annotations

import math
from typing import Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ArchConfig


def make_batch_shape(cfg: ArchConfig, batch: int, seq: int,
                     microbatches: int = 1) -> Dict[str, tuple]:
    def lead(*dims):
        if microbatches > 1:
            return (microbatches, batch // microbatches, *dims)
        return (batch, *dims)

    if cfg.input_mode == "tokens":
        return {"tokens": lead(seq)}
    if cfg.input_mode == "embeddings":
        return {"embeds": lead(seq, cfg.d_model), "labels": lead(seq)}
    return {"tokens": lead(seq - cfg.num_prefix_embeds),
            "prefix_embeds": lead(cfg.num_prefix_embeds, cfg.d_model)}


class SyntheticPipeline:
    """Deterministic per-step batches (seeded); optional device placement."""

    def __init__(self, cfg: ArchConfig, batch: int, seq: int, *,
                 microbatches: int = 1, seed: int = 0,
                 shardings: Optional[Dict] = None):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.microbatches = microbatches
        self.seed = seed
        self.shardings = shardings
        self._shapes = make_batch_shape(cfg, batch, seq, microbatches)

    def batch_at(self, step: int) -> Dict[str, jax.Array]:
        rng = np.random.default_rng(self.seed * 1_000_003 + step)
        out = {}
        for k, shape in self._shapes.items():
            if k == "tokens" or k == "labels":
                a = rng.integers(0, self.cfg.vocab_size, size=shape,
                                 dtype=np.int32)
            else:
                a = rng.standard_normal(shape).astype(np.float32)
            arr = jnp.asarray(a) if k in ("tokens", "labels") else \
                jnp.asarray(a, jnp.dtype(self.cfg.dtype))
            if self.shardings and k in self.shardings:
                arr = jax.device_put(arr, self.shardings[k])
            out[k] = arr
        return out

    def __iter__(self) -> Iterator[Dict[str, jax.Array]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1
