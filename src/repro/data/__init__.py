from repro.data.pipeline import SyntheticPipeline, make_batch_shape  # noqa: F401
