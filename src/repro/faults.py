"""Typed failure domain for the cold path.

Everything that can go wrong between "bytes on flash" and "activations on the
big core" is classified here into exactly two retry semantics:

  * ``TransientFault`` — worth retrying (bounded, with backoff).  I/O hiccups,
    a stage that lost a race with memory pressure, an overdue task rescued by
    the pool watchdog.
  * ``PermanentFault`` — retrying cannot help.  Checksum mismatches, kernels
    that fault deterministically, workers that never came back.

The module is deliberately stdlib-only: ``checkpoint/`` and ``executor/`` both
import it, so it must sit below every other ``repro`` package.

Also here, because every fault consumer needs them:

  * ``RetryPolicy``     — bounded attempts + exponential backoff schedule.
  * ``FaultInjector``   — deterministic, seedable chaos: the decision to fault
                          is a pure function of (seed, site, key, attempt), so
                          a chaos run is reproducible regardless of thread
                          interleaving.
  * ``CircuitBreaker``  — per-(kernel, shape-class) trip wire persisted next
                          to the store, used to demote faulting kernels.
  * ``RepairLog``       — append-only journal of degradation events (cache
                          recomputes, kernel demotions, model quarantines).

See docs/robustness.md for the full taxonomy table and ladder semantics.
"""
from __future__ import annotations

import errno
import hashlib
import json
import os
import threading
import time
from dataclasses import dataclass
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Set


# ---------------------------------------------------------------------------
# taxonomy
# ---------------------------------------------------------------------------

class Fault(Exception):
    """Base of the typed taxonomy. Carries structured context for reports."""

    retryable = False

    def __init__(self, msg: str = "", *, layer: Optional[str] = None,
                 kernel: Optional[str] = None, site: Optional[str] = None,
                 retry_after: Optional[float] = None):
        super().__init__(msg)
        self.layer = layer
        self.kernel = kernel
        self.site = site
        self.retry_after = retry_after

    def describe(self) -> dict:
        d = {"type": type(self).__name__, "retryable": self.retryable,
             "msg": str(self)}
        for k in ("layer", "kernel", "site", "retry_after"):
            v = getattr(self, k)
            if v is not None:
                d[k] = v
        return d


class TransientFault(Fault):
    """Retry may succeed (bounded by a RetryPolicy)."""
    retryable = True


class PermanentFault(Fault):
    """Retry cannot help; escalate (fail the job / quarantine / re-decide)."""
    retryable = False


# -- transients --------------------------------------------------------------

class ReadFault(TransientFault):
    """A store read (raw or cached) failed in a retryable way."""


class TransformFault(TransientFault):
    """A weight transform task failed in a retryable way."""


class StageFault(TransientFault):
    """Staging (device_put) failed in a retryable way."""


class ExecuteFault(TransientFault):
    """A kernel execution hiccuped in a way worth one more try."""


class DeadlineExceeded(TransientFault):
    """A task overran its deadline; the watchdog expired it."""


class JobTimeout(TransientFault, TimeoutError):
    """Job.wait()/JobHandle.result() ran out of time. Still a TimeoutError so
    pre-taxonomy callers that catch TimeoutError keep working."""


class ModelQuarantined(TransientFault):
    """The server refused a cold start because the model is in backoff after
    repeated load failures. ``retry_after`` says when to try again."""


class FetchFault(TransientFault):
    """A peer warm-state fetch failed (refused, disconnected, or a chunk
    failed its CRC). Transient by design: the local read→transform chain is
    always racing the fetch, so the caller falls back to disk rather than
    retrying the wire."""


# -- permanents --------------------------------------------------------------

class IntegrityFault(PermanentFault):
    """Stored bytes failed a checksum; the data itself is wrong."""


class KernelFault(PermanentFault):
    """A kernel faults deterministically for a shape class on this host."""


class PlanFault(PermanentFault):
    """A persisted plan is missing/corrupt/inconsistent with the model."""


class WorkerLost(PermanentFault):
    """A worker never came back — a pool thread that leaked at shutdown, or
    a front-door worker *process* that died / stopped heartbeating.

    Permanent **within** the failure domain that raised it: the thread or
    process is gone and retrying there cannot help. One tier up it becomes
    recoverable — the ``FrontDoor`` supervisor catches ``WorkerLost`` for a
    crashed worker and *fails the in-flight request over* to a sibling
    (cold starts are idempotent by construction, so the replay is safe),
    only surfacing it to the client when no sibling can serve."""


#: OS errors that plausibly heal on retry. Everything else (ENOENT, EACCES,
#: ENOSPC, ...) is a real condition retrying will not fix.
TRANSIENT_ERRNOS = frozenset({
    errno.EIO, errno.EAGAIN, errno.EINTR, errno.EBUSY, errno.ETIMEDOUT,
    getattr(errno, "EREMOTEIO", errno.EIO),
})


def classify(exc: BaseException, *, site: Optional[str] = None,
             layer: Optional[str] = None) -> BaseException:
    """Map an arbitrary exception onto the taxonomy.

    Typed faults pass through unchanged. A transient-errno OSError becomes a
    ReadFault chained to the original. Anything else is returned as-is —
    unknown errors are NOT retried (a programming error should surface, not
    loop).
    """
    if isinstance(exc, Fault):
        return exc
    if isinstance(exc, OSError) and exc.errno in TRANSIENT_ERRNOS:
        f = ReadFault(f"transient I/O error ({exc})", site=site, layer=layer)
        f.__cause__ = exc
        return f
    return exc


def is_transient(exc: BaseException) -> bool:
    return isinstance(exc, TransientFault)


# ---------------------------------------------------------------------------
# retry policy
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RetryPolicy:
    """Bounded retry with exponential backoff. ``max_attempts`` counts the
    first try: 3 means one try plus up to two retries."""

    max_attempts: int = 3
    backoff_s: float = 0.005
    backoff_mult: float = 2.0

    def delay(self, attempt: int) -> float:
        """Backoff before retry number ``attempt`` (1-based)."""
        return self.backoff_s * (self.backoff_mult ** max(attempt - 1, 0))


DEFAULT_RETRY = RetryPolicy()


# ---------------------------------------------------------------------------
# supervision policies (the front-door tier)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class HeartbeatPolicy:
    """Liveness contract between a supervisor and a worker process: the
    worker beats every ``interval_s``; ``miss_threshold`` consecutive missed
    beats (no message of any kind) declare it lost."""

    interval_s: float = 0.2
    miss_threshold: int = 5

    @property
    def timeout_s(self) -> float:
        return self.interval_s * self.miss_threshold


@dataclass(frozen=True)
class RestartPolicy:
    """Supervisor restart schedule: exponential backoff between restarts of
    a crashing worker, capped at ``max_s``. ``max_restarts=None`` restarts
    forever (a serving tier should keep trying); a bound turns a flapping
    worker into a permanently-removed one."""

    base_s: float = 0.05
    mult: float = 2.0
    max_s: float = 5.0
    max_restarts: Optional[int] = None

    def delay(self, restarts: int) -> float:
        """Backoff before restart number ``restarts`` (1-based)."""
        return min(self.max_s, self.base_s * (self.mult ** max(restarts - 1, 0)))


# ---------------------------------------------------------------------------
# deterministic fault injection
# ---------------------------------------------------------------------------

#: default fault class raised per injection site
SITE_FAULTS = {
    "store.read_raw": ReadFault,
    "store.read_cached": ReadFault,
    "ioengine.submit": ReadFault,
    "ioengine.reap": ReadFault,
    "ioengine.charge": FetchFault,
    "task.read": ReadFault,
    "task.transform": TransformFault,
    "task.stage": StageFault,
    "task.execute": ExecuteFault,
    "task.fetch_remote": FetchFault,
    "warmstate.fetch": FetchFault,
    "warmstate.chunk": FetchFault,
    "kernel.execute": KernelFault,
}


class FaultInjector:
    """Deterministic, seedable chaos.

    Hook points (``maybe_fault(site, key)``) live in store reads, pool task
    execution, and kernel dispatch. Whether call *n* at a given (site, key)
    faults is a pure function of (seed, site, key, n): a SHA-1 of that tuple
    mapped to [0, 1) and compared against the site's rate. Per-(site, key)
    call counters are kept under a lock, so the decision sequence is identical
    however worker threads interleave — the property the chaos gate's
    bit-identical assertion rests on.

    ``max_faults_per_key`` caps injected faults per (site, key) so a retry
    policy with ``max_attempts > max_faults_per_key`` is guaranteed to clear
    every injected fault eventually (no p^max_attempts run-failure tail).
    ``keys`` optionally restricts a site to an explicit key set (used to
    target one layer in the degradation gates).
    """

    def __init__(self, seed: int = 0, *,
                 rates: Optional[Dict[str, float]] = None,
                 max_faults_per_key: Optional[int] = 2,
                 keys: Optional[Dict[str, Set[str]]] = None):
        self.seed = int(seed)
        self.rates = dict(rates or {})
        self.max_faults_per_key = max_faults_per_key
        self.keys = {k: set(v) for k, v in (keys or {}).items()}
        self._lock = threading.Lock()
        self._calls: Dict[tuple, int] = {}
        self._faulted: Dict[tuple, int] = {}
        self.injected: List[dict] = []

    def _decide(self, site: str, key: str, n: int, p: float) -> bool:
        h = hashlib.sha1(f"{self.seed}|{site}|{key}|{n}".encode()).digest()
        frac = int.from_bytes(h[:8], "big") / float(1 << 64)
        return frac < p

    def maybe_fault(self, site: str, key: str) -> None:
        """Raise the site's fault type if this (site, key, call#) is chosen."""
        p = self.rates.get(site, 0.0)
        if p <= 0.0:
            return
        allowed = self.keys.get(site)
        if allowed is not None and key not in allowed:
            return
        with self._lock:
            sk = (site, key)
            n = self._calls.get(sk, 0)
            self._calls[sk] = n + 1
            nf = self._faulted.get(sk, 0)
            if (self.max_faults_per_key is not None
                    and nf >= self.max_faults_per_key):
                return
            if not self._decide(site, key, n, p):
                return
            self._faulted[sk] = nf + 1
            self.injected.append({"site": site, "key": key, "call": n})
        cls = SITE_FAULTS.get(site, TransientFault)
        raise cls(f"injected fault at {site} ({key}, call {n})",
                  site=site, layer=key)

    @property
    def n_injected(self) -> int:
        with self._lock:
            return len(self.injected)


# ---------------------------------------------------------------------------
# circuit breaker
# ---------------------------------------------------------------------------

class CircuitBreaker:
    """Per-key (``"<kernel>:<shape-class>"``) trip wire, persisted to JSON so
    a kernel that faults on this host stays demoted across processes until an
    explicit re-decide resets it."""

    def __init__(self, path: Optional[Path] = None, *, threshold: int = 1):
        self.path = Path(path) if path is not None else None
        self.threshold = max(int(threshold), 1)
        self._lock = threading.Lock()
        self._state: Dict[str, dict] = {}
        if self.path is not None and self.path.exists():
            try:
                raw = json.loads(self.path.read_text())
                if isinstance(raw, dict):
                    self._state = {str(k): dict(v) for k, v in raw.items()
                                   if isinstance(v, dict)}
            except (OSError, ValueError):
                self._state = {}  # corrupt breaker file = no open breakers

    @staticmethod
    def key(kernel: str, shape_class: str) -> str:
        return f"{kernel}:{shape_class}"

    def allow(self, key: str) -> bool:
        with self._lock:
            st = self._state.get(key)
            return not (st and st.get("open"))

    def record_failure(self, key: str, reason: str = "") -> bool:
        """Record one failure; returns True when this call opened the breaker."""
        with self._lock:
            st = self._state.setdefault(key, {"failures": 0, "open": False})
            st["failures"] += 1
            st["reason"] = reason[:200]
            opened = (not st["open"]) and st["failures"] >= self.threshold
            if opened:
                st["open"] = True
        if opened:
            self.save()
        return opened

    def record_success(self, key: str) -> None:
        with self._lock:
            self._state.pop(key, None)

    def reset(self) -> None:
        with self._lock:
            self._state.clear()
        self.save()

    def open_keys(self) -> List[str]:
        with self._lock:
            return sorted(k for k, v in self._state.items() if v.get("open"))

    def save(self) -> None:
        if self.path is None:
            return
        with self._lock:
            blob = json.dumps(self._state, indent=0, sort_keys=True)
        tmp = self.path.with_suffix(self.path.suffix + ".tmp")
        tmp.write_text(blob)
        os.replace(tmp, self.path)


# ---------------------------------------------------------------------------
# repair log
# ---------------------------------------------------------------------------

class RepairLog:
    """Thread-safe record of degradation/repair events; optionally journaled
    to a ``repairs.jsonl`` next to the store so operators (and tools/scrub.py)
    can see what the ladder did.

    The on-disk journal is size-capped: once it grows past ``max_bytes`` it
    rotates to ``repairs.jsonl.1`` (shifting older generations up to
    ``retention``, the oldest dropped) so a long-running server's advisory
    log can never leak disk. The in-memory event list is capped alongside it
    (``max_events``, oldest evicted) for the same reason."""

    def __init__(self, path: Optional[Path] = None, *,
                 max_bytes: int = 4 * 1024 * 1024, retention: int = 3,
                 max_events: int = 10_000):
        self.path = Path(path) if path is not None else None
        self.max_bytes = int(max_bytes)
        self.retention = max(int(retention), 1)
        self.max_events = max(int(max_events), 1)
        self._lock = threading.Lock()
        self.events: List[dict] = []
        self.rotations = 0

    def _rotate_locked(self) -> None:
        """Shift repairs.jsonl -> .1 -> .2 ... dropping past ``retention``."""
        try:
            for i in range(self.retention - 1, 0, -1):
                src = self.path.with_name(self.path.name + f".{i}")
                if src.exists():
                    os.replace(src, self.path.with_name(
                        self.path.name + f".{i + 1}"))
            stale = self.path.with_name(
                self.path.name + f".{self.retention + 1}")
            if stale.exists():
                stale.unlink()
            os.replace(self.path, self.path.with_name(self.path.name + ".1"))
            self.rotations += 1
        except OSError:
            pass  # advisory; a failed rotation must never fail a request

    def record(self, kind: str, **ctx) -> dict:
        ev = {"kind": kind, "ts": time.time()}
        ev.update({k: v for k, v in ctx.items() if v is not None})
        with self._lock:
            self.events.append(ev)
            if len(self.events) > self.max_events:
                del self.events[:len(self.events) - self.max_events]
            if self.path is not None:
                try:
                    with open(self.path, "a") as f:
                        f.write(json.dumps(ev, default=str) + "\n")
                        size = f.tell()
                    if size > self.max_bytes:
                        self._rotate_locked()
                except OSError:
                    pass  # the log is advisory; never fail a request over it
        return ev

    def of_kind(self, kind: str) -> List[dict]:
        with self._lock:
            return [e for e in self.events if e["kind"] == kind]

    def counts(self) -> Dict[str, int]:
        with self._lock:
            out: Dict[str, int] = {}
            for e in self.events:
                out[e["kind"]] = out.get(e["kind"], 0) + 1
            return out
