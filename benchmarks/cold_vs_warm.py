"""Fig. 2 / Table 1 analogue: cold vs warm inference gap + stage breakdown.

Cold here is the vanilla sequential engine (read -> transform -> execute,
warm-best kernels) — the paper's ncnn baseline. The XLA jit compile stage is
reported separately as the 'GPU preparation' analogue.
"""
from __future__ import annotations

from benchmarks.common import build_engine, csv_line, sim_numbers

MODELS = ["mobilenet", "squeezenet", "resnet18"]


def run(print_csv=True):
    rows = []
    for model in MODELS:
        eng, x = build_engine(model)
        sim = sim_numbers(eng)
        compile_s = sum(min(p.compile_s for p in eng.profiles[l.spec.name])
                        for l in eng.layers)
        read_s = sum(next(iter(eng.profiles[l.spec.name])).read_raw_s
                     for l in eng.layers)
        gap = sim.sequential_s / sim.warm_s
        gap_with_compile = (sim.sequential_s + compile_s) / sim.warm_s
        rows.append((model, sim.sequential_s, sim.warm_s, gap,
                     gap_with_compile, read_s, compile_s))
        if print_csv:
            print(csv_line(f"cold_vs_warm/{model}/cold", sim.sequential_s,
                           f"gap={gap:.1f}x"))
            print(csv_line(f"cold_vs_warm/{model}/warm", sim.warm_s))
            print(csv_line(f"cold_vs_warm/{model}/compile_stage", compile_s,
                           f"gap_incl_compile={gap_with_compile:.1f}x"))
    return rows


if __name__ == "__main__":
    run()
