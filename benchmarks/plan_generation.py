"""Table 4 analogue: offline scheduling-plan generation time + disk storage
overhead of the post-transformed weight cache, per model — plus the LLM arm
gating shape-class sharing:

  * per-layer path (sharing off, no profile DB) vs shared cold decide vs a
    second decide against the warm shape-class profile DB;
  * asserts (``--smoke``, run in CI): shared-vs-per-layer plan equivalence
    on deterministic profiles; ≤ one profile per (shape-class × kernel) and
    ≤ one XLA compile per (chosen kernel × shape-class); zero profile calls
    and ≥ 10× decide speedup with a warm DB; ≥ 3× cold-decide speedup vs the
    per-layer path; profiling writes NO candidate cache entries into the
    model store.
"""
from __future__ import annotations

import sys
import tempfile
import time
from pathlib import Path

try:
    from benchmarks.common import build_engine, csv_line
except ModuleNotFoundError:  # invoked as `python benchmarks/plan_generation.py`
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.common import build_engine, csv_line

MODELS = ["mobilenet", "squeezenet", "resnet18", "alexnet"]

LLM_BLOCKS = 8


def run(print_csv=True):
    rows = []
    for model in MODELS:
        eng, x = build_engine(model)
        import json
        plan_stats = json.loads(
            (eng.store.root / "plan.json").read_text())["stats"]
        gen = plan_stats["plan_generation_s"]
        cache_mb = plan_stats["cache_bytes"] / 1e6
        model_mb = plan_stats["model_bytes"] / 1e6
        rows.append((model, gen, cache_mb, model_mb))
        if print_csv:
            print(csv_line(
                f"plan_generation/{model}", gen,
                f"cache_mb={cache_mb:.2f};model_mb={model_mb:.2f};"
                f"overhead={cache_mb/max(model_mb,1e-9):.2f}x"))
    return rows


def _decide(graph, toks, store, **engine_kw):
    from repro.core.engine import ColdEngine

    t0 = time.perf_counter()
    eng = ColdEngine(graph, store, **engine_kw)
    stats = eng.decide(toks, n_little=2, calibrate_interference=False)
    return eng, stats, time.perf_counter() - t0


def plan_equivalence(num_layers=LLM_BLOCKS):
    """Shared-profile vs per-layer plans on DETERMINISTIC profiles: with
    bit-identical numbers for equivalent layers, choices, queues, and
    makespan must coincide exactly."""
    from repro.core.llm_graph import tiny_llm_graph
    from repro.core.profiler import SyntheticProfiler

    graph, toks = tiny_llm_graph(num_layers)
    plans = []
    for share in (True, False):
        with tempfile.TemporaryDirectory() as d:
            from repro.core.engine import ColdEngine

            eng = ColdEngine(graph, d, share_shape_classes=share,
                             profile_db=None, shader_cache=False)
            eng.profiler_factory = SyntheticProfiler
            eng.decide(toks, n_little=2, calibrate_interference=False)
            plans.append(eng.plan)
    shared, per_layer = plans
    same_choices = shared.choices == per_layer.choices
    same_queues = (shared.big_prep == per_layer.big_prep
                   and shared.little_queues == per_layer.little_queues)
    dmk = abs(shared.est_makespan - per_layer.est_makespan)
    return same_choices, same_queues, dmk


def run_llm(print_csv=True, smoke=False, num_layers=LLM_BLOCKS):
    from repro.core.llm_graph import tiny_llm_graph

    graph, toks = tiny_llm_graph(num_layers)
    with tempfile.TemporaryDirectory() as d1, \
            tempfile.TemporaryDirectory() as d2:
        # arm 1: legacy per-layer path — every layer profiled, no DB
        eng_pl, s_pl, t_pl = _decide(
            graph, toks, d1, share_shape_classes=False, profile_db=None)
        # arm 2: shared cold decide — one representative per shape class,
        # DB starts empty
        eng_sh, s_sh, t_sh = _decide(graph, toks, d2)
        # arm 3: second decide on the same store — warm profile DB
        eng_w, s_w, t_w = _decide(graph, toks, d2)

        # sharing invariants
        classes = {}
        for l in eng_sh.layers:
            classes.setdefault(eng_sh._sc_by_layer[l.spec.name], l)
        max_profiles = sum(len(eng_sh._kernels_for(l.spec))
                           for l in classes.values())
        assert s_sh["shape_classes"] < len(graph), \
            "identical blocks must collapse into one shape class"
        assert s_sh["profile_calls"] <= max_profiles, \
            (s_sh["profile_calls"], max_profiles)
        assert s_w["profile_calls"] == 0, s_w
        # profiling writes no candidate entries into the model store: only
        # the chosen cache materializations touch it
        chosen_cached = sum(c.use_cache for c in eng_sh.plan.choices)
        assert eng_sh.store.cache_write_count == chosen_cached, \
            (eng_sh.store.cache_write_count, chosen_cached)
        # one XLA compile per (shape-class × chosen kernel)
        eng_sh._jitted_map(eng_sh.plan.choices, toks)
        chosen_pairs = {(eng_sh._sc_by_layer[l.spec.name], c.kernel)
                        for l, c in zip(eng_sh.layers, eng_sh.plan.choices)}
        misses = eng_sh.compile_cache.stats["misses"]
        assert misses <= len(chosen_pairs), (misses, chosen_pairs)

        same_choices, same_queues, dmk = plan_equivalence(num_layers)
        if smoke:
            assert same_choices and same_queues, \
                "shared vs per-layer plans diverged on deterministic profiles"
            assert dmk <= 1e-9, dmk
            assert t_pl / t_sh >= 3.0, \
                f"cold shared decide only {t_pl/t_sh:.1f}x vs per-layer"
            assert t_pl / t_w >= 10.0, \
                f"warm-DB decide only {t_pl/t_w:.1f}x vs per-layer cold"

    if print_csv:
        print(csv_line("plan_generation/llm_per_layer", t_pl,
                       f"profiles={s_pl['profile_calls']}"))
        print(csv_line(
            "plan_generation/llm_shared_cold", t_sh,
            f"profiles={s_sh['profile_calls']};"
            f"classes={s_sh['shape_classes']};"
            f"compiles={misses};speedup={t_pl/t_sh:.1f}x"))
        print(csv_line(
            "plan_generation/llm_warm_db", t_w,
            f"profiles=0;db_hits={s_w['profile_db_hits']};"
            f"speedup={t_pl/t_w:.1f}x"))
        print(csv_line(
            "plan_generation/llm_plan_equivalence", dmk,
            f"choices_equal={same_choices};queues_equal={same_queues}"))
    return {
        "per_layer_s": t_pl, "shared_cold_s": t_sh, "warm_db_s": t_w,
        "profile_calls": (s_pl["profile_calls"], s_sh["profile_calls"],
                          s_w["profile_calls"]),
        "plan_equal": same_choices and same_queues,
    }


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    run_llm(smoke=smoke)
    if not smoke:
        run()
