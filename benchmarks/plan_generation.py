"""Table 4 analogue: offline scheduling-plan generation time + disk storage
overhead of the post-transformed weight cache, per model."""
from __future__ import annotations

from benchmarks.common import build_engine, csv_line

MODELS = ["mobilenet", "squeezenet", "resnet18", "alexnet"]


def run(print_csv=True):
    rows = []
    for model in MODELS:
        eng, x = build_engine(model)
        import json
        plan_stats = json.loads(
            (eng.store.root / "plan.json").read_text())["stats"]
        gen = plan_stats["plan_generation_s"]
        cache_mb = plan_stats["cache_bytes"] / 1e6
        model_mb = plan_stats["model_bytes"] / 1e6
        rows.append((model, gen, cache_mb, model_mb))
        if print_csv:
            print(csv_line(
                f"plan_generation/{model}", gen,
                f"cache_mb={cache_mb:.2f};model_mb={model_mb:.2f};"
                f"overhead={cache_mb/max(model_mb,1e-9):.2f}x"))
    return rows


if __name__ == "__main__":
    run()
