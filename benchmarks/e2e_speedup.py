"""Fig. 8 / Table 5 analogue: end-to-end NNV12 vs sequential baseline
speedups per model, plus the gap to warm inference (sim mode over measured
profiles; wall numbers printed alongside for the 1-core host)."""
from __future__ import annotations

from benchmarks.common import build_engine, csv_line, sim_numbers

MODELS = ["mobilenet", "squeezenet", "resnet18", "alexnet"]


def run(print_csv=True):
    rows = []
    for model in MODELS:
        eng, x = build_engine(model)
        sim = sim_numbers(eng)
        wall_nnv12 = eng.run_cold(x, mode="nnv12").total_s
        wall_seq = eng.run_cold(x, mode="sequential").total_s
        speedup = sim.sequential_s / sim.nnv12_s
        vs_warm = sim.nnv12_s / sim.warm_s
        rows.append((model, sim, wall_nnv12, wall_seq))
        if print_csv:
            print(csv_line(f"e2e/{model}/nnv12_sim", sim.nnv12_s,
                           f"speedup={speedup:.2f}x;vs_warm={vs_warm:.2f}x"))
            print(csv_line(f"e2e/{model}/baseline_sim", sim.sequential_s))
            print(csv_line(f"e2e/{model}/warm_sim", sim.warm_s))
            print(csv_line(f"e2e/{model}/nnv12_wall", wall_nnv12,
                           f"wall_speedup={wall_seq/wall_nnv12:.2f}x"))
    return rows


if __name__ == "__main__":
    run()
