"""Fig. 8 / Table 5 analogue: end-to-end NNV12 vs sequential baseline
speedups per model, plus the gap to warm inference (sim mode over measured
profiles; wall numbers printed alongside for the 1-core host)."""
from __future__ import annotations

from benchmarks.common import build_engine, csv_line, sim_numbers

MODELS = ["mobilenet", "squeezenet", "resnet18", "alexnet"]


def run(print_csv=True):
    rows = []
    for model in MODELS:
        eng, x = build_engine(model)
        sim = sim_numbers(eng)
        wall_nnv12 = eng.run_cold(x, mode="nnv12").total_s
        res_seq = eng.run_cold(x, mode="sequential")
        wall_seq = res_seq.total_s
        # the baseline reads with mmap=False, so its 'read' traces carry the
        # real disk cost — a metadata-only read here means the breakdown is
        # lying (the I/O silently moved into transform/stage). Floor: moving
        # model_bytes off disk/page-cache cannot beat 50 GB/s; the exact
        # mmap=False contract is unit-tested in test_pipeline_concurrency.
        seq_read_s = res_seq.stage_seconds().get("read", 0.0)
        read_floor = eng.store.model_bytes() / 50e9
        assert seq_read_s > max(read_floor, 0.0) and seq_read_s > 0.0, (
            f"{model}: sequential baseline read_s={seq_read_s:.2e}s is "
            f"trivial (< {read_floor:.2e}s floor for "
            f"{eng.store.model_bytes()} bytes) — lazy-mmap reads are "
            "corrupting the baseline breakdown")
        speedup = sim.sequential_s / sim.nnv12_s
        vs_warm = sim.nnv12_s / sim.warm_s
        rows.append((model, sim, wall_nnv12, wall_seq))
        if print_csv:
            print(csv_line(f"e2e/{model}/nnv12_sim", sim.nnv12_s,
                           f"speedup={speedup:.2f}x;vs_warm={vs_warm:.2f}x"))
            print(csv_line(f"e2e/{model}/baseline_sim", sim.sequential_s))
            print(csv_line(f"e2e/{model}/warm_sim", sim.warm_s))
            print(csv_line(f"e2e/{model}/nnv12_wall", wall_nnv12,
                           f"wall_speedup={wall_seq/wall_nnv12:.2f}x"))
    return rows


if __name__ == "__main__":
    run()
