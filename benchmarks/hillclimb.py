"""§Perf hillclimbing: three campaigns over the most interesting
(arch × shape) pairs, each a hypothesis -> change -> re-lower -> validate
loop. Results + the full iteration log land in
benchmarks/results/hillclimb.json and EXPERIMENTS.md §Perf.

Pairs (selected from the §Roofline baseline table):
  A qwen3-32b × decode_32k      — most collective-bound (full-cache
                                   all-gathers per layer)
  B internvl2-76b × prefill_32k — worst memory/compute roofline fraction
                                   (online-softmax score traffic)
  C granite-moe × train_4k      — worst useful-FLOPs ratio of the train
                                   pairs; MoE, the paper's 'non-dense archs
                                   matter most' case

Run (after the baseline sweep):
  PYTHONPATH=src python -m benchmarks.hillclimb
"""
import json
from pathlib import Path

RESULTS = Path(__file__).parent / "results" / "hillclimb.json"


def dominant(r):
    return {"compute": r["compute_s"], "memory": r["memory_s"],
            "collective": r["collective_s"]}[r["bottleneck"]]


CAMPAIGNS = [
    {
        "name": "A qwen3-32b x decode_32k (collective-bound)",
        "arch": "qwen3-32b", "shape": "decode_32k",
        "iters": [
            dict(label="a0 baseline (XLA-auto cache attention)",
                 hypothesis="per-layer attention against the seq-sharded "
                            "cache makes SPMD all-gather the full KV cache "
                            "on every layer -> collective-dominated",
                 flags={"decode_flash": False}),
            dict(label="a1 shard_map flash-decoding",
                 hypothesis="partial softmax per seq shard + (B,H,hd) psum "
                            "combine replaces the O(L*cache) gathers; "
                            "predict >=10x collective reduction",
                 flags={"decode_flash": True}),
            dict(label="a2 + serving params replicated over data",
                 hypothesis="remaining collective is FSDP weight gathering "
                            "- wrong trade for decode (weights are re-"
                            "gathered every token). Replicate params over "
                            "data (TP only): predict collective -> ~0, "
                            "memory +weights-read (~+10ms)",
                 flags={"decode_flash": True},
                 strategy="serve_replicated"),
            dict(label="a3 + int8 KV cache (lossy; per-entry scales)",
                 hypothesis="remaining memory term is dominated by reading "
                            "the bf16 cache (~4.3 GiB/step/device); int8 "
                            "values + f32 per-(entry,head) scales cut cache "
                            "bytes ~47%: predict memory term ~1.6-1.9x "
                            "down, peak -2GiB. Logit error bounded in "
                            "tests/test_int8_cache.py",
                 flags={"decode_flash": True, "kv_cache_int8": True},
                 strategy="serve_replicated"),
        ],
    },
    {
        "name": "B internvl2-76b x prefill_32k (memory-bound)",
        "arch": "internvl2-76b", "shape": "prefill_32k",
        "iters": [
            dict(label="b0 baseline (chunk=1024 online softmax)",
                 hypothesis="memory term dominated by S^2 score traffic + "
                            "per-chunk (m,l,acc) carry sweeps",
                 flags={"attn_chunk": 1024}),
            dict(label="b1 chunk 1024 -> 2048",
                 hypothesis="carry-sweep traffic scales 1/nchunks; predict "
                            "~10-20% memory-term drop, peak VMEM x2",
                 flags={"attn_chunk": 2048}),
            dict(label="b2 chunk 2048 -> 4096",
                 hypothesis="same scaling; check peak memory stays in "
                            "budget",
                 flags={"attn_chunk": 4096}),
            dict(label="b3 Pallas flash-attention kernel (modeled)",
                 hypothesis="chunk size doesn't touch the dominant term "
                            "because the S^2 score buffers themselves are "
                            "the traffic; the Pallas kernel "
                            "(repro.kernels.attention, validated vs oracle "
                            "in interpret mode) keeps them in VMEM. "
                            "Modeled via named_scope-classified HLO "
                            "traffic: memory term -> memory_s_flash",
                 flags={"attn_chunk": 1024}, modeled_flash=True),
        ],
    },
    {
        "name": "C granite-moe-3b x train_4k (compute-replicated)",
        "arch": "granite-moe-3b-a800m", "shape": "train_4k",
        "iters": [
            dict(label="c0 baseline (attention replicated over model)",
                 hypothesis="24 q-heads / 8 kv-heads don't divide the "
                            "16-way model axis, so every model shard "
                            "computes the full attention: useful-FLOPs "
                            "ratio 0.06",
                 flags={"seqpar_attn": False}),
            dict(label="c1 sequence-parallel attention (shard_map)",
                 hypothesis="shard query-sequence over model (K/V full, "
                            "GQA-small): per-device attention compute and "
                            "score traffic /16; predict compute term ~5-8x "
                            "down, memory down, small S-gather collective "
                            "added",
                 flags={"seqpar_attn": True}),
            dict(label="c2 + MoE capacity factor 2.0 -> 1.25",
                 hypothesis="expert blocks run at 2x token slack; 1.25 "
                            "cuts grouped-GEMM compute+traffic ~37% at "
                            "bounded drop risk (aux loss balances load)",
                 flags={"seqpar_attn": True},
                 cfg_overrides={"moe_capacity_factor": 1.25}),
            dict(label="c3 + microbatches 16 -> 8",
                 hypothesis="FSDP weight gathers happen per microbatch: "
                            "halving microbatches halves weight-gather "
                            "wire bytes; activation memory x2 but seqpar "
                            "already cut the scores 16x so it fits",
                 flags={"seqpar_attn": True},
                 cfg_overrides={"moe_capacity_factor": 1.25},
                 microbatches=8),
        ],
    },
    {
        "name": "D internvl2-76b x train_4k (largest absolute collective)",
        "arch": "internvl2-76b", "shape": "train_4k",
        "iters": [
            dict(label="d0 baseline (16 microbatches, remat groups of 4)",
                 hypothesis="FSDP (ZeRO-3) gathers every layer's weights "
                            "on every microbatch fwd+bwd: wire ~ 2 x nmb x "
                            "params -> collective-dominated",
                 flags={}),
            dict(label="d1 microbatches 16 -> 8",
                 hypothesis="gathers scale with nmb: predict collective "
                            "~2x down; activations x2 (remat groups keep "
                            "the stack small)",
                 flags={}, microbatches=8),
            dict(label="d2 microbatches 8 -> 4",
                 hypothesis="another ~2x on gathers; activation memory x4 "
                            "vs baseline — check the TPU-projected peak",
                 flags={}, microbatches=4),
        ],
    },
]


def main():
    from repro.launch.dryrun import run_one
    from repro.models.sharding import default_strategy

    out = []
    for camp in CAMPAIGNS:
        print(f"\n##### {camp['name']}")
        prev = None
        iters_out = []
        for it in camp["iters"]:
            strategy = None
            if it.get("strategy") == "serve_replicated":
                strategy = default_strategy(fsdp_axes=None)
            r = run_one(
                camp["arch"], camp["shape"],
                flags=it.get("flags"), strategy=strategy,
                cfg_overrides=it.get("cfg_overrides"),
                microbatches=it.get("microbatches"),
                verbose=False,
            )
            if it.get("modeled_flash"):
                # substitute the kernel-modeled memory term (conservative:
                # only traffic positively attributed to the scope)
                r = dict(r)
                r["memory_s"] = r["memory_s_flash"]
                terms = {"compute": r["compute_s"], "memory": r["memory_s"],
                         "collective": r["collective_s"]}
                r["bottleneck"] = max(terms, key=terms.get)
            dom = dominant(r)
            delta = "" if prev is None else (
                f" | dominant {prev['bottleneck']}:"
                f" {dominant(prev)*1e3:.1f} -> {dom*1e3:.1f} ms"
                f" ({dominant(prev)/dom:.2f}x)"
                if prev["bottleneck"] == r["bottleneck"] else
                f" | bottleneck {prev['bottleneck']} -> {r['bottleneck']}")
            print(f"  {it['label']}")
            print(f"    hypothesis: {it['hypothesis']}")
            print(f"    compute={r['compute_s']*1e3:9.1f}ms "
                  f"memory={r['memory_s']*1e3:9.1f}ms "
                  f"collective={r['collective_s']*1e3:9.1f}ms "
                  f"bottleneck={r['bottleneck']} "
                  f"useful={r['useful_flops_ratio']:.3f} "
                  f"peak_tpu={r['peak_tpu_bytes']/2**30:.2f}GiB{delta}")
            iters_out.append({"label": it["label"],
                              "hypothesis": it["hypothesis"], **r})
            prev = r
        out.append({"campaign": camp["name"], "iters": iters_out})
    RESULTS.parent.mkdir(exist_ok=True)
    RESULTS.write_text(json.dumps(out, indent=1))
    print(f"\nwrote {RESULTS}")


if __name__ == "__main__":
    main()
