"""Chaos benchmark — deterministic fault injection against the cold path.

Three arms, each a gate the fault-domain layer must hold (docs/robustness.md):

  1. **transient chaos** — a seeded ``FaultInjector`` fails ~5% of store
     reads and prep tasks. The cold start must complete with a
     BIT-IDENTICAL output, bounded per-task retries, no leaked admission
     slot or worker thread, and bounded latency inflation.
  2. **cache bit-rot** — a cached extent is corrupted on disk. The lazy
     CRC audit must catch it at read time and the runtime must recompute
     the transform from raw (journaling a ``cache_recompute`` repair) —
     never serve garbage, never fail the request.
  3. **faulting kernel** — the chosen kernel raises at execute. The
     per-(kernel, shape-class) circuit breaker must demote the layer to
     the reference kernel, journal the repair, and mark the plan for
     re-decide — the request completes (allclose, not bit-identical: a
     different kernel ran).

``--smoke`` hard-fails on any gate; CI runs it on every push.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

try:
    from benchmarks.common import csv_line
except ImportError:  # invoked as `python benchmarks/chaos_cold.py`
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.common import csv_line
from repro.executor.pool import CorePool
from repro.executor.server import ColdServer
from repro.faults import FaultInjector
from repro.models.cnn import build_cnn

CHAOS_RATES = {"store.read_raw": 0.08, "store.read_cached": 0.08,
               "task.read": 0.05, "task.stage": 0.05}


def _gate(ok: bool, msg: str, failures: list):
    print(("PASS " if ok else "FAIL ") + msg)
    if not ok:
        failures.append(msg)


def _setup(root: str):
    pool = CorePool(n_little=2, n_big=1, name="chaos")
    server = ColdServer(root, pool=pool, n_little=2)
    layers, x = build_cnn("squeezenet", image=16, width=0.25)
    eng = server.add_model("net", layers, store_fmt="super")
    server.decide("net", x, n_little=2)
    return server, eng, x


def run_transient_chaos(server, eng, x, failures: list):
    """Arm 1: seeded transient faults on reads and prep tasks."""
    pool, store = server.pool, eng.store
    t0 = time.perf_counter()
    y0 = np.asarray(server.cold_start("net", x).result().output)
    base_s = time.perf_counter() - t0

    # seed picked so the deterministic hash injects faults on read, stage
    # AND store sites for this model/job (the decision is a pure function
    # of (seed, site, key, call#) — thread interleaving cannot change it)
    inj = FaultInjector(seed=11, rates=CHAOS_RATES, max_faults_per_key=2)
    store.fault_injector = inj
    pool.fault_injector = inj
    threads_before = pool.threads_created
    try:
        t0 = time.perf_counter()
        cs = server.cold_start("net", x)
        y1 = np.asarray(cs.result().output)
        chaos_s = time.perf_counter() - t0
    finally:
        store.fault_injector = None
        pool.fault_injector = None

    job = cs.job.job
    _gate(inj.n_injected >= 1,
          f"chaos armed: {inj.n_injected} fault(s) injected", failures)
    _gate(job.retries >= 1 and job.retries <= 3 * inj.n_injected + 3,
          f"bounded pool retries absorbed the faults "
          f"(retries={job.retries}, injected={inj.n_injected})", failures)
    _gate(np.array_equal(y0, y1),
          "output BIT-IDENTICAL under injected transient faults", failures)
    _gate(server.stats["active_preps"] == 0,
          "no admission slot leaked", failures)
    _gate(pool.threads_created == threads_before,
          "no worker threads leaked or replaced", failures)
    _gate(pool.health["jobs_failed"] == 0,
          "no job failed under chaos", failures)
    _gate(chaos_s <= 10 * base_s + 0.5,
          f"latency inflation bounded ({base_s:.3f}s -> {chaos_s:.3f}s)",
          failures)
    print(csv_line("chaos/baseline_cold_s", base_s))
    print(csv_line("chaos/chaos_cold_s", chaos_s))
    print(f"chaos/injected_faults,{inj.n_injected},")
    print(f"chaos/pool_retries,{job.retries},")
    return y0


def run_cache_bitrot(server, eng, x, y0, failures: list):
    """Arm 2: corrupt a cached extent on disk mid-fleet."""
    from repro.checkpoint.superbundle import read_super_header
    from repro.core.scheduler import Choice

    store = eng.store
    # force one weighted layer onto the cached-read path so the ladder has
    # a cache extent to lose
    idx, ldef = next((i, l) for i, l in enumerate(eng.layers)
                     if l.spec.weight_shapes)
    name = ldef.spec.name
    kern = eng._kernel_by_name(ldef.spec, eng.plan.choices[idx].kernel)
    eng.plan.choices[idx] = Choice(kern.name, True)
    store.write_cached(name, kern.name,
                       kern.transform(store.read_raw(name), ldef.spec))
    store._super(flush_all=True)
    store.close()  # release the mmap before mutating the file underneath
    eng._runtimes.clear()  # runtimes are plan-bound

    ent = read_super_header(store._super_path)[
        "layers"][name]["cache"][kern.name][0]
    with open(store._super_path, "r+b") as f:
        f.seek(ent["offset"] + ent["nbytes"] // 2)
        b = f.read(1)
        f.seek(ent["offset"] + ent["nbytes"] // 2)
        f.write(bytes([b[0] ^ 0xFF]))

    y2 = np.asarray(server.cold_start("net", x).result().output)
    repairs = eng.repairs.of_kind("cache_recompute")
    _gate(np.array_equal(y0, y2),
          "output BIT-IDENTICAL with a corrupt cache extent", failures)
    _gate(any(r.get("layer") == name for r in repairs),
          f"cache_recompute repair journaled ({len(repairs)} event(s))",
          failures)
    _gate(any(d.get("layer") == name and "checksum" in d.get("reason", "")
              for d in store.dropped_entries),
          "corrupt entry dropped with a checksum reason", failures)
    print(f"chaos/cache_recompute_repairs,{len(repairs)},")


def run_kernel_fault(server, eng, x, y0, failures: list):
    """Arm 3: the chosen kernel faults at execute -> breaker demotion."""
    # a layer whose op type has an alternative kernel to demote to
    target = next(l.spec.name for l in eng.layers
                  if l.spec.weight_shapes
                  and len(eng._kernels_for(l.spec)) > 1)
    inj = FaultInjector(seed=7, rates={"kernel.execute": 1.0},
                        keys={"kernel.execute": {target}},
                        max_faults_per_key=10 ** 6)
    eng.fault_injector = inj
    eng._runtimes.clear()  # rebind runtimes to pick the injector up
    try:
        y3 = np.asarray(server.cold_start("net", x).result().output)
    finally:
        eng.fault_injector = None
        eng._runtimes.clear()

    demotions = eng.repairs.of_kind("kernel_demoted")
    open_keys = eng.breaker.open_keys()
    _gate(np.allclose(y0, y3, rtol=1e-4, atol=1e-5),
          "request completed on the reference kernel (allclose)", failures)
    _gate(any(r.get("layer") == target for r in demotions),
          f"kernel_demoted repair journaled ({len(demotions)} event(s))",
          failures)
    _gate(len(open_keys) >= 1,
          f"circuit breaker open for the sick kernel ({open_keys})",
          failures)
    _gate((eng.store.root / "replan_pending.json").exists(),
          "plan marked for re-decide", failures)

    # breaker already open: the next request short-circuits to the
    # reference kernel without waiting for another fault
    y4 = np.asarray(server.cold_start("net", x).result().output)
    _gate(np.allclose(y0, y4, rtol=1e-4, atol=1e-5),
          "breaker short-circuit serves the follow-up request", failures)
    # and a fresh decide() excludes the demoted kernel + clears the marker
    stats = server.decide("net", x, n_little=2)
    _gate(target in stats.get("replan_cleared", []),
          "re-decide clears the replan marker", failures)
    demoted = {k.split(":", 1)[0] for k in open_keys}
    _gate(stats["choices"][target][0] not in demoted,
          f"re-decide avoids the demoted kernel(s) {sorted(demoted)} "
          f"(picked {stats['choices'][target][0]})", failures)
    print(f"chaos/kernel_demotions,{len(demotions)},")


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--smoke", action="store_true",
                    help="hard-fail on any gate (CI)")
    args = ap.parse_args(argv)
    failures: list = []
    root = tempfile.mkdtemp(prefix="nnv12_chaos_")
    server, eng, x = _setup(root)
    try:
        y0 = run_transient_chaos(server, eng, x, failures)
        run_cache_bitrot(server, eng, x, y0, failures)
        run_kernel_fault(server, eng, x, y0, failures)
    finally:
        leak = server.pool.shutdown()
        _gate(not leak["leaked"], "pool shutdown leaked no workers",
              failures)
    if failures:
        print(f"\n{len(failures)} gate(s) FAILED")
        return 1 if args.smoke else 0
    print("\nall chaos gates passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
