"""Multi-model cold-serving benchmark — the executor subsystem's CI gate.

Arms:
  * concurrent — two CNN models cold-start at once on ONE persistent
    CorePool through a ColdServer with ``max_concurrent_preps=1``:
    outputs must be bit-equal to each model's isolated cold start, the
    admission gauge must never exceed the cap, and the steady path must
    create zero pool threads after warm-up.
  * cold_llm — a tiny LLM cold start through the serving bridge: the
    first token must be emitted before the last layer's decode-path prep
    completes, with at least one weight-prep op still in flight when the
    exec chain started (execute-as-you-load).
  * quantized_llm — the same cold start on a super-bundle store with
    int4 cache extents eligible (format v4): ``decide()`` must pick the
    quantized entry for a majority of matmul layers, the measured cold
    read bytes must drop >= 2x vs the bf16-cache arm, prefill logits
    must stay correlated, and the first-token-before-last-prep policy
    invariant must survive the quantized path.

``--smoke`` hard-fails on any gate; CI runs it on every push.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import threading
import time
from pathlib import Path

import numpy as np

try:
    from benchmarks.common import csv_line
except ImportError:  # invoked as `python benchmarks/serving_cold.py`
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.common import csv_line
from repro.configs import get_config
from repro.core.llm_graph import tiny_llm_graph
from repro.executor.llm_bridge import cold_start_llm
from repro.executor.server import ColdServer
from repro.models.cnn import build_cnn


def _gate(ok: bool, msg: str, failures: list):
    print(("PASS " if ok else "FAIL ") + msg)
    if not ok:
        failures.append(msg)


def run_concurrent(failures: list, *, image=16, width=0.25):
    root = tempfile.mkdtemp(prefix="nnv12_serving_")
    server = ColdServer(root, n_little=2, max_concurrent_preps=1)
    models = {}
    for name, arch in (("mnet", "mobilenet"), ("snet", "squeezenet")):
        layers, x = build_cnn(arch, image=image, width=width)
        server.add_model(name, layers)
        server.decide(name, x, n_little=2)
        models[name] = x

    # isolated baselines (also warms compile caches so the concurrent arm
    # times pure runtime work)
    isolated = {n: server.cold_start(n, x).result()
                for n, x in models.items()}
    pool = server.pool
    threads_before = pool.threads_created

    results = {}

    def go(name, x):
        results[name] = server.cold_start(name, x).result()

    ts = [threading.Thread(target=go, args=item) for item in models.items()]
    t0 = time.perf_counter()
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    wall = time.perf_counter() - t0

    for name in models:
        diff = float(np.abs(np.asarray(results[name].output)
                            - np.asarray(isolated[name].output)).max())
        _gate(diff == 0.0,
              f"concurrent/{name}: output matches isolated cold start "
              f"(max diff {diff:.1e})", failures)
        own = {t.layer for t in results[name].traces}
        _gate(bool(own) and own == {t.layer for t in isolated[name].traces},
              f"concurrent/{name}: traces cover exactly its own layers "
              f"({len(own)} layers)", failures)
    _gate(server.stats["max_active_preps"] <= 1,
          f"admission: co-running preps {server.stats['max_active_preps']} "
          f"<= cap 1", failures)
    _gate(pool.threads_created == threads_before,
          f"steady path: 0 pool threads created across concurrent runs "
          f"(total {pool.threads_created})", failures)
    print(csv_line("serving/concurrent_2model_wall", wall))
    print(csv_line("serving/isolated_sum_wall",
                   sum(r.total_s for r in isolated.values())))


def run_cold_llm(failures: list, *, num_layers=6):
    cfg = get_config("smollm-360m").reduced(
        num_layers=num_layers, d_model=128, d_ff=256, num_heads=2,
        num_kv_heads=1, head_dim=64, vocab_size=512)
    graph, toks = tiny_llm_graph(num_layers)
    root = tempfile.mkdtemp(prefix="nnv12_coldllm_")
    server = ColdServer(root, n_little=2, max_concurrent_preps=2)
    eng = server.add_model("llm", graph)
    server.decide("llm", toks, n_little=2)
    res = cold_start_llm(eng, cfg, toks[0], max_new_tokens=4, n_little=2,
                         server=server, model_name="llm")
    # policy invariant (pack deps must keep packing off the exec chain —
    # a dep regression flips this), not overlap evidence by itself
    _gate(res.first_token_before_last_prep,
          f"cold_llm: first token ({res.first_token_s*1e3:.0f} ms) before "
          f"last layer decode prep ({res.decode_prep_s*1e3:.0f} ms) "
          f"[scheduling-policy invariant]", failures)
    # the actual overlap evidence: execute-as-you-load
    _gate(res.overlapped_layers >= 1,
          f"cold_llm: {res.overlapped_layers} weight-prep ops still in "
          f"flight when the exec chain started (execute-as-you-load); "
          f"{res.overlapped_packs} decode packs overlapped the chain",
          failures)
    _gate(len(res.tokens) == 4,
          f"cold_llm: decoded {len(res.tokens)} tokens through the "
          f"BatchedServer bridge", failures)
    print(csv_line("serving/cold_llm_first_token", res.first_token_s))
    print(csv_line("serving/cold_llm_decode_ready", res.decode_ready_s))


def run_quantized_llm(failures: list, *, num_layers=6):
    """bf16-cache vs int4-cache cold LLM arms over super-bundle v4.

    Both arms run the full serving bridge (ColdServer -> pipeline ->
    BatchedServer decode); they differ only in which transform kernels
    Algorithm 1 may cache. Byte counts come from the store's real read
    path, so the ratio gate measures on-disk cold traffic, not the plan.
    TTFT is reported for both arms but not hard-gated: at this model
    size wall-clock is compile/jit-dominated and would gate on noise.
    """
    from repro.core.profiler import SyntheticProfiler

    cfg = get_config("smollm-360m").reduced(
        num_layers=num_layers, d_model=128, d_ff=256, num_heads=2,
        num_kv_heads=1, head_dim=64, vocab_size=512)
    arms = {}
    for arm, allow in (("bf16", ["bf16_cast"]),
                       ("int4", ["int4", "bf16_cast"])):
        graph, toks = tiny_llm_graph(num_layers)
        matmul = [l.spec.name for l in graph
                  if l.spec.op_type in ("tblock", "lmhead")]
        root = tempfile.mkdtemp(prefix=f"nnv12_qllm_{arm}_")
        server = ColdServer(root, n_little=2, max_concurrent_preps=2)
        eng = server.add_model("llm", graph, store_fmt="super",
                               allow_lossy=True, kernel_allowlist=allow)
        # deterministic synthetic cost model, no wall-clock interference
        # calibration: the pick/byte gates must not depend on host timings
        eng.profiler_factory = SyntheticProfiler
        server.decide("llm", toks, n_little=2,
                      calibrate_interference=False)
        picked = {l.spec.name: c for l, c in zip(eng.layers,
                                                 eng.plan.choices)}
        n_quant = sum(1 for n in matmul
                      if picked[n].kernel == arm and picked[n].use_cache)
        served0 = eng.store.bytes_served()
        res = cold_start_llm(eng, cfg, toks[0], max_new_tokens=4,
                             n_little=2, server=server, model_name="llm")
        arms[arm] = {
            "cold_bytes": eng.store.bytes_served() - served0,
            "ttft": res.first_token_s,
            "logits": np.asarray(res.run.output, np.float32),
            "n_quant": n_quant, "n_matmul": len(matmul), "res": res,
        }
        # bytes/ratios are not seconds — bypass csv_line's us scaling
        print(f"serving/quantized_llm/{arm}/cold_bytes,"
              f"{arms[arm]['cold_bytes']},")
        print(csv_line(f"serving/quantized_llm/{arm}/first_token",
                       res.first_token_s))

    q = arms["int4"]
    _gate(q["n_quant"] > q["n_matmul"] // 2,
          f"quantized_llm: decide() picked the int4 cache for "
          f"{q['n_quant']}/{q['n_matmul']} matmul layers (majority)",
          failures)
    ratio = arms["bf16"]["cold_bytes"] / max(1, q["cold_bytes"])
    _gate(ratio >= 2.0,
          f"quantized_llm: measured cold read bytes "
          f"{arms['bf16']['cold_bytes']} -> {q['cold_bytes']} "
          f"({ratio:.2f}x >= 2.0x below the bf16 cache)", failures)
    a = arms["bf16"]["logits"].ravel()
    b = q["logits"].ravel()
    corr = float(np.corrcoef(a, b)[0, 1])
    # int4 on every matmul of a 6-block model lands ~0.80; gate well below
    # that so the check catches garbage, not quantization noise
    _gate(corr > 0.75,
          f"quantized_llm: prefill logits correlate with the bf16 arm "
          f"(corr {corr:.4f} > 0.75)", failures)
    _gate(q["res"].first_token_before_last_prep,
          f"quantized_llm: first token ({q['res'].first_token_s*1e3:.0f} "
          f"ms) still beats the last decode prep on the quantized path "
          f"({q['res'].decode_prep_s*1e3:.0f} ms)", failures)
    print(f"serving/quantized_llm/bytes_ratio,{ratio:.4f},")
    print(f"serving/quantized_llm/ttft_ratio,"
          f"{q['ttft'] / max(1e-9, arms['bf16']['ttft']):.4f},")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + hard-fail gates (CI)")
    args = ap.parse_args(argv)
    failures: list = []
    run_concurrent(failures)
    run_cold_llm(failures)
    run_quantized_llm(failures)
    if failures:
        print(f"\n{len(failures)} gate(s) failed:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        if args.smoke:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
