# One function per paper table/figure. Prints ``name,us_per_call,derived``
# CSV. Mapping to the paper:
#   cold_vs_warm      -> Fig. 2 / Table 1 (cold/warm gap + stage breakdown)
#   kernel_table      -> Table 2 (per-kernel read/transform/cache/exec)
#   e2e_speedup       -> Fig. 8 / Table 5 (NNV12 vs baseline vs warm)
#   ablation          -> Fig. 13 (K / C / P knobs)
#   dynamic_load      -> Fig. 11 (background load + work stealing)
#   continuous        -> Fig. 14 (kernel switching, 1st/2nd/3rd inference)
#   plan_generation   -> Table 4 (offline decision time, storage overhead)
#   scheduler_quality -> §3.3 (Algorithm 1 vs optimal; annealing baseline)
#   shader_cache      -> §3.4 (XLA executable cache = shader cache)
#   core_sensitivity  -> beyond-paper: scheduler vs big/little asymmetry
#   roofline_report   -> EXPERIMENTS.md §Roofline (from the dry-run JSON)
#   io_formats        -> beyond-paper: per-tensor npy vs packed bundle vs
#                        zero-copy mmap bundle cold-read comparison
import sys
import time


def main() -> None:
    from benchmarks import (
        ablation, cold_vs_warm, continuous, core_sensitivity, dynamic_load,
        e2e_speedup, io_formats, kernel_table, plan_generation,
        roofline_report, scheduler_quality, shader_cache,
    )

    benches = [
        ("io_formats", io_formats.run),
        ("kernel_table", kernel_table.run),
        ("cold_vs_warm", cold_vs_warm.run),
        ("e2e_speedup", e2e_speedup.run),
        ("ablation", ablation.run),
        ("dynamic_load", dynamic_load.run),
        ("continuous", continuous.run),
        ("plan_generation", plan_generation.run),
        ("scheduler_quality", scheduler_quality.run),
        ("shader_cache", shader_cache.run),
        ("core_sensitivity", core_sensitivity.run),
        ("roofline_report", roofline_report.run),
    ]
    print("name,us_per_call,derived")
    for name, fn in benches:
        t0 = time.time()
        try:
            fn(print_csv=True)
        except Exception as e:  # keep the suite going; report the failure
            print(f"{name}/ERROR,0,{type(e).__name__}:{str(e)[:120]}",
                  file=sys.stdout)
        print(f"# {name} finished in {time.time()-t0:.1f}s", file=sys.stderr)


if __name__ == "__main__":
    main()
