"""§Roofline table: per (arch × shape × mesh) compute/memory/collective
terms from the dry-run JSON (benchmarks/results/dryrun_all.json)."""
from __future__ import annotations

import json
from pathlib import Path

RESULTS = Path(__file__).parent / "results" / "dryrun_all.json"


def load():
    if not RESULTS.exists():
        return None
    return json.loads(RESULTS.read_text())


def run(print_csv=True):
    data = load()
    if data is None:
        print("# roofline: run `python -m repro.launch.dryrun --arch all "
              "--shape all --both-meshes --out benchmarks/results/"
              "dryrun_all.json` first")
        return []
    rows = []
    for r in data["results"]:
        dom = {"compute": r["compute_s"], "memory": r["memory_s"],
               "collective": r["collective_s"]}[r["bottleneck"]]
        rows.append(r)
        if print_csv:
            print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
                  f"{dom*1e6:.1f},"
                  f"bottleneck={r['bottleneck']};"
                  f"compute_ms={r['compute_s']*1e3:.2f};"
                  f"memory_ms={r['memory_s']*1e3:.2f};"
                  f"collective_ms={r['collective_s']*1e3:.2f};"
                  f"useful={r['useful_flops_ratio']:.3f};"
                  f"fits={r['fits_hbm']}")
    if data.get("failures"):
        for f in data["failures"]:
            print(f"roofline/FAIL/{f['arch']}/{f['shape']}/{f['mesh']},0,"
                  f"error={f['error'][:80]}")
    return rows


def markdown_table(results) -> str:
    """EXPERIMENTS.md §Roofline table text."""
    lines = [
        "| arch | shape | mesh | compute (ms) | memory (ms) | collective (ms)"
        " | bottleneck | useful FLOPs | peak GiB | fits |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in results:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['compute_s']*1e3:.1f} | {r['memory_s']*1e3:.1f} "
            f"| {r['collective_s']*1e3:.1f} | {r['bottleneck']} "
            f"| {r['useful_flops_ratio']:.3f} "
            f"| {r['peak_memory_bytes']/2**30:.2f} "
            f"| {'Y' if r['fits_hbm'] else 'N'} |")
    return "\n".join(lines)


if __name__ == "__main__":
    run()
