"""Fig. 14 analogue: cold + subsequent warm inferences with kernel switching
(§3.5) — wall-clock on this host."""
from __future__ import annotations

import numpy as np

from repro.core.switching import ContinuousSession
from benchmarks.common import build_engine, csv_line


def run(print_csv=True, model="squeezenet"):
    eng, x = build_engine(model)
    warm_ref = eng.run_warm(x)
    sess = ContinuousSession(eng, n_little=3)
    r1 = sess.cold_infer(x)
    r2 = sess.warm_infer(x, wait=True)   # 2nd inference (switched)
    r3 = sess.warm_infer(x, wait=True)   # 3rd
    rows = [("1st", r1.total_s), ("2nd", r2.total_s), ("3rd", r3.total_s),
            ("warm_ref", warm_ref)]
    if print_csv:
        for k, v in rows:
            print(csv_line(f"continuous/{model}/{k}", v,
                           f"vs_warm={v/warm_ref:.2f}x"))
    return rows


if __name__ == "__main__":
    run()
