"""Table 2 analogue: per-kernel read-raw / transform / read-cache / stage /
execute times for one conv operator (k=3, s=1, C=64 -> O=192, like the
paper's); stage = host->device transfer of the transformed weights."""
from __future__ import annotations

import tempfile

import numpy as np

from repro.checkpoint import LayerStore
from repro.core.profiler import Profiler
from repro.core.registry import LayerSpec, registry_for
from benchmarks.common import csv_line


def run(print_csv=True, cin=64, cout=192, hw=32):
    rng = np.random.default_rng(0)
    spec = LayerSpec(
        "conv_t2", "conv2d",
        {"kernel": 3, "stride": 1, "padding": "SAME",
         "in_channels": cin, "out_channels": cout},
        {"w": (cout, cin, 3, 3), "b": (cout,)},
    )
    raw = {"w": rng.standard_normal((cout, cin, 3, 3)).astype(np.float32),
           "b": np.zeros(cout, np.float32)}
    x = rng.standard_normal((1, hw, hw, cin)).astype(np.float32)
    rows = []
    with tempfile.TemporaryDirectory() as d:
        store = LayerStore(d)
        store.write_raw(spec.name, raw)
        with Profiler(store) as prof:
            rows = _profile_all(prof, spec, x, print_csv)
    return rows


def _profile_all(prof, spec, x, print_csv):
    rows = []
    for kern in registry_for("conv2d"):
        if not kern.supports(spec):
            continue
        p = prof.profile(spec, kern, x)
        rows.append(p)
        if print_csv:
            print(csv_line(f"kernel_table/{kern.name}/read_raw", p.read_raw_s))
            print(csv_line(f"kernel_table/{kern.name}/transform", p.transform_s))
            print(csv_line(f"kernel_table/{kern.name}/read_cache", p.read_cached_s))
            print(csv_line(f"kernel_table/{kern.name}/stage", p.stage_s))
            print(csv_line(
                f"kernel_table/{kern.name}/execute", p.exec_s,
                f"cached_bytes={p.transformed_bytes};raw_bytes={p.raw_bytes}"))
    return rows


if __name__ == "__main__":
    run()
