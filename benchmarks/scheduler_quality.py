"""Scheduler quality: Algorithm 1 vs brute-force optimum vs simulated
annealing (beyond-paper baseline), on synthetic Table-2-shaped profiles.
Reports makespan ratio to optimal and decision time."""
from __future__ import annotations

import random
import time

from repro.core.scheduler import (
    Choice, LayerCandidates, brute_force_optimal, schedule, schedule_annealed,
)
from benchmarks.common import csv_line


def _random_cands(rng, n_layers, n_kernels=2):
    out = []
    for li in range(n_layers):
        opts = []
        for k in range(n_kernels):
            # one winograd-ish (slow prep / fast exec), one sgemm-ish
            if k == 0:
                pl, pb, ex = rng.uniform(2, 6), rng.uniform(1, 3), rng.uniform(0.2, 1)
            else:
                pl, pb, ex = rng.uniform(0.2, 1), rng.uniform(0.1, 0.5), rng.uniform(1, 3)
            opts.append((Choice(f"k{k}", False), pl, pb, ex))
            opts.append((Choice(f"k{k}", True), pl * 0.3, pb * 0.3, ex))
        out.append(LayerCandidates(f"l{li}", opts))
    return out


def run(print_csv=True, trials=8):
    rng = random.Random(0)
    ratios, ann_ratios = [], []
    t_heur = t_opt = t_ann = 0.0
    for _ in range(trials):
        cands = _random_cands(rng, n_layers=5)
        t0 = time.perf_counter(); heur = schedule(cands, M_l=2); t_heur += time.perf_counter() - t0
        t0 = time.perf_counter(); opt = brute_force_optimal(cands, M_l=2); t_opt += time.perf_counter() - t0
        t0 = time.perf_counter(); ann = schedule_annealed(cands, M_l=2, iters=400); t_ann += time.perf_counter() - t0
        ratios.append(heur.est_makespan / opt.est_makespan)
        ann_ratios.append(ann.est_makespan / opt.est_makespan)
    avg, worst = sum(ratios) / len(ratios), max(ratios)
    if print_csv:
        print(csv_line("scheduler/algorithm1_decision", t_heur / trials,
                       f"avg_ratio_to_opt={avg:.3f};worst={worst:.3f}"))
        print(csv_line("scheduler/bruteforce_decision", t_opt / trials,
                       "ratio=1.0"))
        print(csv_line("scheduler/annealing_decision", t_ann / trials,
                       f"avg_ratio_to_opt={sum(ann_ratios)/len(ann_ratios):.3f}"))
    return avg, worst


if __name__ == "__main__":
    run()
