"""Shared benchmark helpers.

Two measurement modes everywhere (DESIGN.md §2):
  wall — real seconds on this 1-core host (threads overlap disk I/O only);
  sim  — deterministic event-driven makespans under the calibrated
         big.LITTLE CoreModel (Fig. 6 factors), fed with *measured*
         per-op profiles. The paper's multi-core claims are evaluated in
         sim; wall numbers validate that the plumbing is real.
"""
from __future__ import annotations

import tempfile
from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from repro.core.engine import ColdEngine
from repro.core.profiler import CoreModel
from repro.core.scheduler import simulate
from repro.models.cnn import build_cnn

CORE_MODEL = CoreModel()


@dataclass
class SimNumbers:
    nnv12_s: float
    sequential_s: float       # warm-best kernels, read->transform->exec
    warm_s: float             # execution only (weights resident)
    kernel_only_s: float      # +K: cold kernels, still sequential
    kernel_cache_s: float     # +KC: cold kernels + cache, sequential


def build_engine(model: str, *, image=40, width=0.6, n_little=3, store=None):
    layers, x = build_cnn(model, image=image, width=width)
    eng = ColdEngine(layers, store or tempfile.mkdtemp(prefix=f"nnv12_{model}_"))
    eng.decide(x, n_little=n_little)
    return eng, x


def sim_numbers(eng: ColdEngine, n_little: int = 3) -> SimNumbers:
    """Deterministic makespans from the measured profiles + CoreModel."""
    cm = CORE_MODEL
    warm = eng.warm_best_choices()
    names = [l.spec.name for l in eng.layers]

    def prof(name, kernel):
        return next(p for p in eng.profiles[name] if p.kernel == kernel)

    # sequential baseline: big-core read + transform + exec, warm kernels
    seq = sum(prof(n, c.kernel).prep_s(False) + prof(n, c.kernel).exec_s
              for n, c in zip(names, warm))
    warm_s = sum(prof(n, c.kernel).exec_s for n, c in zip(names, warm))
    # +K: scheduler's kernels (cold-optimal), sequential, no cache
    choices = eng.plan.choices
    k_only = sum(prof(n, c.kernel).prep_s(False) + prof(n, c.kernel).exec_s
                 for n, c in zip(names, choices))
    # +KC: with the cache decisions
    kc = sum(prof(n, c.kernel).prep_s(c.use_cache) + prof(n, c.kernel).exec_s
             for n, c in zip(names, choices))
    return SimNumbers(
        nnv12_s=eng.plan.est_makespan,
        sequential_s=seq, warm_s=warm_s,
        kernel_only_s=k_only, kernel_cache_s=kc,
    )


def csv_line(name: str, seconds: float, derived: str = "") -> str:
    return f"{name},{seconds*1e6:.1f},{derived}"
