"""CoreModel sensitivity: how the scheduler's plan and its advantage over
the sequential baseline vary with the big/little asymmetry (the paper's
Table 5 spans 6 devices with very different core ratios)."""
from __future__ import annotations

from repro.core.profiler import CoreModel
from repro.core.scheduler import Choice, LayerCandidates, schedule
from benchmarks.common import build_engine, csv_line


def run(print_csv=True, model="resnet18"):
    eng, x = build_engine(model, image=48, width=0.75)
    names = [l.spec.name for l in eng.layers]

    def prof(n, kern):
        return next(p for p in eng.profiles[n] if p.kernel == kern)

    rows = []
    # sweep little-core slowness (paper Fig. 6: Meizu 16T exec 6x, read 2x,
    # transform 3.8x; weaker SoCs are closer to 2x, DSP-like offload ~12x)
    for label, (ex_f, rd_f, tr_f) in {
        "symmetric": (1.0, 1.0, 1.0),
        "mild(2x)": (2.0, 1.3, 1.6),
        "meizu16t(6x)": (6.0, 2.0, 3.8),
        "extreme(12x)": (12.0, 3.0, 7.0),
    }.items():
        cands = []
        for l in eng.layers:
            opts = []
            for p in eng.profiles[l.spec.name]:
                for cache in ((False, True) if l.spec.weight_shapes else (False,)):
                    pl = (p.read_cached_s * rd_f if cache
                          else p.read_raw_s * rd_f + p.transform_s * tr_f)
                    pl += p.stage_s  # device staging: DMA-bound, factor ~1
                    opts.append((Choice(p.kernel, cache), pl,
                                 p.prep_s(cache), p.exec_s))
            cands.append(LayerCandidates(l.spec.name, opts))
        plan = schedule(cands, M_l=3)
        seq = sum(min(p.prep_s(False) + p.exec_s
                      for p in eng.profiles[n]) for n in names)
        cached = sum(1 for c in plan.choices if c.use_cache)
        rows.append((label, plan.est_makespan, seq, cached))
        if print_csv:
            print(csv_line(
                f"core_sensitivity/{model}/{label}", plan.est_makespan,
                f"speedup_vs_seq={seq/plan.est_makespan:.2f}x;"
                f"cached_layers={cached}/{len(names)}"))
    return rows


if __name__ == "__main__":
    run()
