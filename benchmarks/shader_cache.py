"""§3.4 GPU analogue: XLA executable ("shader") caching — compile time vs
deserialize-from-disk time per layer, the cold-start stage the compile cache
removes."""
from __future__ import annotations

import tempfile

from benchmarks.common import build_engine, csv_line


def run(print_csv=True, model="mobilenet"):
    # first engine: cold compile cache -> everything compiles
    with tempfile.TemporaryDirectory() as store:
        eng, x = build_engine(model, store=store)
        eng.run_cold(x)
        s1 = dict(eng.compile_cache.stats)

        # second engine, same store: executables come from disk
        from repro.core.engine import ColdEngine
        from repro.models.cnn import build_cnn

        layers, x2 = build_cnn(model, image=40, width=0.6)
        eng2 = ColdEngine(layers, store)
        eng2.plan = eng.plan
        eng2.profiles = eng.profiles
        eng2._input_example = x2
        eng2.make_runtime(n_little=2)
        s2 = dict(eng2.compile_cache.stats)
    if print_csv:
        print(csv_line("shader_cache/compile_total", s1["compile_s"],
                       f"misses={s1['misses']}"))
        print(csv_line("shader_cache/deserialize_total", s2["deserialize_s"],
                       f"disk_hits={s2['disk_hits']};"
                       f"speedup={s1['compile_s']/max(s2['deserialize_s'],1e-9):.1f}x"))
    return s1, s2


if __name__ == "__main__":
    run()
