"""Cold weight-read formats: per-tensor .npy vs packed bundle vs mmap bundle.

Measures the per-layer 'weights reading' op the scheduler pipelines, across
the three on-disk layouts the ``LayerStore`` supports:

  npy          legacy: one file per tensor, N opens + N full copies
  bundle       packed single-blob layer file, ONE open + one sequential read
  bundle_mmap  same file, zero-copy ``np.memmap`` views — the read op is
               metadata-only; payload pages fault in later, inside
               transform/stage, off the critical exec chain

``bundle_mmap_touch`` additionally faults every payload byte in, so the
mmap row can't hide I/O that merely moved downstream — it bounds the
total cost, while ``bundle_mmap`` is what the pipelined runtime's read op
actually pays.

Workloads: cnn_zoo models (2 tensors/layer — worst case for bundling) and
an LLM decoder graph (10+ tensors per tblock — where N-opens hurt most).

Run: PYTHONPATH=src python benchmarks/io_formats.py [--smoke]
"""
from __future__ import annotations

import sys
import tempfile
import time
from pathlib import Path
from typing import Dict, List, Tuple

import numpy as np

from repro.checkpoint import LayerStore
from repro.core.oscache import CAN_DROP, drop_page_cache

try:
    from benchmarks.common import csv_line
except ModuleNotFoundError:  # invoked as `python benchmarks/io_formats.py`
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.common import csv_line


def _cnn_weights(model: str, image: int, width: float) -> Dict[str, dict]:
    from repro.models.cnn import build_cnn

    layers, _ = build_cnn(model, image=image, width=width)
    return {l.spec.name: l.weights for l in layers if l.weights}


def _llm_weights(num_layers: int, d_model: int) -> Dict[str, dict]:
    import jax

    from repro.configs import get_config
    from repro.core.llm_graph import build_llm_graph
    from repro.models import transformer as T

    cfg = get_config("smollm-360m").reduced(
        num_layers=num_layers, d_model=d_model, d_ff=d_model * 3,
        num_heads=8, num_kv_heads=4, head_dim=d_model // 8,
        vocab_size=2048)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    graph, _ = build_llm_graph(cfg, params)
    return {l.spec.name: l.weights for l in graph if l.weights}


def _sweep(read_fn, names: List[str], repeats: int) -> float:
    """Best-of-N full-model sweep: seconds to read every layer once,
    page cache dropped first when the host allows (paper methodology)."""
    best = float("inf")
    for _ in range(repeats):
        if CAN_DROP:
            drop_page_cache()
        t0 = time.perf_counter()
        for n in names:
            read_fn(n)
        best = min(best, time.perf_counter() - t0)
    return best


def _touch(w: Dict[str, np.ndarray]) -> int:
    total = 0
    for v in w.values():
        total += int(v.view(np.uint8).reshape(-1)[:: 4096].sum())
    return total


def bench_model(name: str, weights: Dict[str, dict], repeats: int = 3,
                print_csv: bool = True) -> Dict[str, float]:
    names = list(weights)
    with tempfile.TemporaryDirectory(prefix=f"iofmt_{name}_") as td:
        s_npy = LayerStore(Path(td) / "npy", fmt="npy")
        s_bun = LayerStore(Path(td) / "bundle", fmt="bundle")
        for ln, w in weights.items():
            s_npy.write_raw(ln, w)
            s_bun.write_raw(ln, w)

        t_npy = _sweep(lambda n: s_npy.read_raw(n), names, repeats)
        t_bun = _sweep(lambda n: s_bun.read_raw(n, mmap=False), names, repeats)
        t_map = _sweep(lambda n: s_bun.read_raw(n, mmap=True), names, repeats)
        t_map_touch = _sweep(
            lambda n: _touch(s_bun.read_raw(n, mmap=True)), names, repeats)

    per_layer = 1.0 / max(len(names), 1)
    res = {
        "npy_s": t_npy, "bundle_s": t_bun, "bundle_mmap_s": t_map,
        "bundle_mmap_touch_s": t_map_touch,
        "speedup_bundle": t_npy / max(t_bun, 1e-9),
        "speedup_mmap": t_npy / max(t_map, 1e-9),
        "speedup_mmap_touch": t_npy / max(t_map_touch, 1e-9),
    }
    if print_csv:
        print(csv_line(f"io_formats/{name}/npy", t_npy * per_layer,
                       f"layers={len(names)}"))
        print(csv_line(f"io_formats/{name}/bundle", t_bun * per_layer,
                       f"speedup={res['speedup_bundle']:.2f}x"))
        print(csv_line(f"io_formats/{name}/bundle_mmap", t_map * per_layer,
                       f"speedup={res['speedup_mmap']:.2f}x"))
        print(csv_line(f"io_formats/{name}/bundle_mmap_touch",
                       t_map_touch * per_layer,
                       f"speedup={res['speedup_mmap_touch']:.2f}x"))
    return res


def run(print_csv: bool = True, smoke: bool = False) -> Dict[str, Dict[str, float]]:
    if smoke:
        cases: List[Tuple[str, Dict[str, dict]]] = [
            ("mobilenet", _cnn_weights("mobilenet", image=24, width=0.5)),
            ("llm_tiny", _llm_weights(num_layers=3, d_model=256)),
        ]
        repeats = 3
    else:
        cases = [
            ("mobilenet", _cnn_weights("mobilenet", image=40, width=1.0)),
            ("resnet18", _cnn_weights("resnet18", image=40, width=1.0)),
            ("squeezenet", _cnn_weights("squeezenet", image=40, width=1.0)),
            ("llm_smollm", _llm_weights(num_layers=8, d_model=512)),
        ]
        repeats = 3
    out = {}
    for name, weights in cases:
        out[name] = bench_model(name, weights, repeats=repeats,
                                print_csv=print_csv)
    if print_csv and not CAN_DROP:
        print("# warning: cannot drop page cache — warm-cache numbers",
              file=sys.stderr)
    return out


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    print("name,us_per_call,derived")
    run(print_csv=True, smoke=smoke)
