"""Cold weight-read formats: per-tensor .npy vs packed bundle vs model-level
super-bundle.

Measures the per-layer 'weights reading' op the scheduler pipelines, across
the on-disk layouts the ``LayerStore`` supports:

  npy          legacy: one file per tensor, N opens + N full copies
  bundle       packed single-blob layer file, ONE open + one sequential read
  bundle_mmap  same file, zero-copy ``np.memmap`` views — the read op is
               metadata-only; payload pages fault in later, inside
               transform/stage, off the critical exec chain
  super        v2 model-level super-bundle: the WHOLE model in one file,
               read through one shared mmap — ONE open per model;
               ``super`` materializes each layer's bytes (real I/O in the
               read op), ``super_mmap`` returns zero-copy views
  *_touch      additionally faults every payload byte in, so a lazy row
               can't hide I/O that merely moved downstream

The super-bundle store is built with ``superbundle.migrate`` from the
per-layer bundle tree, so the migration path is exercised on every run.
Every run cross-checks tensor equivalence across all formats and counts
the file opens a full-model sweep performs (npy: N_tensors, bundle:
N_layers, super: 1) — both are hard failures on mismatch, which is what
CI runs ``--smoke`` for.

Workloads: cnn_zoo models (2 tensors/layer — worst case for bundling) and
an LLM decoder graph (10+ tensors per tblock — where N-opens hurt most).

Run: PYTHONPATH=src python benchmarks/io_formats.py [--smoke]
"""
from __future__ import annotations

import sys
import tempfile
import time
from pathlib import Path
from typing import Dict, List, Tuple

import numpy as np

from repro.checkpoint import LayerStore
from repro.checkpoint.superbundle import migrate
from repro.core.oscache import CAN_DROP, drop_page_cache

try:
    from benchmarks.common import csv_line
except ModuleNotFoundError:  # invoked as `python benchmarks/io_formats.py`
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.common import csv_line


def _cnn_weights(model: str, image: int, width: float) -> Dict[str, dict]:
    from repro.models.cnn import build_cnn

    layers, _ = build_cnn(model, image=image, width=width)
    return {l.spec.name: l.weights for l in layers if l.weights}


def _llm_weights(num_layers: int, d_model: int) -> Dict[str, dict]:
    import jax

    from repro.configs import get_config
    from repro.core.llm_graph import build_llm_graph
    from repro.models import transformer as T

    cfg = get_config("smollm-360m").reduced(
        num_layers=num_layers, d_model=d_model, d_ff=d_model * 3,
        num_heads=8, num_kv_heads=4, head_dim=d_model // 8,
        vocab_size=2048)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    graph, _ = build_llm_graph(cfg, params)
    return {l.spec.name: l.weights for l in graph if l.weights}


def _sweep(read_fn, names: List[str], repeats: int, reset=None) -> float:
    """Best-of-N full-model sweep: seconds to read every layer once,
    page cache dropped first when the host allows (paper methodology).
    ``reset`` runs before each pass (e.g. close the super-bundle's shared
    mmap so every pass pays the cold open)."""
    best = float("inf")
    for _ in range(repeats):
        if reset is not None:
            reset()
        if CAN_DROP:
            drop_page_cache()
        t0 = time.perf_counter()
        for n in names:
            read_fn(n)
        best = min(best, time.perf_counter() - t0)
    return best


def _touch(w: Dict[str, np.ndarray]) -> int:
    total = 0
    for v in w.values():
        total += int(v.view(np.uint8).reshape(-1)[:: 4096].sum())
    return total


def _count_opens(store: LayerStore, names: List[str]) -> int:
    """File opens one cold full-model read sweep performs."""
    store.close()
    store.open_count = 0
    for n in names:
        store.read_raw(n)
    return store.open_count


def _check_equivalence(stores: Dict[str, LayerStore], names: List[str]):
    """Every format must return identical tensors for every layer — a
    mismatch is a hard failure (CI gates on it)."""
    ref = stores["npy"]
    for n in names:
        want = ref.read_raw(n)
        for label, st in stores.items():
            if st is ref:
                continue
            got = st.read_raw(n, mmap=False)
            if set(got) != set(want):
                raise AssertionError(
                    f"equivalence mismatch: {label}/{n} keys {set(got)} "
                    f"!= npy keys {set(want)}")
            for k in want:
                if got[k].dtype != want[k].dtype or not np.array_equal(
                        np.asarray(got[k]), np.asarray(want[k])):
                    raise AssertionError(
                        f"equivalence mismatch: {label}/{n}/{k}")


def bench_model(name: str, weights: Dict[str, dict], repeats: int = 3,
                print_csv: bool = True) -> Dict[str, float]:
    names = list(weights)
    with tempfile.TemporaryDirectory(prefix=f"iofmt_{name}_") as td:
        s_npy = LayerStore(Path(td) / "npy", fmt="npy")
        s_bun = LayerStore(Path(td) / "bundle", fmt="bundle")
        for ln, w in weights.items():
            s_npy.write_raw(ln, w)
            s_bun.write_raw(ln, w)
        # super store: migrated from the per-layer bundle tree, laid out in
        # graph order — exercises the migration path every run
        s_sup = LayerStore(Path(td) / "super", fmt="super")
        migrate(Path(td) / "bundle", Path(td) / "super" / "model.superbundle",
                order=names)

        _check_equivalence(
            {"npy": s_npy, "bundle": s_bun, "super": s_sup}, names)
        opens = {
            "npy": _count_opens(s_npy, names),
            "bundle": _count_opens(s_bun, names),
            "super": _count_opens(s_sup, names),
        }
        assert opens["super"] == 1, (
            f"super-bundle must be ONE open per model, saw {opens['super']}")
        assert opens["bundle"] == len(names), opens

        t_npy = _sweep(lambda n: s_npy.read_raw(n), names, repeats)
        t_bun = _sweep(lambda n: s_bun.read_raw(n, mmap=False), names, repeats)
        t_map = _sweep(lambda n: s_bun.read_raw(n, mmap=True), names, repeats)
        t_map_touch = _sweep(
            lambda n: _touch(s_bun.read_raw(n, mmap=True)), names, repeats)
        t_sup = _sweep(lambda n: s_sup.read_raw(n, mmap=False), names,
                       repeats, reset=s_sup.close)
        t_sup_map = _sweep(lambda n: s_sup.read_raw(n, mmap=True), names,
                           repeats, reset=s_sup.close)
        t_sup_touch = _sweep(
            lambda n: _touch(s_sup.read_raw(n, mmap=True)), names,
            repeats, reset=s_sup.close)

    per_layer = 1.0 / max(len(names), 1)
    res = {
        "npy_s": t_npy, "bundle_s": t_bun, "bundle_mmap_s": t_map,
        "bundle_mmap_touch_s": t_map_touch,
        "super_s": t_sup, "super_mmap_s": t_sup_map,
        "super_mmap_touch_s": t_sup_touch,
        "opens_npy": opens["npy"], "opens_bundle": opens["bundle"],
        "opens_super": opens["super"],
        "speedup_bundle": t_npy / max(t_bun, 1e-9),
        "speedup_mmap": t_npy / max(t_map, 1e-9),
        "speedup_mmap_touch": t_npy / max(t_map_touch, 1e-9),
        "speedup_super": t_npy / max(t_sup, 1e-9),
        "speedup_super_mmap": t_npy / max(t_sup_map, 1e-9),
    }
    if print_csv:
        print(csv_line(f"io_formats/{name}/npy", t_npy * per_layer,
                       f"layers={len(names)};opens={opens['npy']}"))
        print(csv_line(f"io_formats/{name}/bundle", t_bun * per_layer,
                       f"speedup={res['speedup_bundle']:.2f}x"
                       f";opens={opens['bundle']}"))
        print(csv_line(f"io_formats/{name}/bundle_mmap", t_map * per_layer,
                       f"speedup={res['speedup_mmap']:.2f}x"))
        print(csv_line(f"io_formats/{name}/bundle_mmap_touch",
                       t_map_touch * per_layer,
                       f"speedup={res['speedup_mmap_touch']:.2f}x"))
        print(csv_line(f"io_formats/{name}/super", t_sup * per_layer,
                       f"speedup={res['speedup_super']:.2f}x;opens=1"))
        print(csv_line(f"io_formats/{name}/super_mmap", t_sup_map * per_layer,
                       f"speedup={res['speedup_super_mmap']:.2f}x;opens=1"))
        print(csv_line(f"io_formats/{name}/super_mmap_touch",
                       t_sup_touch * per_layer,
                       f"speedup={t_npy / max(t_sup_touch, 1e-9):.2f}x"))
        ok = t_sup_map <= t_map
        print(f"# {name}: super_mmap <= bundle_mmap: {ok} "
              f"({t_sup_map * per_layer * 1e6:.1f} vs "
              f"{t_map * per_layer * 1e6:.1f} us/layer), "
              f"opens {opens['super']} vs {opens['bundle']}")
    return res


def run(print_csv: bool = True, smoke: bool = False) -> Dict[str, Dict[str, float]]:
    if smoke:
        cases: List[Tuple[str, Dict[str, dict]]] = [
            ("mobilenet", _cnn_weights("mobilenet", image=24, width=0.5)),
            ("llm_tiny", _llm_weights(num_layers=3, d_model=256)),
        ]
        repeats = 3
    else:
        cases = [
            ("mobilenet", _cnn_weights("mobilenet", image=40, width=1.0)),
            ("resnet18", _cnn_weights("resnet18", image=40, width=1.0)),
            ("squeezenet", _cnn_weights("squeezenet", image=40, width=1.0)),
            ("llm_smollm", _llm_weights(num_layers=8, d_model=512)),
        ]
        repeats = 3
    out = {}
    for name, weights in cases:
        out[name] = bench_model(name, weights, repeats=repeats,
                                print_csv=print_csv)
    if print_csv and not CAN_DROP:
        print("# warning: cannot drop page cache — warm-cache numbers",
              file=sys.stderr)
    return out


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    print("name,us_per_call,derived")
    run(print_csv=True, smoke=smoke)
