"""Cold weight-read formats: per-tensor .npy vs packed bundle vs model-level
super-bundle.

Measures the per-layer 'weights reading' op the scheduler pipelines, across
the on-disk layouts the ``LayerStore`` supports:

  npy          legacy: one file per tensor, N opens + N full copies
  bundle       packed single-blob layer file, ONE open + one sequential read
  bundle_mmap  same file, zero-copy ``np.memmap`` views — the read op is
               metadata-only; payload pages fault in later, inside
               transform/stage, off the critical exec chain
  super        v2 model-level super-bundle: the WHOLE model in one file,
               read through one shared mmap — ONE open per model;
               ``super`` materializes each layer's bytes (real I/O in the
               read op), ``super_mmap`` returns zero-copy views
  *_touch      additionally faults every payload byte in, so a lazy row
               can't hide I/O that merely moved downstream

The super-bundle store is built with ``superbundle.migrate`` from the
per-layer bundle tree, so the migration path is exercised on every run.
Every run cross-checks tensor equivalence across all formats and counts
the file opens a full-model sweep performs (npy: N_tensors, bundle:
N_layers, super: 1) — both are hard failures on mismatch, which is what
CI runs ``--smoke`` for.

The durability arms (``bench_durability``) exercise the v3 container's
crash-atomicity layer and are also hard gates in ``--smoke``:

  verify overhead   full cold sweeps with ``verify="never"`` vs the
                    default ``verify="lazy"`` — the lazy CRC-32C audit
                    must cost <= 5% on the engine's cold read path (one
                    open + recovery + zero-copy mmap reads); the eager
                    full-file audit (fsck mode) is timed and reported
  crash injection   an in-place cache commit is crashed at every phase
                    (after journal fsync / mid-slot / pre-header / torn
                    header / pre-commit-record); reopening must leave the
                    entry fully applied or fully rolled back — raw
                    weights byte-identical, no torn bytes ever served
  compaction        dropped entries leave dead extents; ``compact`` must
                    reclaim them to exactly zero slack beyond alignment

Workloads: cnn_zoo models (2 tensors/layer — worst case for bundling) and
an LLM decoder graph (10+ tensors per tblock — where N-opens hurt most).

Run: PYTHONPATH=src python benchmarks/io_formats.py [--smoke]
"""
from __future__ import annotations

import sys
import tempfile
import time
from pathlib import Path
from typing import Dict, List, Tuple

import numpy as np

from repro.checkpoint import LayerStore
from repro.checkpoint.superbundle import migrate
from repro.core.oscache import CAN_DROP, drop_page_cache

try:
    from benchmarks.common import csv_line
except ModuleNotFoundError:  # invoked as `python benchmarks/io_formats.py`
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
    from benchmarks.common import csv_line


def _cnn_weights(model: str, image: int, width: float) -> Dict[str, dict]:
    from repro.models.cnn import build_cnn

    layers, _ = build_cnn(model, image=image, width=width)
    return {l.spec.name: l.weights for l in layers if l.weights}


def _llm_weights(num_layers: int, d_model: int) -> Dict[str, dict]:
    import jax

    from repro.configs import get_config
    from repro.core.llm_graph import build_llm_graph
    from repro.models import transformer as T

    cfg = get_config("smollm-360m").reduced(
        num_layers=num_layers, d_model=d_model, d_ff=d_model * 3,
        num_heads=8, num_kv_heads=4, head_dim=d_model // 8,
        vocab_size=2048)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    graph, _ = build_llm_graph(cfg, params)
    return {l.spec.name: l.weights for l in graph if l.weights}


def _sweep(read_fn, names: List[str], repeats: int, reset=None) -> float:
    """Best-of-N full-model sweep: seconds to read every layer once,
    page cache dropped first when the host allows (paper methodology).
    ``reset`` runs before each pass (e.g. close the super-bundle's shared
    mmap so every pass pays the cold open)."""
    best = float("inf")
    for _ in range(repeats):
        if reset is not None:
            reset()
        if CAN_DROP:
            drop_page_cache()
        t0 = time.perf_counter()
        for n in names:
            read_fn(n)
        best = min(best, time.perf_counter() - t0)
    return best


def _touch(w: Dict[str, np.ndarray]) -> int:
    total = 0
    for v in w.values():
        total += int(v.view(np.uint8).reshape(-1)[:: 4096].sum())
    return total


def _count_opens(store: LayerStore, names: List[str]) -> int:
    """File opens one cold full-model read sweep performs."""
    store.close()
    store.open_count = 0
    for n in names:
        store.read_raw(n)
    return store.open_count


def _check_equivalence(stores: Dict[str, LayerStore], names: List[str]):
    """Every format must return identical tensors for every layer — a
    mismatch is a hard failure (CI gates on it)."""
    ref = stores["npy"]
    for n in names:
        want = ref.read_raw(n)
        for label, st in stores.items():
            if st is ref:
                continue
            got = st.read_raw(n, mmap=False)
            if set(got) != set(want):
                raise AssertionError(
                    f"equivalence mismatch: {label}/{n} keys {set(got)} "
                    f"!= npy keys {set(want)}")
            for k in want:
                if got[k].dtype != want[k].dtype or not np.array_equal(
                        np.asarray(got[k]), np.asarray(want[k])):
                    raise AssertionError(
                        f"equivalence mismatch: {label}/{n}/{k}")


def bench_model(name: str, weights: Dict[str, dict], repeats: int = 3,
                print_csv: bool = True) -> Dict[str, float]:
    names = list(weights)
    with tempfile.TemporaryDirectory(prefix=f"iofmt_{name}_") as td:
        s_npy = LayerStore(Path(td) / "npy", fmt="npy")
        s_bun = LayerStore(Path(td) / "bundle", fmt="bundle")
        for ln, w in weights.items():
            s_npy.write_raw(ln, w)
            s_bun.write_raw(ln, w)
        # super store: migrated from the per-layer bundle tree, laid out in
        # graph order — exercises the migration path every run. verify=never:
        # these arms time FORMAT byte movement; checksum-audit cost has its
        # own dedicated arm (and gate) in bench_durability, and the reopen
        # per pass would otherwise re-audit every payload byte every sweep
        s_sup = LayerStore(Path(td) / "super", fmt="super", verify="never")
        migrate(Path(td) / "bundle", Path(td) / "super" / "model.superbundle",
                order=names)

        _check_equivalence(
            {"npy": s_npy, "bundle": s_bun, "super": s_sup}, names)
        opens = {
            "npy": _count_opens(s_npy, names),
            "bundle": _count_opens(s_bun, names),
            "super": _count_opens(s_sup, names),
        }
        assert opens["super"] == 1, (
            f"super-bundle must be ONE open per model, saw {opens['super']}")
        assert opens["bundle"] == len(names), opens

        t_npy = _sweep(lambda n: s_npy.read_raw(n), names, repeats)
        t_bun = _sweep(lambda n: s_bun.read_raw(n, mmap=False), names, repeats)
        t_map = _sweep(lambda n: s_bun.read_raw(n, mmap=True), names, repeats)
        t_map_touch = _sweep(
            lambda n: _touch(s_bun.read_raw(n, mmap=True)), names, repeats)
        t_sup = _sweep(lambda n: s_sup.read_raw(n, mmap=False), names,
                       repeats, reset=s_sup.close)
        t_sup_map = _sweep(lambda n: s_sup.read_raw(n, mmap=True), names,
                           repeats, reset=s_sup.close)
        t_sup_touch = _sweep(
            lambda n: _touch(s_sup.read_raw(n, mmap=True)), names,
            repeats, reset=s_sup.close)

    per_layer = 1.0 / max(len(names), 1)
    res = {
        "npy_s": t_npy, "bundle_s": t_bun, "bundle_mmap_s": t_map,
        "bundle_mmap_touch_s": t_map_touch,
        "super_s": t_sup, "super_mmap_s": t_sup_map,
        "super_mmap_touch_s": t_sup_touch,
        "opens_npy": opens["npy"], "opens_bundle": opens["bundle"],
        "opens_super": opens["super"],
        "speedup_bundle": t_npy / max(t_bun, 1e-9),
        "speedup_mmap": t_npy / max(t_map, 1e-9),
        "speedup_mmap_touch": t_npy / max(t_map_touch, 1e-9),
        "speedup_super": t_npy / max(t_sup, 1e-9),
        "speedup_super_mmap": t_npy / max(t_sup_map, 1e-9),
    }
    if print_csv:
        print(csv_line(f"io_formats/{name}/npy", t_npy * per_layer,
                       f"layers={len(names)};opens={opens['npy']}"))
        print(csv_line(f"io_formats/{name}/bundle", t_bun * per_layer,
                       f"speedup={res['speedup_bundle']:.2f}x"
                       f";opens={opens['bundle']}"))
        print(csv_line(f"io_formats/{name}/bundle_mmap", t_map * per_layer,
                       f"speedup={res['speedup_mmap']:.2f}x"))
        print(csv_line(f"io_formats/{name}/bundle_mmap_touch",
                       t_map_touch * per_layer,
                       f"speedup={res['speedup_mmap_touch']:.2f}x"))
        print(csv_line(f"io_formats/{name}/super", t_sup * per_layer,
                       f"speedup={res['speedup_super']:.2f}x;opens=1"))
        print(csv_line(f"io_formats/{name}/super_mmap", t_sup_map * per_layer,
                       f"speedup={res['speedup_super_mmap']:.2f}x;opens=1"))
        print(csv_line(f"io_formats/{name}/super_mmap_touch",
                       t_sup_touch * per_layer,
                       f"speedup={t_npy / max(t_sup_touch, 1e-9):.2f}x"))
        ok = t_sup_map <= t_map
        print(f"# {name}: super_mmap <= bundle_mmap: {ok} "
              f"({t_sup_map * per_layer * 1e6:.1f} vs "
              f"{t_map * per_layer * 1e6:.1f} us/layer), "
              f"opens {opens['super']} vs {opens['bundle']}")
    return res


def bench_durability(repeats: int = 5, print_csv: bool = True,
                     smoke: bool = False) -> Dict[str, float]:
    """Format-v3 durability arms: checksum-verify overhead on the cold read
    path, eager-audit cost, crash-injection recovery at every commit phase,
    and dead-extent compaction. All assertions are hard failures."""
    import shutil
    import struct

    import repro.checkpoint.superbundle as sbmod
    from repro.checkpoint.bundle import ALIGN
    from repro.checkpoint.superbundle import (
        InjectedCrash, SuperBundle, compact, drop_cache_entry, journal_path,
        set_cache_entry, write_superbundle,
    )

    weights = _llm_weights(num_layers=3 if smoke else 6,
                           d_model=256 if smoke else 512)
    names = list(weights)
    cached = names[::2]
    res: Dict[str, float] = {}
    with tempfile.TemporaryDirectory(prefix="iofmt_durability_") as td:
        p = Path(td) / "model.superbundle"
        cache = {n: {"k": weights[n]} for n in cached}
        write_superbundle(p, weights, cache=cache, order=names)

        # -- checksum-verify overhead on COLD reads (the engine's default
        #    path: one open + journal recovery + zero-copy mmap views; lazy
        #    keeps CRC audits off it by design) -----------------------------
        def sweep(verify: str) -> float:
            best = float("inf")
            for _ in range(repeats):
                if CAN_DROP:
                    drop_page_cache()
                t0 = time.perf_counter()
                with SuperBundle(p, verify=verify) as sb:
                    for n in names:
                        sb.read_raw(n)
                best = min(best, time.perf_counter() - t0)
            return best

        t_never, t_lazy = sweep("never"), sweep("lazy")
        overhead = t_lazy / max(t_never, 1e-9) - 1.0
        if CAN_DROP:
            drop_page_cache()
        t0 = time.perf_counter()
        SuperBundle(p, verify="eager").close()  # full-file audit (fsck)
        t_eager = time.perf_counter() - t0
        res.update(verify_never_s=t_never, verify_lazy_s=t_lazy,
                   verify_overhead=overhead, eager_audit_s=t_eager)
        if smoke:
            assert t_lazy <= t_never * 1.05 + 2e-3, (
                f"lazy checksum mode costs {overhead:+.1%} on the cold mmap "
                f"read path ({t_lazy:.4f}s vs {t_never:.4f}s; gate: <=5%)")

        # -- crash injection: every commit phase must resolve to fully
        #    applied or fully rolled back on reopen -------------------------
        layer = cached[0]
        old = {k: np.array(np.asarray(v)) for k, v in cache[layer]["k"].items()}
        new = {k: np.full_like(np.asarray(v), 0.5) for k, v in old.items()}
        phases = [("journal-synced", False, "old"),
                  ("slot", True, "dropped"),
                  ("header", False, "new"),
                  ("header", True, "new"),
                  ("header-written", False, "new")]
        for i, (phase, partial, expect) in enumerate(phases):
            q = Path(td) / f"crash{i}.superbundle"
            shutil.copy(p, q)

            def hook(ph, **ctx):
                if ph != phase:
                    return
                if partial and ph == "slot":
                    f, off = ctx["file"], ctx["offset"]
                    payload = ctx["payload"]
                    f.seek(off)
                    f.write(payload[: len(payload) // 2])  # torn slot write
                    f.flush()
                if partial and ph == "header":
                    f, hdr = ctx["file"], ctx["header"]
                    f.seek(0)
                    f.write(b"NNVS" + struct.pack("<I", 3) + hdr[:40])
                    f.flush()  # torn header write
                raise InjectedCrash(ph)

            sbmod._crash_hook = hook
            try:
                set_cache_entry(q, layer, "k", new)
                raise AssertionError(f"crash hook never fired at {phase}")
            except InjectedCrash:
                pass
            finally:
                sbmod._crash_hook = None
            t0 = time.perf_counter()
            with SuperBundle(q, verify="eager") as sb:
                t_rec = time.perf_counter() - t0
                for n in names:  # raw weights byte-identical in every arm
                    got = sb.read_raw(n, materialize=True)
                    for k, v in weights[n].items():
                        assert np.array_equal(np.asarray(got[k]),
                                              np.asarray(v)), (phase, n, k)
                if expect == "dropped":
                    assert not sb.has_cached(layer, "k"), phase
                else:
                    assert not sb.dropped, (phase, sb.dropped)
                    want = old if expect == "old" else new
                    got = sb.read_cached(layer, "k", materialize=True)
                    for k, v in want.items():
                        assert np.array_equal(np.asarray(got[k]),
                                              np.asarray(v)), (phase, k)
            assert journal_path(q).stat().st_size == 0, phase
            tag = f"{phase}{'_torn' if partial else ''}"
            res[f"recover_{tag}_s"] = t_rec
            if print_csv:
                print(csv_line(f"io_formats/durability/recover_{tag}",
                               t_rec, f"outcome={expect}"))

        # -- compaction: drops leave dead extents; compact reclaims them to
        #    zero slack (< one alignment unit per layer, trivially) ---------
        for n in cached:
            assert drop_cache_entry(p, n, "k")
        with SuperBundle(p) as sb:
            dead = sb.reclaimable_bytes()
            size_before = sb.file_size()
        assert dead > 0, "drops must leave reclaimable dead extents"
        t0 = time.perf_counter()
        stats = compact(p)
        t_compact = time.perf_counter() - t0
        with SuperBundle(p, verify="eager") as sb:
            # stricter than the acceptance bound (< ALIGN per layer):
            # compaction must leave NO dead bytes at all
            slack = sb.reclaimable_bytes()
            assert slack == 0, (slack, ALIGN * len(names))
            assert sb.cache_disk_bytes() == 0
        assert stats["reclaimed_bytes"] == size_before - stats["file_size"]
        res.update(compact_s=t_compact,
                   reclaimed_bytes=float(stats["reclaimed_bytes"]))
        if print_csv:
            print(csv_line("io_formats/durability/verify_lazy_sweep", t_lazy,
                           f"overhead={overhead:+.1%}_vs_never"))
            print(csv_line("io_formats/durability/eager_audit", t_eager,
                           "full-file_fsck"))
            print(csv_line("io_formats/durability/compact", t_compact,
                           f"reclaimed={stats['reclaimed_bytes']}B;slack=0"))
    return res


def bench_async(name: str, weights: Dict[str, dict], repeats: int = 3,
                print_csv: bool = True, smoke: bool = False,
                depth: int = 8) -> Dict[str, float]:
    """Async read-engine arms vs the sync reference path, per backend.

    For every backend that passes its self-check on this host (uring where
    the kernel offers it, the portable aio thread pool, the forced-sync
    degenerate backend), a cold full-model sweep is timed through the
    store's ``submit_read_raw`` extent API at queue depth 1 (submit, reap,
    next — the async path's floor) and at ``depth`` (a sliding window of
    in-flight reads, the executor's steady state). The sync ``read_raw``
    path stays as the reference arm.

    Hard gate (always): every backend × depth reaps tensors bit-identical
    to the sync reference. ``--smoke`` adds timing gates: depth 1 must not
    fall meaningfully behind sync (submit/reap bookkeeping bound), and
    depth > 1 must at least match the sync arm's cold throughput."""
    from repro.ioengine import IOEngine, available_backends

    names = list(weights)
    res: Dict[str, float] = {}
    with tempfile.TemporaryDirectory(prefix=f"iofmt_async_{name}_") as td:
        store = LayerStore(Path(td) / "super", fmt="super", verify="never")
        for ln, w in weights.items():
            store.write_raw(ln, w)
        store._super(flush_all=True)
        ref = {n: {k: np.array(np.asarray(v), copy=True)
                   for k, v in store.read_raw(n, mmap=False).items()}
               for n in names}

        t_sync = _sweep(lambda n: store.read_raw(n, mmap=False), names,
                        repeats, reset=store.close)
        res["sync_s"] = t_sync
        per_layer = 1.0 / max(len(names), 1)
        if print_csv:
            print(csv_line(f"io_async/{name}/sync", t_sync * per_layer,
                           f"layers={len(names)};reference"))

        def sweep_depth(engine: IOEngine, window: int) -> float:
            def reset():
                store.close()
                # reopen outside the timed region is NOT done: the cold
                # open is part of the read path, same as the sync arm
            best = float("inf")
            for _ in range(repeats):
                reset()
                if CAN_DROP:
                    drop_page_cache()
                t0 = time.perf_counter()
                pending: List = []

                def reap_one():
                    ln, h = pending.pop(0)
                    got = h.wait()
                    for k, v in ref[ln].items():
                        if not np.array_equal(np.asarray(got[k]), v):
                            raise AssertionError(
                                f"async/{engine.name}/d{window}: "
                                f"{ln}/{k} differs from sync arm")
                    h.release()

                for n in names:
                    while len(pending) >= window:
                        reap_one()   # window full: oldest read reaps first
                    pending.append((n, store.submit_read_raw(engine, n)))
                while pending:
                    reap_one()
                best = min(best, time.perf_counter() - t0)
            return best

        for backend in available_backends():
            engine = IOEngine(backend=backend)
            try:
                t1 = sweep_depth(engine, 1)
                td_ = sweep_depth(engine, depth)
            finally:
                engine.close()
            res[f"{backend}_d1_s"] = t1
            res[f"{backend}_d{depth}_s"] = td_
            if print_csv:
                print(csv_line(f"io_async/{name}/{backend}_d1",
                               t1 * per_layer,
                               f"vs_sync={t_sync / max(t1, 1e-9):.2f}x"))
                print(csv_line(f"io_async/{name}/{backend}_d{depth}",
                               td_ * per_layer,
                               f"vs_sync={t_sync / max(td_, 1e-9):.2f}x"))
            if smoke:
                assert t1 <= t_sync * 1.25 + 5e-3, (
                    f"{backend} depth-1 async sweep {t1:.4f}s falls behind "
                    f"sync reference {t_sync:.4f}s (gate: <=25% + 5ms)")
                assert td_ <= t_sync * 1.05 + 5e-3, (
                    f"{backend} depth-{depth} sweep {td_:.4f}s slower than "
                    f"sync reference {t_sync:.4f}s — depth must at least "
                    f"match the sync arm's cold throughput")
        store.close()
    return res


def bench_quantized(print_csv: bool = True, smoke: bool = False,
                    num_layers: int = 8) -> Dict[str, float]:
    """Quantized transform-cache arms (format v4): three ColdEngines over
    the SAME LLM graph, differing only in eligible kernels —

      bf16   bf16_cast cache entries (the lossless reference arm)
      int8   per-channel int8 extents (+bf16_cast for the embed gather)
      int4   nibble-packed int4 extents (+bf16_cast for the embed)

    Each arm runs Algorithm-1 ``decide()`` under the deterministic
    synthetic cost model (quantized entries = smaller read, nonzero
    dequant surcharge), then a REAL ``run_cold`` whose cold cache bytes
    are metered via the store's ``bytes_served()`` counter.

    ``--smoke`` hard gates (the PR's acceptance criteria):
      * decide() picks the quantized (kernel, cache) choice for a majority
        of matmul-dominated layers (tblocks + lm_head);
      * measured cold bytes served: int8 >= 1.8x and int4 >= 3x below the
        bf16 cache arm;
      * outputs stay within per-dtype tolerance of the bf16 arm
        (correlation > 0.99 for int8, > 0.8 for int4)."""
    from repro.core.engine import ColdEngine
    from repro.core.llm_graph import tiny_llm_graph
    from repro.core.profiler import SyntheticProfiler

    graph, x = tiny_llm_graph(num_layers)
    matmul_layers = [l.spec.name for l in graph
                     if l.spec.op_type in ("tblock", "lmhead")]
    arms = [("bf16", ["bf16_cast"]),
            ("int8", ["int8", "bf16_cast"]),
            ("int4", ["int4", "bf16_cast"])]
    res: Dict[str, float] = {}
    outputs: Dict[str, np.ndarray] = {}
    with tempfile.TemporaryDirectory(prefix="iofmt_quant_") as td:
        for arm, allow in arms:
            eng = ColdEngine(graph, Path(td) / arm, store_fmt="super",
                             allow_lossy=True, kernel_allowlist=allow)
            eng.profiler_factory = SyntheticProfiler
            # no wall-clock interference calibration: the pick gates must
            # be a pure function of the synthetic cost model, not of how
            # much I/O the preceding benchmark sections churned
            stats = eng.decide(x, n_little=2, calibrate_interference=False)
            picked = {l.spec.name: c for l, c in zip(eng.layers,
                                                     eng.plan.choices)}
            n_quant = sum(1 for n in matmul_layers
                          if picked[n].kernel == arm and picked[n].use_cache)
            served0 = eng.store.bytes_served()
            t0 = time.perf_counter()
            out = eng.run_cold(x, n_little=2)
            t_cold = time.perf_counter() - t0
            cold_bytes = eng.store.bytes_served() - served0
            outputs[arm] = np.asarray(out.output, np.float32)
            res[f"{arm}_cold_bytes"] = float(cold_bytes)
            res[f"{arm}_cold_s"] = t_cold
            res[f"{arm}_planned_cached_bytes"] = float(
                stats["planned_cold_read_bytes"]["cached_bytes"])
            res[f"{arm}_quant_picks"] = float(n_quant)
            if print_csv:
                print(csv_line(
                    f"io_quant/{arm}/cold", t_cold,
                    f"bytes={cold_bytes};quant_picks={n_quant}"
                    f"/{len(matmul_layers)}"))
            if smoke and arm in ("int8", "int4"):
                assert n_quant > len(matmul_layers) // 2, (
                    f"{arm}: decide() picked quantized cache for only "
                    f"{n_quant}/{len(matmul_layers)} matmul layers")
        for arm, floor in (("int8", 1.8), ("int4", 3.0)):
            ratio = res["bf16_cold_bytes"] / max(res[f"{arm}_cold_bytes"], 1)
            res[f"{arm}_bytes_ratio"] = ratio
            a = outputs[arm].ravel()
            b = outputs["bf16"].ravel()
            corr = float(np.corrcoef(a, b)[0, 1])
            res[f"{arm}_corr"] = corr
            if print_csv:
                print(f"# quantized/{arm}: cold-bytes {ratio:.2f}x below "
                      f"bf16 (floor {floor}x), output corr {corr:.4f}")
            if smoke:
                assert ratio >= floor, (
                    f"{arm} arm read {res[f'{arm}_cold_bytes']:.0f}B cold vs "
                    f"bf16 {res['bf16_cold_bytes']:.0f}B — "
                    f"{ratio:.2f}x < required {floor}x")
                tol = 0.99 if arm == "int8" else 0.8
                assert corr > tol, (
                    f"{arm} output corr {corr:.4f} <= {tol} vs bf16 arm")
    return res


def run(print_csv: bool = True, smoke: bool = False) -> Dict[str, Dict[str, float]]:
    if smoke:
        cases: List[Tuple[str, Dict[str, dict]]] = [
            ("mobilenet", _cnn_weights("mobilenet", image=24, width=0.5)),
            ("llm_tiny", _llm_weights(num_layers=3, d_model=256)),
        ]
        repeats = 3
    else:
        cases = [
            ("mobilenet", _cnn_weights("mobilenet", image=40, width=1.0)),
            ("resnet18", _cnn_weights("resnet18", image=40, width=1.0)),
            ("squeezenet", _cnn_weights("squeezenet", image=40, width=1.0)),
            ("llm_smollm", _llm_weights(num_layers=8, d_model=512)),
        ]
        repeats = 3
    out = {}
    for name, weights in cases:
        out[name] = bench_model(name, weights, repeats=repeats,
                                print_csv=print_csv)
    # async engine arms on the LLM workload (many tensors/extents per layer
    # — where queue depth pays); the CNN case covers the small-extent shape
    out["async_llm"] = bench_async(
        cases[-1][0], cases[-1][1], repeats=repeats, print_csv=print_csv,
        smoke=smoke)
    out["durability"] = bench_durability(print_csv=print_csv, smoke=smoke)
    out["quantized"] = bench_quantized(print_csv=print_csv, smoke=smoke)
    if print_csv and not CAN_DROP:
        print("# warning: cannot drop page cache — warm-cache numbers",
              file=sys.stderr)
    return out


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv
    print("name,us_per_call,derived")
    run(print_csv=True, smoke=smoke)
