"""Front-door chaos + priority benchmark — the supervision tier's CI gate.

Arms:
  * failover — two supervised worker processes; a cold-start request is
    dispatched and its worker is SIGKILLed mid-flight. Gates: the request
    fails over to the sibling and completes within its deadline, the
    output is bit-identical to an isolated single-server cold start, the
    victim restarts under the exponential-backoff policy and serves
    again, and nothing leaks (no stuck in-flight entries, queues empty).
  * priority — worker slots saturated with batch-lane requests; an
    interactive request must dispatch ahead of the backlog with bounded
    queue delay, and over-deadline requests are shed with typed
    ``DeadlineExceeded`` BEFORE consuming a worker slot (dispatch
    counters unchanged).
  * warm-transfer — two workers on an emulated-slow disk
    (``--sim-disk-bytes-per-s``); w0 cold-starts from disk (the
    no-transfer baseline), then a request pinned to w1 races a peer
    warm-state fetch from w0's RAM against w1's local chains. Gates:
    the race armed and the donor served it, w1's cold start read ≥2×
    fewer local disk bytes than the baseline, the output is
    bit-identical to w0's, and nothing leaked after the race (no I/O
    in flight, no held pinned bytes, no stuck requests).

``--smoke`` hard-fails on any gate; CI runs it on every push.
"""
from __future__ import annotations

import argparse
import sys
import tempfile
import time
from pathlib import Path

import numpy as np

try:
    from benchmarks.common import csv_line  # noqa: F401  (import-path probe)
except ImportError:  # invoked as `python benchmarks/serving_frontdoor.py`
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))
from repro.executor.frontdoor import BATCH, INTERACTIVE, FrontDoor
from repro.executor.server import ColdServer
from repro.faults import DeadlineExceeded
from repro.models.cnn import build_cnn

WORKER_ARGS = {"n_little": 2, "n_big": 1}


def _gate(ok: bool, msg: str, failures: list):
    print(("PASS " if ok else "FAIL ") + msg)
    if not ok:
        failures.append(msg)


def run_failover(failures: list, *, image=32, width=0.5):
    root = tempfile.mkdtemp(prefix="nnv12_frontdoor_")
    layers, x = build_cnn("mobilenet", image=image, width=width)

    iso = ColdServer(root + "/iso", n_little=2)
    iso.add_model("mnet", layers)
    iso.decide("mnet", x, n_little=2)
    ref = np.asarray(iso.cold_start("mnet", x).result().output)

    fd = FrontDoor(root + "/fd", n_workers=2, worker_args=WORKER_ARGS)
    fd.start()
    try:
        fd.add_model("mnet", "repro.models.cnn:build_cnn",
                     name="mobilenet", image=image, width=width)

        deadline = 120.0
        req = fd.request("mnet", x, deadline_s=deadline)
        for _ in range(1000):        # wait for dispatch so we know the victim
            if req.worker is not None:
                break
            time.sleep(0.002)
        victim = req.worker
        _gate(victim is not None, "failover: request dispatched", failures)
        t_kill = time.monotonic()
        fd.kill_worker(victim)       # SIGKILL mid cold start

        res = req.result(timeout=deadline)
        t_recover = time.monotonic() - t_kill
        _gate(res["worker"] != victim,
              f"failover: sibling {res['worker']} served after {victim} "
              f"was SIGKILLed ({t_recover:.2f}s after kill)", failures)
        _gate(t_recover < deadline,
              f"failover: completed within the {deadline:.0f}s deadline",
              failures)
        diff = float(np.abs(np.asarray(res["output"]) - ref).max())
        _gate(diff == 0.0,
              f"failover: output bit-identical to isolated cold start "
              f"(max diff {diff:.1e})", failures)

        h = fd.health()
        for _ in range(600):         # restart fires under backoff
            if h["workers"][victim]["alive"]:
                break
            time.sleep(0.05)
            h = fd.health()
        wv = h["workers"][victim]
        _gate(wv["alive"] and h["stats"]["worker_restarts"] >= 1,
              f"failover: {victim} restarted (restarts={wv['restarts']})",
              failures)
        expect = fd.restart.delay(wv["restarts"])
        _gate(abs(wv["last_restart_delay"] - expect) < 1e-9,
              f"failover: restart waited the policy backoff "
              f"({wv['last_restart_delay']:.2f}s)", failures)

        res2 = fd.request("mnet", x, deadline_s=deadline).result(deadline)
        diff2 = float(np.abs(np.asarray(res2["output"]) - ref).max())
        _gate(diff2 == 0.0, "failover: fleet serves bit-identical after "
              "restart", failures)

        h = fd.health()
        leaked = (sum(w["in_flight"] for w in h["workers"].values())
                  + sum(h["queues"].values()) + h["batch_in_flight"])
        _gate(leaked == 0,
              f"failover: nothing leaked (in-flight+queued={leaked})",
              failures)
        print(f"  failovers={h['stats']['failovers']} "
              f"restarts={h['stats']['worker_restarts']} "
              f"recover_s={t_recover:.2f}")
    finally:
        fd.shutdown()


def run_priority(failures: list, *, image=16, width=0.25, n_batch=8):
    root = tempfile.mkdtemp(prefix="nnv12_frontdoor_prio_")
    fd = FrontDoor(root + "/fd", n_workers=2, max_inflight_per_worker=1,
                   interactive_reserve=1, worker_args=WORKER_ARGS)
    fd.start()
    try:
        fd.add_model("mnet", "repro.models.cnn:build_cnn",
                     name="mobilenet", image=image, width=width)
        _, x = build_cnn("mobilenet", image=image, width=width)
        fd.request("mnet", x).result(120)    # warm workers + seed the EWMA

        batch = [fd.request("mnet", x, lane=BATCH) for _ in range(n_batch)]
        time.sleep(0.05)                     # let the batch lane saturate
        t0 = time.monotonic()
        inter = fd.request("mnet", x, lane=INTERACTIVE)
        inter.result(120)
        delay = time.monotonic() - t0
        for b in batch:
            b.result(120)
        svc = fd._svc_ewma["mnet"]
        bound = max(0.5, 5 * svc)            # ~one service time + slack,
        #                                      NOT the n_batch*svc backlog
        _gate(delay < bound,
              f"priority: interactive delay {delay*1e3:.0f}ms bounded "
              f"(< {bound*1e3:.0f}ms) under {n_batch} queued batch "
              f"requests", failures)

        h0 = fd.health()["stats"]
        for tag, kw in (("rpc-floor", {"deadline_s": 1e-4}),
                        ("queue-est", {"deadline_s": max(0.05, 0.5 * svc),
                                       "lane": BATCH})):
            if tag == "queue-est":           # rebuild a saturating backlog
                flood = [fd.request("mnet", x, lane=BATCH)
                         for _ in range(4 * n_batch)]
            try:
                fd.request("mnet", x, **kw)
                shed = False
            except DeadlineExceeded:
                shed = True
            _gate(shed, f"priority: over-deadline request shed typed "
                  f"({tag})", failures)
            if tag == "queue-est":
                for b in flood:
                    b.result(120)
        h1 = fd.health()["stats"]
        _gate(h1["shed_deadline"] - h0["shed_deadline"] >= 2
              and (h1["dispatched_interactive"] + h1["dispatched_batch"]
                   - h0["dispatched_interactive"] - h0["dispatched_batch"])
              == 4 * n_batch,
              "priority: shed requests never consumed a dispatch slot",
              failures)
        print(f"  interactive_delay_ms={delay*1e3:.0f} "
              f"svc_ewma_ms={svc*1e3:.1f} "
              f"shed={h1['shed_deadline']}")
    finally:
        fd.shutdown()


def _poll_health(fd, wid, pred, *, timeout=10.0):
    """Wait for a worker heartbeat snapshot satisfying ``pred``; returns
    the snapshot (or the last one seen on timeout)."""
    deadline = time.monotonic() + timeout
    h = fd._workers[wid].health or {}
    while time.monotonic() < deadline:
        h = fd._workers[wid].health or {}
        if h and pred(h):
            break
        time.sleep(0.05)
    return h


def run_warm_transfer(failures: list, *, image=32, width=0.5,
                      sim_disk_bytes_per_s=4e6):
    root = tempfile.mkdtemp(prefix="nnv12_frontdoor_warm_")
    # 'super' store fmt gives measured local-read-bytes accounting; the
    # simulated disk bandwidth makes local read time REAL on CI hosts that
    # would otherwise serve the store from page cache at memory speed
    wargs = dict(WORKER_ARGS, store_fmt="super",
                 sim_disk_bytes_per_s=sim_disk_bytes_per_s)
    fd = FrontDoor(root + "/fd", n_workers=2, worker_args=wargs)
    fd.start()
    try:
        fd.add_model("mnet", "repro.models.cnn:build_cnn",
                     name="mobilenet", image=image, width=width)
        _, x = build_cnn("mobilenet", image=image, width=width)

        # w0's cold start IS the no-transfer baseline: no sibling holds the
        # model yet, so every byte comes off its (emulated) local disk
        h0 = _poll_health(fd, "w0", lambda h: "local_read_bytes" in h)
        pre0 = int(h0.get("local_read_bytes") or 0)
        r0 = fd.request("mnet", x, worker="w0").result(120)
        # wait for a post-completion heartbeat: "mnet" resident means the
        # job finished AND registered — only then is the byte count final
        # and only then does the front door see w0 as a transfer donor
        h0 = _poll_health(
            fd, "w0", lambda h: "mnet" in (h.get("resident") or ()))
        baseline = int(h0.get("local_read_bytes") or 0) - pre0
        _gate(r0["worker"] == "w0" and baseline > 0,
              f"warm-transfer: baseline cold start on w0 read "
              f"{baseline} bytes from local disk", failures)

        # w1 pinned: w0 is now a resident donor → the front door attaches
        # it as a peer and w1's ColdServer arms the fetch race
        h1 = _poll_health(fd, "w1", lambda h: "local_read_bytes" in h)
        pre1 = int(h1.get("local_read_bytes") or 0)
        r1 = fd.request("mnet", x, worker="w1").result(120)
        # the fetch outcome is folded into server stats by a job-done
        # callback — poll until a heartbeat carries it (and the engine
        # reports the race's cancelled reads fully drained)
        h1 = _poll_health(
            fd, "w1",
            lambda h: int((h.get("stats") or {})
                          .get("peer_layers_fetched") or 0) > 0
            and int((h.get("io_engine") or {}).get("in_flight", 1)) == 0)
        s1 = h1.get("stats") or {}
        local1 = int(h1.get("local_read_bytes") or 0) - pre1
        hd = _poll_health(
            fd, "w0",
            lambda h: int((h.get("stats") or {})
                          .get("transfers_served") or 0) > 0)
        donor = hd.get("stats") or {}

        _gate(r1["worker"] == "w1" and int(s1.get("peer_races") or 0) >= 1
              and int(donor.get("transfers_served") or 0) >= 1,
              f"warm-transfer: w1 raced a peer fetch and w0 served it "
              f"(layers={s1.get('peer_layers_fetched')} "
              f"bytes={s1.get('peer_bytes_fetched')})", failures)
        _gate(2 * local1 <= baseline,
              f"warm-transfer: w1 read >=2x fewer local disk bytes "
              f"({local1} vs baseline {baseline})", failures)
        diff = float(np.abs(np.asarray(r1["output"])
                            - np.asarray(r0["output"])).max())
        _gate(diff == 0.0,
              f"warm-transfer: fetched-state output bit-identical to "
              f"local cold start (max diff {diff:.1e})", failures)

        io1 = h1.get("io_engine") or {}
        fh = fd.health()
        stuck = (sum(w["in_flight"] for w in fh["workers"].values())
                 + sum(fh["queues"].values()) + fh["batch_in_flight"])
        _gate(int(io1.get("in_flight", -1)) == 0
              and int(io1.get("bytes_in_flight", -1)) == 0
              and int(s1.get("peer_crc_failures") or 0) == 0
              and stuck == 0,
              f"warm-transfer: nothing leaked after the race "
              f"(io_in_flight={io1.get('in_flight')} "
              f"bytes_in_flight={io1.get('bytes_in_flight')} "
              f"stuck={stuck})", failures)
        print(f"  baseline_bytes={baseline} w1_local_bytes={local1} "
              f"fetched_bytes={s1.get('peer_bytes_fetched')} "
              f"races={s1.get('peer_races')} "
              f"declined={s1.get('peer_races_declined')} "
              f"donor_transfers={donor.get('transfers_served')}")
        return r0, r1
    finally:
        fd.shutdown()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes + hard-fail gates (CI)")
    args = ap.parse_args(argv)
    failures: list = []
    run_failover(failures, **({"image": 24, "width": 0.4}
                              if args.smoke else {}))
    run_priority(failures)
    run_warm_transfer(failures, **({"image": 24, "width": 0.4}
                                   if args.smoke else {}))
    if failures:
        print(f"\n{len(failures)} gate(s) failed:", file=sys.stderr)
        for f in failures:
            print(f"  - {f}", file=sys.stderr)
        if args.smoke:
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
