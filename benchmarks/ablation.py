"""Fig. 13 analogue: ablation of the three knobs — K (kernel selection),
C (post-transformed weight cache), P (pipelined execution) — in the
deterministic big.LITTLE simulator fed with measured profiles."""
from __future__ import annotations

from benchmarks.common import build_engine, csv_line, sim_numbers

MODELS = ["mobilenet", "resnet18", "squeezenet"]


def run(print_csv=True):
    rows = []
    for model in MODELS:
        eng, x = build_engine(model)
        sim = sim_numbers(eng)
        stages = {
            "baseline": sim.sequential_s,
            "K": sim.kernel_only_s,
            "KC": sim.kernel_cache_s,
            "KCP": sim.nnv12_s,
            "warm": sim.warm_s,
        }
        rows.append((model, stages))
        if print_csv:
            for k, v in stages.items():
                print(csv_line(
                    f"ablation/{model}/{k}", v,
                    f"speedup={stages['baseline']/v:.2f}x"))
    return rows


if __name__ == "__main__":
    run()
