"""Fig. 11 analogue: cold inference under background load on little cores,
with and without work stealing (deterministic simulator over measured
profiles; load = slowdown factor on the loaded cores)."""
from __future__ import annotations

from repro.core.scheduler import simulate
from benchmarks.common import build_engine, csv_line, CORE_MODEL


def run(print_csv=True, model="resnet18"):
    # resnet18: deepest little-core queues (6-7 preps/core) — the regime
    # where a busy core's *tail* delays the pipeline and stealing can move
    # it (a running op can't migrate, matching the paper's semantics)
    eng, x = build_engine(model, image=64, width=1.0)
    cm = CORE_MODEL
    names = [l.spec.name for l in eng.layers]

    def prof(n, kern):
        return next(p for p in eng.profiles[n] if p.kernel == kern)

    pl, pb, ex = [], [], []
    for n, c in zip(names, eng.plan.choices):
        p = prof(n, c.kernel)
        stage = p.stage_s * cm.little_stage
        if c.use_cache:
            pl.append(p.read_cached_s * cm.little_read + stage)
        else:
            pl.append(p.read_raw_s * cm.little_read
                      + p.transform_s * cm.little_transform + stage)
        pb.append(p.prep_s(c.use_cache))
        ex.append(p.exec_s)

    rows = []
    # background load on ONE little core (paper Fig. 11 loads a subset of
    # cores; stealing migrates its queue tail to the idle cores)
    for label, slow in [("0%", 1.0), ("50%", 2.0), ("75%", 4.0)]:
        load = {0: slow}
        mk_static, _ = simulate(pl, pb, ex, eng.plan.big_prep,
                                eng.plan.little_queues, core_load=load,
                                work_stealing=False)
        mk_steal, _ = simulate(pl, pb, ex, eng.plan.big_prep,
                               eng.plan.little_queues, core_load=load,
                               work_stealing=True)
        rows.append((label, mk_static, mk_steal))
        if print_csv:
            print(csv_line(f"dynamic_load/{model}/{label}/static", mk_static))
            print(csv_line(f"dynamic_load/{model}/{label}/stealing", mk_steal,
                           f"recovery={mk_static/mk_steal:.2f}x"))
    return rows


if __name__ == "__main__":
    run()
