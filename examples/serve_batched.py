"""Batched serving example: a reduced qwen3 model serving concurrent
requests with continuous batching (prefill + lockstep decode ticks).

Run: PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main(["--arch", "qwen3-32b", "--requests", "6", "--new-tokens", "8"])
