"""End-to-end driver: train a ~100M-parameter smollm-family model for a few
hundred steps on synthetic data, with microbatching, checkpointing, and a
loss curve printed every 10 steps.

Run: PYTHONPATH=src python examples/train_100m.py [--steps 300]
(defaults are sized so a CPU run finishes in minutes; on TPU use the full
config via repro.launch.train)
"""
import argparse

from repro.launch.train import main as train_main


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--batch", type=int, default=8)
    args = ap.parse_args()
    # ~100M params: d_model=768, 12 layers, vocab 49152 (reduced keeps the
    # smollm family: GQA + SwiGLU + RoPE + tied embeddings)
    train_main([
        "--arch", "smollm-360m", "--reduced",
        "--d-model", "768", "--layers", "12",
        "--steps", str(args.steps), "--batch", str(args.batch),
        "--seq", str(args.seq), "--microbatches", "2",
        "--lr", "1e-3", "--ckpt-every", str(max(args.steps // 2, 1)),
    ])
