"""Quickstart: the three layers of the framework in ~60 lines.

1. cold inference with the NNV12 engine (the paper's contribution);
2. one training step of an assigned architecture;
3. one batched decode step with a KV cache.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

# --- 1. NNV12 cold inference ------------------------------------------------
from repro.core.engine import ColdEngine
from repro.models.cnn import build_cnn

layers, x = build_cnn("mobilenet", image=32, width=0.5)
with tempfile.TemporaryDirectory() as store:
    eng = ColdEngine(layers, store)
    stats = eng.decide(x, n_little=3)          # offline decision stage
    print(f"[cold] plan generated in {stats['plan_generation_s']:.2f}s; "
          f"est makespan {stats['est_makespan_s']*1e3:.2f}ms; "
          f"cache {stats['cache_bytes']/1e6:.1f}MB")
    cold = eng.run_cold(x)                      # pipelined cold inference
    seq = eng.run_cold(x, mode="sequential")    # ncnn-like baseline
    warm = eng.run_warm(x)
    print(f"[cold] nnv12 {cold.total_s*1e3:.1f}ms  "
          f"sequential {seq.total_s*1e3:.1f}ms  warm {warm*1e3:.1f}ms")

# --- 2. train an assigned architecture --------------------------------------
from repro.configs import get_config
from repro.models import transformer as T
from repro.optim import adamw_init
from repro.train import make_train_step

cfg = get_config("qwen3-32b").reduced()
params = T.init_params(jax.random.PRNGKey(0), cfg)
opt = adamw_init(params)
step = jax.jit(make_train_step(cfg, num_microbatches=1))
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 64), 0,
                                      cfg.vocab_size)}
params, opt, metrics = step(params, opt, batch)
print(f"[train] {cfg.name}: loss {float(metrics['loss']):.3f} "
      f"grad_norm {float(metrics['grad_norm']):.3f}")

# --- 3. batched decode with a KV cache --------------------------------------
state = T.init_decode_state(cfg, batch=4, context_len=128)
logits, state = T.decode_step(
    params, state, {"tokens": jnp.zeros((4, 1), jnp.int32)}, jnp.int32(0), cfg)
print(f"[serve] decode logits {logits.shape}, cache kv {state['k'].shape}")
