"""Cold-inference walkthrough — the paper's Fig. 4 workflow end to end, with
every mode: NNV12 full, ablations (no pipeline / no cache / no selection),
work-stealing under background load, and continuous-inference switching.

Run: PYTHONPATH=src python examples/cold_inference.py [--model resnet18]
"""
import argparse
import tempfile
import threading
import time

import numpy as np

from repro.core.engine import ColdEngine
from repro.core.scheduler import simulate
from repro.core.switching import ContinuousSession
from repro.models.cnn import build_cnn, CNN_NAMES


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--model", choices=CNN_NAMES, default="mobilenet")
    ap.add_argument("--image", type=int, default=48)
    ap.add_argument("--width", type=float, default=0.75)
    args = ap.parse_args()

    layers, x = build_cnn(args.model, image=args.image, width=args.width)
    with tempfile.TemporaryDirectory() as store:
        eng = ColdEngine(layers, store)
        print(f"== offline decision stage ({args.model}) ==")
        stats = eng.decide(x, n_little=3)
        print(f"  plan generation: {stats['plan_generation_s']:.2f}s")
        print(f"  storage: model {stats['model_bytes']/1e6:.2f}MB "
              f"+ cache {stats['cache_bytes']/1e6:.2f}MB")
        kinds = {}
        for name, (kern, cached) in stats["choices"].items():
            kinds[(kern, cached)] = kinds.get((kern, cached), 0) + 1
        print(f"  kernel choices: {kinds}")

        print("== online cold inference ==")
        r_nnv12 = eng.run_cold(x, mode="nnv12")
        r_seq = eng.run_cold(x, mode="sequential")
        warm = eng.run_warm(x)
        print(f"  nnv12 (wall, 1 host core): {r_nnv12.total_s*1e3:.1f}ms")
        print(f"  sequential baseline:       {r_seq.total_s*1e3:.1f}ms")
        print(f"  warm inference:            {warm*1e3:.1f}ms")
        print(f"  breakdown: {({k: round(v*1e3,1) for k,v in r_nnv12.stage_seconds().items()})}")
        agree = float(np.abs(np.asarray(r_nnv12.output)
                             - np.asarray(r_seq.output)).max())
        print(f"  output agreement vs baseline: {agree:.2e} (zero accuracy loss)")

        print("== continuous inference (kernel switching, §3.5) ==")
        sess = ContinuousSession(eng, n_little=3)
        c1 = sess.cold_infer(x)
        c2 = sess.warm_infer(x, wait=True)
        print(f"  1st (cold) {c1.total_s*1e3:.1f}ms -> "
              f"2nd (switched) {c2.total_s*1e3:.1f}ms vs warm {warm*1e3:.1f}ms")


if __name__ == "__main__":
    main()
