"""Cold-start LLM serving through the persistent executor: a ColdServer
admits the model, the cold task graph streams weights from disk while the
prefill executes layer-by-layer (execute-as-you-load), the first token is
sampled from the streamed prefill, and decode continues on a BatchedServer
whose per-layer decode params were packed in the background — the first
token is out before the last layer's decode-path prep completes.

Run: PYTHONPATH=src python examples/serve_cold_llm.py
"""
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core.llm_graph import build_llm_graph
from repro.executor.llm_bridge import cold_start_llm
from repro.executor.server import ColdServer
from repro.models import transformer as T


def main():
    # ~65M-param smollm-family model (f32 master checkpoint ≈ 260 MB on disk)
    cfg = get_config("smollm-360m").reduced(
        num_layers=8, d_model=512, d_ff=1536, num_heads=8, num_kv_heads=4,
        head_dim=64, vocab_size=16_384)
    print(f"model: {cfg.name} ≈{cfg.param_count()/1e6:.0f}M params")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    graph, toks = build_llm_graph(cfg, params)

    with tempfile.TemporaryDirectory() as root:
        server = ColdServer(root, n_little=3, max_concurrent_preps=2)
        eng = server.add_model("smollm", graph)
        stats = server.decide("smollm", toks)
        kinds = {}
        for name, (kern, cached) in stats["choices"].items():
            kinds[(kern, cached)] = kinds.get((kern, cached), 0) + 1
        print(f"offline plan: {stats['plan_generation_s']:.1f}s; "
              f"kernel choices {kinds}")
        print(f"storage: raw {stats['model_bytes']/1e6:.0f} MB + "
              f"bf16 cache {stats['cache_bytes']/1e6:.0f} MB")

        res = cold_start_llm(eng, cfg, toks[0], max_new_tokens=8,
                             n_little=3, server=server, model_name="smollm")
        print(f"first token at {res.first_token_s*1e3:.0f} ms "
              f"({res.overlapped_layers} prep ops still in flight when the "
              f"exec chain started; {res.overlapped_packs} decode packs "
              f"overlapped it)")
        print(f"last weight prep {res.last_weight_prep_s*1e3:.0f} ms | "
              f"last layer decode prep {res.decode_prep_s*1e3:.0f} ms | "
              f"decode ready {res.decode_ready_s*1e3:.0f} ms")
        assert res.first_token_before_last_prep
        print(f"tokens: {res.tokens}")

        cold = res.run                            # pipelined weight streaming
        seq = eng.run_cold(toks, mode="sequential")
        warm = eng.run_warm(toks)
        # first-prefill latency = end of the exec chain (res.first_token_s);
        # cold.total_s would also include the background decode-path packs
        print(f"cold first-prefill latency: nnv12 {res.first_token_s*1e3:.0f} ms "
              f"| sequential {seq.total_s*1e3:.0f} ms "
              f"| warm {warm*1e3:.0f} ms")
        print(f"  breakdown: "
              f"{ {k: round(v*1e3) for k, v in cold.stage_seconds().items()} }")
        agree = float(np.abs(np.asarray(cold.output)
                             - np.asarray(seq.output)).max())
        print(f"  logits agree vs baseline: {agree:.2e}")
        sim = eng.plan.est_makespan
        print(f"  sim-mode (big.LITTLE) est makespan: {sim*1e3:.0f} ms")


if __name__ == "__main__":
    main()
