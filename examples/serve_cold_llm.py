"""Cold-start LLM serving: stream a transformer's weights from disk through
the NNV12 engine while the prefill computes — the paper's technique applied
to the framework's own models (first-class integration).

Run: PYTHONPATH=src python examples/serve_cold_llm.py
"""
import tempfile

import jax
import numpy as np

from repro.configs import get_config
from repro.core.engine import ColdEngine
from repro.core.llm_graph import build_llm_graph
from repro.models import transformer as T


def main():
    # ~65M-param smollm-family model (f32 master checkpoint ≈ 260 MB on disk)
    cfg = get_config("smollm-360m").reduced(
        num_layers=8, d_model=512, d_ff=1536, num_heads=8, num_kv_heads=4,
        head_dim=64, vocab_size=16_384)
    print(f"model: {cfg.name} ≈{cfg.param_count()/1e6:.0f}M params")
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    graph, toks = build_llm_graph(cfg, params)

    with tempfile.TemporaryDirectory() as store:
        eng = ColdEngine(graph, store)
        stats = eng.decide(toks, n_little=3)
        kinds = {}
        for name, (kern, cached) in stats["choices"].items():
            kinds[(kern, cached)] = kinds.get((kern, cached), 0) + 1
        print(f"offline plan: {stats['plan_generation_s']:.1f}s; "
              f"kernel choices {kinds}")
        print(f"storage: raw {stats['model_bytes']/1e6:.0f} MB + "
              f"bf16 cache {stats['cache_bytes']/1e6:.0f} MB")

        cold = eng.run_cold(toks)               # pipelined weight streaming
        seq = eng.run_cold(toks, mode="sequential")
        warm = eng.run_warm(toks)
        print(f"cold first-prefill latency: nnv12 {cold.total_s*1e3:.0f} ms "
              f"| sequential {seq.total_s*1e3:.0f} ms "
              f"| warm {warm*1e3:.0f} ms")
        print(f"  breakdown: "
              f"{ {k: round(v*1e3) for k, v in cold.stage_seconds().items()} }")
        agree = float(np.abs(np.asarray(cold.output)
                             - np.asarray(seq.output)).max())
        print(f"  logits agree vs baseline: {agree:.2e}")
        sim = eng.plan.est_makespan
        print(f"  sim-mode (big.LITTLE) est makespan: {sim*1e3:.0f} ms")


if __name__ == "__main__":
    main()
