"""Packed weight-bundle format + mmap LayerStore + staged pipeline tests.

Covers the cold-path I/O overhaul: bundle round-trips across dtypes
(f32/bf16/int8), 64-byte segment alignment, mmap-view immutability,
bundle-vs-legacy LayerStore equivalence on a cnn_zoo model, and the
pipeline's 'stage' ops (weights arrive on device during prep — no
host->device conversion on the exec chain).
"""
import numpy as np
import jax
import pytest

from repro.checkpoint import LayerStore
from repro.checkpoint.bundle import (
    ALIGN, bundle_nbytes, read_bundle, read_header, write_bundle,
)


def _example_weights():
    import ml_dtypes

    rng = np.random.default_rng(0)
    return {
        "w": rng.standard_normal((17, 33)).astype(np.float32),
        "b": rng.standard_normal(33).astype(np.float32),
        "q8": (rng.standard_normal((5, 9)) * 20).astype(np.int8),
        "hb": rng.standard_normal((12, 8)).astype(np.float32)
              .astype(ml_dtypes.bfloat16),
    }


@pytest.mark.parametrize("mmap", [False, True])
def test_bundle_roundtrip_dtypes(tmp_path, mmap):
    w = _example_weights()
    write_bundle(tmp_path / "l.bundle", w)
    back = read_bundle(tmp_path / "l.bundle", mmap=mmap)
    assert set(back) == set(w)
    for k in w:
        assert back[k].dtype == w[k].dtype, k      # incl. native bf16
        np.testing.assert_array_equal(np.asarray(back[k]), np.asarray(w[k]))


def test_bundle_alignment_and_accounting(tmp_path):
    w = _example_weights()
    total = write_bundle(tmp_path / "l.bundle", w)
    hdr = read_header(tmp_path / "l.bundle")
    offsets = [e["offset"] for e in hdr["tensors"]]
    assert all(o % ALIGN == 0 for o in offsets)
    assert offsets == sorted(offsets)              # sequential layout
    payload = bundle_nbytes(tmp_path / "l.bundle")
    assert payload == sum(v.nbytes for v in w.values())
    assert payload < total == (tmp_path / "l.bundle").stat().st_size


def test_mmap_views_are_immutable(tmp_path):
    w = {"w": np.arange(64, dtype=np.float32)}
    write_bundle(tmp_path / "l.bundle", w)
    view = read_bundle(tmp_path / "l.bundle", mmap=True)["w"]
    assert not view.flags.writeable
    with pytest.raises(ValueError):
        view[0] = 1.0
    # transforms copy, so downstream mutation never corrupts the store
    doubled = np.asarray(view) * 2
    np.testing.assert_array_equal(
        read_bundle(tmp_path / "l.bundle", mmap=True)["w"], w["w"])
    assert doubled[1] == 2.0


def test_bundle_rejects_bad_magic(tmp_path):
    p = tmp_path / "junk.bundle"
    p.write_bytes(b"NOPE" + b"\0" * 64)
    with pytest.raises(ValueError):
        read_bundle(p)


def test_layerstore_bundle_matches_legacy_npy(tmp_path):
    """Bundle reads == legacy per-tensor reads on a cnn_zoo model."""
    from repro.models.cnn import build_cnn

    layers, _ = build_cnn("mobilenet", image=24, width=0.35)
    s_bun = LayerStore(tmp_path / "bundle", fmt="bundle")
    s_npy = LayerStore(tmp_path / "npy", fmt="npy")
    for l in layers:
        if not l.weights:
            continue
        s_bun.write_raw(l.spec.name, l.weights)
        s_npy.write_raw(l.spec.name, l.weights)
    for l in layers:
        if not l.weights:
            continue
        for mmap in (False, True):
            b = s_bun.read_raw(l.spec.name, mmap=mmap)
            n = s_npy.read_raw(l.spec.name)
            assert set(b) == set(n)
            for k in b:
                assert b[k].dtype == n[k].dtype
                np.testing.assert_array_equal(np.asarray(b[k]), n[k])
    # weightless layers read back as {} in both formats
    assert s_bun.read_raw("stateless_layer") == {}
    assert s_npy.read_raw("stateless_layer") == {}


def test_layerstore_dotted_layer_names_do_not_collide(tmp_path):
    """'block.0' and 'block.1' must map to distinct bundle files (a naive
    with_suffix would truncate at the last dot and collide)."""
    st = LayerStore(tmp_path)
    w0 = {"w": np.zeros((2, 2), np.float32)}
    w1 = {"w": np.ones((3, 3), np.float32)}
    st.write_raw("block.0", w0)
    st.write_raw("block.1", w1)
    np.testing.assert_array_equal(np.asarray(st.read_raw("block.0")["w"]),
                                  w0["w"])
    np.testing.assert_array_equal(np.asarray(st.read_raw("block.1")["w"]),
                                  w1["w"])
    assert st.raw_bytes("block.0") > 0 and st.raw_bytes("block.1") > 0


def test_layerstore_cached_bundle_roundtrip_bf16(tmp_path):
    import ml_dtypes

    st = LayerStore(tmp_path)
    w = {"w": np.ones((8, 8), np.float32).astype(ml_dtypes.bfloat16)}
    st.write_cached("l0", "bf16_cast", w)
    assert st.has_cached("l0", "bf16_cast")
    back = st.read_cached("l0", "bf16_cast")
    assert back["w"].dtype == ml_dtypes.bfloat16
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(w["w"]))
    assert st.cache_bytes() > 0
    st.drop_cached("l0", "bf16_cast")
    assert not st.has_cached("l0", "bf16_cast")
    assert st.cache_bytes() == 0


def test_stage_weights_never_alias_mmap(tmp_path):
    """CPU XLA zero-copy-aliases aligned host buffers; staging must still
    end with device-resident memory, never file-backed mmap pages (which
    would defer the disk I/O into execute)."""
    from repro.core.staging import stage_weights

    rng = np.random.default_rng(0)
    w = {"w": rng.standard_normal((256, 256)).astype(np.float32)}
    write_bundle(tmp_path / "l.bundle", w)
    view = read_bundle(tmp_path / "l.bundle", mmap=True)
    staged = stage_weights(view)
    assert isinstance(staged["w"], jax.Array)
    assert not np.shares_memory(np.asarray(staged["w"]), view["w"])
    np.testing.assert_array_equal(np.asarray(staged["w"]), w["w"])


@pytest.fixture(scope="module")
def staged_run(tmp_path_factory):
    from repro.core.engine import ColdEngine
    from repro.models.cnn import build_cnn

    layers, x = build_cnn("squeezenet", image=24, width=0.35)
    eng = ColdEngine(layers, tmp_path_factory.mktemp("stage_store"))
    eng.decide(x, n_little=2)
    return eng, eng.run_cold(x)


def test_stage_ops_on_prep_not_exec_chain(staged_run):
    """Every weighted layer is staged by a dedicated 'stage' op; execute ops
    see device-resident weights (no host->device conversion inside them)."""
    eng, res = staged_run
    staged_layers = {t.layer for t in res.traces if t.kind == "stage"}
    weighted = {l.spec.name for l in eng.layers if l.spec.weight_shapes}
    assert staged_layers == weighted
    # stage ops ran on prep cores / off the exec chain, and finished before
    # the layer's execute started
    exec_start = {t.layer: t.start for t in res.traces if t.kind == "execute"}
    for t in res.traces:
        if t.kind == "stage":
            assert t.end <= exec_start[t.layer] + 1e-9
    # resident weights are device arrays, ready for warm reuse
    for name, w in (res.weights or {}).items():
        for v in w.values():
            assert isinstance(v, jax.Array)


def test_sequential_baseline_also_stages(staged_run):
    eng, _ = staged_run
    layers = [l for l in eng.layers]
    x = eng._input_example
    res = eng.run_cold(x, mode="sequential")
    kinds = [t.kind for t in res.traces]
    assert "stage" in kinds
    weighted = sum(1 for l in layers if l.spec.weight_shapes)
    assert sum(1 for k in kinds if k == "execute") == len(layers)


def test_profiles_carry_stage_split(staged_run):
    """The profiler reports the read-vs-stage split the scheduler plans
    against; staged transfer costs are > 0 for weighted layers."""
    eng, _ = staged_run
    for l in eng.layers:
        if not l.spec.weight_shapes:
            continue
        for p in eng.profiles[l.spec.name]:
            assert p.stage_s > 0.0
            assert p.prep_s(False) == pytest.approx(
                p.read_raw_s + p.transform_s + p.stage_s)
            assert p.prep_s(False, include_stage=False) == pytest.approx(
                p.read_raw_s + p.transform_s)


def test_profile_json_roundtrip_with_stage(tmp_path, staged_run):
    from repro.core.profiler import load_profiles, save_profiles

    eng, _ = staged_run
    save_profiles(tmp_path / "p.json", eng.profiles)
    back = load_profiles(tmp_path / "p.json")
    assert back.keys() == eng.profiles.keys()
    any_p = next(iter(back.values()))[0]
    assert hasattr(any_p, "stage_s")
