"""Per-architecture smoke tests: a REDUCED variant of each assigned family
(2 layers, d_model<=256, <=4 experts) runs one forward/train step and one
decode step on CPU; output shapes are checked and NaN-free."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import transformer as T
from repro.optim import adamw_init
from repro.train import make_train_step


def make_batch(cfg, B, S, key):
    if cfg.input_mode == "tokens":
        return {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.input_mode == "embeddings":
        return {
            "embeds": jax.random.normal(key, (B, S, cfg.d_model)),
            "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        }
    P = cfg.num_prefix_embeds
    return {
        "tokens": jax.random.randint(key, (B, S - P), 0, cfg.vocab_size),
        "prefix_embeds": jax.random.normal(key, (B, P, cfg.d_model)),
    }


@pytest.fixture(scope="module")
def key():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_forward_and_loss(arch, key):
    cfg = get_config(arch).reduced(ssm_chunk=16)
    params = T.init_params(key, cfg)
    batch = make_batch(cfg, 2, 32, key)
    logits, aux, _ = T.forward(params, batch, cfg)
    S_total = 32
    assert logits.shape == (2, S_total, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    loss, metrics = T.loss_fn(params, batch, cfg)
    assert jnp.isfinite(loss)
    # loss should be ~ln(V) for random init
    import math
    assert abs(float(metrics["loss"]) - math.log(cfg.vocab_size)) < 1.5


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step(arch, key):
    cfg = get_config(arch).reduced(ssm_chunk=16)
    params = T.init_params(key, cfg)
    opt = adamw_init(params)
    step = make_train_step(cfg, num_microbatches=1, remat=True)
    batch = make_batch(cfg, 2, 32, key)
    params2, opt2, metrics = jax.jit(step)(params, opt, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert int(opt2.step) == 1
    # params actually moved
    moved = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, params2)
    assert max(jax.tree.leaves(moved)) > 0


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_step(arch, key):
    cfg = get_config(arch).reduced(ssm_chunk=16)
    params = T.init_params(key, cfg)
    B = 2
    state = T.init_decode_state(cfg, B, 64)
    if cfg.input_mode == "embeddings":
        batch = {"embeds": jax.random.normal(key, (B, 1, cfg.d_model))}
    else:
        batch = {"tokens": jnp.zeros((B, 1), jnp.int32)}
    logits, state2 = T.decode_step(params, state, batch, jnp.int32(3), cfg)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(logits).any())
    # state structure preserved
    assert jax.tree.structure(state) == jax.tree.structure(state2)
