"""Cold-start LLM serving: engine graph output must match the reference
transformer forward, and the bf16-cast kernel must halve cache bytes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.engine import ColdEngine
from repro.core.llm_graph import build_llm_graph
from repro.models import transformer as T


@pytest.fixture(scope="module")
def setup(tmp_path_factory):
    cfg = get_config("smollm-360m").reduced(
        num_layers=2, d_model=128, d_ff=256, num_heads=2, num_kv_heads=1,
        head_dim=64, vocab_size=512)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    graph, toks = build_llm_graph(cfg, params)
    eng = ColdEngine(graph, tmp_path_factory.mktemp("llm_store"))
    eng.decide(toks, n_little=2)
    return cfg, params, graph, toks, eng


def test_llm_graph_matches_transformer(setup):
    cfg, params, graph, toks, eng = setup
    res = eng.run_cold(toks)
    ref, _, _ = T.forward(params, {"tokens": jnp.asarray(toks)}, cfg)
    got = np.asarray(res.output)
    # engine runs bf16 like the model; logits returned f32
    np.testing.assert_allclose(got, np.asarray(ref), atol=0.1, rtol=0.05)


def test_llm_modes_agree_exactly(setup):
    cfg, params, graph, toks, eng = setup
    r1 = eng.run_cold(toks)
    r2 = eng.run_cold(toks, mode="sequential")
    # both paths execute the same selected kernels in bf16
    assert float(np.abs(np.asarray(r1.output) - np.asarray(r2.output)).max()) < 1e-5


def test_bf16_cache_halves_bytes(setup):
    cfg, params, graph, toks, eng = setup
    for l in eng.layers:
        if l.spec.op_type != "tblock":
            continue
        ps = eng.profiles[l.spec.name]
        bf = next((p for p in ps if p.kernel == "bf16_cast"), None)
        if bf is not None and bf.transformed_bytes:
            assert bf.transformed_bytes * 2 == bf.raw_bytes
