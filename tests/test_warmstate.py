"""Peer warm-state transfer: the wire round-trip, CRC integrity and
fallback, the fetch-vs-disk race (bit-identity + journaling), chaos at
the fetch sites, memory-pressure refusal, and the abortable paced read
that keeps a race-losing read from sleeping out the emulated disk."""
import os
import tempfile
import threading
import time

import numpy as np
import pytest

from repro.core.scheduler import DEFAULT_LINK_BYTES_PER_S, transfer_estimate
from repro.executor.server import ColdServer
from repro.executor.warmstate import PeerFetcher, WarmStateServer
from repro.faults import FaultInjector, FetchFault, TransientFault
from repro.models.cnn import build_cnn


def _mk_server(root, **kw):
    """One ColdServer with 'mnet' registered + decided on the measured
    super-bundle store. build_cnn is seed-deterministic, so every server
    built this way holds bit-identical weights."""
    layers, x = build_cnn("mobilenet", image=16, width=0.25)
    srv = ColdServer(root, n_little=2, max_concurrent_preps=2, **kw)
    srv.add_model("mnet", layers, store_fmt="super")
    srv.decide("mnet", x, n_little=2)
    return srv, x


@pytest.fixture(scope="module")
def donor():
    """Server A: model resident (one completed cold start) + its warm-state
    endpoint, shared by the read-only tests in this module."""
    root = tempfile.mkdtemp(prefix="warmstate_donor_")
    srv, x = _mk_server(root)
    ref = np.asarray(srv.cold_start("mnet", x).result().output)
    warm = WarmStateServer(srv)
    yield srv, warm, x, ref
    warm.close()


def _peers(warm, resident_bytes=1, link_bytes_per_s=1e9):
    """A peer the cost model will always arm against: tiny advertised
    state over a fast link beats any local plan estimate. (The decline
    branch is exercised explicitly in test_slow_peer_declined.)"""
    return [{"host": warm.host, "port": warm.port,
             "resident_bytes": resident_bytes,
             "link_bytes_per_s": link_bytes_per_s}]


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------
def test_transfer_estimate_units():
    assert transfer_estimate(200_000_000, 200e6) == pytest.approx(1.0)
    assert transfer_estimate(100_000_000, 200e6, rtt_s=0.25) == \
        pytest.approx(0.75)
    # bw<=0 means "unknown link": falls back to the default, never div/0
    assert transfer_estimate(DEFAULT_LINK_BYTES_PER_S, 0.0) == \
        pytest.approx(1.0)
    assert transfer_estimate(0, 200e6) == 0.0


# ---------------------------------------------------------------------------
# wire round-trip
# ---------------------------------------------------------------------------
def test_fetch_roundtrip_bit_identical(donor):
    srv, warm, _, _ = donor
    state, reason = srv.resident_state_for_transfer("mnet")
    assert reason == "ok" and state
    pf = PeerFetcher("mnet", [(warm.host, warm.port)])
    try:
        for lname, kv in state.items():
            got = pf.fetch(lname)
            assert set(got) == set(kv)
            for k, v in kv.items():
                np.testing.assert_array_equal(got[k], np.asarray(v))
    finally:
        pf.close()
    assert pf.stats["layers_fetched"] == len(state)
    assert pf.stats["bytes_fetched"] > 0
    assert pf.stats["crc_failures"] == 0


def test_stream_delivers_every_layer(donor):
    srv, warm, _, _ = donor
    state, _ = srv.resident_state_for_transfer("mnet")
    landed, errs = {}, []
    done = threading.Event()

    pf = PeerFetcher("mnet", [(warm.host, warm.port)])
    try:
        def on_layer(name, kv):
            landed[name] = kv
            if len(landed) == len(state):
                done.set()

        assert pf.start_stream(on_layer, on_error=errs.append)
        # idempotent: the second call must not start a second drain
        assert not pf.start_stream(on_layer, on_error=errs.append)
        assert done.wait(10.0), f"stream delivered {len(landed)} layers"
    finally:
        pf.close()
    assert not errs
    assert set(landed) == set(state)


def test_fetch_unknown_model_raises_typed():
    with pytest.raises(FetchFault):
        # nothing listens here: connect fails as a typed, catchable fault
        PeerFetcher("ghost", [("127.0.0.1", 1)], timeout_s=2.0).fetch("l0")


# ---------------------------------------------------------------------------
# the race, end to end (two servers, one process)
# ---------------------------------------------------------------------------
def test_race_bit_identical_and_journaled(donor, tmp_path):
    _, warm, _, ref = donor
    srv_b, x = _mk_server(tmp_path)
    ticket = srv_b.cold_start("mnet", x, peers=_peers(warm))
    out = np.asarray(ticket.result().output)
    np.testing.assert_array_equal(out, ref)
    assert srv_b.stats["peer_races"] == 1
    events = ticket.job.job.fault_events
    ends = [e for e in events if e.get("action") == "fetch_race_end"]
    assert len(ends) == 1, "every race journals exactly one summary"
    assert ends[0]["crc_failures"] == 0 and ends[0]["refused"] == 0
    # the done-callback folded the outcome into the server's counters
    assert srv_b.stats["peer_layers_fetched"] == ends[0]["layers_fetched"]
    assert srv_b.stats["peer_bytes_fetched"] == ends[0]["bytes_fetched"]


def test_slow_peer_declined(donor, tmp_path):
    """The cost model declines the race when the transfer estimate loses
    to the local plan: no fetcher is built, no session hits the donor."""
    _, warm, _, ref = donor
    srv_b, x = _mk_server(tmp_path)
    sessions = warm.stats["sessions"]
    slow = [{"host": warm.host, "port": warm.port,
             "resident_bytes": 1 << 40, "link_bytes_per_s": 1e3}]
    out = np.asarray(srv_b.cold_start("mnet", x, peers=slow)
                     .result().output)
    np.testing.assert_array_equal(out, ref)
    assert srv_b.stats["peer_races"] == 0
    assert srv_b.stats["peer_races_declined"] == 1
    assert warm.stats["sessions"] == sessions


def test_crc_corruption_falls_back_bit_identical(donor, tmp_path):
    """A corrupted chunk must surface as a typed integrity failure on the
    fetching side and NEVER into the weights: the cold start falls back to
    its local chains and still produces the bit-identical output."""
    _, warm, _, ref = donor
    srv_b, x = _mk_server(tmp_path)
    warm.corrupt_chunks = 2
    try:
        ticket = srv_b.cold_start("mnet", x, peers=_peers(warm))
        out = np.asarray(ticket.result().output)
    finally:
        warm.corrupt_chunks = 0
    np.testing.assert_array_equal(out, ref)
    assert srv_b.stats["peer_crc_failures"] >= 1
    events = ticket.job.job.fault_events
    assert any(e.get("action") == "fetch_fallback" for e in events)


def test_injected_fetch_fault_falls_back_no_leaks(donor, tmp_path):
    """Chaos at the warmstate.fetch site: every delivery faults, the
    stream falls back, the local chains win — and nothing leaks (the
    engine drains, a follow-up cold start still completes)."""
    _, warm, _, ref = donor
    srv_b, x = _mk_server(tmp_path)
    eng = srv_b.engines["mnet"]
    eng.fault_injector = FaultInjector(
        seed=3, rates={"warmstate.fetch": 1.0}, max_faults_per_key=None)
    try:
        ticket = srv_b.cold_start("mnet", x, peers=_peers(warm))
        out = np.asarray(ticket.result().output)
    finally:
        eng.fault_injector = None
    np.testing.assert_array_equal(out, ref)
    events = ticket.job.job.fault_events
    assert any(e.get("action") == "fetch_fallback" for e in events)
    if srv_b.io_engine is not None:
        assert srv_b.io_engine.drain(10.0), "reads leaked after the race"
    # the pool survived the race + fallback: serve again, bit-identical
    out2 = np.asarray(
        srv_b.cold_start("mnet", x, peers=_peers(warm)).result().output)
    np.testing.assert_array_equal(out2, ref)


def test_refusal_under_memory_pressure(donor):
    srv, warm, _, _ = donor
    total = srv.budget.total
    srv.budget.total = 1          # any resident state is now over budget
    srv.budget.charge("test:pressure", 2)
    try:
        state, reason = srv.resident_state_for_transfer("mnet")
        assert state is None and "pressure" in reason
        pf = PeerFetcher("mnet", [(warm.host, warm.port)])
        try:
            with pytest.raises(TransientFault):
                pf.fetch("conv0")
        finally:
            pf.close()
        assert pf.stats["refused"] == 1
    finally:
        srv.budget.total = total
        srv.budget.release("test:pressure")
    state, reason = srv.resident_state_for_transfer("mnet")
    assert reason == "ok" and state


# ---------------------------------------------------------------------------
# abortable paced reads (the race-loser's slot is freed promptly)
# ---------------------------------------------------------------------------
def test_interrupt_unblocks_paced_read(tmp_path):
    from repro.ioengine import IOEngine, ReadAbandoned

    payload = os.urandom(1 << 20)
    p = tmp_path / "blob"
    p.write_bytes(payload)
    eng = IOEngine()
    try:
        # 100 KB/s: the 1 MB read owes ~10s of simulated device time
        eng.set_sim_read_bandwidth(100_000)
        fd = os.open(p, os.O_RDONLY)
        try:
            t = eng.submit(fd, 0, len(payload), key="blob")
            threading.Timer(0.1, t.interrupt).start()
            t0 = time.monotonic()
            with pytest.raises(ReadAbandoned):
                t.wait(5.0)
            assert time.monotonic() - t0 < 2.0, \
                "interrupt did not unblock the paced wait promptly"
            t.release()
        finally:
            os.close(fd)
        # pacing off: the same read completes and the bytes are intact
        eng.set_sim_read_bandwidth(None)
        fd = os.open(p, os.O_RDONLY)
        try:
            t2 = eng.submit(fd, 0, len(payload), key="blob2")
            assert bytes(t2.wait(10.0)) == payload
            t2.release()
        finally:
            os.close(fd)
        assert eng.drain(5.0)
    finally:
        eng.close()
