"""int8-quantized KV cache: decode must track the full-precision forward
within quantization tolerance, and the state must actually be int8."""
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as T
from repro.models.runtime_flags import FLAGS


def test_int8_cache_decode_close_to_forward(restore_flags):
    cfg = get_config("qwen3-32b").reduced()
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    B, S = 2, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    logits, _, _ = T.forward(params, {"tokens": toks}, cfg)
    FLAGS["kv_cache_int8"] = True
    state = T.init_decode_state(cfg, B, S)
    assert state["k"].dtype == jnp.int8
    assert state["k_scale"].shape == state["k"].shape[:-1]
    dstep = jax.jit(lambda p, s, b, pos: T.decode_step(p, s, b, pos, cfg))
    outs = []
    for t in range(S):
        lg, state = dstep(params, state, {"tokens": toks[:, t:t + 1]},
                          jnp.int32(t))
        outs.append(lg[:, 0])
    dec = jnp.stack(outs, axis=1)
    assert float(jnp.abs(dec - logits).max()) < 0.3   # int8 tolerance
    # and distinctly tighter than garbage: correlation with reference
    import numpy as np

    a = np.asarray(dec, np.float32).ravel()
    b = np.asarray(logits, np.float32).ravel()
    corr = float(np.corrcoef(a, b)[0, 1])
    assert corr > 0.999


def test_quantize_roundtrip():
    from repro.models.layers import _quantize_kv

    k = jax.random.normal(jax.random.PRNGKey(0), (2, 1, 4, 64)) * 3.0
    q, scale = _quantize_kv(k)
    back = q.astype(jnp.float32) * scale[..., None]
    rel = float(jnp.max(jnp.abs(back - k)) / jnp.max(jnp.abs(k)))
    assert rel < 1.0 / 64  # <= half an int8 step of the absmax
