"""Shape-class decision generation: profile/compile sharing + profile DB.

- canonical key properties (identical specs share, stateless never share);
- a graph of repeated identical blocks produces the SAME plan whether
  profiles are shared per shape-class or measured per layer (deterministic
  profiles);
- profile-DB round-trip: a second decide() performs zero Profiler.profile
  calls and reproduces the plan; host-fingerprint scoping;
- profiling writes no candidate cache entries into the model store;
- CompileCache keyed by (kernel, shape-class, jax version): one compile per
  class, stale-version entries miss cleanly, no jit built on hits.
"""
import numpy as np
import pytest

from repro.core.engine import ColdEngine
from repro.core.llm_graph import tiny_llm_graph
from repro.core.profiler import OpProfile, ProfileDB, SyntheticProfiler
from repro.core.registry import LayerSpec, shape_class_key

N_BLOCKS = 6


# ---------------------------------------------------------------------------
# the key itself
# ---------------------------------------------------------------------------
def test_identical_specs_share_key():
    a = LayerSpec("block000", "tblock", {"d": 4}, {"w": (8, 8)})
    b = LayerSpec("block007", "tblock", {"d": 4}, {"w": (8, 8)})
    assert shape_class_key(a) == shape_class_key(b)


def test_shape_and_config_and_input_feed_key():
    base = LayerSpec("l", "linear", {"in_features": 8, "out_features": 8},
                     {"w": (8, 8)})
    other_shape = LayerSpec("l", "linear",
                            {"in_features": 8, "out_features": 16},
                            {"w": (8, 16)})
    other_op = LayerSpec("l", "conv2d", {"in_features": 8, "out_features": 8},
                         {"w": (8, 8)})
    assert shape_class_key(base) != shape_class_key(other_shape)
    assert shape_class_key(base) != shape_class_key(other_op)
    assert (shape_class_key(base, input_shape=(1, 8), input_dtype="float32")
            != shape_class_key(base, input_shape=(2, 8),
                               input_dtype="float32"))


def test_stateless_layers_never_share():
    a = LayerSpec("relu1", "stateless")
    b = LayerSpec("relu2", "stateless")
    assert shape_class_key(a) != shape_class_key(b)


# ---------------------------------------------------------------------------
# engines over a graph with repeated identical blocks
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def llm_graph():
    return tiny_llm_graph(N_BLOCKS)


def _engine(graph, toks, store, *, share=True, db=None, profiler=None):
    eng = ColdEngine(graph, store, share_shape_classes=share,
                     profile_db=db, shader_cache=False)
    if profiler is not None:
        eng.profiler_factory = profiler
    stats = eng.decide(toks, n_little=2, calibrate_interference=False)
    return eng, stats


def test_shared_profiles_match_per_layer_plan(llm_graph, tmp_path):
    """Same Plan — choices, queues, makespan — whether profiles are shared
    per shape-class or measured per layer, given deterministic profiles."""
    graph, toks = llm_graph
    shared, _ = _engine(graph, toks, tmp_path / "a",
                        share=True, profiler=SyntheticProfiler)
    per_layer, _ = _engine(graph, toks, tmp_path / "b",
                           share=False, profiler=SyntheticProfiler)
    assert shared.plan.choices == per_layer.plan.choices
    assert shared.plan.big_prep == per_layer.plan.big_prep
    assert shared.plan.little_queues == per_layer.plan.little_queues
    assert shared.plan.est_makespan == pytest.approx(
        per_layer.plan.est_makespan, rel=1e-12)


def test_one_profile_per_shape_class_kernel(llm_graph, tmp_path):
    graph, toks = llm_graph
    eng, stats = _engine(graph, toks, tmp_path,
                         share=True, profiler=SyntheticProfiler)
    # embed / tblock / lmhead: identical tblocks collapse into one class
    assert stats["shape_classes"] == 3
    reps = {}
    for l in eng.layers:
        reps.setdefault(eng._sc_by_layer[l.spec.name], l)
    expect = sum(len(eng._kernels_for(l.spec)) for l in reps.values())
    assert stats["profile_calls"] == expect


def test_profiling_writes_nothing_to_model_store(llm_graph, tmp_path):
    graph, toks = llm_graph
    eng, _ = _engine(graph, toks, tmp_path,
                     share=True, profiler=SyntheticProfiler)
    chosen = sum(c.use_cache for c in eng.plan.choices)
    # only decide()'s materialization of CHOSEN entries writes the store —
    # candidate profiling goes through the profiler's scratch area
    assert eng.store.cache_write_count == chosen


def test_profile_db_roundtrip_zero_profile_calls(llm_graph, tmp_path):
    graph, toks = llm_graph
    db_path = tmp_path / "profile_db.json"
    eng1, s1 = _engine(graph, toks, tmp_path / "s", share=True,
                       db=db_path, profiler=SyntheticProfiler)
    assert s1["profile_calls"] > 0

    calls = []

    class Forbidden(SyntheticProfiler):
        def profile(self, spec, kernel, x):
            calls.append((spec.name, kernel.name))
            return super().profile(spec, kernel, x)

    eng2, s2 = _engine(graph, toks, tmp_path / "s", share=True,
                       db=db_path, profiler=Forbidden)
    assert calls == [] and s2["profile_calls"] == 0
    assert s2["profile_db_hits"] == s1["profile_calls"]
    assert eng2.plan.choices == eng1.plan.choices
    assert eng2.plan.little_queues == eng1.plan.little_queues


def test_force_reprofile_bypasses_db(llm_graph, tmp_path):
    graph, toks = llm_graph
    db_path = tmp_path / "profile_db.json"
    _engine(graph, toks, tmp_path / "s", share=True,
            db=db_path, profiler=SyntheticProfiler)
    eng = ColdEngine(graph, tmp_path / "s", share_shape_classes=True,
                     profile_db=db_path, shader_cache=False)
    eng.profiler_factory = SyntheticProfiler
    stats = eng.decide(toks, n_little=2, force_reprofile=True,
                       calibrate_interference=False)
    assert stats["profile_calls"] > 0 and stats["profile_db_hits"] == 0


def test_profile_db_scoped_by_host(tmp_path):
    db = ProfileDB(tmp_path / "db.json")
    p = OpProfile(layer="l", kernel="k", read_raw_s=1e-3, transform_s=1e-3,
                  read_cached_s=1e-3, exec_s=1e-3, compile_s=1e-3,
                  raw_bytes=4, transformed_bytes=4)
    db.put("sc0", "k", p)
    db.save()
    again = ProfileDB(tmp_path / "db.json")
    assert again.get("sc0", "k") is not None
    # a different host fingerprint never gets a FRESH hit: the donor host's
    # entries are served as STALE drift fallbacks (flagged for background
    # re-profiling) rather than adopted silently
    foreign = ProfileDB(tmp_path / "db.json")
    foreign.host = "elsewhere"
    foreign.entries = {}
    foreign._load()
    assert foreign.drifted_from == db.host
    assert foreign.get("sc0", "k") is not None
    assert foreign.stats["hits"] == 0
    assert foreign.stats["stale_hits"] == 1
    assert foreign.stale_pending() == [("sc0", "k")]
    # a fresh local measurement supersedes the drifted fallback
    foreign.put("sc0", "k", p)
    assert foreign.stale_pending() == []


# ---------------------------------------------------------------------------
# compile sharing
# ---------------------------------------------------------------------------
def test_one_compile_per_shape_class(llm_graph, tmp_path):
    graph, toks = llm_graph
    eng, _ = _engine(graph, toks, tmp_path,
                     share=True, profiler=SyntheticProfiler)
    eng._jitted_map(eng.plan.choices, toks)
    pairs = {(eng._sc_by_layer[l.spec.name], c.kernel)
             for l, c in zip(eng.layers, eng.plan.choices)}
    assert eng.compile_cache.stats["misses"] == len(pairs)
    # the N identical tblocks share ONE executable object
    jitted = eng._jitted_map(eng.plan.choices, toks)
    tbl = [jitted[l.spec.name] for l in eng.layers
           if l.spec.op_type == "tblock"]
    ch = {c.kernel for l, c in zip(eng.layers, eng.plan.choices)
          if l.spec.op_type == "tblock"}
    if len(ch) == 1:
        assert all(f is tbl[0] for f in tbl)


def test_compile_cache_version_guard(tmp_path):
    from repro.core import compile_cache as cc

    spec = LayerSpec("l", "linear", {"in_features": 4, "out_features": 4},
                     {"w": (4, 4)})
    import jax.numpy as jnp

    w = {"w": jnp.ones((4, 4), jnp.float32)}
    x = jnp.ones((2, 4), jnp.float32)
    fn = lambda w, x: x @ w["w"]

    cache = cc.CompileCache(tmp_path)
    cache.get("k", spec, fn, w, x, shape_class="sc")
    assert cache.stats["misses"] == 1
    # same key hits memory without compiling again
    cache.get("k", spec, fn, w, x, shape_class="sc")
    assert cache.stats["hits"] == 1 and cache.stats["misses"] == 1

    # fresh cache over the same dir: disk hit
    cache2 = cc.CompileCache(tmp_path)
    cache2.get("k", spec, fn, w, x, shape_class="sc")
    assert cache2.stats["disk_hits"] == 1 and cache2.stats["misses"] == 0

    # a different jax/jaxlib version must MISS cleanly (key changes)
    orig = cc._version_tag
    cc._version_tag = lambda: "jax-0.0.0/jaxlib-0.0.0"
    try:
        cache3 = cc.CompileCache(tmp_path)
        cache3.get("k", spec, fn, w, x, shape_class="sc")
        assert cache3.stats["misses"] == 1 and cache3.stats["disk_hits"] == 0
    finally:
        cc._version_tag = orig


def test_cache_invalidated_on_weight_update(tmp_path):
    """A second decide() over UPDATED raw weights must not keep serving the
    previous checkpoint's cached transformed entries (fingerprint sidecar):
    cold output must match the no-cache sequential path on the new model."""
    store = tmp_path / "s"
    graph1, toks = tiny_llm_graph(3, seed=0)
    eng1 = ColdEngine(graph1, store, shader_cache=False)
    eng1.decide(toks, n_little=2, calibrate_interference=False)

    graph2, _ = tiny_llm_graph(3, seed=1)  # same shapes, new weights
    eng2 = ColdEngine(graph2, store, shader_cache=False)
    eng2.decide(toks, n_little=2, calibrate_interference=False)
    r_cold = eng2.run_cold(toks)
    r_seq = eng2.run_cold(toks, mode="sequential")  # never reads the cache
    np.testing.assert_allclose(np.asarray(r_cold.output),
                               np.asarray(r_seq.output), atol=1e-5)


def test_unchanged_weights_skip_rematerialization(tmp_path):
    """Same weights, second decide(): cached entries are reused, zero new
    cache writes."""
    store = tmp_path / "s"
    graph, toks = tiny_llm_graph(3)
    eng1 = ColdEngine(graph, store, shader_cache=False)
    eng1.profiler_factory = SyntheticProfiler
    eng1.decide(toks, n_little=2, calibrate_interference=False)
    eng2 = ColdEngine(graph, store, shader_cache=False)
    eng2.profiler_factory = SyntheticProfiler
    eng2.decide(toks, n_little=2, calibrate_interference=False)
    assert eng2.plan.choices == eng1.plan.choices
    assert eng2.store.cache_write_count == 0


def test_compile_from_avatars_matches_real(tmp_path):
    """Executables lowered from ShapeDtypeStruct avatars run correctly on
    real weights — end-to-end cold run equals the reference forward."""
    import jax.numpy as jnp

    graph, toks = tiny_llm_graph(4)
    eng = ColdEngine(graph, tmp_path, shader_cache=False)
    eng.decide(toks, n_little=2, calibrate_interference=False)
    res = eng.run_cold(toks)
    res2 = eng.run_cold(toks, mode="sequential")
    np.testing.assert_allclose(np.asarray(res.output),
                               np.asarray(res2.output), atol=1e-5)
