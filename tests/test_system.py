"""End-to-end behaviour tests: training reduces loss; microbatching is
consistent; serving produces tokens; the cold engine beats its baseline in
the deterministic simulator."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import SyntheticPipeline
from repro.models import transformer as T
from repro.optim import adamw_init
from repro.train import make_train_step


def test_training_reduces_loss():
    cfg = get_config("smollm-360m").reduced(num_layers=2, vocab_size=128)
    key = jax.random.PRNGKey(0)
    params = T.init_params(key, cfg)
    opt = adamw_init(params)
    step = jax.jit(make_train_step(cfg, lr=3e-3, warmup=5, total_steps=60,
                                   num_microbatches=1, remat=False))
    # overfit a single small batch
    toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    first = None
    for i in range(40):
        params, opt, m = step(params, opt, batch)
        if first is None:
            first = float(m["loss"])
    last = float(m["loss"])
    assert last < first * 0.8, (first, last)


def test_microbatched_grads_match_full_batch():
    cfg = get_config("smollm-360m").reduced(num_layers=2, vocab_size=64)
    key = jax.random.PRNGKey(1)
    params = T.init_params(key, cfg)
    toks = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)

    def loss_full(p):
        return T.loss_fn(p, {"tokens": toks}, cfg)[0]

    def loss_micro(p):
        mb = toks.reshape(2, 2, 16)
        l0 = T.loss_fn(p, {"tokens": mb[0]}, cfg)[0]
        l1 = T.loss_fn(p, {"tokens": mb[1]}, cfg)[0]
        return (l0 + l1) / 2

    g1 = jax.grad(loss_full)(params)
    g2 = jax.grad(loss_micro)(params)
    leaves1, leaves2 = jax.tree.leaves(g1), jax.tree.leaves(g2)
    for a, b in zip(leaves1, leaves2):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=5e-2, rtol=5e-2)


def test_train_step_with_pipeline_microbatches():
    cfg = get_config("granite-moe-3b-a800m").reduced(vocab_size=128)
    key = jax.random.PRNGKey(2)
    params = T.init_params(key, cfg)
    opt = adamw_init(params)
    pipe = SyntheticPipeline(cfg, batch=4, seq=16, microbatches=2)
    step = jax.jit(make_train_step(cfg, num_microbatches=2, remat=True))
    params, opt, m = step(params, opt, pipe.batch_at(0))
    assert jnp.isfinite(m["loss"])


def test_batched_server_generates():
    from repro.serving import BatchedServer, Request

    cfg = get_config("smollm-360m").reduced(num_layers=2, vocab_size=64)
    key = jax.random.PRNGKey(3)
    params = T.init_params(key, cfg)
    srv = BatchedServer(params, cfg, max_batch=2, max_len=64)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, 64, size=5),
                    max_new_tokens=4) for i in range(3)]
    for r in reqs:
        srv.submit(r)
    srv.run_until_drained()
    for r in reqs:
        assert len(r.out_tokens) >= 4
        assert r.first_token_s is not None


def test_cold_engine_sim_beats_sequential():
    """In the deterministic big.LITTLE simulator, the NNV12 plan must beat
    the sequential (read-all, transform-all, execute-all) baseline."""
    from repro.core.scheduler import inner_schedule

    # synthetic profile shaped like Table 2: heavy prep, light exec
    N, M_l = 12, 3
    prep_l = [3.8 * 2.0] * N       # little-core prep
    prep_b = [2.0] * N             # big-core prep
    ex = [1.0] * N
    big_prep, qs, mk = inner_schedule(prep_l, prep_b, ex, M_l)
    sequential = sum(prep_b) + sum(ex)
    assert mk < sequential


def test_sampling_modes():
    from repro.serving.server import sample_token

    key = jax.random.PRNGKey(0)
    logits = jnp.array([0.1, 5.0, 0.2, 4.9, -3.0])
    # greedy
    assert int(sample_token(logits, key)) == 1
    # top_k=2 restricts support to {1, 3}
    for i in range(20):
        t = int(sample_token(logits, jax.random.PRNGKey(i), temperature=1.0,
                             top_k=2))
        assert t in (1, 3)
    # top_p tiny -> effectively greedy
    for i in range(10):
        t = int(sample_token(logits, jax.random.PRNGKey(i), temperature=1.0,
                             top_p=0.01))
        assert t == 1
