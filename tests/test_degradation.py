"""Degradation ladder (PR 6): a cold request must survive a missing or
corrupt plan, cache bit-rot, a faulting kernel, and repeated load failures
— degrading latency, never correctness, and journaling every repair."""
import json
import time

import numpy as np
import pytest

from repro.core.engine import ColdEngine
from repro.core.scheduler import Choice
from repro.executor.server import ColdServer
from repro.faults import FaultInjector, ModelQuarantined, ReadFault
from repro.models.cnn import build_cnn


def _build(store_dir, **kw):
    layers, x = build_cnn("squeezenet", image=16, width=0.25)
    return ColdEngine(layers, store_dir, **kw), x


# ---------------------------------------------------------------------------
# rung: the plan itself
# ---------------------------------------------------------------------------
def test_fallback_plan_serves_without_decide(tmp_path):
    eng, x = _build(tmp_path / "s")
    plan = eng.ensure_plan(x, n_little=2)
    assert eng.plan is plan
    assert len(plan.choices) == len(eng.layers)
    assert all(not c.use_cache for c in plan.choices)
    res = eng.run_cold(x, n_little=2)
    # the fallback picks each op's registry-head kernel — the same default
    # the shape tracer executes, so the output is pinned by it
    eng._trace_shapes(x)
    np.testing.assert_allclose(np.asarray(res.output), eng._output_example,
                               rtol=1e-4, atol=1e-5)


def test_plan_reloads_from_disk_after_restart(tmp_path):
    eng, x = _build(tmp_path / "s")
    eng.decide(x, n_little=2)
    # same store, fresh process (new engine, no in-memory plan)
    eng2, _ = _build(tmp_path / "s")
    plan = eng2.ensure_plan(x, n_little=2)
    assert [c.kernel for c in plan.choices] == \
        [c.kernel for c in eng.plan.choices]
    assert eng2.repairs.of_kind("plan_fallback") == []


def test_corrupt_or_invalid_plan_json_falls_back(tmp_path):
    eng, x = _build(tmp_path / "s")
    eng.decide(x, n_little=2)
    # garbled JSON
    (tmp_path / "s" / "plan.json").write_text("{ not json")
    eng2, _ = _build(tmp_path / "s")
    plan = eng2.ensure_plan(x, n_little=2)
    assert all(not c.use_cache for c in plan.choices)
    assert eng2.repairs.of_kind("plan_fallback")
    # structurally valid JSON naming a kernel that does not exist
    (tmp_path / "s" / "plan.json").write_text(json.dumps({"plan": {
        "choices": [["no_such_kernel", False]] * len(eng.layers),
        "big_prep": [0], "little_queues": [[], []], "est_makespan": 0.0}}))
    eng3, _ = _build(tmp_path / "s")
    eng3.ensure_plan(x, n_little=2)
    assert eng3.repairs.of_kind("plan_fallback")
    # and the degraded engine still serves
    res = eng3.run_cold(x, n_little=2)
    assert np.asarray(res.output).shape == (1, 100)


def test_decide_degrades_on_profiler_fault(tmp_path):
    eng, x = _build(tmp_path / "s")

    class SickProfiler:
        calls = 0

        def __init__(self, store, **kw):
            pass

        def profile(self, *a, **kw):
            raise ReadFault("profiling read failed")

        def close(self):
            pass

    eng.profiler_factory = SickProfiler
    stats = eng.decide(x, n_little=2, calibrate_interference=False)
    assert stats["degraded"] is True
    assert eng.repairs.of_kind("decide_degraded")
    # the degraded plan still serves the request
    res = eng.run_cold(x, n_little=2)
    assert np.asarray(res.output).shape == (1, 100)


# ---------------------------------------------------------------------------
# rung: cache bit-rot at runtime
# ---------------------------------------------------------------------------
def test_corrupt_cache_extent_recomputes_and_repairs(tmp_path):
    from repro.checkpoint.superbundle import read_super_header

    eng, x = _build(tmp_path / "s", store_fmt="super")
    eng.decide(x, n_little=2)
    y0 = np.asarray(eng.run_cold(x, n_little=2).output)

    # force one weighted layer onto the cached path, then rot its extent
    idx, ldef = next((i, l) for i, l in enumerate(eng.layers)
                     if l.spec.weight_shapes)
    name = ldef.spec.name
    kern = eng._kernel_by_name(ldef.spec, eng.plan.choices[idx].kernel)
    eng.plan.choices[idx] = Choice(kern.name, True)
    eng.store.write_cached(name, kern.name,
                           kern.transform(eng.store.read_raw(name),
                                          ldef.spec))
    eng.store._super(flush_all=True)
    eng.store.close()
    eng._runtimes.clear()
    ent = read_super_header(eng.store._super_path)[
        "layers"][name]["cache"][kern.name][0]
    with open(eng.store._super_path, "r+b") as f:
        f.seek(ent["offset"] + ent["nbytes"] // 2)
        b = f.read(1)
        f.seek(ent["offset"] + ent["nbytes"] // 2)
        f.write(bytes([b[0] ^ 0xFF]))

    y1 = np.asarray(eng.run_cold(x, n_little=2).output)
    np.testing.assert_array_equal(y0, y1)  # same kernels: bit-identical
    repairs = eng.repairs.of_kind("cache_recompute")
    assert any(r["layer"] == name for r in repairs)
    assert any(d.get("layer") == name and "checksum" in d.get("reason", "")
               for d in eng.store.dropped_entries)


# ---------------------------------------------------------------------------
# rung: faulting kernel -> circuit breaker demotion
# ---------------------------------------------------------------------------
def test_kernel_fault_demotes_then_decide_excludes(tmp_path):
    eng, x = _build(tmp_path / "s")
    eng.decide(x, n_little=2)
    y0 = np.asarray(eng.run_cold(x, n_little=2).output)
    target = next(l.spec.name for l in eng.layers
                  if l.spec.weight_shapes
                  and len(eng._kernels_for(l.spec)) > 1)

    eng.fault_injector = FaultInjector(
        seed=0, rates={"kernel.execute": 1.0},
        keys={"kernel.execute": {target}}, max_faults_per_key=10 ** 6)
    eng._runtimes.clear()
    try:
        y1 = np.asarray(eng.run_cold(x, n_little=2).output)
    finally:
        eng.fault_injector = None
        eng._runtimes.clear()

    # the request completed on the reference kernel (allclose, not
    # bit-identical: a different kernel ran for the demoted layer)
    np.testing.assert_allclose(y0, y1, rtol=1e-4, atol=1e-5)
    assert any(r["layer"] == target
               for r in eng.repairs.of_kind("kernel_demoted"))
    open_keys = eng.breaker.open_keys()
    assert open_keys
    assert (tmp_path / "s" / "replan_pending.json").exists()

    # breaker already open: the next request short-circuits the demotion
    demotions_before = len(eng.repairs.of_kind("kernel_demoted"))
    y2 = np.asarray(eng.run_cold(x, n_little=2).output)
    np.testing.assert_allclose(y0, y2, rtol=1e-4, atol=1e-5)
    assert len(eng.repairs.of_kind("kernel_demoted")) == demotions_before

    # a fresh decide() avoids the demoted kernel and clears the marker
    demoted = {k.split(":", 1)[0] for k in open_keys}
    stats = eng.decide(x, n_little=2)
    assert stats["choices"][target][0] not in demoted
    assert target in stats["replan_cleared"]
    assert not (tmp_path / "s" / "replan_pending.json").exists()

    # force_reprofile is the operator reset: breakers close again
    eng.decide(x, n_little=2, force_reprofile=True)
    assert eng.breaker.open_keys() == []


# ---------------------------------------------------------------------------
# rung: model-level quarantine in the server
# ---------------------------------------------------------------------------
def test_server_quarantines_failing_model_with_backoff(tmp_path):
    server = ColdServer(tmp_path, n_little=2, quarantine_base_s=0.2,
                        quarantine_max_s=1.0)
    layers, x = build_cnn("squeezenet", image=16, width=0.25)
    eng = server.add_model("m", layers)
    server.decide("m", x, n_little=2)

    # every store read fails, past all retries: the load is doomed
    eng.store.fault_injector = FaultInjector(
        seed=0, rates={"store.read_raw": 1.0}, max_faults_per_key=10 ** 9)
    with pytest.raises(ReadFault):
        server.cold_start("m", x).result()
    assert server.stats["load_failures"] == 1
    assert server.stats["active_preps"] == 0  # slot released on failure

    # quarantined: fast-fail BEFORE burning an admission slot
    admitted_before = server.stats["admitted"]
    with pytest.raises(ModelQuarantined) as ei:
        server.cold_start("m", x)
    assert server.stats["quarantined"] == 1
    assert server.stats["admitted"] == admitted_before
    assert 0 < ei.value.retry_after <= 0.2
    assert eng.repairs.of_kind("model_quarantined")

    # backoff expires -> another doomed attempt -> backoff doubles
    time.sleep(0.25)
    with pytest.raises(ReadFault):
        server.cold_start("m", x).result()
    assert server.stats["load_failures"] == 2
    q = server._model_quarantine["m"]
    assert q["fails"] == 2

    # heal the store; after the backoff a success clears the quarantine
    eng.store.fault_injector = None
    time.sleep(0.45)
    res = server.cold_start("m", x).result()
    assert np.asarray(res.output).shape == (1, 100)
    assert server._model_quarantine == {}
    h = server.health()
    assert h["stats"]["load_failures"] == 2
    assert h["quarantine"] == {}
