"""Quantized transform cache (super-bundle format v4) test suite.

Pins the whole quantization stack:

  * ``repro.quant`` numpy substrate — seeded random sweeps over shapes and
    source dtypes with a HARD per-dtype reconstruction bound (half a
    quantization step), per-channel scale edge cases (all-zero channel,
    single-element channel, large-magnitude outliers), int4 odd-length
    nibble packing, asymmetric int8 zero points;
  * the fold/expand hooks: a companion group folds into one v4 extent and
    expands back bit-identically;
  * cross-format compatibility: a genuine v3 container opens read-identical
    under v4 code, upgrades to v4 on its first rewrite, and a mixed
    container (bf16 + int8 + int4 cache extents side by side) round-trips
    bit-exactly through the journaled commit / replay path;
  * the Pallas dequant kernels (interpret mode) against the jnp oracles in
    ``repro.kernels.ref``, including odd-K int4 and non-block-multiple
    shapes;
  * the registered lossy kernels (``linear``/``tblock``/``lmhead``) and the
    store-level bytes accounting ``decide()``'s read-cost model consumes.

Property-style tests draw from seeded ``np.random`` generators (no
hypothesis dependency in the image): every trial's parameters are in the
assertion message, so a failure is replayable.
"""
import numpy as np
import pytest

import repro.checkpoint.superbundle as S
from repro import quant
from repro.checkpoint import LayerStore
from repro.checkpoint.superbundle import (
    SuperBundle, read_super_header, recover_journal, set_cache_entries,
    set_cache_entry, write_superbundle,
)


# ---------------------------------------------------------------------------
# quantize -> dequantize round-trip properties (seeded sweeps)
# ---------------------------------------------------------------------------
def _random_matrix(rng, K, N, src_dtype, scale_pow):
    a = rng.standard_normal((K, N)) * (10.0 ** scale_pow)
    if src_dtype == "bfloat16":
        import ml_dtypes

        return a.astype(ml_dtypes.bfloat16)
    return a.astype(src_dtype)


@pytest.mark.parametrize("bits,tag", [(8, "int8"), (4, "int4")])
def test_roundtrip_error_bound_sweep(bits, tag):
    """|w - dq(q(w))| <= scale/2 elementwise, for random shapes, source
    dtypes, and magnitude regimes."""
    rng = np.random.default_rng(1234 + bits)
    for trial in range(40):
        K = int(rng.integers(1, 97))
        N = int(rng.integers(1, 97))
        src = ["float32", "float64", "bfloat16"][trial % 3]
        pw = float(rng.uniform(-3, 3))
        a = _random_matrix(rng, K, N, src, pw)
        comps = quant.quantize_weight("w", np.asarray(a, np.float32),
                                      bits=bits)
        back = quant.dequantize_weight(comps, "w", logical_shape=(K, N))
        # (1 + 1e-5): exact-half ratios (common with bf16 sources) sit ON
        # the bound and f32 rounding of q*scale can tip them a few ulps over
        bound = quant.error_bound(comps["w:qscale"]) * (1 + 1e-5) + 1e-7
        err = np.abs(np.asarray(a, np.float32) - back)
        assert (err <= bound).all(), (trial, bits, src, K, N, pw,
                                      float(err.max()), float(bound.max()))
        # payloads carry the advertised storage dtype and shape
        if bits == 8:
            assert comps["w:q8"].dtype == np.int8
            assert comps["w:q8"].shape == (K, N)
        else:
            assert comps["w:q4"].dtype == np.uint8
            assert comps["w:q4"].shape == ((K + 1) // 2, N)
        assert comps["w:qscale"].dtype == np.float32
        assert comps["w:qscale"].shape == (1, N)


def test_all_zero_channel_quantizes_exactly():
    a = np.zeros((16, 4), np.float32)
    a[:, 1] = np.linspace(-2, 2, 16)
    for bits in (8, 4):
        comps = quant.quantize_weight("w", a, bits=bits)
        s = comps["w:qscale"]
        assert s[0, 0] == 1.0 and s[0, 2] == 1.0 and s[0, 3] == 1.0
        back = quant.dequantize_weight(comps, "w", logical_shape=a.shape)
        # zero channels reconstruct EXACTLY, not just within bound
        assert (back[:, 0] == 0).all() and (back[:, 3] == 0).all()


def test_single_element_channel_is_exact_at_the_extreme():
    """K=1: the sole element IS the absmax, so it lands on +/-qmax and
    reconstructs to full precision of scale*qmax."""
    a = np.array([[3.0, -0.125, 0.0]], np.float32)
    for bits, qmax in ((8, 127), (4, 7)):
        comps = quant.quantize_weight("w", a, bits=bits)
        back = quant.dequantize_weight(comps, "w", logical_shape=a.shape)
        np.testing.assert_allclose(back, a, rtol=1e-6, atol=1e-7)
        s = comps["w:qscale"]
        np.testing.assert_allclose(s[0, 0], 3.0 / qmax, rtol=1e-6)


def test_large_magnitude_outlier_channel():
    """A 1e20-scale outlier column must not poison its neighbors' scales
    (per-channel isolation) and must still satisfy the hard bound."""
    rng = np.random.default_rng(7)
    a = rng.standard_normal((64, 8)).astype(np.float32)
    a[:, 3] *= 1e20
    for bits in (8, 4):
        comps = quant.quantize_weight("w", a, bits=bits)
        s = comps["w:qscale"][0]
        assert s[3] > 1e17 and (s[np.arange(8) != 3] < 1.0).all()
        back = quant.dequantize_weight(comps, "w", logical_shape=a.shape)
        err = np.abs(a - back)
        bound = quant.error_bound(comps["w:qscale"]) + 1e-7
        assert (err <= bound).all(), bits


def test_int4_odd_length_packing_roundtrip_sweep():
    rng = np.random.default_rng(42)
    for trial in range(30):
        K = int(rng.integers(1, 64))
        N = int(rng.integers(1, 32))
        q = rng.integers(-7, 8, size=(K, N)).astype(np.int8)
        packed = quant.pack_int4(q)
        assert packed.shape == ((K + 1) // 2, N)
        np.testing.assert_array_equal(quant.unpack_int4(packed, K), q)
        if K % 2:
            # the pad nibble is the encoding of 0 — inert under any scale
            assert ((packed[-1] >> 4) == 0).all(), (trial, K, N)


def test_asymmetric_int8_zero_point_roundtrip():
    """Asymmetric int8 (skewed distributions): lo/hi map to -127/+127
    exactly and the half-step bound still holds through (q - z) * s."""
    rng = np.random.default_rng(11)
    for trial in range(20):
        K = int(rng.integers(2, 80))
        a = (rng.standard_normal((K, 5)) + rng.uniform(-9, 9)) \
            .astype(np.float32)
        q, s, z = quant.quantize_int8(a, symmetric=False)
        assert z is not None and z.dtype == np.int32
        comps = {"w:q8": q, "w:qscale": s, "w:qzero": z}
        back = quant.dequantize_weight(comps, "w")
        err = np.abs(a - back)
        assert (err <= quant.error_bound(s) + 1e-6).all(), \
            (trial, float(err.max()), float(s.max()))
        lo_col = a.argmin(axis=0)
        for n, r in enumerate(lo_col):
            assert q[r, n] == -127, trial


def test_quantize_weights_passthrough_rules():
    """Only 2-D float tensors of at least min_size quantize; biases, norm
    gains, small and integer tensors pass through untouched."""
    raw = {
        "w": np.ones((8, 8), np.float32),
        "b": np.arange(8, dtype=np.float32),          # 1-D: passthrough
        "tiny": np.ones((2, 2), np.float32),          # < min_size
        "lut": np.ones((8, 8), np.int32),             # integer
    }
    out = quant.quantize_weights(raw, bits=8)
    assert set(out) == {"w:q8", "w:qscale", "b", "tiny", "lut"}
    for k in ("b", "tiny", "lut"):
        assert out[k] is raw[k] or np.shares_memory(out[k], raw[k]) or \
            np.array_equal(out[k], raw[k])
    groups, rest = quant.split_groups(out)
    assert set(groups) == {"w"} and set(rest) == {"b", "tiny", "lut"}
    assert quant.is_quantized(out) and not quant.is_quantized(raw)
    # logical bytes = f32 bytes of the dequantized view
    assert quant.logical_nbytes(out) == (64 * 4 + raw["b"].nbytes
                                         + raw["tiny"].nbytes
                                         + raw["lut"].nbytes)


def test_fold_expand_bit_identical():
    """split_groups + quant_meta + expand_entry is a bit-exact involution
    — the super-bundle's v4 write/read path in miniature."""
    rng = np.random.default_rng(3)
    a = rng.standard_normal((33, 9)).astype(np.float32)
    for bits, suf in ((8, ":q8"), (4, ":q4")):
        comps = quant.quantize_weight("w", a, bits=bits)
        groups, rest = quant.split_groups(comps)
        assert rest == {} and set(groups) == {"w"}
        meta = quant.quant_meta(groups["w"])
        assert meta["scheme"] == ("int8" if bits == 8 else "int4")
        back = quant.expand_entry("w", meta, groups["w"]["data"])
        assert set(back) == set(comps)
        for k in comps:
            assert back[k].dtype == comps[k].dtype, k
            np.testing.assert_array_equal(back[k], comps[k])
    # a data key without its scale companion is NOT a group (stays plain)
    groups, rest = quant.split_groups({"w:q8": np.ones(4, np.int8)})
    assert groups == {} and set(rest) == {"w:q8"}


# ---------------------------------------------------------------------------
# Pallas dequant kernels vs jnp oracles (interpret mode)
# ---------------------------------------------------------------------------
_SHAPES_MKN = [(4, 37, 16), (8, 64, 130), (3, 129, 7), (2, 256, 256)]


@pytest.mark.parametrize("M,K,N", _SHAPES_MKN)
def test_pallas_dequant_matches_ref(M, K, N):
    import jax.numpy as jnp

    from repro.kernels import quant as kq
    from repro.kernels import ref

    rng = np.random.default_rng(K * 131 + N)
    a = rng.standard_normal((K, N)).astype(np.float32) * 3.0

    q8, s8, _ = quant.quantize_int8(a)
    got8 = np.asarray(kq.dequant_int8(jnp.asarray(q8), jnp.asarray(s8),
                                      interpret=True))
    want8 = np.asarray(ref.dequant_int8_ref(jnp.asarray(q8),
                                            jnp.asarray(s8)))
    np.testing.assert_array_equal(got8, want8)
    assert (np.abs(a - got8) <= quant.error_bound(s8) + 1e-6).all()

    p4, s4 = quant.quantize_int4(a)
    got4 = np.asarray(kq.dequant_int4(jnp.asarray(p4), jnp.asarray(s4), K,
                                      interpret=True))
    want4 = np.asarray(ref.dequant_int4_ref(jnp.asarray(p4),
                                            jnp.asarray(s4), K))
    assert got4.shape == (K, N)
    np.testing.assert_array_equal(got4, want4)
    assert (np.abs(a - got4) <= quant.error_bound(s4) + 1e-6).all()


@pytest.mark.parametrize("M,K,N", _SHAPES_MKN)
def test_pallas_fused_dequant_matmul_matches_ref(M, K, N):
    import jax.numpy as jnp

    from repro.kernels import quant as kq
    from repro.kernels import ref

    rng = np.random.default_rng(M * 7 + K * 13 + N)
    x = rng.standard_normal((M, K)).astype(np.float32)
    a = rng.standard_normal((K, N)).astype(np.float32)

    q8, s8, _ = quant.quantize_int8(a)
    got = np.asarray(kq.matmul_dequant_int8(
        jnp.asarray(x), jnp.asarray(q8), jnp.asarray(s8), interpret=True))
    want = np.asarray(ref.matmul_dequant_int8_ref(
        jnp.asarray(x), jnp.asarray(q8), jnp.asarray(s8)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    p4, s4 = quant.quantize_int4(a)
    got = np.asarray(kq.matmul_dequant_int4(
        jnp.asarray(x), jnp.asarray(p4), jnp.asarray(s4), K,
        interpret=True))
    want = np.asarray(ref.matmul_dequant_int4_ref(
        jnp.asarray(x), jnp.asarray(p4), jnp.asarray(s4), K))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_registered_lossy_linear_kernels_execute_within_bound():
    """LinearInt8/LinearInt4 transform+execute must match the f32 matmul
    within the propagated quantization bound (||x||_1 * scale/2)."""
    import jax.numpy as jnp

    from repro.core.registry import LayerSpec, LOSSY_KERNELS

    rng = np.random.default_rng(0)
    raw = {"w": rng.standard_normal((48, 24)).astype(np.float32),
           "b": rng.standard_normal(24).astype(np.float32)}
    x = rng.standard_normal((5, 48)).astype(np.float32)
    spec = LayerSpec("l", "linear", weight_shapes={"w": (48, 24)})
    want = x @ raw["w"] + raw["b"]
    for kern in LOSSY_KERNELS["linear"]:
        if kern.name not in ("int8", "int4"):
            continue
        tw = kern.transform(dict(raw), spec)
        got = np.asarray(kern.execute(
            {k: jnp.asarray(v) for k, v in tw.items()}, jnp.asarray(x),
            spec))
        bound = (np.abs(x).sum(axis=1, keepdims=True)
                 * quant.error_bound(tw["w:qscale"])) + 1e-4
        assert (np.abs(got - want) <= bound).all(), kern.name
        # and distinctly better than noise
        corr = np.corrcoef(got.ravel(), want.ravel())[0, 1]
        assert corr > (0.999 if kern.name == "int8" else 0.98), kern.name


# ---------------------------------------------------------------------------
# container format v4: quantized extents end to end
# ---------------------------------------------------------------------------
def _mixed_cache(rng):
    """bf16 + int8 + int4 cache entries for one layer, side by side."""
    import ml_dtypes

    a = rng.standard_normal((40, 12)).astype(np.float32)
    return {
        "bf16_cast": {"w": a.astype(ml_dtypes.bfloat16)},
        "int8": quant.quantize_weight("w", a, bits=8),
        "int4": quant.quantize_weight("w", a, bits=4),
    }


def _assert_weights_equal(got, want):
    assert set(got) == set(want)
    for k in want:
        assert got[k].dtype == want[k].dtype, k
        np.testing.assert_array_equal(np.asarray(got[k]),
                                      np.asarray(want[k]))


def test_v4_quantized_extent_roundtrip_and_header_layout(tmp_path):
    """A quantized companion group folds into ONE extent whose payload is
    exactly the quantized bytes (CRC over them) and whose header entry
    carries the scales — and expands back bit-identically."""
    rng = np.random.default_rng(5)
    p = tmp_path / "m.superbundle"
    raw = {"l": {"w": rng.standard_normal((40, 12)).astype(np.float32)}}
    write_superbundle(p, raw, order=["l"])
    caches = _mixed_cache(rng)
    for kern, wdict in caches.items():
        set_cache_entry(p, "l", kern, wdict)
    hdr = read_super_header(p)
    assert S.VERSION == 4
    for kern, scheme, suf in (("int8", "int8", ":q8"),
                              ("int4", "int4", ":q4")):
        ents = hdr["layers"]["l"]["cache"][kern]
        assert len(ents) == 1, kern  # folded: one extent per group
        e = ents[0]
        assert e["dtype"] == scheme and e["quant"]["scheme"] == scheme
        assert e["nbytes"] == caches[kern]["w" + suf].nbytes
        assert e["quant"]["scale"]["shape"] == [1, 12]
    for mat in (False, True):
        with SuperBundle(p, verify="eager") as sb:
            for kern, wdict in caches.items():
                _assert_weights_equal(
                    sb.read_cached("l", kern, materialize=mat), wdict)


def test_v4_inplace_refresh_preserves_quant_metadata(tmp_path):
    """Replacing a quantized entry with same-shape payload commits in
    place and the NEW scales land with the new bytes."""
    rng = np.random.default_rng(6)
    p = tmp_path / "m.superbundle"
    write_superbundle(
        p, {"l": {"w": rng.standard_normal((40, 12)).astype(np.float32)}},
        order=["l"])
    first = quant.quantize_weight(
        "w", rng.standard_normal((40, 12)).astype(np.float32), bits=8)
    assert set_cache_entry(p, "l", "int8", first) == "rewrite"
    second = quant.quantize_weight(
        "w", (rng.standard_normal((40, 12)) * 5).astype(np.float32), bits=8)
    assert set_cache_entry(p, "l", "int8", second) == "inplace"
    with SuperBundle(p, verify="eager") as sb:
        _assert_weights_equal(
            sb.read_cached("l", "int8", materialize=True), second)


def test_mixed_container_roundtrips_through_journal_replay(tmp_path):
    """bf16 + int8 + int4 extents refreshed in ONE journaled transaction,
    torn before the header lands: replay must roll all three forward
    bit-exactly."""
    rng = np.random.default_rng(8)
    p = tmp_path / "m.superbundle"
    write_superbundle(
        p, {"l": {"w": rng.standard_normal((40, 12)).astype(np.float32)}},
        order=["l"])
    old = _mixed_cache(rng)
    for kern, wdict in old.items():
        set_cache_entry(p, "l", kern, wdict)
    new = _mixed_cache(rng)  # fresh draws, same shapes -> in-place slots

    def hook(ph, **ctx):
        if ph == "header":
            raise S.InjectedCrash(ph)

    S._crash_hook = hook
    try:
        with pytest.raises(S.InjectedCrash):
            set_cache_entries(p, {("l", k): w for k, w in new.items()})
    finally:
        S._crash_hook = None
    assert S.journal_path(p).stat().st_size > 0  # intent landed pre-crash
    assert recover_journal(p) == []  # roll-forward: nothing dropped
    assert S.journal_path(p).stat().st_size == 0  # drained
    with SuperBundle(p, verify="eager") as sb:
        assert not sb.dropped
        for kern, wdict in new.items():
            _assert_weights_equal(
                sb.read_cached("l", kern, materialize=True), wdict)


def test_v3_container_reads_identical_and_upgrades_on_rewrite(tmp_path):
    """A genuine v3 container (authored by pinning VERSION=3: no quantized
    extents, v3 header) opens read-identically under v4 code; the first
    rewrite upgrades it to v4, after which quantized extents work."""
    import ml_dtypes

    rng = np.random.default_rng(9)
    raw = {"l": {"w": rng.standard_normal((40, 12)).astype(np.float32)}}
    bf16 = {"w": raw["l"]["w"].astype(ml_dtypes.bfloat16)}
    p = tmp_path / "old.superbundle"
    old_version = S.VERSION
    S.VERSION = 3
    try:
        write_superbundle(p, raw, order=["l"])
        set_cache_entry(p, "l", "bf16_cast", bf16)
    finally:
        S.VERSION = old_version
    with SuperBundle(p, verify="eager") as sb:
        assert sb.version == 3
        _assert_weights_equal(sb.read_raw("l", materialize=True), raw["l"])
        _assert_weights_equal(
            sb.read_cached("l", "bf16_cast", materialize=True), bf16)
    # first rewrite (growing append) stamps the current version...
    q = quant.quantize_weight("w", raw["l"]["w"], bits=4)
    assert set_cache_entry(p, "l", "int4", q) == "rewrite"
    with SuperBundle(p, verify="eager") as sb:
        assert sb.version == S.VERSION
        _assert_weights_equal(
            sb.read_cached("l", "bf16_cast", materialize=True), bf16)
        _assert_weights_equal(sb.read_cached("l", "int4",
                                             materialize=True), q)


def test_layerstore_quantized_cache_roundtrip_and_bytes(tmp_path):
    """LayerStore round-trips companion dicts through the buffered write /
    flush / read path, and cached_bytes() (decide()'s read-cost input)
    reports the FOLDED byte count — int4 ~1/8 of f32."""
    rng = np.random.default_rng(10)
    raw = {"w": rng.standard_normal((64, 32)).astype(np.float32)}
    st = LayerStore(tmp_path, fmt="super")
    st.write_raw("l", raw)
    q8 = quant.quantize_weight("w", raw["w"], bits=8)
    q4 = quant.quantize_weight("w", raw["w"], bits=4)
    st.write_cached("l", "int8", q8)
    st.write_cached("l", "int4", q4)
    # pending (buffered) entries already serve and account correctly
    _assert_weights_equal(st.read_cached("l", "int8", mmap=False), q8)
    b8, b4 = st.cached_bytes("l", "int8"), st.cached_bytes("l", "int4")
    fraw = st.raw_bytes("l")
    assert b8 is not None and b4 is not None
    assert b8 < fraw / 3 and b4 < fraw / 6, (b8, b4, fraw)
    assert st.cache_bytes() > 0  # flush point
    # on-disk accounting matches the pending-buffer accounting
    assert st.cached_bytes("l", "int8") == b8
    assert st.cached_bytes("l", "int4") == b4
    _assert_weights_equal(st.read_cached("l", "int8", mmap=False), q8)
    _assert_weights_equal(st.read_cached("l", "int4", mmap=False), q4)


def test_async_submit_read_expands_quantized_extents(tmp_path):
    """submit_read serves the expanded companion dict bit-exactly and the
    reader's bytes_served counter advances by the FOLDED extent size."""
    from repro.ioengine import IOEngine

    rng = np.random.default_rng(12)
    raw = {"w": rng.standard_normal((64, 32)).astype(np.float32)}
    st = LayerStore(tmp_path, fmt="super")
    st.write_raw("l", raw)
    q4 = quant.quantize_weight("w", raw["w"], bits=4)
    st.write_cached("l", "int4", q4)
    st._super(flush_all=True)
    served0 = st.bytes_served()
    eng = IOEngine(backend="aio")
    try:
        h = st.submit_read_cached(eng, "l", "int4")
        got = h.wait(10.0)
        _assert_weights_equal(got, q4)
        folded = q4["w:q4"].nbytes
        assert st.bytes_served() - served0 == folded
    finally:
        eng.close()
        st.close()


def test_corrupt_quantized_payload_is_dropped_never_served(tmp_path):
    """A flipped byte inside the quantized payload fails the extent CRC:
    the entry drops (eager at open, lazy at first materializing read) and
    raw still serves clean."""
    rng = np.random.default_rng(13)
    raw = {"w": rng.standard_normal((64, 32)).astype(np.float32)}
    p = tmp_path / "m.superbundle"
    write_superbundle(p, {"l": raw}, order=["l"])
    set_cache_entry(p, "l", "int8",
                    quant.quantize_weight("w", raw["w"], bits=8))
    e = read_super_header(p)["layers"]["l"]["cache"]["int8"][0]
    with open(p, "r+b") as f:
        f.seek(e["offset"] + 3)
        b = f.read(1)
        f.seek(e["offset"] + 3)
        f.write(bytes([b[0] ^ 0xFF]))
    with SuperBundle(p, verify="eager") as sb:
        assert not sb.has_cached("l", "int8")
        assert sb.dropped and sb.dropped[0]["kernel"] == "int8"
        _assert_weights_equal(sb.read_raw("l", materialize=True), raw)
    with SuperBundle(p, verify="lazy") as sb:
        assert sb.read_cached("l", "int8", materialize=True) == {}
        assert sb.dropped and sb.dropped[0]["kernel"] == "int8"
