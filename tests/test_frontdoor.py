"""FrontDoor: supervised multi-worker tier — wire framing, typed-fault
transport, lane/shed admission logic, cache-aware routing, and one
end-to-end chaos integration (spawn, SIGKILL, failover, restart)."""
import socket
import threading
import time

import numpy as np
import pytest

from repro.executor.frontdoor import (
    BATCH, INTERACTIVE, FrontDoor, FrontDoorRequest, _Worker, rebuild_fault,
    recv_msg, send_msg,
)
from repro.faults import (
    DeadlineExceeded, ModelQuarantined, ReadFault, WorkerLost,
)


# -- wire format -------------------------------------------------------------

def test_framing_roundtrip_with_numpy():
    a, b = socket.socketpair()
    x = np.arange(12, dtype=np.float32).reshape(3, 4)
    msg = {"type": "result", "rid": 7, "output": x, "total_s": 0.5}
    send_msg(a, msg, threading.Lock())
    got = recv_msg(b)
    assert got["type"] == "result" and got["rid"] == 7
    np.testing.assert_array_equal(got["output"], x)
    # several messages back to back stay framed
    for i in range(3):
        send_msg(a, {"i": i})
    assert [recv_msg(b)["i"] for _ in range(3)] == [0, 1, 2]
    a.close()
    assert recv_msg(b) is None  # clean EOF -> None, not an exception
    b.close()


def test_rebuild_fault_preserves_taxonomy():
    e = rebuild_fault({"type": "DeadlineExceeded", "msg": "late",
                       "site": "watchdog"})
    assert isinstance(e, DeadlineExceeded) and e.site == "watchdog"
    e = rebuild_fault({"type": "ModelQuarantined", "msg": "sick",
                       "retry_after": 1.5})
    assert isinstance(e, ModelQuarantined) and e.retry_after == 1.5
    e = rebuild_fault(ReadFault("torn", layer="conv1").describe())
    assert isinstance(e, ReadFault) and e.layer == "conv1"
    # unknown / non-fault types degrade to RuntimeError, never crash
    assert isinstance(rebuild_fault({"type": "ValueError", "msg": "x"}),
                      RuntimeError)
    assert isinstance(rebuild_fault({}), RuntimeError)


# -- admission: shed before queuing (no workers needed) ----------------------

@pytest.fixture
def door(tmp_path):
    fd = FrontDoor(tmp_path / "fd", n_workers=2)
    fd._models["m"] = {"name": "m", "builder": "x:y", "kwargs": {}}
    return fd


def test_shed_quarantined_model_typed(door):
    door._quarantine["m"] = time.monotonic() + 10.0
    with pytest.raises(ModelQuarantined) as ei:
        door.request("m", None)
    assert ei.value.retry_after is not None
    assert door.stats["shed_quarantine"] == 1
    assert not door._queues[INTERACTIVE]  # never reached a queue


def test_shed_budget_below_rpc_floor_typed(door):
    with pytest.raises(DeadlineExceeded):
        door.request("m", None, deadline_s=door.rpc_overhead_s / 2)
    assert door.stats["shed_deadline"] == 1
    assert not door._queues[INTERACTIVE]


def test_shed_on_estimated_queue_delay(door):
    door._svc_ewma["m"] = 0.2
    # 12 queued ahead, zero live slots -> est (12//1)*0.2 = 2.4s > 1s budget
    for _ in range(12):
        door._queues[BATCH].append(object())
    with pytest.raises(DeadlineExceeded):
        door.request("m", None, deadline_s=1.0, lane=BATCH)
    # unknown service time: NEVER shed on zero knowledge
    door._svc_ewma.clear()
    req = door.request("m", None, deadline_s=1.0, lane=BATCH)
    assert req in door._queues[BATCH]


def test_unknown_model_and_lane_rejected(door):
    with pytest.raises(KeyError):
        door.request("nope", None)
    with pytest.raises(ValueError):
        door.request("m", None, lane="bulk")


# -- routing + lane policy (fabricated workers) ------------------------------

def _fake_worker(wid, *, alive=True, in_flight=0, resident=(), served=()):
    w = _Worker(wid)
    w.alive = alive
    w.health = {"resident": list(resident),
                "served": {m: 1 for m in served}}
    for i in range(in_flight):
        w.in_flight[-(i + 1)] = object()
    return w


def test_routing_prefers_resident_then_served_then_least_loaded(tmp_path):
    fd = FrontDoor(tmp_path / "fd", n_workers=3, max_inflight_per_worker=4)
    fd._workers["w0"] = _fake_worker("w0", in_flight=0)
    fd._workers["w1"] = _fake_worker("w1", in_flight=3, served=("m",))
    fd._workers["w2"] = _fake_worker("w2", in_flight=3, resident=("m",))
    assert fd._route_locked("m").wid == "w2"      # device-resident wins
    fd._workers["w2"].health["resident"] = []
    assert fd._route_locked("m").wid == "w1"      # then page-cache warm
    fd._workers["w1"].health["served"] = {}
    assert fd._route_locked("m").wid == "w0"      # then least-loaded
    for w in fd._workers.values():
        w.alive = False
    assert fd._route_locked("m") is None          # nobody alive


def test_batch_lane_leaves_interactive_reserve(tmp_path):
    fd = FrontDoor(tmp_path / "fd", n_workers=2, max_inflight_per_worker=1,
                   interactive_reserve=1)
    fd._workers["w0"] = _fake_worker("w0")
    fd._workers["w1"] = _fake_worker("w1", in_flight=1)
    fd._models["m"] = {"name": "m"}
    # one free slot total == the reserve: batch must NOT take it
    fd._queues[BATCH].append(FrontDoorRequest(1, "m", None, BATCH, None))
    assert fd._pick_locked() is None
    assert len(fd._queues[BATCH]) == 1            # still queued, not lost
    # an interactive request takes that same last slot immediately
    fd._queues[INTERACTIVE].append(
        FrontDoorRequest(2, "m", None, INTERACTIVE, None))
    req, w = fd._pick_locked()
    assert req.lane == INTERACTIVE and w.wid == "w0"


def test_failover_requeues_at_lane_head_then_worker_lost(tmp_path):
    fd = FrontDoor(tmp_path / "fd", n_workers=2, max_failovers=1)
    w = _fake_worker("w0")
    fd._workers["w0"] = w
    young = FrontDoorRequest(1, "m", None, INTERACTIVE, None)
    young.attempts = 1
    spent = FrontDoorRequest(2, "m", None, INTERACTIVE, None)
    spent.attempts = 2                            # max_failovers exhausted
    w.in_flight = {1: young, 2: spent}
    fd._queues[INTERACTIVE].append(
        FrontDoorRequest(3, "m", None, INTERACTIVE, None))
    fd._on_worker_lost(w)
    assert not w.in_flight
    assert fd._queues[INTERACTIVE][0] is young    # failover jumps the queue
    assert spent.done()
    with pytest.raises(WorkerLost):
        spent.result(0)
    assert fd.stats["failovers"] == 1 and fd.stats["failover_lost"] == 1


# -- end-to-end: spawn real workers, kill one, fail over ---------------------

def test_frontdoor_chaos_end_to_end(tmp_path):
    from repro.executor.server import ColdServer
    from repro.models.cnn import build_cnn

    layers, x = build_cnn("mobilenet", image=16, width=0.25)
    iso = ColdServer(tmp_path / "iso", n_little=2)
    iso.add_model("mnet", layers)
    iso.decide("mnet", x, n_little=2)
    ref = np.asarray(iso.cold_start("mnet", x).result().output)

    fd = FrontDoor(tmp_path / "fd", n_workers=2,
                   worker_args={"n_little": 2, "n_big": 1})
    fd.start()
    try:
        fd.add_model("mnet", "repro.models.cnn:build_cnn",
                     name="mobilenet", image=16, width=0.25)
        req = fd.request("mnet", x, deadline_s=120.0)
        for _ in range(1000):
            if req.worker is not None:
                break
            time.sleep(0.002)
        victim = req.worker
        fd.kill_worker(victim)                    # SIGKILL mid cold start
        res = req.result(timeout=120)
        assert res["worker"] != victim            # a sibling served it
        # vs the in-process isolated server: numerical equivalence only —
        # its decide() profiles/calibrates under whatever load the test
        # suite is generating and may legitimately pick a different (but
        # numerically equivalent) kernel plan. Bit-identity is asserted
        # below across WORKERS, which share one plan.json + ProfileDB by
        # construction (the benchmark gates bit-identity vs isolated in a
        # quiet dedicated CI step).
        np.testing.assert_allclose(np.asarray(res["output"]), ref,
                                   rtol=1e-5, atol=1e-6)

        deadline = time.monotonic() + 30
        while time.monotonic() < deadline:        # restart under backoff
            h = fd.health()
            if h["workers"][victim]["alive"]:
                break
            time.sleep(0.05)
        h = fd.health()
        assert h["workers"][victim]["alive"]
        assert h["stats"]["worker_restarts"] >= 1
        assert h["stats"]["failovers"] >= 1
        # nothing leaked: no stuck in-flight entries or queued requests
        assert sum(w["in_flight"] for w in h["workers"].values()) == 0
        assert sum(h["queues"].values()) == 0
        # the restarted fleet still serves BIT-identically to the failover
        # result: every worker (including the respawned victim) loads the
        # same plan.json and shared profile DB, so outputs are idempotent
        # across workers
        res2 = fd.request("mnet", x, deadline_s=120.0).result(120)
        np.testing.assert_array_equal(np.asarray(res2["output"]),
                                      np.asarray(res["output"]))
    finally:
        fd.shutdown()
